"""whisper-tiny: encoder-decoder audio transformer [arXiv:2212.04356].
4+4L d=384 6H d_ff=1536 vocab 51865 (padded 52096). The conv/mel frontend is
a STUB per the brief: input_specs() provides precomputed 1500-frame
embeddings; the transformer backbone (enc self-attn, dec self+cross attn)
is fully implemented."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,              # decoder layers
    encoder_layers=4,
    encoder_seq=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    activation="gelu",
    tie_embeddings=True,
)
