"""mamba2-780m: SSD (state-space duality) LM [arXiv:2405.21060].
48L d_model=1536, attention-free, ssm_state=128, vocab 50280 (padded 50432
for TP divisibility), d_inner = 2*d = 3072, headdim 64 => 48 SSD heads."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=48,          # SSD heads (d_inner / ssm_head_dim)
    n_kv_heads=48,
    d_ff=0,              # attention/MLP-free: the Mamba2 block is the layer
    vocab_size=50_280,
    ssm_state=128,
    d_inner=3072,
    ssm_head_dim=64,
    ssm_groups=8,        # B/C groups (TP-friendly grouping)
    conv_kernel=4,
    activation="gelu",
    tie_embeddings=True,
)
