"""internvl2-76b: InternViT frontend (STUB) + 76B-class LM backbone
[arXiv:2404.16821]. LM: 80L d=8192 64H GQA kv=8 d_ff=28672 vocab 128256.
The vision tower is stubbed: input_specs() provides precomputed patch
embeddings (256 image tokens) that a projector maps into the LM stream."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    num_image_tokens=256,
    rope_theta=500_000.0,
)
