"""recurrentgemma-2b: Griffin-style hybrid — RG-LRU recurrent blocks with
1:2 local attention [arXiv:2402.19427]. 26L d=2560, pattern (rec, rec, attn),
10H MQA kv=1 head_dim 256, window 2048, lru_width 2560, GeGLU d_ff 7680."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    window=2048,
    activation="geglu",
    logits_soft_cap=30.0,
    tie_embeddings=True,
)
