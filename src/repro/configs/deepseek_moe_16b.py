"""deepseek-moe-16b: fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066]. 28L d=2048 16H (kv=16: MHA) d_ff=1408/expert
vocab 102400; layer 0 is a dense FFN (d_ff 10944) per the paper."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    first_layer_dense=True,
    dense_d_ff=10_944,
)
