"""Config system: architecture + shape + run configs.

Every assigned architecture gets a ``ModelConfig`` with its exact published
dimensions (one file per arch in this package); reduced smoke variants are
derived with ``.smoke()``. Input-shape cells come from ``SHAPES`` (the
assigned seq_len x global_batch grid).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


def pad_vocab(v: int, multiple: int = 256) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                      # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    activation: str = "swiglu"             # swiglu | geglu | gelu
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    first_layer_dense: bool = False        # deepseek-moe: layer 0 is dense
    dense_d_ff: int = 0                    # d_ff of that dense layer

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    d_inner: int = 0                       # 0 => 2 * d_model
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_kernel: int = 4
    ssm_chunk: int = 256

    # hybrid (RG-LRU + local attention, RecurrentGemma / Griffin)
    block_pattern: tuple[str, ...] = ()    # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    window: int = 0                        # local-attention window
    logits_soft_cap: float = 0.0

    # encoder-decoder / modality frontend (STUBBED per the brief)
    encoder_layers: int = 0
    encoder_seq: int = 0                   # whisper: 1500 frames
    num_image_tokens: int = 0              # internvl: patch embeddings

    vocab_pad: int = 256
    # unroll depth scans (used by the dry-run's reduced-depth variants so
    # XLA cost_analysis sees straight-line layers; False for real runs)
    scan_unroll: bool = False
    # q-chunked (flash-style blocked) causal attention: 0 = paper-faithful
    # unblocked baseline; >0 = block size (a §Perf beyond-paper change)
    attn_q_chunk: int = 0
    # cast softmax weights to bf16 for the PV matmul (halves that tile's
    # traffic; logits/softmax stay f32)
    attn_w_bf16: bool = False
    # constrain SSD intermediates to shard on the head axis ("model") —
    # pairs with FSDP-only in_proj so the big (b,nc,Q,H,*) tensors split
    # across TP instead of replicating (a §Perf beyond-paper change)
    ssd_shard_heads: bool = False
    # bf16 SSD intra-chunk operands (decay math stays f32; einsums
    # accumulate in f32): halves the dominant (b,nc,H,Q,Q) tile traffic
    ssd_bf16: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family == "ssm" and self.d_inner == 0:
            object.__setattr__(self, "d_inner", 2 * self.d_model)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size, self.vocab_pad)

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.d_inner else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Supports very long context with O(1)/O(window) decode state."""
        return self.family in ("ssm", "hybrid")

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 2 + (2 if self.block_pattern else 0)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256,
            head_dim=32,
            vocab_size=512,
            vocab_pad=64,
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(self.top_k, 2),
                      n_shared_experts=min(self.n_shared_experts, 1),
                      dense_d_ff=256 if self.first_layer_dense else 0)
        if self.family == "ssm":
            kw.update(d_inner=256, ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
        if self.block_pattern:
            kw.update(n_layers=3, lru_width=128, window=64, head_dim=32,
                      n_heads=4, n_kv_heads=1)
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=64)
        if self.num_image_tokens:
            kw.update(num_image_tokens=16)
        return replace(self, name=self.name + "-smoke", **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The brief's skip rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention (skip per brief; see DESIGN.md)"
    return True, ""


@dataclass(frozen=True)
class RunConfig:
    """Training/serving hyperparameters for the launchers."""
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0               # 0 => no gradient accumulation
    remat: str = "block"              # none | block | full
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    grad_compression: str = "none"    # none | int8_ef
    seed: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 100
    async_ckpt: bool = True
