"""Architecture registry: ``--arch <id>`` resolution."""
from . import (
    deepseek_moe_16b,
    granite_3_2b,
    internvl2_76b,
    mamba2_780m,
    phi35_moe,
    qwen15_32b,
    qwen25_32b,
    recurrentgemma_2b,
    whisper_tiny,
    yi_6b,
)
from .base import SHAPES, ModelConfig, RunConfig, ShapeConfig, shape_applicable

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        mamba2_780m, internvl2_76b, yi_6b, qwen15_32b, granite_3_2b,
        qwen25_32b, phi35_moe, deepseek_moe_16b, recurrentgemma_2b,
        whisper_tiny,
    )
}


def get_arch(name: str, smoke: bool = False) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    cfg = ARCHS[name]
    return cfg.smoke() if smoke else cfg


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "RunConfig", "ShapeConfig",
           "get_arch", "shape_applicable"]
