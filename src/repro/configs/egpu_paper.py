"""The paper's own machine configuration (for core/ benchmarks)."""
from repro.core.machine import SMConfig

CONFIG = SMConfig()          # 512 threads, 16 SPs, 3K-word shared memory
QUAD = dict(n_instances=4)   # the quad-packed sector of paper SIII.E
