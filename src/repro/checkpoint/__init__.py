"""Checkpointing: atomic npz shards, async save, elastic restore."""
from . import ckpt

__all__ = ["ckpt"]
