"""Fault-tolerant checkpointing: npz shards + manifest, async save,
mesh-agnostic restore (elastic rescaling).

Layout of a checkpoint directory:
    <dir>/step_000123/
        manifest.json       {step, leaf paths, shapes, dtypes, config hash,
                             pipeline state, rng}
        shard_<i>.npz       host numpy arrays (full, unsharded)
    <dir>/LATEST            atomic pointer file (write-temp + rename)

Because shards store *global* arrays, a restore may target any mesh: the
caller re-shards with ``jax.device_put(x, sharding)`` per leaf. Saves are
step-atomic: a crash mid-save leaves LATEST pointing at the previous
complete checkpoint. ``async_save`` double-buffers: device->host copy is
synchronous (consistency), the disk write happens on a worker thread.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

_SEP = "/"

# dtypes numpy's npz container can't serialize natively: stored as a raw
# bit-pattern view + the true dtype in the manifest
_VIEWED = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _to_disk(v: np.ndarray) -> np.ndarray:
    name = str(v.dtype)
    if name in _VIEWED:
        return v.view(_VIEWED[name][1])
    return v


def _from_disk(v: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEWED:
        return v.view(_VIEWED[dtype_name][0])
    return v


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None,
         shard_mb: int = 512) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    flat = _flatten(tree)
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        shards: list[list[str]] = [[]]
        size = 0
        limit = shard_mb * 1024 * 1024
        for k, v in flat.items():
            if size > limit:
                shards.append([])
                size = 0
            shards[-1].append(k)
            size += v.nbytes
        manifest = {
            "step": step,
            "n_shards": len(shards),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "shard": si}
                       for si, keys in enumerate(shards) for k in keys
                       for v in [flat[k]]},
            "extra": extra or {},
        }
        for si, keys in enumerate(shards):
            np.savez(os.path.join(tmp, f"shard_{si}.npz"),
                     **{k: _to_disk(flat[k]) for k in keys})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, ".LATEST_tmp")
    with open(ptr_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


class AsyncSaver:
    """Double-buffered async checkpointing: the device->host copy happens on
    the caller thread (so the snapshot is consistent), serialization+IO on a
    worker. A second save while one is in flight blocks until it finishes
    (bounded memory)."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None
        self._err: BaseException | None = None

    def save(self, ckpt_dir: str, step: int, tree, extra=None):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # sync snapshot

        def work():
            try:
                self.last_path = save(ckpt_dir, step, host_tree, extra)
            except BaseException as e:  # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err


def latest_step(ckpt_dir: str) -> int | None:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, tree_like, step: int | None = None,
            shardings=None) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like``. ``shardings`` (same tree
    structure or a callable path->sharding) re-shards each leaf onto the
    current mesh — THIS is the elastic-rescale path: checkpoints written on
    any mesh restore onto any other."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data: dict[str, np.ndarray] = {}
    for si in range(manifest["n_shards"]):
        with np.load(os.path.join(path, f"shard_{si}.npz")) as z:
            data.update({k: _from_disk(z[k], manifest["leaves"][k]["dtype"])
                         for k in z.files})

    paths_leaves = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None and not callable(shardings) else None)
    for i, (path_t, leaf) in enumerate(paths_leaves[0]):
        key = _SEP.join(_path_str(p) for p in path_t)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs model {want}")
        if callable(shardings):
            arr = jax.device_put(arr, shardings(key))
        elif flat_sh is not None:
            arr = jax.device_put(arr, flat_sh[i])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(paths_leaves[1], leaves)
    return tree, manifest["extra"]
