"""Serving substrate: continuous-batching engine (flexible active mask)."""
from .engine import Engine, Request

__all__ = ["Engine", "Request"]
