"""Serving substrate: continuous batching at two levels.

``Engine``/``Request``: the slot-based LM decode engine (flexible active
mask over a fixed-capacity batch). ``LaunchServer``/``LaunchRequest``:
the device-level front door — asynchronous kernel-launch admission,
priority-aware continuous batching into merged heterogeneous waves, and
the launch-queue/dispatch-latency cycle model.
"""
from .engine import FINISH_REASONS, Engine, Request
from .launch_server import (
    ADMISSIONS,
    LaunchRequest,
    LaunchServer,
    QueueFull,
    ServeResult,
)

__all__ = [
    "Engine", "Request", "FINISH_REASONS",
    "LaunchServer", "LaunchRequest", "ServeResult", "QueueFull",
    "ADMISSIONS",
]
