"""Serving engine: continuous batching with a flexible active mask.

This is where the eGPU's FLEXIBLE ISA genuinely transfers (DESIGN.md §5):
the paper resizes the active thread block per instruction with zero flush;
the serving analogue is a fixed-capacity decode batch whose *active-slot
mask* varies per step with zero recompilation — requests enter and leave
slots while one compiled ``decode_step`` XLA program runs every step. Like
an eGPU {w8,d1} instruction, a half-empty batch executes the same
wavefront with inactive lanes masked.

Slots: each request owns a batch row of every cache tensor. Prefill runs
at batch 1 and its caches are spliced into the slot row; decode advances
ALL slots every step, sampling is masked by activity, finished slots free
immediately.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


FINISH_REASONS = ("eos", "budget", "capacity", "unadmitted")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16         # budget for ALL emitted tokens,
                                     # including the prefill-sampled first
    eos_id: int = -1                 # -1: never
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: str | None = None  # one of FINISH_REASONS once done
                                      # ("unadmitted": never got a slot)

    def _finish(self, reason: str) -> None:
        self.done = True
        self.finish_reason = reason


class Engine:
    def __init__(self, model, params, *, max_slots: int = 8,
                 capacity: int = 256, dtype=jnp.float32):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.capacity = capacity
        self.caches = model.init_decode_caches(max_slots, capacity, dtype)
        self.active = np.zeros(max_slots, bool)
        self.positions = np.zeros(max_slots, np.int32)
        self.budget = np.zeros(max_slots, np.int32)
        self.eos = np.full(max_slots, -1, np.int32)
        self.requests: dict[int, Request] = {}
        self.slot_of: dict[int, int] = {}
        self.last_token = np.zeros(max_slots, np.int32)
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)
        self.steps_run = 0
        self.active_history: list[int] = []
        self.pending: list["Request"] = []

    # ---- jitted kernels -----------------------------------------------------
    def _prefill_impl(self, params, tokens):
        logits, caches = self.model.prefill(params, {"tokens": tokens})
        return logits[:, -1], caches

    def _decode_impl(self, params, caches, tokens, positions, active):
        # vectorized per-slot positions: each slot decodes at its own point
        # in its sequence (decode_attention takes (B,) positions)
        logits, caches = self.model.decode_step(params, caches,
                                                tokens[:, None], positions)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        nxt = jnp.where(active, nxt, 0)
        return nxt, caches

    # ---- slot management ------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admit a request; queues it if all slots are busy.

        The request is registered in ``self.requests`` immediately — a
        queued request that never gets a slot still appears in
        ``run_until_done``'s results (``finish_reason="unadmitted"``)
        instead of being silently dropped.
        """
        self.requests[req.rid] = req
        if req.max_new_tokens <= 0:
            req._finish("budget")        # zero budget: emit nothing
            return True
        free = np.flatnonzero(~self.active)
        if free.size == 0:
            self.pending.append(req)
            return False
        slot = int(free[0])
        # prefill at batch 1, splice caches into the slot row
        toks = jnp.asarray(req.prompt[None].astype(np.int32))
        last_logits, pf_caches = self._prefill(self.params, toks)

        def splice(slot_cache, pf):
            if not isinstance(pf, jax.Array) or pf.ndim == 0:
                return slot_cache
            # caches are stacked (layers, B, ...) or (B, ...); find the batch
            # axis: prefill arrays have batch=1 where slot caches have
            # max_slots
            for ax in range(pf.ndim):
                if pf.shape[ax] == 1 and slot_cache.shape[ax] == self.max_slots:
                    # pad/crop the sequence axis to capacity before splicing
                    pfa = pf
                    for sax in range(pf.ndim):
                        if sax == ax:
                            continue
                        if pfa.shape[sax] != slot_cache.shape[sax]:
                            pad = slot_cache.shape[sax] - pfa.shape[sax]
                            if pad < 0:
                                idx = [slice(None)] * pfa.ndim
                                idx[sax] = slice(0, slot_cache.shape[sax])
                                pfa = pfa[tuple(idx)]
                            else:
                                widths = [(0, 0)] * pfa.ndim
                                widths[sax] = (0, pad)
                                pfa = jnp.pad(pfa, widths)
                    start = [0] * pf.ndim
                    start[ax] = slot
                    return jax.lax.dynamic_update_slice(
                        slot_cache, pfa.astype(slot_cache.dtype), start)
            return slot_cache

        self.caches = jax.tree_util.tree_map(
            splice, self.caches, pf_caches,
            is_leaf=lambda x: isinstance(x, jax.Array))
        if "pos" in self.caches:
            pass  # engine tracks positions host-side
        first = int(np.argmax(np.asarray(last_logits)[0]))
        req.out.append(first)
        # the prefill-sampled token spends budget too: a request emits at
        # most max_new_tokens tokens TOTAL (the old code budgeted the
        # decode loop separately and emitted max_new_tokens + 1)
        if first == req.eos_id:
            req._finish("eos")
            return True
        if req.max_new_tokens == 1:
            req._finish("budget")
            return True
        self.active[slot] = True
        self.positions[slot] = len(req.prompt)
        self.budget[slot] = req.max_new_tokens - 1
        self.eos[slot] = req.eos_id
        self.last_token[slot] = first
        self.slot_of[req.rid] = slot
        return True

    def step(self) -> int:
        """One decode step over all slots (flexible width = #active)."""
        while self.pending and not self.active.all():
            self.submit(self.pending.pop(0))
        if not self.active.any():
            return 0
        act = jnp.asarray(self.active)
        toks = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.positions)
        nxt, self.caches = self._decode(self.params, self.caches, toks, pos,
                                        act)
        nxt = np.asarray(nxt)
        self.steps_run += 1
        self.active_history.append(int(self.active.sum()))
        n_active = 0
        for rid, slot in list(self.slot_of.items()):
            if not self.active[slot]:
                continue
            tok = int(nxt[slot])
            req = self.requests[rid]
            req.out.append(tok)
            self.positions[slot] += 1
            self.budget[slot] -= 1
            if tok == self.eos[slot]:
                reason = "eos"
            elif self.budget[slot] <= 0:
                reason = "budget"
            elif self.positions[slot] >= self.capacity - 1:
                reason = "capacity"      # cache rows exhausted: truncated
            else:
                reason = None
            if reason is not None:
                req._finish(reason)
                self.active[slot] = False
                del self.slot_of[rid]
            else:
                self.last_token[slot] = tok
                n_active += 1
        return n_active

    def run_until_done(self, max_steps: int = 10_000):
        """Decode until every request finishes (or ``max_steps`` runs
        out). Returns ``{rid: out_tokens}`` over EVERY submitted request
        — queued requests that never reached a slot are included with
        ``finish_reason="unadmitted"`` (requests still mid-decode when
        the step budget ran out keep ``done=False``)."""
        for _ in range(max_steps):
            self.step()
            if not self.active.any() and not self.pending:
                break
        for req in self.pending:
            if not req.done:
                req._finish("unadmitted")
        return {rid: r.out for rid, r in self.requests.items()}

    def finish_reasons(self) -> dict[int, str | None]:
        """Per-request termination cause (see ``FINISH_REASONS``)."""
        return {rid: r.finish_reason for rid, r in self.requests.items()}
