"""Serving engine: continuous batching with a flexible active mask.

This is where the eGPU's FLEXIBLE ISA genuinely transfers (DESIGN.md §5):
the paper resizes the active thread block per instruction with zero flush;
the serving analogue is a fixed-capacity decode batch whose *active-slot
mask* varies per step with zero recompilation — requests enter and leave
slots while one compiled ``decode_step`` XLA program runs every step. Like
an eGPU {w8,d1} instruction, a half-empty batch executes the same
wavefront with inactive lanes masked.

Slots: each request owns a batch row of every cache tensor. Prefill runs
at batch 1 and its caches are spliced into the slot row; decode advances
ALL slots every step, sampling is masked by activity, finished slots free
immediately.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                 # -1: never
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model, params, *, max_slots: int = 8,
                 capacity: int = 256, dtype=jnp.float32):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.capacity = capacity
        self.caches = model.init_decode_caches(max_slots, capacity, dtype)
        self.active = np.zeros(max_slots, bool)
        self.positions = np.zeros(max_slots, np.int32)
        self.budget = np.zeros(max_slots, np.int32)
        self.eos = np.full(max_slots, -1, np.int32)
        self.requests: dict[int, Request] = {}
        self.slot_of: dict[int, int] = {}
        self.last_token = np.zeros(max_slots, np.int32)
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)
        self.steps_run = 0
        self.active_history: list[int] = []
        self.pending: list["Request"] = []

    # ---- jitted kernels -----------------------------------------------------
    def _prefill_impl(self, params, tokens):
        logits, caches = self.model.prefill(params, {"tokens": tokens})
        return logits[:, -1], caches

    def _decode_impl(self, params, caches, tokens, positions, active):
        # vectorized per-slot positions: each slot decodes at its own point
        # in its sequence (decode_attention takes (B,) positions)
        logits, caches = self.model.decode_step(params, caches,
                                                tokens[:, None], positions)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        nxt = jnp.where(active, nxt, 0)
        return nxt, caches

    # ---- slot management ------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admit a request; queues it if all slots are busy."""
        free = np.flatnonzero(~self.active)
        if free.size == 0:
            self.pending.append(req)
            return False
        slot = int(free[0])
        # prefill at batch 1, splice caches into the slot row
        toks = jnp.asarray(req.prompt[None].astype(np.int32))
        last_logits, pf_caches = self._prefill(self.params, toks)

        def splice(slot_cache, pf):
            if not isinstance(pf, jax.Array) or pf.ndim == 0:
                return slot_cache
            # caches are stacked (layers, B, ...) or (B, ...); find the batch
            # axis: prefill arrays have batch=1 where slot caches have
            # max_slots
            for ax in range(pf.ndim):
                if pf.shape[ax] == 1 and slot_cache.shape[ax] == self.max_slots:
                    # pad/crop the sequence axis to capacity before splicing
                    pfa = pf
                    for sax in range(pf.ndim):
                        if sax == ax:
                            continue
                        if pfa.shape[sax] != slot_cache.shape[sax]:
                            pad = slot_cache.shape[sax] - pfa.shape[sax]
                            if pad < 0:
                                idx = [slice(None)] * pfa.ndim
                                idx[sax] = slice(0, slot_cache.shape[sax])
                                pfa = pfa[tuple(idx)]
                            else:
                                widths = [(0, 0)] * pfa.ndim
                                widths[sax] = (0, pad)
                                pfa = jnp.pad(pfa, widths)
                    start = [0] * pf.ndim
                    start[ax] = slot
                    return jax.lax.dynamic_update_slice(
                        slot_cache, pfa.astype(slot_cache.dtype), start)
            return slot_cache

        self.caches = jax.tree_util.tree_map(
            splice, self.caches, pf_caches,
            is_leaf=lambda x: isinstance(x, jax.Array))
        if "pos" in self.caches:
            pass  # engine tracks positions host-side
        self.active[slot] = True
        self.positions[slot] = len(req.prompt)
        self.budget[slot] = req.max_new_tokens
        self.eos[slot] = req.eos_id
        self.last_token[slot] = int(np.argmax(np.asarray(last_logits)[0]))
        req.out.append(int(self.last_token[slot]))
        self.requests[req.rid] = req
        self.slot_of[req.rid] = slot
        return True

    def step(self) -> int:
        """One decode step over all slots (flexible width = #active)."""
        while self.pending and not self.active.all():
            self.submit(self.pending.pop(0))
        if not self.active.any():
            return 0
        act = jnp.asarray(self.active)
        toks = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.positions)
        nxt, self.caches = self._decode(self.params, self.caches, toks, pos,
                                        act)
        nxt = np.asarray(nxt)
        self.steps_run += 1
        self.active_history.append(int(self.active.sum()))
        n_active = 0
        for rid, slot in list(self.slot_of.items()):
            if not self.active[slot]:
                continue
            tok = int(nxt[slot])
            req = self.requests[rid]
            req.out.append(tok)
            self.positions[slot] += 1
            self.budget[slot] -= 1
            if tok == self.eos[slot] or self.budget[slot] <= 0 \
                    or self.positions[slot] >= self.capacity - 1:
                req.done = True
                self.active[slot] = False
                del self.slot_of[rid]
            else:
                self.last_token[slot] = tok
                n_active += 1
        return n_active

    def run_until_done(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            self.step()
            if not self.active.any() and not self.pending:
                break
        return {rid: r.out for rid, r in self.requests.items()}
