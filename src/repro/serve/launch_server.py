"""LaunchServer: continuous batching of device kernel launches.

The millions-of-users front door for the multi-SM eGPU device
(``core.device``). Clients submit :class:`LaunchRequest`\\ s — one
``Kernel`` each, with its grid and per-block shared-memory images — into
a bounded admission queue and get a future back; the batching loop
coalesces compatible pending requests into ONE merged heterogeneous
launch (the PR 4/5 machinery: merged trace/megakernel waves +
schedule-aware wave packing make a mixed batch nearly free) and routes
per-request results and cycle counts back through the futures.

This is the request-queue/slot-reuse shape of MaxText's offline
inference engine transplanted to the device layer, with the launch-queue
cost model of arXiv 2401.04261 (*A Statically and Dynamically Scalable
Soft GPGPU*) underneath: every dispatched batch reports the queue depth
it saw, and the device charges ``dispatch_latency + queue_latency *
depth`` host cycles before the first block issues
(``launch(queue_depth=)`` -> ``profile()["host_dispatch"]``).

Design points:

* **Admission ordering is priority-aware end-to-end.** The queue orders
  pending requests by ``Kernel(priority=)`` (descending; FIFO within a
  level), so a high-priority tenant's request enters an earlier batch —
  and inside the merged launch the same priority rides the dynamic
  dispatch heap of ``core.scheduler``. The two layers honor one field.
* **Backpressure.** The queue is bounded (``max_queue``);
  ``admission="reject"`` makes an over-full ``submit`` raise
  :class:`QueueFull`, ``admission="block"`` makes it wait — inline
  (dispatching a batch itself) in synchronous use, on a condition
  variable when the background batcher thread is running.
* **Coalescing contract.** Requests merged into one launch share the
  device like concurrently-launched kernels always have: same
  ``DeviceConfig`` (per-``Kernel`` imem/shmem overrides are fine — the
  merged engines handle heterogeneous configs), no cross-request global
  memory races. Requests that carry ``buffers=`` (a private gmem image)
  or a ``barrier=True`` kernel (a multi-phase structure that would fence
  *other* tenants' blocks) are dispatched solo; everything else
  coalesces up to ``max_batch`` requests.
* **Deterministic virtual-time accounting.** The server keeps a virtual
  device clock in modeled cycles: a batch dispatches at
  ``max(clock, arrival)``, the clock advances by the launch's modeled
  ``cycles`` (host dispatch latency included), and each request's
  latency is ``finish - arrival`` with per-request finish read off the
  scheduler's per-block retire times. Same request trace => same
  per-request cycle counts, regardless of wall-clock jitter — the
  property ``tests/test_serve.py`` pins and ``benchmarks/serve_bench.py``
  builds its p50/p99 on.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.device import DeviceConfig, Kernel, as_kernel, launch
from ..core.machine import MAX_THREADS, N_REGS

ADMISSIONS = ("block", "reject")


class QueueFull(RuntimeError):
    """Raised by ``submit`` under ``admission="reject"`` backpressure."""


@dataclasses.dataclass(frozen=True)
class LaunchRequest:
    """One client's kernel launch.

    ``kernel`` may be a :class:`core.device.Kernel`, an assembled
    ``Program``, or a raw word array (bare programs get device-default
    block size). ``grid`` is the number of thread blocks; ``shmem`` is
    None, one per-block image, or a ``(grid, depth)`` batch. ``buffers``
    gives the request a private global-memory image (named segments, as
    in ``launch(buffers=)``) — such requests dispatch solo, never merged
    with another tenant's. ``arrival_cycle`` places the request on the
    server's virtual device clock for latency accounting (None: "now",
    i.e. the clock at submit time).
    """

    kernel: Any
    grid: int = 1
    shmem: Any = None
    buffers: Mapping[str, Any] | None = None
    arrival_cycle: int | None = None
    tag: Any = None                   # opaque client cookie, echoed back

    def __post_init__(self):
        if int(self.grid) < 1:
            raise ValueError(f"grid={self.grid} must be >= 1")


@dataclasses.dataclass
class ServeResult:
    """Per-request slice of a dispatched batch, plus its cycle story."""

    rid: int
    tag: Any
    regs: jax.Array                 # (grid, MAX_THREADS, N_REGS) uint32
    shmem: jax.Array                # (grid, shmem_depth) uint32
    oob: jax.Array                  # (grid,) bool
    gmem: jax.Array | None          # final gmem (solo buffer requests)
    buffer_offsets: dict | None
    arrival_cycle: int              # virtual clock when the request arrived
    dispatch_cycle: int             # virtual clock when its batch launched
    finish_cycle: int               # virtual clock when its last block retired
    cycles: int                     # dispatch -> finish (host latency incl.)
    wait_cycles: int                # arrival -> dispatch (queueing)
    latency_cycles: int             # arrival -> finish (wait + cycles)
    batch_id: int
    batch_size: int                 # requests merged into the launch
    batch_occupancy: float          # mean wave fill of the merged launch
    queue_depth: int                # launch-queue depth the dispatch saw
    profile: dict[str, Any]         # the merged launch's profile()
    finish_reason: str = "ok"       # "ok" | "unadmitted" (server stopped)

    def shmem_f32(self) -> jax.Array:
        return jax.lax.bitcast_convert_type(self.shmem, jnp.float32)


@dataclasses.dataclass
class _Entry:
    seq: int
    req: LaunchRequest
    kernel: Kernel                  # normalized (as_kernel applied)
    arrival: int
    future: Future

    @property
    def priority(self) -> int:
        return int(self.kernel.priority)

    @property
    def solo(self) -> bool:
        return self.req.buffers is not None or bool(self.kernel.barrier)


class LaunchServer:
    """Admission queue + continuous-batching dispatch loop over one device.

    Synchronous use (deterministic — what the tests and the modeled
    benchmark numbers use)::

        server = LaunchServer(dcfg, max_batch=8)
        futs = [server.submit(LaunchRequest(kernel=fft_kernel(64),
                                            shmem=img)) for img in imgs]
        server.drain()                      # dispatch until queue empty
        outs = [f.result() for f in futs]   # ServeResult each

    Threaded use (clients submit from anywhere; a background batcher
    coalesces whatever is pending each time the device frees up)::

        server.start()
        fut = server.submit(req)            # blocks/rejects when full
        res = fut.result(timeout=60)
        server.stop()
    """

    def __init__(self, dcfg: DeviceConfig, *,
                 max_queue: int = 64, admission: str = "block",
                 max_batch: int | None = None,
                 schedule: str | None = None, engine: str | None = None,
                 packing: str | None = None, backend: str | None = None):
        if admission not in ADMISSIONS:
            raise ValueError(f"admission={admission!r} must be one of "
                             f"{ADMISSIONS}")
        if max_queue < 1:
            raise ValueError(f"max_queue={max_queue} must be >= 1")
        self.dcfg = dcfg
        self.max_queue = int(max_queue)
        self.admission = admission
        # default batch width: two full waves of the device's SMs —
        # enough to amortize dispatch, small enough to bound tail latency
        self.max_batch = int(max_batch) if max_batch is not None \
            else max(2 * dcfg.n_sms, 2)
        if self.max_batch < 1:
            raise ValueError(f"max_batch={self.max_batch} must be >= 1")
        self._launch_kw = dict(schedule=schedule, engine=engine,
                               packing=packing, backend=backend)
        self.clock = 0                  # virtual device clock (cycles)
        self._queue: list[_Entry] = []
        self._seq = 0
        self._batch_id = 0
        self._lock = threading.RLock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self._thread: threading.Thread | None = None
        self._stopping = False
        self._stats = {
            "submitted": 0, "completed": 0, "rejected": 0,
            "unadmitted": 0, "batches": 0, "batched_requests": 0,
            "max_queue_depth": 0, "occupancy_sum": 0.0,
        }

    # ---- admission --------------------------------------------------------
    def submit(self, req: LaunchRequest) -> Future:
        """Enqueue one launch request; returns a future of ServeResult.

        Backpressure: with the queue at ``max_queue``, ``"reject"``
        raises :class:`QueueFull`; ``"block"`` waits for space — by
        dispatching a batch inline when no batcher thread is running
        (synchronous callers make their own progress), or by blocking on
        the batcher otherwise.

        A stopped server (``stop()`` called after ``start()``, no
        restart yet) never admits: the returned future is already
        resolved to a terminal :class:`ServeResult` with
        ``finish_reason="unadmitted"``. This covers the submitter that
        was blocked in the full-queue wait while ``stop()`` ran — it
        must not enqueue into a dead server and hang its client.
        """
        with self._lock:
            if self._stopping:
                return self._unadmitted_future_locked(req)
            while len(self._queue) >= self.max_queue:
                if self.admission == "reject":
                    self._stats["rejected"] += 1
                    raise QueueFull(
                        f"admission queue full ({self.max_queue} pending); "
                        f"retry later or use admission='block'")
                if self._thread is not None:
                    self._not_full.wait()
                    if self._stopping:
                        # woken by stop(): the batcher is gone, nothing
                        # will ever serve this request — terminal result,
                        # never a hang
                        return self._unadmitted_future_locked(req)
                else:
                    self._dispatch_next_locked()
            kern = as_kernel(req.kernel)
            arrival = int(req.arrival_cycle) \
                if req.arrival_cycle is not None else int(self.clock)
            fut: Future = Future()
            self._queue.append(_Entry(seq=self._seq, req=req, kernel=kern,
                                      arrival=arrival, future=fut))
            self._seq += 1
            self._stats["submitted"] += 1
            self._stats["max_queue_depth"] = max(
                self._stats["max_queue_depth"], len(self._queue))
            self._not_empty.notify()
        return fut

    def _unadmitted_result(self, rid: int, tag: Any, grid: int,
                           arrival: int) -> ServeResult:
        """Terminal result for a request the server will never run:
        zeroed state, zero cycles, ``finish_reason="unadmitted"`` (the
        same terminal vocabulary as ``serve.engine.FINISH_REASONS``)."""
        depth = self.dcfg.sm.shmem_depth
        return ServeResult(
            rid=rid, tag=tag,
            regs=np.zeros((grid, MAX_THREADS, N_REGS), np.uint32),
            shmem=np.zeros((grid, depth), np.uint32),
            oob=np.zeros((grid,), bool),
            gmem=None, buffer_offsets=None,
            arrival_cycle=int(arrival), dispatch_cycle=int(self.clock),
            finish_cycle=int(self.clock), cycles=0,
            wait_cycles=max(0, int(self.clock) - int(arrival)),
            latency_cycles=max(0, int(self.clock) - int(arrival)),
            batch_id=-1, batch_size=0, batch_occupancy=0.0,
            queue_depth=len(self._queue), profile={},
            finish_reason="unadmitted")

    def _unadmitted_future_locked(self, req: LaunchRequest) -> Future:
        arrival = int(req.arrival_cycle) if req.arrival_cycle is not None \
            else int(self.clock)
        fut: Future = Future()
        fut.set_result(self._unadmitted_result(self._seq, req.tag,
                                               int(req.grid), arrival))
        self._seq += 1
        self._stats["unadmitted"] += 1
        return fut

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._queue)

    # ---- dispatch ---------------------------------------------------------
    def pump(self) -> int:
        """Dispatch one batch if anything is pending; returns its size."""
        with self._lock:
            return self._dispatch_next_locked()

    def drain(self) -> int:
        """Dispatch until the queue is empty; returns requests served."""
        served = 0
        with self._lock:
            while self._queue:
                served += self._dispatch_next_locked()
        return served

    def _select_locked(self) -> tuple[list[_Entry], int]:
        """Pick the next batch: at the dispatch instant (device free, or
        first arrival if it is idle-waiting), take the highest-priority
        arrived requests in (priority desc, FIFO) order, stopping at a
        solo request's boundary or ``max_batch``."""
        now = self.clock
        arrived = [e for e in self._queue if e.arrival <= now]
        if not arrived:
            # device idles until the next request arrives
            now = min(e.arrival for e in self._queue)
            arrived = [e for e in self._queue if e.arrival <= now]
        arrived.sort(key=lambda e: (-e.priority, e.seq))
        batch: list[_Entry] = []
        for e in arrived:
            if e.solo:
                # a solo request dispatches alone, and never jumps the
                # priority order: it either heads this batch or ends it
                if not batch:
                    batch = [e]
                break
            batch.append(e)
            if len(batch) >= self.max_batch:
                break
        return batch, now

    def _dispatch_next_locked(self) -> int:
        if not self._queue:
            return 0
        batch, now = self._select_locked()
        depth = len(self._queue)        # queue depth this dispatch sees
        ids = {id(e) for e in batch}
        self._queue = [e for e in self._queue if id(e) not in ids]
        try:
            self._dispatch_batch(batch, now, depth)
        except Exception as exc:        # route the failure to the clients
            for e in batch:
                e.future.set_exception(exc)
            raise
        finally:
            self._not_full.notify_all()
        return len(batch)

    def _dispatch_batch(self, batch: list[_Entry], now: int,
                        depth: int) -> None:
        # ---- build one merged launch: dedup kernels, request-major grid --
        kernels: list[Kernel] = []
        kernel_of: dict[tuple, int] = {}
        blocks_of: list[list[int]] = [[] for _ in batch]
        gmap: list[int] = []
        shmem_rows: list[list[Any]] = []    # per kernel: per-block images
        any_shmem: list[bool] = []
        for i, e in enumerate(batch):
            kern = e.kernel
            words = kern.program.words if hasattr(kern.program, "words") \
                else np.asarray(kern.program)
            key = (np.asarray(words).tobytes(), kern.block, kern.dim_x,
                   kern.imem_depth, kern.shmem_depth, kern.priority,
                   kern.barrier)
            k = kernel_of.get(key)
            if k is None:
                k = len(kernels)
                kernel_of[key] = k
                kernels.append(kern)
                shmem_rows.append([])
                any_shmem.append(False)
            grid = int(e.req.grid)
            b0 = len(gmap)
            blocks_of[i] = list(range(b0, b0 + grid))
            gmap.extend([k] * grid)
            rows = self._request_images(e.req, grid)
            any_shmem[k] = any_shmem[k] or rows is not None
            shmem_rows[k].append((grid, rows))
        shmems: list[Any] = []
        for k in range(len(kernels)):
            if not any_shmem[k]:
                shmems.append(None)
                continue
            parts = []
            for grid, rows in shmem_rows[k]:
                if rows is None:
                    depth_k = kernels[k].shmem_depth \
                        or self.dcfg.sm.shmem_depth
                    rows = np.zeros((grid, depth_k), np.uint32)
                parts.append(np.asarray(rows))
            width = max(p.shape[1] for p in parts)
            parts = [np.pad(p, ((0, 0), (0, width - p.shape[1])))
                     if p.shape[1] < width else p for p in parts]
            shmems.append(np.concatenate(parts, axis=0))
        solo = batch[0].req.buffers if len(batch) == 1 else None

        res = launch(self.dcfg, programs=kernels, grid_map=gmap,
                     shmem=shmems, buffers=solo, queue_depth=depth,
                     **self._launch_kw)

        # ---- route per-request slices + cycle counts back ----------------
        finish = np.asarray(res.timing.block_finish)
        bid = self._batch_id
        self._batch_id += 1
        occ = res.wave_packing.occupancy if res.wave_packing else 0.0
        profile = res.profile()
        start = int(now)
        for i, e in enumerate(batch):
            blocks = np.asarray(blocks_of[i])
            req_cycles = int(finish[blocks].max())
            r = ServeResult(
                rid=e.seq, tag=e.req.tag,
                regs=res.regs[blocks], shmem=res.shmem[blocks],
                oob=res.oob[blocks],
                gmem=res.gmem if solo is not None else None,
                buffer_offsets=res.buffer_offsets,
                arrival_cycle=int(e.arrival),
                dispatch_cycle=start,
                finish_cycle=start + req_cycles,
                cycles=req_cycles,
                wait_cycles=start - int(e.arrival),
                latency_cycles=start + req_cycles - int(e.arrival),
                batch_id=bid, batch_size=len(batch),
                batch_occupancy=occ, queue_depth=depth,
                profile=profile)
            e.future.set_result(r)
        self.clock = start + int(res.cycles)
        self._stats["completed"] += len(batch)
        self._stats["batches"] += 1
        self._stats["batched_requests"] += len(batch)
        self._stats["occupancy_sum"] += occ

    @staticmethod
    def _request_images(req: LaunchRequest, grid: int):
        """Normalize a request's shmem init to a (grid, depth) u32 batch
        (None stays None; float32 images are bitcast like the device
        memory system everywhere else)."""
        if req.shmem is None:
            return None
        a = np.asarray(req.shmem)
        if a.dtype == np.float32:
            a = a.view(np.uint32)
        elif a.dtype != np.uint32:
            a = a.astype(np.uint32)
        if a.ndim == 1:
            a = np.broadcast_to(a, (grid, a.shape[0]))
        if a.ndim != 2 or a.shape[0] != grid:
            raise ValueError(f"shmem batch of shape {a.shape} != "
                             f"({grid}, depth)")
        return a

    # ---- background batcher ----------------------------------------------
    def start(self) -> None:
        """Run the batching loop on a daemon thread: whenever requests
        are pending and the previous batch retired, dispatch the next."""
        with self._lock:
            if self._thread is not None:
                raise RuntimeError("LaunchServer already started")
            self._stopping = False
            self._thread = threading.Thread(target=self._serve_loop,
                                            name="launch-server",
                                            daemon=True)
            self._thread.start()

    def stop(self, drain: bool = True) -> None:
        """Stop the batcher thread. ``drain=True`` (default) dispatches
        every pending request first; ``drain=False`` resolves pending
        futures to terminal ``finish_reason="unadmitted"`` results. A
        queued ``Future`` never hangs its client either way, and
        ``_stopping`` stays set until the next ``start()`` so a
        submitter racing this call (including one blocked in the
        full-queue wait) gets an unadmitted result instead of enqueuing
        into a dead server."""
        with self._lock:
            if self._thread is None:
                return
            self._stopping = True
            self._not_empty.notify_all()
        self._thread.join()
        self._thread = None
        with self._lock:
            if drain:
                while self._queue:
                    self._dispatch_next_locked()
            else:
                for e in self._queue:
                    e.future.set_result(self._unadmitted_result(
                        e.seq, e.req.tag, int(e.req.grid), e.arrival))
                    self._stats["unadmitted"] += 1
                self._queue.clear()
            # wake any submitter still blocked in the full-queue wait;
            # it re-checks _stopping and resolves its client terminally
            self._not_full.notify_all()

    def _serve_loop(self) -> None:
        while True:
            with self._lock:
                while not self._queue and not self._stopping:
                    self._not_empty.wait()
                if self._stopping:
                    return
                try:
                    self._dispatch_next_locked()
                except Exception:
                    # the failure already reached the affected futures;
                    # keep serving other tenants
                    pass

    # ---- reporting --------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        with self._lock:
            s = dict(self._stats)
            s["pending"] = len(self._queue)
            s["clock_cycles"] = int(self.clock)
            s["mean_batch_size"] = (s["batched_requests"] / s["batches"]
                                    if s["batches"] else 0.0)
            s["mean_batch_occupancy"] = (s["occupancy_sum"] / s["batches"]
                                         if s["batches"] else 0.0)
            del s["occupancy_sum"]
            return s
