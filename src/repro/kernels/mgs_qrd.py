"""Pallas TPU kernel: batched Modified Gram-Schmidt QRD (the paper's
flagship benchmark, §IV.B, as a TPU-native fused kernel).

The eGPU exists to make SMALL dense linear algebra efficient — 16x16 QRD is
the case where big GPUs achieve single-digit efficiency (paper refs
[24][25]). The TPU analogue of that insight: batch many small matrices into
one VMEM-resident tile and run the whole factorization without touching HBM
between iterations (the eGPU's shared-memory-resident dataset, scaled to
VMEM). Iterations are branch-free — finished columns carry zero residuals,
exactly like the eGPU assembly — so there is no divergence and no dynamic
slicing on the minor dimension (TPU-hostile); columns are selected with a
one-hot mask, and norms use rsqrt (the SFU).

Layout: (B, n, n) f32, column index minor. A block of 32 16x16 matrices is
32 KiB; operands+outputs stay well inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mgs_kernel(a_ref, q_ref, r_ref):
    a = a_ref[...]
    B, n, _ = a.shape
    eye = jnp.eye(n, dtype=a.dtype)

    def body(j, carry):
        res, q, r = carry
        onehot = eye[j]                                     # (n,)
        aj = jnp.sum(res * onehot[None, None, :], axis=2)   # (B, n) column j
        # re-orthogonalization ("twice is enough"): one extra projection of
        # the residual against the already-computed Q columns (cols >= j
        # are still zero) removes the O(kappa^2 eps) orthogonality loss of
        # plain f32 MGS; the coefficients fold into R column j so A = QR
        # is preserved exactly
        coeff = jnp.sum(q * aj[:, :, None], axis=1)         # (B, n) <q_i,aj>
        corr = jnp.sum(q * coeff[:, None, :], axis=2)       # (B, n) Q coeff
        aj = aj - corr
        res = res - corr[:, :, None] * onehot[None, None, :]
        r = r + coeff[:, :, None] * onehot[None, None, :]
        nrm2 = jnp.sum(aj * aj, axis=1, keepdims=True)
        recip = jax.lax.rsqrt(nrm2)                         # the SFU
        qj = aj * recip
        rrow = jnp.sum(qj[:, :, None] * res, axis=1)        # (B, n) row j of R
        res = res - qj[:, :, None] * rrow[:, None, :]
        q = q + qj[:, :, None] * onehot[None, None, :]
        r = r + rrow[:, None, :] * onehot[None, :, None]
        return res, q, r

    _, q, r = jax.lax.fori_loop(
        0, n, body, (a, jnp.zeros_like(a), jnp.zeros_like(a)))
    q_ref[...] = q
    r_ref[...] = r


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def mgs_qrd(a: jax.Array, *, interpret: bool = True,
            block_b: int = 32) -> tuple[jax.Array, jax.Array]:
    """Batched QRD: (B, n, n) -> (Q, R), MGS column algorithm in VMEM."""
    B, n, n2 = a.shape
    if n != n2:
        raise ValueError("square matrices only")
    block_b = min(block_b, B)
    if B % block_b:
        raise ValueError(f"B={B} must be a multiple of block_b={block_b}")
    grid = (B // block_b,)
    spec = pl.BlockSpec((block_b, n, n), lambda i: (i, 0, 0))
    q, r = pl.pallas_call(
        _mgs_kernel,
        out_shape=(jax.ShapeDtypeStruct((B, n, n), jnp.float32),
                   jax.ShapeDtypeStruct((B, n, n), jnp.float32)),
        grid=grid,
        in_specs=[spec],
        out_specs=(spec, spec),
        interpret=interpret,
    )(a.astype(jnp.float32))
    return q, r
