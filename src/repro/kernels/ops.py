"""Public jit'd entry points for the kernel layer.

Pallas interpret mode is a *setting*, not a constant: the kernels TARGET
TPU/GPU and are validated via the Pallas interpreter against the
``ref.py`` oracles on CPU. Resolution order for whether a kernel runs
interpreted:

  1. an explicit ``interpret=`` argument at the call site;
  2. ``set_interpret(True|False|None)`` — process-wide programmatic
     override (None restores auto-detection);
  3. the ``EGPU_PALLAS_INTERPRET`` environment variable: ``1/true/yes``
     forces interpret mode, ``0/false/no`` forces compiled Pallas,
     ``auto`` (or unset) defers to platform detection;
  4. platform auto-detection: interpret everywhere except on a real
     TPU/GPU backend, where the compiled path is the point.

``INTERPRET`` is kept as the import-time auto-detected default for
backward compatibility; new code should call ``interpret_mode()``, which
re-resolves the setting on every call so the compiled (non-interpret)
path is reachable without editing source — set
``EGPU_PALLAS_INTERPRET=0`` (or call ``set_interpret(False)``) on a
machine with a real accelerator.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref
from .fft_r2 import fft_r2
from .flash_attention import flash_attention
from .mgs_qrd import mgs_qrd
from .simt_alu import simt_alu
from .wavefront_dot import wavefront_dot

_ENV = "EGPU_PALLAS_INTERPRET"
_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")

# process-wide programmatic override (None = defer to env / platform)
_override: bool | None = None


def _platform_default() -> bool:
    """Interpret everywhere the compiled Pallas path can't run: only a
    real TPU/GPU backend lowers these kernels natively."""
    return jax.default_backend() not in ("tpu", "gpu")


def set_interpret(value: bool | None) -> None:
    """Force (True/False) or restore auto-detection (None) process-wide.

    Takes precedence over ``EGPU_PALLAS_INTERPRET``; explicit
    ``interpret=`` call-site arguments still win.
    """
    global _override
    _override = None if value is None else bool(value)


def interpret_mode() -> bool:
    """Resolve the current interpret setting (override > env > platform)."""
    if _override is not None:
        return _override
    env = os.environ.get(_ENV, "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    if env and env != "auto":
        raise ValueError(
            f"{_ENV}={env!r} must be one of 1/0/true/false/yes/no/on/off/"
            f"auto")
    return _platform_default()


# import-time auto-detected default, kept for back-compat with code that
# reads/sets ``ops.INTERPRET`` directly (the executor now resolves via
# ``interpret_mode()`` per call instead)
INTERPRET = _platform_default()


def alu(op, typ, a, b, mask, old, **kw):
    kw.setdefault("interpret", interpret_mode())
    return simt_alu(jnp.asarray(op), jnp.asarray(typ), a, b, mask, old, **kw)


def dot(a, b, mask=None, mode=0, **kw):
    kw.setdefault("interpret", interpret_mode())
    if mask is None:
        mask = jnp.ones(a.shape, jnp.float32)
    return wavefront_dot(a, b, mask, jnp.asarray(mode), **kw)


def qrd(a, **kw):
    kw.setdefault("interpret", interpret_mode())
    return mgs_qrd(a, **kw)


def fft(re, im, **kw):
    kw.setdefault("interpret", interpret_mode())
    return fft_r2(re, im, **kw)


def flash(q, k, v, **kw):
    kw.setdefault("interpret", interpret_mode())
    return flash_attention(q, k, v, **kw)


__all__ = ["alu", "dot", "qrd", "fft", "flash", "ref", "INTERPRET",
           "interpret_mode", "set_interpret"]
