"""Public jit'd entry points for the kernel layer.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU and are validated via the Pallas interpreter against
the ``ref.py`` oracles). On a real TPU backend set
``repro.kernels.ops.INTERPRET = False`` (or pass interpret=False).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .fft_r2 import fft_r2
from .flash_attention import flash_attention
from .mgs_qrd import mgs_qrd
from .simt_alu import simt_alu
from .wavefront_dot import wavefront_dot

INTERPRET = jax.default_backend() != "tpu"


def alu(op, typ, a, b, mask, old, **kw):
    kw.setdefault("interpret", INTERPRET)
    return simt_alu(jnp.asarray(op), jnp.asarray(typ), a, b, mask, old, **kw)


def dot(a, b, mask=None, mode=0, **kw):
    kw.setdefault("interpret", INTERPRET)
    if mask is None:
        mask = jnp.ones(a.shape, jnp.float32)
    return wavefront_dot(a, b, mask, jnp.asarray(mode), **kw)


def qrd(a, **kw):
    kw.setdefault("interpret", INTERPRET)
    return mgs_qrd(a, **kw)


def fft(re, im, **kw):
    kw.setdefault("interpret", INTERPRET)
    return fft_r2(re, im, **kw)


def flash(q, k, v, **kw):
    kw.setdefault("interpret", INTERPRET)
    return flash_attention(q, k, v, **kw)


__all__ = ["alu", "dot", "qrd", "fft", "flash", "ref", "INTERPRET"]
