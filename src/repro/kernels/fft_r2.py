"""Pallas TPU kernel: batched radix-2 DIF FFT (paper §IV.A on TPU terms).

Hardware adaptation. The eGPU keeps the whole working set in its quad-port
shared memory and pays 75% of its cycles moving data through it (Table
III). The TPU-native restatement: keep the whole (batch, N) signal block in
VMEM for ALL log2(N) passes — a single kernel launch, zero HBM traffic
between passes. Complex data is stored as separate re/im planes (the
interleaved layout the eGPU uses is hostile to 128-lane vectors; this is a
recorded deviation). Passes are unrolled at trace time (N is static), each
pass doing the butterfly as reshape -> split -> vector math, with per-pass
twiddle rows precomputed on the host into a (log2N, N/2) table.

Output is bit-reversed (DIF); the wrapper exposes `natural=True` to apply
the permutation outside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import bitrev


def _twiddle_table(n: int) -> np.ndarray:
    """(2, log2n, n//2): per-pass twiddles, re/im planes, repeated so pass
    p's row holds W at each butterfly position (period H = n/2 >> p)."""
    log2n = n.bit_length() - 1
    tw = np.zeros((2, log2n, n // 2), np.float32)
    for p in range(log2n):
        h = (n // 2) >> p
        stride = n // (2 * h)
        k = (np.arange(n // 2) % h) * stride
        w = np.exp(-2j * np.pi * k / n)
        tw[0, p] = w.real
        tw[1, p] = w.imag
    return tw


def _fft_kernel(tw_ref, re_ref, im_ref, ore_ref, oim_ref, *, n: int):
    log2n = n.bit_length() - 1
    re = re_ref[...]
    im = im_ref[...]
    blk = re.shape[0]
    for p in range(log2n):                       # unrolled: n is static
        h = (n // 2) >> p
        nb = n // (2 * h)
        wre = tw_ref[0, p, :h].reshape(1, 1, h)
        wim = tw_ref[1, p, :h].reshape(1, 1, h)
        re4 = re.reshape(blk, nb, 2, h)
        im4 = im.reshape(blk, nb, 2, h)
        a_re, b_re = re4[:, :, 0, :], re4[:, :, 1, :]
        a_im, b_im = im4[:, :, 0, :], im4[:, :, 1, :]
        u_re, u_im = a_re + b_re, a_im + b_im    # upper butterfly output
        d_re, d_im = a_re - b_re, a_im - b_im
        v_re = d_re * wre - d_im * wim           # rotate lower output
        v_im = d_re * wim + d_im * wre
        re = jnp.stack([u_re, v_re], axis=2).reshape(blk, n)
        im = jnp.stack([u_im, v_im], axis=2).reshape(blk, n)
    ore_ref[...] = re
    oim_ref[...] = im


@functools.partial(jax.jit, static_argnames=("interpret", "block_b", "natural"))
def fft_r2(re: jax.Array, im: jax.Array, *, interpret: bool = True,
           block_b: int = 8, natural: bool = True) -> tuple[jax.Array, jax.Array]:
    """Batched radix-2 DIF FFT: (B, N) f32 re/im planes -> transformed planes."""
    B, n = re.shape
    if n & (n - 1):
        raise ValueError("N must be a power of two")
    block_b = min(block_b, B)
    if B % block_b:
        raise ValueError(f"B={B} must be a multiple of block_b={block_b}")
    log2n = n.bit_length() - 1
    tw = jnp.asarray(_twiddle_table(n))
    grid = (B // block_b,)
    spec = pl.BlockSpec((block_b, n), lambda i: (i, 0))
    ore, oim = pl.pallas_call(
        functools.partial(_fft_kernel, n=n),
        out_shape=(jax.ShapeDtypeStruct((B, n), jnp.float32),
                   jax.ShapeDtypeStruct((B, n), jnp.float32)),
        grid=grid,
        in_specs=[pl.BlockSpec((2, log2n, n // 2), lambda i: (0, 0, 0)),
                  spec, spec],
        out_specs=(spec, spec),
        interpret=interpret,
    )(tw, re.astype(jnp.float32), im.astype(jnp.float32))
    if natural:
        inv = np.argsort(bitrev(n))
        ore, oim = ore[:, inv], oim[:, inv]
    return ore, oim
