"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose`` truth).

These are deliberately straightforward implementations — no tiling, no
memory-space reasoning — used by tests and as CPU fallbacks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# opcode numbering shared with the kernels (subset of core.isa.Op that the
# SIMT ALU executes)
ALU_ADD, ALU_SUB, ALU_MUL = 1, 2, 3
ALU_AND, ALU_OR, ALU_XOR, ALU_NOT = 4, 5, 6, 7
ALU_LSL, ALU_LSR = 8, 9
TYP_INT32, TYP_UINT32, TYP_FP32 = 0, 1, 2


def _sext16(x):
    low = x & 0xFFFF
    return low | (((low >> 15) & 1) * jnp.uint32(0xFFFF0000))


def alu_ref(op: jax.Array, typ: jax.Array, a_u32: jax.Array,
            b_u32: jax.Array) -> jax.Array:
    """eGPU SIMT ALU semantics on uint32 lanes (any shape)."""
    a_f = jax.lax.bitcast_convert_type(a_u32, jnp.float32)
    b_f = jax.lax.bitcast_convert_type(b_u32, jnp.float32)
    add_u = a_u32 + b_u32
    sub_u = a_u32 - b_u32
    mul_int = _sext16(a_u32) * _sext16(b_u32)
    mul_uint = (a_u32 & 0xFFFF) * (b_u32 & 0xFFFF)
    mul_u = jnp.where(typ == TYP_UINT32, mul_uint, mul_int)
    sh = b_u32 & 31
    res_int = jnp.select(
        [op == ALU_ADD, op == ALU_SUB, op == ALU_MUL, op == ALU_AND,
         op == ALU_OR, op == ALU_XOR, op == ALU_NOT, op == ALU_LSL],
        [add_u, sub_u, mul_u, a_u32 & b_u32, a_u32 | b_u32, a_u32 ^ b_u32,
         ~a_u32, a_u32 << sh],
        a_u32 >> sh)
    res_fp = jax.lax.bitcast_convert_type(jnp.select(
        [op == ALU_ADD, op == ALU_SUB], [a_f + b_f, a_f - b_f], a_f * b_f),
        jnp.uint32)
    fp_op = (typ == TYP_FP32) & ((op == ALU_ADD) | (op == ALU_SUB)
                                 | (op == ALU_MUL))
    return jnp.where(fp_op, res_fp, res_int)


def wavefront_dot_ref(a: jax.Array, b: jax.Array, active: jax.Array,
                      n_sp: int = 16) -> jax.Array:
    """Per-wavefront dot product: (..., n_threads) f32 -> (..., n_waves).

    The eGPU dot unit multiplies a wavefront's a*b lanewise and reduces;
    inactive lanes contribute zero (flexible-ISA masking).
    """
    *lead, n = a.shape
    waves = n // n_sp
    a2 = a.reshape(*lead, waves, n_sp)
    b2 = b.reshape(*lead, waves, n_sp)
    m2 = active.reshape(*lead, waves, n_sp)
    return jnp.sum(jnp.where(m2, a2 * b2, 0.0), axis=-1)


def mgs_qrd_ref(a: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Batched Modified Gram-Schmidt QRD: (B, n, n) -> (Q, R).

    Column version, exactly the eGPU benchmark's math: q_j = a_j/||a_j||
    (via rsqrt, the SFU), r_jk = <q_j, a_k>, a_k -= r_jk q_j. Branch-free:
    already-finished columns have zero residuals.

    The projections contract with an explicit lanewise multiply-then-sum
    (NOT ``einsum``/``dot_general``): that is what the eGPU dot-product
    unit does, and it keeps the oracle's f32 accumulation order identical
    to the ``mgs_qrd`` Pallas kernel's — in interpret mode the two are
    bitwise equal, so kernel-vs-ref sweeps can assert tight tolerances
    on any input (a dot_general here drifted up to ~1e-3 on
    ill-conditioned draws purely from summation order).
    """
    B, n, _ = a.shape
    q = jnp.zeros_like(a)
    r = jnp.zeros_like(a)
    eye = jnp.eye(n, dtype=a.dtype)

    def body(j, carry):
        res, q, r = carry
        onehot = eye[j]                                     # (n,)
        aj = jnp.sum(res * onehot[None, None, :], axis=2)   # (B, n)
        # "twice is enough" re-orthogonalization, mirrored in the kernel:
        # project the residual once more against the computed Q columns
        # and fold the coefficients into R column j
        coeff = jnp.sum(q * aj[:, :, None], axis=1)
        corr = jnp.sum(q * coeff[:, None, :], axis=2)
        aj = aj - corr
        res = res - corr[:, :, None] * onehot[None, None, :]
        r = r + coeff[:, :, None] * onehot[None, None, :]
        recip = jax.lax.rsqrt(jnp.sum(aj * aj, axis=1, keepdims=True))
        qj = aj * recip                                     # (B, n)
        rrow = jnp.sum(qj[:, :, None] * res, axis=1)        # (B, n)
        res = res - qj[:, :, None] * rrow[:, None, :]
        q = q + qj[:, :, None] * onehot[None, None, :]
        r = r + rrow[:, None, :] * onehot[None, :, None]
        return res, q, r

    _, q, r = jax.lax.fori_loop(0, n, body, (a, q, r))
    return q, r


def fft_r2_ref(re: jax.Array, im: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Batched radix-2 DIF FFT, natural-order output: (B, N) f32 planes."""
    x = (re + 1j * im).astype(jnp.complex64)
    y = jnp.fft.fft(x, axis=-1)
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def fft_r2_ref_br(re: jax.Array, im: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Same, but in the kernel's bit-reversed output order."""
    n = re.shape[-1]
    rr, ri = fft_r2_ref(re, im)
    idx = bitrev(n)
    return rr[..., idx], ri[..., idx]


def bitrev(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    out = np.zeros(n, dtype=np.int64)
    for b in range(bits):
        out |= ((idx >> b) & 1) << (bits - 1 - b)
    return out
