"""Pallas TPU kernel: the eGPU SIMT ALU, one instruction across N SMs.

TPU adaptation of the SP array (paper Fig. 2): a wavefront-parallel ALU
operating on gathered register operands. On the FPGA, 16 SPs execute one
wavefront per cycle out of M20K register files; on TPU the natural analogue
is a VMEM-resident lane vector — we batch THREADS x SMS into (sm, 512)
tiles (512 = 4 x 128 lanes, hardware-aligned) and execute the decoded op on
the VPU, with the flexible-ISA thread mask applied in-kernel.

Operands arrive pre-gathered (register-file column reads are a gather the
XLA scatter/gather units handle better than a Pallas minor-dim dynamic
index); the kernel is the execute stage.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (
    ALU_ADD,
    ALU_AND,
    ALU_LSL,
    ALU_MUL,
    ALU_NOT,
    ALU_OR,
    ALU_SUB,
    ALU_XOR,
    TYP_FP32,
    TYP_UINT32,
    _sext16,
)

N_THREADS = 512


def _alu_kernel(opv_ref, a_ref, b_ref, mask_ref, old_ref, out_ref):
    op = opv_ref[0]
    typ = opv_ref[1]
    a = a_ref[...]
    b = b_ref[...]
    a_f = jax.lax.bitcast_convert_type(a, jnp.float32)
    b_f = jax.lax.bitcast_convert_type(b, jnp.float32)

    mul_int = _sext16(a) * _sext16(b)
    mul_uint = (a & 0xFFFF) * (b & 0xFFFF)
    sh = b & 31
    res_int = jnp.select(
        [op == ALU_ADD, op == ALU_SUB, op == ALU_MUL, op == ALU_AND,
         op == ALU_OR, op == ALU_XOR, op == ALU_NOT, op == ALU_LSL],
        [a + b, a - b,
         jnp.where(typ == TYP_UINT32, mul_uint, mul_int),
         a & b, a | b, a ^ b, ~a, a << sh],
        a >> sh)
    res_fp = jax.lax.bitcast_convert_type(
        jnp.select([op == ALU_ADD, op == ALU_SUB],
                   [a_f + b_f, a_f - b_f], a_f * b_f), jnp.uint32)
    fp_op = (typ == TYP_FP32) & ((op == ALU_ADD) | (op == ALU_SUB)
                                 | (op == ALU_MUL))
    res = jnp.where(fp_op, res_fp, res_int)
    # flexible-ISA: inactive threads keep their old destination value
    out_ref[...] = jnp.where(mask_ref[...] != 0, res, old_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret", "block_sm"))
def simt_alu(op: jax.Array, typ: jax.Array, a: jax.Array, b: jax.Array,
             mask: jax.Array, old: jax.Array, *, interpret: bool = True,
             block_sm: int = 8) -> jax.Array:
    """Execute one ALU instruction on (n_sm, 512) uint32 operand tiles.

    block_sm SMs per grid step: a (block_sm, 512) uint32 tile is
    block_sm * 2 KiB of VMEM per operand — 5 operands x 8 SMs = 80 KiB,
    comfortably inside a v5e core's VMEM.
    """
    n_sm = a.shape[0]
    block_sm = min(block_sm, n_sm)
    if n_sm % block_sm:
        raise ValueError(f"n_sm={n_sm} must be a multiple of block_sm={block_sm}")
    opv = jnp.stack([op.astype(jnp.int32), typ.astype(jnp.int32)])
    grid = (n_sm // block_sm,)
    spec = pl.BlockSpec((block_sm, N_THREADS), lambda i: (i, 0))
    return pl.pallas_call(
        _alu_kernel,
        out_shape=jax.ShapeDtypeStruct((n_sm, N_THREADS), jnp.uint32),
        grid=grid,
        in_specs=[pl.BlockSpec((2,), lambda i: (0,)),
                  spec, spec, spec, spec],
        out_specs=spec,
        interpret=interpret,
    )(opv, a, b, mask.astype(jnp.uint32), old)
