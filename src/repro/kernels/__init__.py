"""Pallas TPU kernels for the eGPU's compute hot-spots.

Each kernel: ``<name>.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling), with ``ops.py`` as the jit'd wrapper layer and ``ref.py`` the
pure-jnp oracles. Validated in interpret mode on CPU; TPU is the target.
"""
from . import ops, ref
from .fft_r2 import fft_r2
from .flash_attention import flash_attention, flash_attention_ref
from .mgs_qrd import mgs_qrd
from .simt_alu import simt_alu
from .wavefront_dot import wavefront_dot

__all__ = ["ops", "ref", "fft_r2", "flash_attention",
           "flash_attention_ref", "mgs_qrd", "simt_alu", "wavefront_dot"]
