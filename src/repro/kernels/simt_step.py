"""Pallas TPU kernels: the eGPU execute stage beyond the ALU.

``simt_alu`` (kept in ``simt_alu.py``, re-exported here) covers the SP
array's arithmetic path; this module extends the Pallas backend seam over
the *memory* half of the execute stage, so a trace-engine or step-machine
instruction runs its whole data path through Pallas:

  * ``simt_gather``         — LOD: the quad-read-port shared-memory gather,
    one SM's ``(depth,)`` image indexed by its 512 lanes;
  * ``simt_scatter``        — STO: the single-write-port scatter; writeback
    is sequential in thread order, so the LAST active thread wins on
    address collisions (reproduced with a commutative scatter-max, exactly
    the inline backend's trick — bit-identical by construction);
  * ``simt_gather_shared``  — GLD: every SM's lanes gather from the ONE
    device-wide global-memory segment;
  * ``simt_scatter_shared`` — GST: the single device-wide port drains in
    (sm, thread) order; last (sm, thread) writer wins.

TPU adaptation notes: lane-indexed gathers map to VMEM dynamic gathers
(``jnp.take_along_axis`` on an in-register tile); the scatters express the
port-serialization semantics as a max-reduction over writer order followed
by a masked store, which keeps them associative/commutative and therefore
safe on the VPU. Like the ALU kernel these are validated bit-exact against
the inline jnp backend via the Pallas interpreter on CPU and TARGET real
TPU lowering for the compiled path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .simt_alu import N_THREADS, simt_alu  # noqa: F401  (re-export)

_I32 = jnp.int32
_U32 = jnp.uint32


# ---------------------------------------------------------------------------
# LOD: per-SM shared-memory gather (quad read port)
# ---------------------------------------------------------------------------

def _gather_kernel(mem_ref, addr_ref, mask_ref, old_ref, out_ref):
    mem = mem_ref[...]                       # (block_sm, depth)
    addr = addr_ref[...]                     # (block_sm, 512)
    vals = jnp.take_along_axis(mem, addr, axis=1)
    out_ref[...] = jnp.where(mask_ref[...] != 0, vals, old_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def simt_gather(mem: jax.Array, addr: jax.Array, mask: jax.Array,
                old: jax.Array, *, interpret: bool = True) -> jax.Array:
    """LOD gather: ``out[s, t] = mem[s, addr[s, t]]`` where masked.

    ``mem`` is the (n_sm, depth) shared-memory batch, ``addr`` pre-clipped
    lane addresses, ``old`` the destination column inactive lanes keep.
    One SM per grid step: a 3K-word image is 12 KiB of VMEM plus three
    2 KiB lane tiles — far inside a core's VMEM.
    """
    n_sm, depth = mem.shape
    lane_spec = pl.BlockSpec((1, N_THREADS), lambda i: (i, 0))
    return pl.pallas_call(
        _gather_kernel,
        out_shape=jax.ShapeDtypeStruct((n_sm, N_THREADS), _U32),
        grid=(n_sm,),
        in_specs=[pl.BlockSpec((1, depth), lambda i: (i, 0)),
                  lane_spec, lane_spec, lane_spec],
        out_specs=lane_spec,
        interpret=interpret,
    )(mem, addr.astype(_I32), mask.astype(_U32), old)


# ---------------------------------------------------------------------------
# STO: per-SM shared-memory scatter (single write port, last thread wins)
# ---------------------------------------------------------------------------

def _scatter_kernel(mem_ref, addr_ref, vals_ref, do_ref, out_ref):
    depth = mem_ref.shape[1]
    addr = addr_ref[0]                       # (512,)
    do = do_ref[0] != 0
    order = jax.lax.iota(_I32, addr.shape[0])
    slot = jnp.where(do, addr, depth)        # park masked writes
    winner = jnp.full((depth + 1,), -1, _I32).at[slot].max(order)
    write = do & (winner[slot] == order)
    mem = mem_ref[0]
    out_ref[0, :] = mem.at[jnp.where(write, addr, depth)].set(
        vals_ref[0], mode="drop")


@functools.partial(jax.jit, static_argnames=("interpret",))
def simt_scatter(mem: jax.Array, addr: jax.Array, vals: jax.Array,
                 do: jax.Array, *, interpret: bool = True) -> jax.Array:
    """STO scatter: serialized single-port writeback in thread order.

    Among enabled writers to one address the highest thread wins; masked
    and out-of-range lanes write nothing (the caller pre-masks ``do``).
    """
    n_sm, depth = mem.shape
    lane_spec = pl.BlockSpec((1, N_THREADS), lambda i: (i, 0))
    mem_spec = pl.BlockSpec((1, depth), lambda i: (i, 0))
    return pl.pallas_call(
        _scatter_kernel,
        out_shape=jax.ShapeDtypeStruct((n_sm, depth), _U32),
        grid=(n_sm,),
        in_specs=[mem_spec, lane_spec, lane_spec, lane_spec],
        out_specs=mem_spec,
        interpret=interpret,
    )(mem, addr.astype(_I32), vals, do.astype(_U32))


# ---------------------------------------------------------------------------
# GLD/GST: the device-wide global-memory port
# ---------------------------------------------------------------------------

def _gather_shared_kernel(mem_ref, addr_ref, mask_ref, old_ref, out_ref):
    mem = mem_ref[...]                       # (gdepth,)
    vals = mem[addr_ref[...]]                # (block_sm, 512) gather
    out_ref[...] = jnp.where(mask_ref[...] != 0, vals, old_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def simt_gather_shared(mem: jax.Array, addr: jax.Array, mask: jax.Array,
                       old: jax.Array, *, interpret: bool = True
                       ) -> jax.Array:
    """GLD gather: every SM's lanes read the one global segment."""
    (gdepth,) = mem.shape
    n_sm = addr.shape[0]
    lane_spec = pl.BlockSpec((1, N_THREADS), lambda i: (i, 0))
    return pl.pallas_call(
        _gather_shared_kernel,
        out_shape=jax.ShapeDtypeStruct((n_sm, N_THREADS), _U32),
        grid=(n_sm,),
        in_specs=[pl.BlockSpec((gdepth,), lambda i: (0,)),
                  lane_spec, lane_spec, lane_spec],
        out_specs=lane_spec,
        interpret=interpret,
    )(mem, addr.astype(_I32), mask.astype(_U32), old)


# ---------------------------------------------------------------------------
# fused segment: a whole run of SM-local instructions in ONE kernel
# ---------------------------------------------------------------------------

def simt_segment(cfg, rows, block_idx, prog_idx, regs, shmem, oob, *,
                 shmem_depth: int | None = None,
                 interpret: bool = True):
    """Megakernel fused segment: unroll ``rows`` body-to-body inside one
    ``pallas_call``, keeping the SM's registers, shared memory and OOB
    flag resident across every fused step instead of round-tripping
    through HBM per instruction.

    ``rows`` is the host-constant ``executor.FusedRow`` tuple of one
    segment (SM-local ops only — the global port delimits segments). The
    kernel body stages the SAME ``executor.apply_segment_rows`` handler
    chain the inline backend runs, over the one-SM block the grid step
    owns: (1, 512, 16) registers (32 KiB) + the (1, depth) shared image
    + three lane tiles, comfortably inside a core's VMEM.

    Not jitted here: ``rows`` is unhashable by design (numpy masks), and
    every caller is already inside the megakernel runner's jit.
    """
    from ..core.executor import apply_segment_rows, get_execute_backend

    inline = get_execute_backend("inline")
    n_sm, depth = shmem.shape
    n_regs = regs.shape[2]

    def kernel(bidx_ref, pidx_ref, regs_ref, sh_ref, oob_ref,
               regs_out, sh_out, oob_out):
        r, s, o = apply_segment_rows(
            cfg, inline, rows, bidx_ref[...], pidx_ref[...],
            regs_ref[...], sh_ref[...], oob_ref[...] != 0,
            shmem_depth=shmem_depth)
        regs_out[...] = r
        sh_out[...] = s
        oob_out[...] = o.astype(_U32)

    sm_spec = pl.BlockSpec((1,), lambda i: (i,))
    regs_spec = pl.BlockSpec((1, N_THREADS, n_regs), lambda i: (i, 0, 0))
    mem_spec = pl.BlockSpec((1, depth), lambda i: (i, 0))
    regs_o, shmem_o, oob_o = pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((n_sm, N_THREADS, n_regs), _U32),
                   jax.ShapeDtypeStruct((n_sm, depth), _U32),
                   jax.ShapeDtypeStruct((n_sm,), _U32)),
        grid=(n_sm,),
        in_specs=[sm_spec, sm_spec, regs_spec, mem_spec, sm_spec],
        out_specs=(regs_spec, mem_spec, sm_spec),
        interpret=interpret,
    )(block_idx.astype(_I32), prog_idx.astype(_I32), regs, shmem,
      oob.astype(_U32))
    return regs_o, shmem_o, oob_o != 0


def _scatter_shared_kernel(mem_ref, addr_ref, vals_ref, do_ref, out_ref):
    depth = mem_ref.shape[0]
    addr = addr_ref[...]                     # (n_sm * 512,) flattened
    do = do_ref[...] != 0
    order = jax.lax.iota(_I32, addr.shape[0])    # (sm, thread) drain order
    slot = jnp.where(do, addr, depth)
    winner = jnp.full((depth + 1,), -1, _I32).at[slot].max(order)
    write = do & (winner[slot] == order)
    out_ref[...] = mem_ref[...].at[jnp.where(write, addr, depth)].set(
        vals_ref[...], mode="drop")


@functools.partial(jax.jit, static_argnames=("interpret",))
def simt_scatter_shared(mem: jax.Array, addr: jax.Array, vals: jax.Array,
                        do: jax.Array, *, interpret: bool = True
                        ) -> jax.Array:
    """GST scatter: one port for the whole sector, (sm, thread) order."""
    (gdepth,) = mem.shape
    flat = pl.BlockSpec((addr.size,), lambda: (0,))
    mem_spec = pl.BlockSpec((gdepth,), lambda: (0,))
    return pl.pallas_call(
        _scatter_shared_kernel,
        out_shape=jax.ShapeDtypeStruct((gdepth,), _U32),
        in_specs=[mem_spec, flat, flat, flat],
        out_specs=mem_spec,
        interpret=interpret,
    )(mem, addr.reshape(-1).astype(_I32), vals.reshape(-1),
      do.reshape(-1).astype(_U32))
