"""Pallas TPU kernel: flash-style causal attention (online softmax).

The §Perf hillclimb showed 32k prefill is dominated by materialized S^2
score tiles (EXPERIMENTS.md cell C); the jnp-level fix (blocked causal
attention) halves traffic, but the full win — score tiles that never leave
VMEM — needs a kernel. This is it: one (batch*head) x q-block grid cell
holds a (blk_q, D) query tile plus the whole (S, D) K/V stripe in VMEM
(32k x 128 x bf16 = 8 MiB) and runs the numerically-stable online-softmax
recurrence over k-blocks:

    m' = max(m, rowmax(S_blk))            S_blk = q k^T / sqrt(D)
    l' = e^{m-m'} l + rowsum(e^{S_blk - m'})
    acc' = e^{m-m'} acc + e^{S_blk - m'} v_blk

Causality is enforced with global row/col indices; fully-masked k-blocks
(those entirely in the future) are skipped by bounding the k-loop at the
q-block's last row. The oracle is plain softmax attention (ref.py-style).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, blk_q: int, blk_k: int,
                  seq: int, causal: bool):
    j = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                  # (blk_q, D)
    D = q.shape[-1]
    scale = 1.0 / np.sqrt(D)
    q_row0 = j * blk_q

    n_kblocks = seq // blk_k
    if causal:
        # k-blocks strictly beyond this q-block's last row are all-masked
        last_row = q_row0 + blk_q - 1
        n_live = jnp.minimum((last_row // blk_k) + 1, n_kblocks)
    else:
        n_live = n_kblocks

    def body(kb, carry):
        acc, m, l = carry
        k_blk = jax.lax.dynamic_slice_in_dim(
            k_ref[0], kb * blk_k, blk_k).astype(jnp.float32)   # (blk_k, D)
        v_blk = jax.lax.dynamic_slice_in_dim(
            v_ref[0], kb * blk_k, blk_k).astype(jnp.float32)
        s = (q @ k_blk.T) * scale                     # (blk_q, blk_k)
        if causal:
            rows = q_row0 + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 0)
            cols = kb * blk_k + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))   # (blk_q,)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v_blk
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((blk_q, D), jnp.float32)
    m0 = jnp.full((blk_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((blk_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "blk_q", "blk_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q, k, v: (BH, S, D) — heads folded into the leading dim (GQA
    repetition is the wrapper's job). Returns (BH, S, D)."""
    BH, S, D = q.shape
    if S % blk_q or S % blk_k:
        raise ValueError(f"S={S} must be a multiple of blk_q/blk_k")
    grid = (BH, S // blk_q)
    return pl.pallas_call(
        functools.partial(_flash_kernel, blk_q=blk_q, blk_k=blk_k, seq=S,
                          causal=causal),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((1, blk_q, D), lambda i, j: (i, j, 0)),
                  pl.BlockSpec((1, S, D), lambda i, j: (i, 0, 0)),
                  pl.BlockSpec((1, S, D), lambda i, j: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, blk_q, D), lambda i, j: (i, j, 0)),
        interpret=interpret,
    )(q, k, v)


def flash_attention_ref(q, k, v, causal: bool = True):
    """Oracle: plain softmax attention on (BH, S, D)."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(D)
    if causal:
        S = q.shape[1]
        i = jax.lax.broadcasted_iota(jnp.int32, (S, S), 0)
        jx = jax.lax.broadcasted_iota(jnp.int32, (S, S), 1)
        s = jnp.where(jx <= i, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
