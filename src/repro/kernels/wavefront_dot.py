"""Pallas TPU kernel: the eGPU wavefront dot-product / reduction unit.

The paper's DOT extension consumes one 16-lane wavefront per cycle
(16 multiplies + 15 adds = 31 flops/instruction) and writes the result to
lane 0. TPU adaptation: batch (n_sm, 512) thread vectors, reshape each
512-thread block to (32 waves, 16 lanes) inside VMEM and reduce the lane
axis on the VPU. SUM mode reduces (a + b) instead of a*b — both modes of
the paper's extension unit in one kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_THREADS = 512
N_SP = 16
N_WAVES = N_THREADS // N_SP


def _dot_kernel(mode_ref, a_ref, b_ref, mask_ref, out_ref):
    blk = a_ref.shape[0]
    a = a_ref[...].reshape(blk, N_WAVES, N_SP)
    b = b_ref[...].reshape(blk, N_WAVES, N_SP)
    m = mask_ref[...].reshape(blk, N_WAVES, N_SP)
    prod = jnp.where(mode_ref[0] == 0, a * b, a + b)
    out_ref[...] = jnp.sum(jnp.where(m != 0, prod, 0.0), axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret", "block_sm"))
def wavefront_dot(a: jax.Array, b: jax.Array, mask: jax.Array,
                  mode: jax.Array, *, interpret: bool = True,
                  block_sm: int = 8) -> jax.Array:
    """(n_sm, 512) f32 x2 + mask -> (n_sm, 32) per-wavefront reductions.

    mode 0 = DOT (sum a*b), 1 = SUM (sum a+b). Lane-0 writeback is the
    caller's scatter (it is a register-file update, not kernel math).
    """
    n_sm = a.shape[0]
    block_sm = min(block_sm, n_sm)
    if n_sm % block_sm:
        raise ValueError(f"n_sm={n_sm} must be a multiple of block_sm={block_sm}")
    grid = (n_sm // block_sm,)
    in_spec = pl.BlockSpec((block_sm, N_THREADS), lambda i: (i, 0))
    return pl.pallas_call(
        _dot_kernel,
        out_shape=jax.ShapeDtypeStruct((n_sm, N_WAVES), jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)),
                  in_spec, in_spec, in_spec],
        out_specs=pl.BlockSpec((block_sm, N_WAVES), lambda i: (i, 0)),
        interpret=interpret,
    )(mode.reshape(1).astype(jnp.int32), a, b, mask.astype(jnp.float32))
