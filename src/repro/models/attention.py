"""Attention: GQA/MQA/MHA, causal + local-window masks, KV-cache decode.

GQA grouped einsum (no materialized KV-head replication): q heads are
reshaped (G kv groups x R reps). Softmax in f32. The decode path addresses
a fixed-capacity cache with dynamic_update_slice (rolling for windowed
attention, so RG-LRU-style hybrids keep O(window) state at 500k context).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_rope, dense_init

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def attn_params(key, d_model, n_heads, n_kv_heads, head_dim, dtype,
                qkv_bias=False, d_kv_model=None):
    d_kv_model = d_kv_model or d_model
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d_model, n_heads * head_dim, dtype),
        "wk": dense_init(kk, d_kv_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(kv, d_kv_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ko, n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


class KVCache(NamedTuple):
    k: jax.Array        # (B, S_cap, KVH, D)
    v: jax.Array        # (B, S_cap, KVH, D)
    # for windowed attention the cache is a ring buffer of size window


def _project_qkv(p, x, x_kv, n_heads, n_kv_heads, head_dim):
    B, S, _ = x.shape
    Skv = x_kv.shape[1]
    q = x @ p["wq"]
    k = x_kv @ p["wk"]
    v = x_kv @ p["wv"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return (q.reshape(B, S, n_heads, head_dim),
            k.reshape(B, Skv, n_kv_heads, head_dim),
            v.reshape(B, Skv, n_kv_heads, head_dim))


def _gqa_scores(q, k):
    """q: (B,S,H,D), k: (B,T,G,D) -> scores (B,G,R,S,T)."""
    B, S, H, D = q.shape
    G = k.shape[2]
    R = H // G
    qg = q.reshape(B, S, G, R, D)
    return jnp.einsum("bsgrd,btgd->bgrst", qg.astype(jnp.float32),
                      k.astype(jnp.float32)) / np.sqrt(D)


def _gqa_out(weights, v, out_dtype):
    """weights: (B,G,R,S,T), v: (B,T,G,D) -> (B,S,H*D)."""
    B, G, R, S, T = weights.shape
    D = v.shape[-1]
    o = jnp.einsum("bgrst,btgd->bsgrd", weights, v.astype(jnp.float32))
    return o.reshape(B, S, G * R * D).astype(out_dtype)


def attention(p, x, positions, cfg, *, x_kv=None, causal=True,
              window: int = 0, rope: bool = True):
    """Full (prefill/train) attention. x: (B,S,D).

    When ``cfg.attn_q_chunk`` is set (and applicable) the score computation
    is q-chunk-blocked with STATIC causal/banded key ranges — the S^2 score
    tensor is never materialized whole, and banded (windowed) attention
    skips out-of-window key blocks entirely. This is the beyond-paper
    §Perf optimization; the un-blocked path is the paper-faithful baseline.
    """
    x_kv = x if x_kv is None else x_kv
    q, k, v = _project_qkv(p, x, x_kv, cfg.n_heads, cfg.n_kv_heads,
                           cfg.head_dim)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    S = q.shape[1]
    qc = getattr(cfg, "attn_q_chunk", 0)
    if causal and qc and S > qc and S % qc == 0 and x_kv is x:
        out = _blocked_causal(q, k, v, qc, window, x.dtype,
                              getattr(cfg, "attn_w_bf16", False))
        return out @ p["wo"], (k, v)
    scores = _gqa_scores(q, k)                       # (B,G,R,S,T)
    S, T = scores.shape[-2], scores.shape[-1]
    if causal:
        i = jax.lax.broadcasted_iota(jnp.int32, (S, T), 0)
        j = jax.lax.broadcasted_iota(jnp.int32, (S, T), 1)
        mask = j <= i
        if window:
            mask &= j > i - window
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(w, v, x.dtype)
    return out @ p["wo"], (k, v)


def _blocked_causal(q, k, v, chunk: int, window: int, out_dtype,
                    w_bf16: bool = False):
    """Causal (optionally banded) attention, q-chunked with static key
    slices. Peak score tile: (B,G,R,chunk,kmax) instead of (...,S,S);
    windowed attention touches only ceil((window+chunk)/chunk) key blocks
    per q block — O(S*window) work instead of O(S^2)."""
    B, S, H, D = q.shape
    outs = []
    for ci in range(S // chunk):
        q_lo, q_hi = ci * chunk, (ci + 1) * chunk
        k_lo = 0
        if window:
            k_lo = max(0, q_hi - window - chunk)
            k_lo = (k_lo // chunk) * chunk           # static, block-aligned
        k_hi = q_hi
        qs = q[:, q_lo:q_hi]
        ks = k[:, k_lo:k_hi]
        vs = v[:, k_lo:k_hi]
        scores = _gqa_scores(qs, ks)                 # (B,G,R,chunk,k_hi-k_lo)
        i = jax.lax.broadcasted_iota(jnp.int32, (chunk, k_hi - k_lo), 0) \
            + q_lo
        j = jax.lax.broadcasted_iota(jnp.int32, (chunk, k_hi - k_lo), 1) \
            + k_lo
        mask = j <= i
        if window:
            mask &= j > i - window
        scores = jnp.where(mask, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        if w_bf16:
            w = w.astype(jnp.bfloat16)
        outs.append(_gqa_out(w, vs, out_dtype))
    return jnp.concatenate(outs, axis=1)


def init_cache(batch, capacity, n_kv_heads, head_dim, dtype) -> KVCache:
    z = jnp.zeros((batch, capacity, n_kv_heads, head_dim), dtype)
    return KVCache(k=z, v=z)


def decode_attention(p, x, pos, cache: KVCache, cfg, *, window: int = 0,
                     rope: bool = True):
    """One-token decode. x: (B,1,D); pos: scalar int32 or (B,) vector (the
    serving engine's slots sit at different positions — the flexible-mask
    batching of DESIGN.md §5).

    The cache has fixed capacity C (= seq_len, or window for local
    attention, where it is addressed as a ring buffer).
    """
    B = x.shape[0]
    C = cache.k.shape[1]
    pos_v = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q, k, v = _project_qkv(p, x, x, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    if rope:
        q = apply_rope(q, pos_v[:, None], cfg.rope_theta)
        k = apply_rope(k, pos_v[:, None], cfg.rope_theta)
    slot = jnp.where(window > 0, pos_v % jnp.maximum(C, 1), pos_v)
    rows = jnp.arange(B)
    newk = cache.k.at[rows, slot].set(k[:, 0].astype(cache.k.dtype))
    newv = cache.v.at[rows, slot].set(v[:, 0].astype(cache.v.dtype))
    scores = _gqa_scores(q, newk)                    # (B,G,R,1,C)
    idx = jnp.arange(C)[None, :]
    if window > 0:
        valid = (idx <= slot[:, None]) | (pos_v[:, None] >= C)  # ring full
    else:
        valid = idx <= pos_v[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(w, newv, x.dtype)
    return out @ p["wo"], KVCache(k=newk, v=newv)
