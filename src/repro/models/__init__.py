"""Model zoo: dense/GQA, MoE, Mamba2-SSD, RG-LRU hybrid, enc-dec, VLM."""
from .registry import build_model, cache_specs, input_specs, param_specs
from .transformer import LM
from .whisper import EncDec

__all__ = ["build_model", "cache_specs", "input_specs", "param_specs",
           "LM", "EncDec"]
