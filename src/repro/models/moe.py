"""Mixture-of-Experts layer: top-k routing, capacity-slot dispatch.

Dispatch is INDEX-based (gather/scatter), not the one-hot einsum form: the
(tokens x experts x capacity) dispatch tensor is O(T^2) and breaks at
32k-sequence prefill; index dispatch is O(E*C*d) = O(T*k*cf*d) — linear.

  1. top-k routing probabilities per token (renormalized over the k picks);
  2. in-expert slot positions via a priority-ordered cumulative count
     (all first choices, then second choices, ... — GShard order);
  3. slot table (E, C) <- token index (unique, collision-free scatter);
  4. expert FFNs run on gathered (E, C, d) tiles — vmapped over the expert
     axis, shardable with experts on the "model" mesh axis (EP);
  5. outputs gathered back per (token, choice) and combined with gates.

Tokens overflowing capacity are dropped (combine weight zero) — standard
at capacity_factor ~1.25. Shared experts (DeepSeekMoE) are dense FFNs
added unconditionally. Returns the Switch-style load-balance aux loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, mlp, mlp_params


def moe_params(key, cfg, dtype):
    kr, ke, ks = jax.random.split(key, 3)
    E = cfg.n_experts
    ekeys = jax.random.split(ke, E)
    experts = jax.vmap(
        lambda k: mlp_params(k, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    )(ekeys)
    p = {"router": dense_init(kr, cfg.d_model, E, dtype, scale=0.02),
         "experts": experts}
    if cfg.n_shared_experts:
        p["shared"] = mlp_params(ks, cfg.d_model,
                                 cfg.d_ff * cfg.n_shared_experts,
                                 cfg.activation, dtype)
    return p


def route_topk(logits, k: int, capacity: int):
    """logits: (T, E) -> routing plan.

    Returns dict with:
      expert (T, k) int32, slot (T, k) int32, keep (T, k) bool,
      gate (T, k) f32 (renormalized), slot_token (E, C) int32 (-1 = empty),
      aux scalar.
    """
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, expert = jax.lax.top_k(probs, k)                    # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(expert, E, dtype=jnp.float32)     # (T, k, E)
    # priority order: all 1st choices first, then 2nd, ... (GShard)
    flat = onehot.transpose(1, 0, 2).reshape(k * T, E)
    pos = (jnp.cumsum(flat, axis=0) - flat)                   # (kT, E)
    pos = pos.reshape(k, T, E).transpose(1, 0, 2)
    slot = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)   # (T, k)
    keep = slot < capacity
    # slot table: (E, C) <- token index (unique slots: collision-free)
    tok_ids = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None],
                               (T, k))
    e_safe = jnp.where(keep, expert, 0)
    s_safe = jnp.where(keep, slot, capacity)                  # drop lane
    slot_token = jnp.full((E, capacity + 1), -1, jnp.int32)
    slot_token = slot_token.at[e_safe.reshape(-1),
                               s_safe.reshape(-1)].set(
        jnp.where(keep, tok_ids, -1).reshape(-1), mode="drop")
    slot_token = slot_token[:, :capacity]
    # Switch aux loss: E * sum_e fraction_routed_e * mean_prob_e
    f = onehot.sum(axis=1).mean(axis=0)
    aux = E * jnp.sum(f * probs.mean(axis=0))
    return {"expert": expert, "slot": slot, "keep": keep, "gate": gate,
            "slot_token": slot_token, "aux": aux}


def moe_layer(p, cfg, x):
    """x: (B, S, d). Returns (y, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    capacity = int(np.ceil(T / E * cfg.capacity_factor * k))
    xt = x.reshape(T, d)
    plan = route_topk(xt @ p["router"], k, capacity)
    # gather tokens into expert tiles: (E, C, d); empty slots read row 0
    # and are masked after
    st = plan["slot_token"]                                   # (E, C)
    xe = xt[jnp.maximum(st, 0)]                               # (E, C, d)
    xe = jnp.where((st >= 0)[..., None], xe, 0).astype(x.dtype)
    ye = jax.vmap(lambda pp, xx: mlp(pp, xx, cfg.activation))(
        p["experts"], xe)                                     # (E, C, d)
    # gather back per (token, choice) and combine with gates
    e_safe = jnp.where(plan["keep"], plan["expert"], 0)
    s_safe = jnp.where(plan["keep"], plan["slot"], 0)
    yt = ye[e_safe, s_safe]                                   # (T, k, d)
    w = (plan["gate"] * plan["keep"]).astype(jnp.float32)
    y = jnp.einsum("tkd,tk->td", yt.astype(jnp.float32), w)
    y = y.astype(x.dtype).reshape(B, S, d)
    if "shared" in p:
        y = y + mlp(p["shared"], x, cfg.activation)
    return y, plan["aux"]
