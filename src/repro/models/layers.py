"""Shared neural-net layers (pure JAX, param dicts, dtype-polymorphic)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                jnp.float32)).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return truncated_normal(key, (d_in, d_out), scale, dtype)


def rmsnorm_params(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def layernorm_params(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_params(key, d_model, d_ff, activation, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {"w_gate": dense_init(k1, d_model, d_ff, dtype),
                "w_up": dense_init(k2, d_model, d_ff, dtype),
                "w_down": dense_init(k3, d_ff, d_model, dtype)}
    return {"w_up": dense_init(k1, d_model, d_ff, dtype),
            "w_down": dense_init(k2, d_ff, d_model, dtype)}


def mlp(p, x, activation):
    if activation == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif activation == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_params(key, vocab, d_model, dtype, tie: bool):
    k1, k2 = jax.random.split(key)
    p = {"embedding": truncated_normal(k1, (vocab, d_model), 0.02, dtype)}
    if not tie:
        p["unembed"] = dense_init(k2, d_model, vocab, dtype)
    return p


def embed(p, tokens):
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p, x, soft_cap: float = 0.0):
    if "unembed" in p:
        logits = x @ p["unembed"]
    else:
        logits = x @ p["embedding"].T.astype(x.dtype)
    logits = logits.astype(jnp.float32)
    if soft_cap > 0.0:
        logits = soft_cap * jnp.tanh(logits / soft_cap)
    return logits


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta):
    """x: (B, S, H, D); positions: (B, S) or (S,)"""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)   # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B,S,d/2)
    cos = jnp.cos(ang)[..., None, :]                          # (B,S,1,d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(n, d):
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / d)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, ignore: int = -100):
    """Mean CE over non-ignored positions; logits f32 (B,S,V), labels (B,S)."""
    mask = labels != ignore
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
