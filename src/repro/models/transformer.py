"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layers are stacked with ``jax.lax.scan`` over depth (per-layer params carry
a leading n_layers axis) — essential to keep HLO size and compile time flat
in depth for the 512-device dry-runs. Heterogeneous stacks (deepseek's
dense first layer, recurrentgemma's (rec, rec, attn) pattern) scan the
homogeneous portion and unroll the remainder.

API (used by train/serve/launch):
    init(key, dtype)                     -> params
    forward(params, batch)               -> logits (f32)
    loss(params, batch)                  -> (scalar, metrics)
    prefill(params, batch)               -> (logits, caches)
    decode_step(params, caches, tok, pos)-> (logits, caches)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from . import rglru as rg
from . import ssm as ssm_mod
from .layers import (
    cross_entropy,
    dense_init,
    embed,
    embed_params,
    mlp,
    mlp_params,
    rmsnorm,
    rmsnorm_params,
    unembed,
)


# ---------------------------------------------------------------------------
# per-layer param builders
# ---------------------------------------------------------------------------

def _attn_block_params(key, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln_attn": rmsnorm_params(cfg.d_model, dtype),
         "attn": attn.attn_params(k1, cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.head_dim, dtype,
                                  cfg.qkv_bias),
         "ln_mlp": rmsnorm_params(cfg.d_model, dtype)}
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_params(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return p


def _ssm_block_params(key, cfg: ModelConfig, dtype):
    k1, _ = jax.random.split(key)
    return {"ln": rmsnorm_params(cfg.d_model, dtype),
            "ssm": ssm_mod.ssd_params(k1, cfg, dtype)}


def _rec_block_params(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln_mix": rmsnorm_params(cfg.d_model, dtype),
            "rec": rg.rglru_params(k1, cfg, dtype),
            "ln_mlp": rmsnorm_params(cfg.d_model, dtype),
            "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype)}


def _hyb_attn_block_params(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln_mix": rmsnorm_params(cfg.d_model, dtype),
            "attn": attn.attn_params(k1, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim, dtype),
            "ln_mlp": rmsnorm_params(cfg.d_model, dtype),
            "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype)}


# ---------------------------------------------------------------------------
# per-layer forward (full sequence)
# ---------------------------------------------------------------------------

def _attn_block_fwd(p, cfg, x, positions, window=0):
    h, _ = attn.attention(p["attn"], rmsnorm(p["ln_attn"], x, cfg.norm_eps),
                          positions, cfg, window=window)
    x = x + h
    y = rmsnorm(p["ln_mlp"], x, cfg.norm_eps)
    if cfg.family == "moe" and "moe" in p:
        m, aux = moe_mod.moe_layer(p["moe"], cfg, y)
    else:
        m, aux = mlp(p["mlp"], y, cfg.activation), 0.0
    return x + m, aux


def _ssm_block_fwd(p, cfg, x, conv_st=None, ssm_st=None, decode=False):
    y, st = ssm_mod.ssd_block(p["ssm"], cfg, rmsnorm(p["ln"], x, cfg.norm_eps),
                              conv_state=conv_st, ssm_state=ssm_st,
                              decode=decode)
    return x + y, st


def _rec_block_fwd(p, cfg, x, conv_st=None, h_st=None, decode=False):
    y, st = rg.recurrent_block(p["rec"], rmsnorm(p["ln_mix"], x, cfg.norm_eps),
                               conv_state=conv_st, h_state=h_st, decode=decode)
    x = x + y
    return x + mlp(p["mlp"], rmsnorm(p["ln_mlp"], x, cfg.norm_eps),
                   cfg.activation), st


def _hyb_attn_fwd(p, cfg, x, positions):
    h, _ = attn.attention(p["attn"], rmsnorm(p["ln_mix"], x, cfg.norm_eps),
                          positions, cfg, window=cfg.window)
    x = x + h
    return x + mlp(p["mlp"], rmsnorm(p["ln_mlp"], x, cfg.norm_eps),
                   cfg.activation)


def _stacked_init(fn, key, n, cfg, dtype):
    return jax.vmap(lambda k: fn(k, cfg, dtype))(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# the LM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    # ---- init -------------------------------------------------------------
    def init(self, key, dtype=jnp.float32) -> dict:
        cfg = self.cfg
        kE, kL, kX, kP = jax.random.split(key, 4)
        params: dict[str, Any] = {
            "embed": embed_params(kE, cfg.padded_vocab, cfg.d_model, dtype,
                                  cfg.tie_embeddings),
            "ln_f": rmsnorm_params(cfg.d_model, dtype),
        }
        if cfg.family == "ssm":
            params["blocks"] = _stacked_init(_ssm_block_params, kL,
                                             cfg.n_layers, cfg, dtype)
        elif cfg.family == "hybrid":
            pat = cfg.block_pattern
            n_groups, rem = divmod(cfg.n_layers, len(pat))
            groups = {}
            kG = jax.random.split(kL, len(pat))
            for i, kind in enumerate(pat):
                fn = _rec_block_params if kind == "rec" else _hyb_attn_block_params
                groups[f"{i}_{kind}"] = _stacked_init(fn, kG[i], n_groups,
                                                      cfg, dtype)
            params["groups"] = groups
            kR = jax.random.split(kX, max(rem, 1))
            params["tail"] = [
                (_rec_block_params if pat[i % len(pat)] == "rec"
                 else _hyb_attn_block_params)(kR[i], cfg, dtype)
                for i in range(rem)]
        else:  # dense / moe / vlm
            n_scan = cfg.n_layers - int(cfg.first_layer_dense)
            params["blocks"] = _stacked_init(_attn_block_params, kL, n_scan,
                                             cfg, dtype)
            if cfg.first_layer_dense:
                dense_cfg = dataclasses.replace(cfg, family="dense",
                                                d_ff=cfg.dense_d_ff)
                params["block0"] = _attn_block_params(kX, dense_cfg, dtype)
            if cfg.family == "vlm":
                params["img_proj"] = dense_init(kP, cfg.d_model, cfg.d_model,
                                                dtype)
        return params

    # ---- embedding frontends ------------------------------------------------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"])
        if cfg.family == "vlm":
            img = batch["image_embeds"].astype(x.dtype) @ params["img_proj"]
            x = jnp.concatenate([img, x], axis=1)
        if cfg.family == "hybrid":
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)  # gemma scaling
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        return x, positions

    # ---- full-sequence forward ----------------------------------------------
    def forward(self, params, batch, last_only: bool = False):
        logits, _, _ = self._forward_full(params, batch, want_cache=False,
                                          last_only=last_only)
        return logits

    def _forward_full(self, params, batch, want_cache: bool,
                      last_only: bool = False):
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        aux_total = 0.0
        caches = None

        if cfg.family == "ssm":
            def body(h, layer_p):
                h2, st = _ssm_block_fwd(layer_p, cfg, h)
                return h2, st if want_cache else 0
            x, sts = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
            caches = {"ssm": sts} if want_cache else None

        elif cfg.family == "hybrid":
            pat = cfg.block_pattern
            n_groups, rem = divmod(cfg.n_layers, len(pat))

            def body(h, group_p):
                sts = {}
                for i, kind in enumerate(pat):
                    p_i = group_p[f"{i}_{kind}"]
                    if kind == "rec":
                        h, st = _rec_block_fwd(p_i, cfg, h)
                        sts[f"{i}_rec"] = st
                    else:
                        h = _hyb_attn_fwd(p_i, cfg, h, positions)
                        sts[f"{i}_attn"] = 0
                return h, sts if want_cache else 0
            x, sts = jax.lax.scan(body, x, params["groups"], unroll=cfg.scan_unroll)
            for i, tail_p in enumerate(params["tail"]):
                kind = pat[i % len(pat)]
                if kind == "rec":
                    x, _ = _rec_block_fwd(tail_p, cfg, x)
                else:
                    x = _hyb_attn_fwd(tail_p, cfg, x, positions)
            caches = {"hybrid": sts} if want_cache else None

        else:  # dense / moe / vlm
            if cfg.first_layer_dense:
                dense_cfg = dataclasses.replace(cfg, family="dense")
                x, _ = _attn_block_fwd(params["block0"], dense_cfg, x, positions)

            def body(h, layer_p):
                h2, aux = _attn_block_fwd(layer_p, cfg, h, positions)
                return h2, aux
            x, auxs = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
            aux_total = jnp.sum(auxs) if cfg.family == "moe" else 0.0

        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        if last_only:
            # serving prefill: only the last position's logits are needed —
            # slicing BEFORE the unembed removes a 2*B*S*D*V matmul
            x = x[:, -1:]
        logits = unembed(params["embed"], x, cfg.logits_soft_cap)
        return logits, aux_total, caches

    # ---- loss ----------------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        logits, aux, _ = self._forward_full(params, batch, want_cache=False)
        if cfg.family == "vlm":
            logits = logits[:, cfg.num_image_tokens:, :]
        ce = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    # ---- serving: prefill + single-token decode -------------------------------
    def prefill(self, params, batch):
        """Full-context forward that also materializes decode caches."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        S = x.shape[1]

        if cfg.family == "ssm":
            def body(h, layer_p):
                h2, st = _ssm_block_fwd(layer_p, cfg, h)
                return h2, st
            x, sts = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
            caches = {"ssm": sts, "pos": jnp.int32(S)}
        elif cfg.family == "hybrid":
            caches = self._hybrid_prefill_caches(params, batch)
            x = caches.pop("_hidden")
        else:
            def body(h, layer_p):
                hn = rmsnorm(layer_p["ln_attn"], h, cfg.norm_eps)
                a, (k, v) = attn.attention(layer_p["attn"], hn, positions,
                                           cfg, window=0)
                h = h + a
                y = rmsnorm(layer_p["ln_mlp"], h, cfg.norm_eps)
                if cfg.family == "moe" and "moe" in layer_p:
                    m, _ = moe_mod.moe_layer(layer_p["moe"], cfg, y)
                else:
                    m = mlp(layer_p["mlp"], y, cfg.activation)
                return h + m, attn.KVCache(k=k, v=v)
            x0 = x
            if cfg.first_layer_dense:
                dense_cfg = dataclasses.replace(cfg, family="dense")
                x0, _ = _attn_block_fwd(params["block0"], dense_cfg, x, positions)
                # (cache for block0 omitted from scan; handled separately)
                hn = rmsnorm(params["block0"]["ln_attn"], x, cfg.norm_eps)
                _, (k0, v0) = attn.attention(params["block0"]["attn"], hn,
                                             positions, cfg)
                cache0 = attn.KVCache(k=k0, v=v0)
            x, kv = jax.lax.scan(body, x0, params["blocks"], unroll=cfg.scan_unroll)
            caches = {"kv": kv, "pos": jnp.int32(S)}
            if cfg.first_layer_dense:
                caches["kv0"] = cache0
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.logits_soft_cap)
        return logits, caches

    def _hybrid_prefill_caches(self, params, batch):
        cfg = self.cfg
        pat = cfg.block_pattern
        x, positions = self._embed_inputs(params, batch)

        def body(h, group_p):
            sts = {}
            for i, kind in enumerate(pat):
                p_i = group_p[f"{i}_{kind}"]
                if kind == "rec":
                    h, st = _rec_block_fwd(p_i, cfg, h)
                    sts[f"{i}_rec"] = st
                else:
                    hn = rmsnorm(p_i["ln_mix"], h, cfg.norm_eps)
                    a, (k, v) = attn.attention(p_i["attn"], hn, positions,
                                               cfg, window=cfg.window)
                    h = h + a
                    h = h + mlp(p_i["mlp"],
                                rmsnorm(p_i["ln_mlp"], h, cfg.norm_eps),
                                cfg.activation)
                    # keep only the last `window` positions (ring cache)
                    sts[f"{i}_attn"] = attn.KVCache(
                        k=k[:, -cfg.window:], v=v[:, -cfg.window:])
            return h, sts
        x, sts = jax.lax.scan(body, x, params["groups"], unroll=cfg.scan_unroll)
        tails = []
        for i, tail_p in enumerate(params["tail"]):
            kind = pat[i % len(pat)]
            if kind == "rec":
                x, st = _rec_block_fwd(tail_p, cfg, x)
                tails.append(st)
            else:
                x = _hyb_attn_fwd(tail_p, cfg, x, positions)
                tails.append(0)
        return {"groups": sts, "tail": tails,
                "pos": jnp.int32(x.shape[1]), "_hidden": x}

    def init_decode_caches(self, batch_size: int, capacity: int,
                           dtype=jnp.float32):
        """Zero caches for decode-from-scratch (the dry-run serve_step)."""
        cfg = self.cfg
        L = cfg.n_layers
        if cfg.family == "ssm":
            K = cfg.conv_kernel
            conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            return {"ssm": (
                jnp.zeros((L, batch_size, K - 1, conv_dim), dtype),
                jnp.zeros((L, batch_size, cfg.ssm_heads, cfg.ssm_head_dim,
                           cfg.ssm_state), jnp.float32)),
                "pos": jnp.int32(0)}
        if cfg.family == "hybrid":
            pat = cfg.block_pattern
            n_groups, rem = divmod(cfg.n_layers, len(pat))
            groups = {}
            for i, kind in enumerate(pat):
                if kind == "rec":
                    groups[f"{i}_rec"] = (
                        jnp.zeros((n_groups, batch_size, 3, cfg.lru_width), dtype),
                        jnp.zeros((n_groups, batch_size, cfg.lru_width),
                                  jnp.float32))
                else:
                    cap = min(cfg.window, capacity) if cfg.window else capacity
                    z = jnp.zeros((n_groups, batch_size, cap, cfg.n_kv_heads,
                                   cfg.head_dim), dtype)
                    groups[f"{i}_attn"] = attn.KVCache(k=z, v=z)
            tail = []
            for i in range(rem):
                if pat[i % len(pat)] == "rec":
                    tail.append((jnp.zeros((batch_size, 3, cfg.lru_width), dtype),
                                 jnp.zeros((batch_size, cfg.lru_width),
                                           jnp.float32)))
                else:
                    cap = min(cfg.window, capacity) if cfg.window else capacity
                    z = jnp.zeros((batch_size, cap, cfg.n_kv_heads,
                                   cfg.head_dim), dtype)
                    tail.append(attn.KVCache(k=z, v=z))
            return {"groups": groups, "tail": tail, "pos": jnp.int32(0)}
        # dense / moe / vlm
        n_scan = L - int(cfg.first_layer_dense)
        z = jnp.zeros((n_scan, batch_size, capacity, cfg.n_kv_heads,
                       cfg.head_dim), dtype)
        caches = {"kv": attn.KVCache(k=z, v=z), "pos": jnp.int32(0)}
        if cfg.first_layer_dense:
            z0 = jnp.zeros((batch_size, capacity, cfg.n_kv_heads,
                            cfg.head_dim), dtype)
            caches["kv0"] = attn.KVCache(k=z0, v=z0)
        return caches

    def decode_step(self, params, caches, token, pos=None):
        """token: (B, 1) int32. Returns (logits (B,1,V), new caches)."""
        cfg = self.cfg
        pos = caches["pos"] if pos is None else pos
        x = embed(params["embed"], token)
        if cfg.family == "hybrid":
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)

        if cfg.family == "ssm":
            def body(h, xs):
                layer_p, conv_st, ssm_st = xs
                h2, (c2, s2) = _ssm_block_fwd(layer_p, cfg, h, conv_st,
                                              ssm_st, decode=True)
                return h2, (c2, s2)
            x, sts = jax.lax.scan(body, x,
                                  (params["blocks"], *caches["ssm"]), unroll=cfg.scan_unroll)
            new = {"ssm": sts, "pos": pos + 1}

        elif cfg.family == "hybrid":
            pat = cfg.block_pattern
            new_groups = {}

            def body(h, xs):
                group_p, gcaches = xs
                outs = {}
                for i, kind in enumerate(pat):
                    p_i = group_p[f"{i}_{kind}"]
                    if kind == "rec":
                        conv_st, h_st = gcaches[f"{i}_rec"]
                        h, st = _rec_block_fwd(p_i, cfg, h, conv_st, h_st,
                                               decode=True)
                        outs[f"{i}_rec"] = st
                    else:
                        hn = rmsnorm(p_i["ln_mix"], h, cfg.norm_eps)
                        a, kv = attn.decode_attention(
                            p_i["attn"], hn, pos, gcaches[f"{i}_attn"], cfg,
                            window=cfg.window)
                        h = h + a
                        h = h + mlp(p_i["mlp"],
                                    rmsnorm(p_i["ln_mlp"], h, cfg.norm_eps),
                                    cfg.activation)
                        outs[f"{i}_attn"] = kv
                return h, outs
            x, new_groups = jax.lax.scan(body, x,
                                         (params["groups"], caches["groups"]), unroll=cfg.scan_unroll)
            new_tail = []
            for i, tail_p in enumerate(params["tail"]):
                kind = pat[i % len(pat)]
                if kind == "rec":
                    conv_st, h_st = caches["tail"][i]
                    x, st = _rec_block_fwd(tail_p, cfg, x, conv_st, h_st,
                                           decode=True)
                    new_tail.append(st)
                else:
                    hn = rmsnorm(tail_p["ln_mix"], x, cfg.norm_eps)
                    a, kv = attn.decode_attention(tail_p["attn"], hn, pos,
                                                  caches["tail"][i], cfg,
                                                  window=cfg.window)
                    x = x + a
                    x = x + mlp(tail_p["mlp"],
                                rmsnorm(tail_p["ln_mlp"], x, cfg.norm_eps),
                                cfg.activation)
                    new_tail.append(kv)
            new = {"groups": new_groups, "tail": new_tail, "pos": pos + 1}

        else:
            new = {"pos": pos + 1}
            if cfg.first_layer_dense:
                p0 = params["block0"]
                hn = rmsnorm(p0["ln_attn"], x, cfg.norm_eps)
                a, kv0 = attn.decode_attention(p0["attn"], hn, pos,
                                               caches["kv0"], cfg)
                x = x + a
                x = x + mlp(p0["mlp"], rmsnorm(p0["ln_mlp"], x, cfg.norm_eps),
                            cfg.activation)
                new["kv0"] = kv0

            def body(h, xs):
                layer_p, kv = xs
                hn = rmsnorm(layer_p["ln_attn"], h, cfg.norm_eps)
                a, kv2 = attn.decode_attention(layer_p["attn"], hn, pos, kv,
                                               cfg)
                h = h + a
                y = rmsnorm(layer_p["ln_mlp"], h, cfg.norm_eps)
                if cfg.family == "moe" and "moe" in layer_p:
                    m, _ = moe_mod.moe_layer(layer_p["moe"], cfg, y)
                else:
                    m = mlp(layer_p["mlp"], y, cfg.activation)
                return h + m, kv2
            x, kv = jax.lax.scan(body, x, (params["blocks"], caches["kv"]), unroll=cfg.scan_unroll)
            new["kv"] = kv

        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.logits_soft_cap)
        return logits, new
