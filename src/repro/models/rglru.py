"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluates the diagonal linear recurrence with
``jax.lax.associative_scan`` (log-depth, TPU-friendly); decode is a single
fused step carrying (conv_state, h). The surrounding block is Griffin's
recurrent block: two input branches, a width-4 causal conv on the
recurrent branch, GeLU gating on the other, and an output projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, truncated_normal

_C = 8.0


def rglru_params(key, cfg, dtype):
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    # Lambda init so a^c in [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log u / c)
    return {
        "w_x": dense_init(ks[1], d, w, dtype),       # recurrent branch
        "w_y": dense_init(ks[2], d, w, dtype),       # gate branch
        "conv_w": truncated_normal(ks[3], (4, w), 0.5, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(ks[4], w, w, dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(ks[5], w, w, dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "w_out": dense_init(ks[6], w, d, dtype),
    }


def _conv(p, u, state=None):
    K = p["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    idx = jnp.arange(u.shape[1])[:, None] + jnp.arange(K)[None, :]
    win = full[:, idx, :]
    y = jnp.einsum("blkc,kc->blc", win, p["conv_w"].astype(u.dtype))
    return y + p["conv_b"].astype(u.dtype), full[:, -(K - 1):, :]


def _gates(p, x):
    """x: (..., w) -> (log_a, gated_input) in f32."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(x32 @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * x32)


def rglru(p, x, h0=None):
    """x: (B, L, w). Returns (y, h_last). Associative scan over time."""
    a, bx = _gates(p, x)                       # (B,L,w) f32

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        # fold carried state into the first step: h_1 = a_1 h0 + b_1
        bx = bx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p, x, h):
    """Single decode step. x: (B, 1, w); h: (B, w) f32."""
    a, bx = _gates(p, x)
    hn = a[:, 0] * h.astype(jnp.float32) + bx[:, 0]
    return hn[:, None, :].astype(x.dtype), hn


def recurrent_block(p, x, *, conv_state=None, h_state=None, decode=False):
    """Griffin recurrent block. x: (B, L, d). Returns (y, (conv, h))."""
    branch = x @ p["w_x"]
    gate = jax.nn.gelu(x @ p["w_y"])
    conv_out, new_conv = _conv(p, branch, conv_state if decode else None)
    if decode:
        h, new_h = rglru_step(p, conv_out, h_state)
    else:
        h, new_h = rglru(p, conv_out, h0=h_state)
    return (h * gate) @ p["w_out"], (new_conv, new_h)
