"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The conv/mel frontend is a STUB per the brief: the model consumes
precomputed frame embeddings (B, encoder_seq, d_model) from
``input_specs()``. The transformer backbone is complete: encoder
(bidirectional self-attention, LayerNorm+GELU), decoder (causal
self-attention with KV cache + cross-attention over encoder output).
Decoder positions use sinusoidal tables so any assigned decode length
works without a learned-table resize (architectural choice documented in
DESIGN.md; real whisper-tiny caps at 448 learned positions).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn
from .layers import (
    cross_entropy,
    embed,
    embed_params,
    layernorm,
    layernorm_params,
    mlp,
    mlp_params,
    sinusoidal_positions,
    unembed,
)


def _enc_block_params(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": layernorm_params(cfg.d_model, dtype),
            "attn": attn.attn_params(k1, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim, dtype),
            "ln2": layernorm_params(cfg.d_model, dtype),
            "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, "gelu", dtype)}


def _dec_block_params(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": layernorm_params(cfg.d_model, dtype),
            "self": attn.attn_params(k1, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim, dtype),
            "ln_x": layernorm_params(cfg.d_model, dtype),
            "cross": attn.attn_params(k2, cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim, dtype),
            "ln2": layernorm_params(cfg.d_model, dtype),
            "mlp": mlp_params(k3, cfg.d_model, cfg.d_ff, "gelu", dtype)}


@dataclasses.dataclass(frozen=True)
class EncDec:
    cfg: ModelConfig

    def init(self, key, dtype=jnp.float32) -> dict:
        cfg = self.cfg
        kE, kEnc, kDec = jax.random.split(key, 3)
        enc = jax.vmap(lambda k: _enc_block_params(k, cfg, dtype))(
            jax.random.split(kEnc, cfg.encoder_layers))
        dec = jax.vmap(lambda k: _dec_block_params(k, cfg, dtype))(
            jax.random.split(kDec, cfg.n_layers))
        return {"embed": embed_params(kE, cfg.padded_vocab, cfg.d_model,
                                      dtype, cfg.tie_embeddings),
                "enc_blocks": enc, "dec_blocks": dec,
                "ln_enc": layernorm_params(cfg.d_model, dtype),
                "ln_dec": layernorm_params(cfg.d_model, dtype)}

    # ---- encoder -----------------------------------------------------------
    def encode(self, params, frames):
        cfg = self.cfg
        S = frames.shape[1]
        pos_tab = jnp.asarray(sinusoidal_positions(S, cfg.d_model),
                              frames.dtype)
        x = frames + pos_tab[None]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     frames.shape[:2])

        def body(h, p):
            a, _ = attn.attention(p["attn"], layernorm(p["ln1"], h),
                                  positions, cfg, causal=False, rope=False)
            h = h + a
            return h + mlp(p["mlp"], layernorm(p["ln2"], h), "gelu"), 0
        x, _ = jax.lax.scan(body, x, params["enc_blocks"], unroll=cfg.scan_unroll)
        return layernorm(params["ln_enc"], x)

    # ---- decoder (full sequence: train/prefill) ------------------------------
    def decode_full(self, params, tokens, enc_out, want_cache=False):
        cfg = self.cfg
        B, S = tokens.shape
        x = embed(params["embed"], tokens)
        x = x + jnp.asarray(sinusoidal_positions(S, cfg.d_model),
                            x.dtype)[None]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None],
            enc_out.shape[:2])

        def body(h, p):
            a, (k, v) = attn.attention(p["self"], layernorm(p["ln1"], h),
                                       positions, cfg, causal=True,
                                       rope=False)
            h = h + a
            c, (ck, cv) = attn.attention(p["cross"], layernorm(p["ln_x"], h),
                                         enc_pos, cfg, x_kv=enc_out,
                                         causal=False, rope=False)
            h = h + c
            h = h + mlp(p["mlp"], layernorm(p["ln2"], h), "gelu")
            ys = (attn.KVCache(k, v), attn.KVCache(ck, cv)) if want_cache else 0
            return h, ys
        x, caches = jax.lax.scan(body, x, params["dec_blocks"], unroll=cfg.scan_unroll)
        x = layernorm(params["ln_dec"], x)
        return unembed(params["embed"], x), caches

    # ---- losses / serving ----------------------------------------------------
    def loss(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        logits, _ = self.decode_full(params, batch["tokens"], enc_out)
        ce = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        return ce, {"ce": ce, "aux": 0.0}

    # generic LM-compatible API
    def forward(self, params, batch, last_only: bool = False):
        enc_out = self.encode(params, batch["frames"])
        logits, _ = self.decode_full(params, batch["tokens"], enc_out)
        return logits[:, -1:] if last_only else logits

    def prefill(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        logits, caches = self.decode_full(params, batch["tokens"], enc_out,
                                          want_cache=True)
        return logits, {"dec": caches, "enc_out": enc_out,
                        "pos": jnp.int32(batch["tokens"].shape[1])}

    def init_decode_caches(self, batch_size, capacity, dtype=jnp.float32):
        cfg = self.cfg
        L = cfg.n_layers
        z = jnp.zeros((L, batch_size, capacity, cfg.n_kv_heads, cfg.head_dim),
                      dtype)
        enc = jnp.zeros((batch_size, cfg.encoder_seq, cfg.d_model), dtype)
        zc = jnp.zeros((L, batch_size, cfg.encoder_seq, cfg.n_kv_heads,
                        cfg.head_dim), dtype)
        return {"dec": (attn.KVCache(z, z), attn.KVCache(zc, zc)),
                "enc_out": enc, "pos": jnp.int32(0)}

    def decode_step(self, params, caches, token, pos=None):
        """One decoder token against cached self-attn + encoder cross-attn."""
        cfg = self.cfg
        pos = caches["pos"] if pos is None else pos
        B = token.shape[0]
        pos_v = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        x = embed(params["embed"], token)
        # sinusoidal position at a dynamic (per-row) index, computed directly
        d = cfg.d_model
        i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
        ang = pos_v.astype(jnp.float32)[:, None] / jnp.power(10_000.0, 2 * i / d)
        posemb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, None]
        x = x + posemb.astype(x.dtype)
        enc_out = caches["enc_out"]
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None],
            enc_out.shape[:2])
        self_kv, cross_kv = caches["dec"]

        def body(h, xs):
            p, skv, ck, cv = xs
            a, skv2 = attn.decode_attention(p["self"],
                                            layernorm(p["ln1"], h), pos,
                                            skv, cfg, rope=False)
            h = h + a
            # cross-attention reads the static encoder K/V (precomputed at
            # prefill; zeros in decode-from-scratch dry-runs)
            c, _ = attn.attention(p["cross"], layernorm(p["ln_x"], h),
                                  enc_pos, cfg, x_kv=enc_out, causal=False,
                                  rope=False)
            h = h + c
            h = h + mlp(p["mlp"], layernorm(p["ln2"], h), "gelu")
            return h, skv2
        x, self_kv2 = jax.lax.scan(body, x, (params["dec_blocks"], self_kv,
                                             cross_kv.k, cross_kv.v), unroll=cfg.scan_unroll)
        x = layernorm(params["ln_dec"], x)
        logits = unembed(params["embed"], x)
        return logits, {"dec": (self_kv2, cross_kv), "enc_out": enc_out,
                        "pos": pos + 1}
