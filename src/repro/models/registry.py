"""Model + input-spec registry: config -> model instance -> batch specs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from .transformer import LM
from .whisper import EncDec


def build_model(cfg: ModelConfig):
    if cfg.family == "audio":
        return EncDec(cfg)
    return LM(cfg)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a given cell —
    weak-type-correct, shardable, no device allocation.

    train/prefill: the full-sequence batch. decode: one new token (the KV
    cache / recurrent state is a separate input built by ``cache_specs``).
    """
    B, S = shape.global_batch, shape.seq_len
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
    if shape.kind == "decode":
        batch = {"tokens": tok(B, 1)}
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), dtype)
        return batch
    if cfg.family == "audio":
        return {"frames": jax.ShapeDtypeStruct((B, cfg.encoder_seq,
                                                cfg.d_model), dtype),
                "tokens": tok(B, S), "labels": tok(B, S)}
    if cfg.family == "vlm":
        s_text = S - cfg.num_image_tokens
        return {"tokens": tok(B, s_text),
                "image_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.num_image_tokens, cfg.d_model), dtype),
                "labels": tok(B, s_text)}
    batch = {"tokens": tok(B, S)}
    if shape.kind == "train":
        batch["labels"] = tok(B, S)
    return batch


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStructs of the decode caches for a cell (via eval_shape)."""
    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init_decode_caches(shape.global_batch, shape.seq_len,
                                         dtype))


def param_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), dtype))
