"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm: within chunks of Q tokens the recurrence is
evaluated as (masked) matmuls — MXU food — and a `lax.scan` carries the
(H, P, N) recurrent state across chunks, so training/prefill are linear in
sequence length and decode carries O(H*P*N) state (this is why mamba2 runs
the 500k-context cell that full-attention archs must skip).

Block = in_proj -> short conv (x,B,C) -> SSD -> gated RMSNorm -> out_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, rmsnorm, rmsnorm_params, truncated_normal


def ssd_params(key, cfg, dtype):
    d, di = cfg.d_model, cfg.d_inner
    H, N, G = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    P = cfg.ssm_head_dim
    ks = jax.random.split(key, 6)
    d_in_proj = 2 * di + 2 * G * N + H
    conv_dim = di + 2 * G * N
    return {
        "in_proj": dense_init(ks[0], d, d_in_proj, dtype),
        "conv_w": truncated_normal(ks[1], (cfg.conv_kernel, conv_dim),
                                   1.0 / np.sqrt(cfg.conv_kernel), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                       np.log(1e-3), np.log(1e-1))))),
        "norm": rmsnorm_params(di, dtype),
        "out_proj": dense_init(ks[3], di, d, dtype),
    }


def _split_proj(cfg, zxbcdt):
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, x, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
    return z, x, B, C, dt


def _conv(p, u, state=None):
    """Causal depthwise short conv. u: (B, L, C). Returns (y, new_state)."""
    K = p["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)               # (B, L+K-1, C)
    idx = jnp.arange(u.shape[1])[:, None] + jnp.arange(K)[None, :]
    win = full[:, idx, :]                                   # (B, L, K, C)
    y = jnp.einsum("blkc,kc->blc", win, p["conv_w"].astype(u.dtype))
    y = y + p["conv_b"].astype(u.dtype)
    return jax.nn.silu(y), full[:, -(K - 1):, :] if K > 1 else None


def _segsum(x):
    """(..., Q) -> (..., Q, Q) lower-triangular segment sums."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    return jnp.where(j <= i, seg, -jnp.inf)


def ssd_scan(cfg, x, dt, B, C, a_log, init_state=None):
    """Chunked SSD. x: (b,L,H,P); dt: (b,L,H) (post-softplus);
    B, C: (b,L,G,N). Returns (y (b,L,H,P), final_state (b,H,P,N))."""
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Q = min(cfg.ssm_chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q
    rep = H // G
    A = -jnp.exp(a_log)                                    # (H,)

    f32 = jnp.float32
    xc = x.astype(f32).reshape(b, nc, Q, H, P)
    dtc = dt.astype(f32).reshape(b, nc, Q, H)
    Bc = B.astype(f32).reshape(b, nc, Q, G, N)
    Cc = C.astype(f32).reshape(b, nc, Q, G, N)
    dA = dtc * A[None, None, None, :]                      # (b,nc,Q,H)

    # intra-chunk (diagonal block): decay matrix per head
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))      # (b,nc,H,Q,Q)
    CB = jnp.einsum("bcqgn,bcsgn->bcgqs", Cc, Bc)          # (b,nc,G,Q,S)
    CB = jnp.repeat(CB, rep, axis=2)                       # (b,nc,H,Q,S)
    dtx = dtc[..., None] * xc                              # dt-weighted input
    if getattr(cfg, "ssd_bf16", False):
        # bf16 operands, f32 accumulation: halves the Q^2-tile traffic
        bf = jnp.bfloat16
        y_diag = jnp.einsum("bchqs,bcshp->bcqhp",
                            (CB * Lmat).astype(bf), dtx.astype(bf),
                            preferred_element_type=f32)
    else:
        y_diag = jnp.einsum("bchqs,bcshp->bcqhp", CB * Lmat, dtx)

    # per-chunk input -> state contribution:
    #   sum_q exp(sum_{s>q} dA_s) * dt_q B_q x_q
    total = jnp.sum(dA, axis=2, keepdims=True)             # (b,nc,1,H)
    decay_states = jnp.exp(total - jnp.cumsum(dA, axis=2))  # (b,nc,Q,H)
    Brep = jnp.repeat(Bc, rep, axis=3) if rep > 1 else Bc   # (b,nc,Q,H,N)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Brep, decay_states, dtx)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))             # (b,nc,H)

    def scan_fn(h, inp):
        st, dec = inp                                      # (b,H,P,N), (b,H)
        h = h * dec[..., None, None] + st
        return h, h

    h0 = (jnp.zeros((b, H, P, N), f32) if init_state is None
          else init_state.astype(f32))
    hT, hs = jax.lax.scan(scan_fn, h0,
                          (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    # state entering chunk c is hs[c-1]
    prev = jnp.concatenate([h0[None], hs[:-1]], axis=0).swapaxes(0, 1)

    # contribution of carried state to outputs inside each chunk:
    #   y_q += C_q . (exp(sum_{s<=q} dA_s) * h_prev)
    state_decay = jnp.exp(jnp.cumsum(dA, axis=2))          # (b,nc,Q,H)
    Crep = jnp.repeat(Cc, rep, axis=3) if rep > 1 else Cc  # (b,nc,Q,H,N)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Crep, prev, state_decay)

    y = (y_diag + y_off).reshape(b, L, H, P)
    return y.astype(x.dtype), hT


def ssd_block(p, cfg, x, *, conv_state=None, ssm_state=None, decode=False):
    """Full Mamba2 block. x: (B, L, d_model). Returns (y, (conv_st, ssm_st))."""
    H, P, G, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    zxbcdt = x @ p["in_proj"]
    z, xin, B, C, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    conv_out, new_conv = _conv(p, conv_in,
                               conv_state if decode else None)
    xin, B, C = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + G * N], -1)
    b, L = x.shape[0], x.shape[1]
    xh = xin.reshape(b, L, H, P)
    Bh = B.reshape(b, L, G, N)
    Ch = C.reshape(b, L, G, N)
    dth = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"][None, None, :])   # (b,L,H)
    if getattr(cfg, "ssd_shard_heads", False) and not decode:
        from jax.sharding import PartitionSpec as _P
        _c = jax.lax.with_sharding_constraint
        xh = _c(xh, _P(None, None, "model", None))
        dth = _c(dth, _P(None, None, "model"))
    if decode:
        # single-token recurrence: h = h*exp(dt*A) + dt*B*x
        A = -jnp.exp(p["a_log"])
        dA = jnp.exp(dth[:, 0] * A[None, :])               # (b,H)
        rep = H // G
        Bx = jnp.repeat(Bh[:, 0], rep, axis=1).reshape(b, H, N) if G != H else Bh[:, 0]
        Cx = jnp.repeat(Ch[:, 0], rep, axis=1).reshape(b, H, N) if G != H else Ch[:, 0]
        dtx = dth[:, 0, :, None] * xh[:, 0].astype(jnp.float32)
        h = ssm_state.astype(jnp.float32) * dA[..., None, None] \
            + dtx[..., None] * Bx[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, Cx)
        y = y + p["d_skip"][None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(b, 1, H * P).astype(x.dtype)
        new_ssm = h
    else:
        y, new_ssm = ssd_scan(cfg, xh, dth, Bh, Ch, p["a_log"],
                              init_state=ssm_state)
        y = y + p["d_skip"].astype(x.dtype)[None, None, :, None] * xh
        y = y.reshape(b, L, H * P)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"], (new_conv, new_ssm)
