"""eGPU machine configuration and architectural state.

One SM = 16 SPs, 512 threads max, 16 registers/thread (one M20K per two
registers: the 512x32 M20K geometry is what fixed these numbers in the
paper). Shared memory is quad-read-port / single-write-port; depth is
parameterizable (the §III.E sector-packing budget gives 3K words when four
SMs share one Agilex sector).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

N_SP = 16                # scalar processors per SM
MAX_THREADS = 512        # threads per SM
N_REGS = 16              # registers per thread
MAX_WAVES = MAX_THREADS // N_SP
RET_STACK_DEPTH = 8
LOOP_STACK_DEPTH = 4


@dataclasses.dataclass(frozen=True)
class SMConfig:
    """Static (trace-time) machine parameters."""

    n_threads: int = MAX_THREADS       # initialized threads (<= 512)
    dim_x: int = 16                    # 2D thread space: x dimension
    shmem_depth: int = 3072            # words (12 KiB: §III.E sector budget)
    imem_depth: int = 512              # one M20K of 512x40
    max_steps: int = 100_000           # ISS fuel
    with_dot: bool = True              # dot-product extension unit
    with_sfu: bool = True              # inverse-sqrt SFU

    def __post_init__(self):
        if not 1 <= self.n_threads <= MAX_THREADS:
            raise ValueError(f"n_threads={self.n_threads} not in [1, {MAX_THREADS}]")
        if self.n_threads % self.dim_x:
            raise ValueError("n_threads must be divisible by dim_x")

    @property
    def dim_y(self) -> int:
        return self.n_threads // self.dim_x

    @property
    def n_waves(self) -> int:
        return max(1, (self.n_threads + N_SP - 1) // N_SP)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MachineState:
    """Architectural + profiling state (a JAX pytree; scanned by the ISS)."""

    regs: jax.Array        # (MAX_THREADS, N_REGS) uint32
    shmem: jax.Array       # (shmem_depth,) uint32
    pc: jax.Array          # () int32
    ret_stack: jax.Array   # (RET_STACK_DEPTH,) int32
    ret_sp: jax.Array      # () int32
    loop_ctr: jax.Array    # (LOOP_STACK_DEPTH,) int32
    loop_sp: jax.Array     # () int32
    halted: jax.Array      # () bool
    oob: jax.Array         # () bool — any out-of-range shared-memory access
    steps: jax.Array       # () int32 — instructions executed
    cycles: jax.Array      # () int32 — sequencer cycles (cost model)
    cycles_by_class: jax.Array  # (NUM_CLASSES,) int32

    def replace(self, **kw) -> "MachineState":
        return dataclasses.replace(self, **kw)

    def replace_regs(self, regs) -> "MachineState":
        return dataclasses.replace(self, regs=regs)


def as_u32_image(arr, depth: int, what: str = "memory") -> jax.Array:
    """Coerce a host array to a (..., depth) uint32 memory image.

    float32 input is bitcast (the eGPU memory system is typeless 32-bit
    words); shorter images are zero-padded on the last axis. Shared by
    ``init_state`` (per-SM shared memory) and the device layer (per-block
    shared-memory batches and the global-memory segment).
    """
    a = jnp.asarray(arr)
    if a.dtype in (jnp.float32, np.float32):
        a = jax.lax.bitcast_convert_type(a.astype(jnp.float32), jnp.uint32)
    a = a.astype(jnp.uint32)
    pad = depth - a.shape[-1]
    if pad < 0:
        raise ValueError(f"{what} image of {a.shape[-1]} words exceeds "
                         f"depth {depth}")
    if pad:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
    return a


def init_state(cfg: SMConfig, shmem: np.ndarray | None = None) -> MachineState:
    from .isa import NUM_CLASSES

    if shmem is None:
        sh = jnp.zeros((cfg.shmem_depth,), jnp.uint32)
    else:
        sh = as_u32_image(shmem, cfg.shmem_depth, "shared-memory")
    return MachineState(
        regs=jnp.zeros((MAX_THREADS, N_REGS), jnp.uint32),
        shmem=sh,
        pc=jnp.zeros((), jnp.int32),
        ret_stack=jnp.zeros((RET_STACK_DEPTH,), jnp.int32),
        ret_sp=jnp.zeros((), jnp.int32),
        loop_ctr=jnp.zeros((LOOP_STACK_DEPTH,), jnp.int32),
        loop_sp=jnp.zeros((), jnp.int32),
        halted=jnp.zeros((), jnp.bool_),
        oob=jnp.zeros((), jnp.bool_),
        steps=jnp.zeros((), jnp.int32),
        cycles=jnp.zeros((), jnp.int32),
        cycles_by_class=jnp.zeros((NUM_CLASSES,), jnp.int32),
    )


def shmem_f32(state: MachineState) -> jax.Array:
    return jax.lax.bitcast_convert_type(state.shmem, jnp.float32)


def shmem_i32(state: MachineState) -> jax.Array:
    return jax.lax.bitcast_convert_type(state.shmem, jnp.int32)


def regs_f32(state: MachineState) -> jax.Array:
    return jax.lax.bitcast_convert_type(state.regs, jnp.float32)


def regs_i32(state: MachineState) -> jax.Array:
    return jax.lax.bitcast_convert_type(state.regs, jnp.int32)


def profile(state: MachineState) -> dict[str, Any]:
    """Cycle profile by instruction class — the Tables III/IV view."""
    from .isa import CLASS_NAMES

    by = np.asarray(state.cycles_by_class)
    total = int(by.sum())
    return {
        "total_cycles": total,
        "instructions": int(state.steps),
        "by_class": {n: int(c) for n, c in zip(CLASS_NAMES, by)},
        "pct_by_class": {n: (100.0 * int(c) / total if total else 0.0)
                         for n, c in zip(CLASS_NAMES, by)},
    }
