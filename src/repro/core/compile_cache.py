"""Persistent on-disk compile cache: production cold-start skips
re-tracing.

The in-process lowering caches (``cycles._trace_cached``,
``trace_engine._compile_cached`` and the megakernel plan/runner caches)
make repeated launches free *within* one process — but a fresh process
re-walks every program trace and re-decodes every schedule before the
first wave runs. This module adds the missing tier: a content-addressed
pickle store on disk, keyed by a sha256 over

    (format version, artifact kind, program words, SMConfig fields,
     backend, engine)

so a production cold start loads the host-side lowering artifacts
(``ProgramTrace`` walks and decoded schedule columns) instead of
recomputing them. Two artifact kinds ship: ``"trace"`` (the issued-trace
walk, consulted by ``cycles.program_trace``) and ``"lowering"`` (the
pre-decoded schedule columns, consulted by
``trace_engine._compile_cached``); both are backend/engine-independent,
so those key components are fixed tags — backend/engine-*dependent*
compiled artifacts are covered by JAX's own persistent compilation
cache, which ``configure`` wires to a sibling directory when available.

The cache is OPT-IN (tests and casual runs must not litter the
filesystem): activate it with ``configure(path)`` or by exporting
``EGPU_CACHE_DIR``. Robustness contract: a corrupt, truncated,
wrong-version or otherwise unreadable entry is a MISS — the caller
re-traces and overwrites the entry; the cache never raises into the
launch path. ``stats()`` exposes hit/miss/error counters so tests and
the cold-start benchmark can prove an entry was actually served.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import tempfile

_ENV = "EGPU_CACHE_DIR"
_JAX_ENV = "EGPU_JAX_CACHE"     # set to 0 to skip wiring jax's own cache
_FORMAT = 1
_MAGIC = "egpu-compile-cache"


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    errors: int = 0      # unreadable/corrupt entries (counted as misses)
    stores: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class CompileCache:
    """One on-disk cache directory of pickled lowering artifacts."""

    def __init__(self, path: str):
        self.path = str(path)
        self.stats = CacheStats()
        os.makedirs(self.path, exist_ok=True)

    def _file(self, key: str) -> str:
        return os.path.join(self.path, key[:2], key + ".pkl")

    def get(self, key: str):
        """The cached value, or None on miss. ANY failure to read or
        validate the entry — missing file, truncated pickle, foreign
        format, version skew, key collision — is a miss: the caller
        recomputes and ``put`` overwrites the bad entry."""
        f = self._file(key)
        try:
            with open(f, "rb") as fh:
                entry = pickle.load(fh)
            if (not isinstance(entry, dict)
                    or entry.get("magic") != _MAGIC
                    or entry.get("format") != _FORMAT
                    or entry.get("key") != key):
                raise ValueError("malformed cache entry")
        except FileNotFoundError:
            self.stats.misses += 1
            return None
        except Exception:
            self.stats.errors += 1
            self.stats.misses += 1
            try:
                os.unlink(f)             # quarantine: next run rewrites
            except OSError:
                pass
            return None
        self.stats.hits += 1
        return entry["value"]

    def put(self, key: str, value) -> None:
        """Atomically persist ``value``; failures are silent (the cache
        is an accelerator, never a correctness dependency)."""
        f = self._file(key)
        try:
            os.makedirs(os.path.dirname(f), exist_ok=True)
            blob = pickle.dumps({"magic": _MAGIC, "format": _FORMAT,
                                 "key": key, "value": value},
                                protocol=pickle.HIGHEST_PROTOCOL)
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(f),
                                       suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as fh:
                    fh.write(blob)
                os.replace(tmp, f)       # atomic on POSIX
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
            self.stats.stores += 1
        except Exception:
            pass


# the active cache (None = disabled); resolved lazily from the env so
# `import repro` alone never touches the filesystem
_active: CompileCache | None = None
_resolved = False


def key_for(kind: str, words, cfg, *, backend: str = "-",
            engine: str = "-") -> str:
    """Content hash of one artifact: (version, kind, program words,
    SMConfig, backend, engine). ``cfg`` may be an SMConfig or any object
    with a deterministic repr; backend/engine default to fixed tags for
    backend-independent artifacts."""
    h = hashlib.sha256()
    h.update(repr((_FORMAT, kind, tuple(int(w) for w in words),
                   repr(cfg), backend, engine)).encode())
    return h.hexdigest()


def configure(path: str | None) -> CompileCache | None:
    """Activate the cache at ``path`` (None disables it). Also wires
    JAX's persistent compilation cache to ``<path>/xla`` — covering the
    backend/engine-dependent compiled artifacts — unless
    ``EGPU_JAX_CACHE=0`` or the running jax can't."""
    global _active, _resolved
    _resolved = True
    if path is None:
        _active = None
        return None
    _active = CompileCache(path)
    if os.environ.get(_JAX_ENV, "1").strip().lower() not in \
            ("0", "false", "no", "off"):
        try:
            import jax

            jax.config.update("jax_compilation_cache_dir",
                              os.path.join(_active.path, "xla"))
            jax.config.update("jax_persistent_cache_min_compile_time_secs",
                              0.0)
        except Exception:
            pass                         # jax cache unavailable: harmless
    return _active


def active() -> CompileCache | None:
    """The configured cache, resolving ``EGPU_CACHE_DIR`` on first use."""
    global _resolved
    if not _resolved:
        _resolved = True
        env = os.environ.get(_ENV, "").strip()
        if env:
            configure(env)
    return _active


def load(key: str):
    cc = active()
    return cc.get(key) if cc is not None else None


def store(key: str, value) -> None:
    cc = active()
    if cc is not None:
        cc.put(key, value)


def stats() -> dict | None:
    cc = active()
    return cc.stats.as_dict() if cc is not None else None
