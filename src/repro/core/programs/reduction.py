"""Reduction benchmark (paper §III.D): sum 512 values without shared memory.

Stage 1: SUM per wavefront -> 32 partials in lane 0 (SP0's register file).
Stage 2: thread snooping — thread 0 reads every wavefront's lane-0 partial
directly ("without having to go through the shared memory") and folds them
with a NOP-padded accumulation tree that respects the 9-cycle RAW window.

``launch_reduction`` scales this past one SM on the device layer: a grid
of blocks each folds its 512-element chunk of GLOBAL memory and commits
its partial with a single-cycle ``GST {w1,d1}``; a second one-block launch
(reading the same global segment — waves and launches share it) folds the
partials to the final scalar. The classic two-level grid reduction.
"""
from __future__ import annotations

import numpy as np

from ..assembler import Program, assemble, auto_nop
from ..device import DeviceConfig, Kernel, LaunchResult, launch
from ..executor import run
from ..machine import SMConfig, shmem_f32


def reduction_asm(n_threads: int = 512) -> str:
    n_waves = max(1, n_threads // 16)
    lines = ["    TDX R1",
             "    LOD R2, (R1)+0            // x[tid]",
             "    SUM.FP32 R3, R2, R0       // wavefront partials -> lane0"]
    # fold pairs via snooping: R4..R9 hold independent accumulator chains
    # (6 chains keep dependent uses >= 9 cycles apart without NOPs).
    accs = [4, 5, 6, 7, 8, 9]
    n_chains = min(len(accs), max(1, n_waves // 2))
    for c in range(n_chains):
        w0, w1 = 2 * c, 2 * c + 1 if 2 * c + 1 < n_waves else 2 * c
        lines.append(f"    ADD.FP32 R{accs[c]}, R3@{w0}, R3@{w1} {{d1}}")
    for w in range(2 * n_chains, n_waves):
        c = w % n_chains
        lines.append(f"    ADD.FP32 R{accs[c]}, R{accs[c]}, R3@{w} {{d1}}")
        if n_chains < 6:
            lines.append("    NOP\n    NOP\n    NOP\n    NOP")
    # fold the chains (single thread; pad the RAW window)
    lines.append("    NOP\n    NOP\n    NOP\n    NOP\n    NOP\n    NOP\n"
                 "    NOP\n    NOP")
    live = accs[:n_chains]
    while len(live) > 1:
        nxt = []
        for i in range(0, len(live) - 1, 2):
            lines.append(f"    ADD.FP32 R{live[i]}, R{live[i]}, R{live[i+1]} {{w1,d1}}")
            nxt.append(live[i])
        if len(live) % 2:
            nxt.append(live[-1])
        live = nxt
        lines.append("    NOP\n    NOP\n    NOP\n    NOP\n    NOP\n    NOP\n"
                     "    NOP\n    NOP")
    lines.append(f"    STO R{live[0]}, (R0)+{n_threads} {{w1,d1}}  // result")
    lines.append("    STOP")
    return "\n".join(lines)


def reduction_program(n_threads: int = 512) -> Program:
    return assemble(reduction_asm(n_threads))


# ---------------------------------------------------------------------------
# grid version on the device layer
# ---------------------------------------------------------------------------

def reduction_grid_asm(n_threads: int, src_base: int, dst_base: int,
                       grid: bool) -> str:
    """One reduction block over global memory.

    Loads ``x[gid]`` from ``src_base`` (``gid = BID*n_threads + TDX`` when
    ``grid``, else just ``TDX``), folds via SUM + thread snooping exactly
    like ``reduction_asm``, and stores the block partial to
    ``dst_base + BID`` with the paper's single-cycle ``{w1,d1}`` store —
    through the GLOBAL port, so the next launch stage can read it.
    """
    n_waves = max(1, n_threads // 16)
    lines = ["    BID R10", "    TDX R1"]
    if grid:
        lines += [f"    LOD R11, #{n_threads}",
                  "    MUL.INT32 R12, R10, R11",
                  "    ADD.INT32 R1, R12, R1      // gid"]
    lines += [f"    GLD R2, (R1)+{src_base}      // x[gid]",
              "    SUM.FP32 R3, R2, R0          // wavefront partials -> lane0"]
    accs = [4, 5, 6, 7, 8, 9]
    n_chains = min(len(accs), max(1, n_waves // 2))
    for c in range(n_chains):
        w0 = 2 * c
        if 2 * c + 1 < n_waves:
            lines.append(f"    ADD.FP32 R{accs[c]}, R3@{w0}, R3@{2*c+1} {{d1}}")
        else:
            # odd tail / single wavefront: seed the chain with partial + 0
            # (R0 is never written, so R0@0 is 0.0)
            lines.append(f"    ADD.FP32 R{accs[c]}, R3@{w0}, R0@{w0} {{d1}}")
    for w in range(2 * n_chains, n_waves):
        c = w % n_chains
        lines.append(f"    ADD.FP32 R{accs[c]}, R{accs[c]}, R3@{w} {{d1}}")
    live = accs[:n_chains]
    while len(live) > 1:
        nxt = []
        for i in range(0, len(live) - 1, 2):
            lines.append(
                f"    ADD.FP32 R{live[i]}, R{live[i]}, R{live[i+1]} {{w1,d1}}")
            nxt.append(live[i])
        if len(live) % 2:
            nxt.append(live[-1])
        live = nxt
    lines.append(f"    GST R{live[0]}, (R10)+{dst_base} {{w1,d1}}  // partial")
    lines.append("    STOP")
    return auto_nop("\n".join(lines), n_threads)


def launch_reduction(x: np.ndarray, device: DeviceConfig | None = None,
                     block: int = 512, backend: str | None = None,
                     schedule: str | None = None, fused: bool = False
                     ) -> tuple[float, LaunchResult]:
    """Two-level grid reduction of x on the multi-SM device.

    Any length up to ~16K elements (every global-memory offset is a GLD/GST
    immediate, so the padded x + partials + result layout must fit the
    signed 14-bit immediate range). Returns (total, LaunchResult).

    ``fused=False``: two back-to-back launches — stage 1 writes one
    partial per block, stage 2 is a one-block launch over the
    carried-forward global memory that folds the partials. The result is
    the stage-2 LaunchResult.

    ``fused=True``: ONE multi-program launch — the stage-2 program rides
    in the same grid with ``barrier=True``, so its block dispatches only
    after every stage-1 block retired (the scheduler's dependency fence).
    The result is the whole launch's LaunchResult, so ``profile()`` shows
    both stages' per-SM occupancy.
    """
    x = np.asarray(x, np.float32).reshape(-1)
    n = x.shape[0]
    block = min(block, max(16, -(-n // 16) * 16))
    n_blocks = max(1, -(-n // block))
    if n_blocks * block + n_blocks + 32 >= 1 << 14:
        # every gmem offset is a GLD/GST immediate (signed 14-bit)
        raise ValueError(f"n={n} too large for immediate addressing "
                         f"(padded layout must stay below {1 << 14} words)")
    x_pad = np.zeros(n_blocks * block, np.float32)
    x_pad[:n] = x
    # stage-2 block must be a multiple of 16 threads; excess partials are 0
    n2 = -(-n_blocks // 16) * 16
    buffers = {
        "x": x_pad,
        "partials": np.zeros(n2, np.float32),
        "result": np.zeros(16, np.float32),
    }
    from ..device import buffer_layout

    layout = buffer_layout(buffers)
    src, par, res_off = (layout[k][0] for k in ("x", "partials", "result"))
    if device is None:
        depth = layout["result"][0] + layout["result"][1]
        device = DeviceConfig(global_mem_depth=max(depth, 64),
                              sm=SMConfig(max_steps=50_000))
    stage1 = assemble(reduction_grid_asm(block, src, par, True))
    stage2 = assemble(reduction_grid_asm(n2, par, res_off, False))
    if fused:
        res = launch(
            device,
            programs=[Kernel(stage1, block=block, name="reduce.stage1"),
                      Kernel(stage2, block=n2, name="reduce.stage2",
                             barrier=True)],
            grid_map=[0] * n_blocks + [1], buffers=buffers,
            backend=backend, schedule=schedule)
        total = float(np.asarray(res.buffer("result"))[0])
        return total, res
    s1 = launch(device, stage1, grid=(n_blocks,), block=block,
                buffers=buffers, backend=backend, schedule=schedule)
    s2 = launch(device, stage2, grid=(1,), block=n2, gmem=s1.gmem,
                backend=backend, schedule=schedule)
    s2.buffer_offsets = layout  # stage 2 inherits the stage-1 layout
    total = float(np.asarray(s2.buffer("result"))[0])
    return total, s2


def run_reduction(x: np.ndarray):
    """Sum x (length <= 512) on the eGPU; returns (total, final_state)."""
    n = int(x.shape[0])
    if n % 16:
        raise ValueError("length must be a multiple of 16")
    cfg = SMConfig(n_threads=n, dim_x=n, shmem_depth=max(n + 16, 64),
                   max_steps=50_000)
    img = np.zeros(cfg.shmem_depth, np.float32)
    img[:n] = np.asarray(x, np.float32)
    state = run(cfg, reduction_program(n), img)
    total = float(np.asarray(shmem_f32(state))[n])
    return total, state
