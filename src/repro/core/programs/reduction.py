"""Reduction benchmark (paper §III.D): sum 512 values without shared memory.

Stage 1: SUM per wavefront -> 32 partials in lane 0 (SP0's register file).
Stage 2: thread snooping — thread 0 reads every wavefront's lane-0 partial
directly ("without having to go through the shared memory") and folds them
with a NOP-padded accumulation tree that respects the 9-cycle RAW window.
"""
from __future__ import annotations

import numpy as np

from ..assembler import Program, assemble
from ..executor import run
from ..machine import SMConfig, shmem_f32


def reduction_asm(n_threads: int = 512) -> str:
    n_waves = max(1, n_threads // 16)
    lines = ["    TDX R1",
             "    LOD R2, (R1)+0            // x[tid]",
             "    SUM.FP32 R3, R2, R0       // wavefront partials -> lane0"]
    # fold pairs via snooping: R4..R9 hold independent accumulator chains
    # (6 chains keep dependent uses >= 9 cycles apart without NOPs).
    accs = [4, 5, 6, 7, 8, 9]
    n_chains = min(len(accs), max(1, n_waves // 2))
    for c in range(n_chains):
        w0, w1 = 2 * c, 2 * c + 1 if 2 * c + 1 < n_waves else 2 * c
        lines.append(f"    ADD.FP32 R{accs[c]}, R3@{w0}, R3@{w1} {{d1}}")
    for w in range(2 * n_chains, n_waves):
        c = w % n_chains
        lines.append(f"    ADD.FP32 R{accs[c]}, R{accs[c]}, R3@{w} {{d1}}")
        if n_chains < 6:
            lines.append("    NOP\n    NOP\n    NOP\n    NOP")
    # fold the chains (single thread; pad the RAW window)
    lines.append("    NOP\n    NOP\n    NOP\n    NOP\n    NOP\n    NOP\n"
                 "    NOP\n    NOP")
    live = accs[:n_chains]
    while len(live) > 1:
        nxt = []
        for i in range(0, len(live) - 1, 2):
            lines.append(f"    ADD.FP32 R{live[i]}, R{live[i]}, R{live[i+1]} {{w1,d1}}")
            nxt.append(live[i])
        if len(live) % 2:
            nxt.append(live[-1])
        live = nxt
        lines.append("    NOP\n    NOP\n    NOP\n    NOP\n    NOP\n    NOP\n"
                     "    NOP\n    NOP")
    lines.append(f"    STO R{live[0]}, (R0)+{n_threads} {{w1,d1}}  // result")
    lines.append("    STOP")
    return "\n".join(lines)


def reduction_program(n_threads: int = 512) -> Program:
    return assemble(reduction_asm(n_threads))


def run_reduction(x: np.ndarray):
    """Sum x (length <= 512) on the eGPU; returns (total, final_state)."""
    n = int(x.shape[0])
    if n % 16:
        raise ValueError("length must be a multiple of 16")
    cfg = SMConfig(n_threads=n, dim_x=n, shmem_depth=max(n + 16, 64),
                   max_steps=50_000)
    img = np.zeros(cfg.shmem_depth, np.float32)
    img[:n] = np.asarray(x, np.float32)
    state = run(cfg, reduction_program(n), img)
    total = float(np.asarray(shmem_f32(state))[n])
    return total, state
