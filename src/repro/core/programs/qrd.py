"""16x16 Modified Gram-Schmidt QR decomposition for the eGPU (paper §IV.B).

Thread mapping: 256 threads; thread t holds element A[row, col] with
row = t % 16 (its lane) and col = t // 16 (its wavefront). Each thread
keeps its *residual* element in a register (R2) for the whole
factorization — column k lives in wavefront k.

Two variants:

``qrd_asm()`` — the paper-faithful choreography (§III.D walkthrough),
iterations unrolled so the thread-snooping wavefront index can be encoded
per iteration (the X-bit register-address extensions are immediate
fields). Per iteration j:

  1. wave 0 *snoops* column j's residual out of wavefront j's registers
     (``ADD.FP32 R5, R2@j, R4@j`` with R4 kept = 0.0 — a register move
     through the FP adder), avoiding any shared-memory traffic;
  2. ``DOT {d1}``: the norm on the isolated wavefront          [1 cycle]
  3. ``INVSQR {w1,d1}``: the SFU on a single thread            [1]
  4. ``STO {w1,d1}``: THE paper highlight — the norm reciprocal
     writeback costs a SINGLE cycle instead of 256             [1]
  5. recip to wave 0 ``{w16,d1}``                              [4]
  6. q_j = a_j * recip in wave 0, stored as Q column j         [1+16]
  7. q_j broadcast to all threads through shared memory        [64]
  8. full-depth DOT: r_jk = <q_j, a_k> for every wavefront     [16]
     (finished columns have zero residuals => r_jk = 0; column j itself
     yields r_jj = ||a_j|| and projects to zero — branch-free, no thread
     divergence: the paper's point)
  9. R row j stored from lane 0 ``{w1,dfull}``                 [16]
 10. r_jk broadcast + projection a_k -= r_jk q_j               [64+16+16]

Per-iteration totals: LOD = 4+64+64 = 132, STO = 1+16+16 = 33,
DOT = 1+16 = 17, SFU = 1 — Table IV's rows, reproduced exactly; the NOP
padding demanded by the 9-cycle RAW window lands at the paper's ~15%.

``qrd_asm_loop()`` — a compact zero-overhead-loop variant (the "40
instruction" scale the paper quotes for I-MEM sizing). A loop body cannot
vary the snoop immediates, so column j is re-broadcast from shared memory
instead, and residuals are written back each iteration (a full-depth
store) — correct true-MGS numerics, more store cycles. The cycle-profile
fidelity claims attach to the unrolled variant; the loop variant shows
program-size scaling.

Shared-memory layout:
    [0   .. 256)   A, column-major (A[i,k] at 16k+i)
    [256 .. 512)   Q, column-major
    [512 .. 768)   R, row-major    (R[j,k] at 512 + 16j + k)
    [768 .. 784)   dot scratch (loop variant)
    [784]          norm reciprocal
"""
from __future__ import annotations

import numpy as np

from ..assembler import Program, assemble, auto_nop
from ..device import DeviceConfig, Kernel, LaunchResult, launch
from ..executor import run
from ..machine import SMConfig, shmem_f32

A_BASE, Q_BASE, R_BASE, DOT_BASE, RECIP = 0, 256, 512, 768, 784


def qrd_asm(pad_hazards: bool = True) -> str:
    """Paper-faithful unrolled MGS QRD (snooping + flexible ISA)."""
    chunks = [f"""
    // ---- setup: R3=lane, R12=wave, R15=tid, R2=A element, R4=0.0 ----
    LOD R1, #4
    TDX R3
    TDY R12
    LSL.INT32 R15, R12, R1
    NOP
    NOP
    ADD.INT32 R15, R15, R3
    NOP
    NOP
    LOD R2, (R15)+{A_BASE}
"""]
    for j in range(16):
        chunks.append(f"""
    // ======== MGS iteration j={j} ========
    ADD.FP32 R5, R2@{j}, R4@{j} {{d1}}        // snoop residual col {j} into wave 0
    DOT.FP32 R6, R5, R5 {{d1}}                // ||a_{j}||^2 -> thread 0
    INVSQR.FP32 R8, R6 {{w1,d1}}              // recip = 1/||a_{j}||
    STO R8, (R0)+{RECIP} {{w1,d1}}            // single-cycle norm writeback
    LOD R8, (R0)+{RECIP} {{w16,d1}}           // recip -> wave 0 lanes
    MUL.FP32 R5, R5, R8 {{d1}}                // q_{j} in wave 0
    STO R5, (R3)+{Q_BASE + 16 * j} {{w16,d1}} // Q column {j}
    LOD R5, (R3)+{Q_BASE + 16 * j}            // q_{j}[lane] everywhere
    DOT.FP32 R9, R5, R2                       // r_{j}k -> lane 0 of wave k
    STO R9, (R12)+{R_BASE + 16 * j} {{w1,dfull}}  // R row {j}
    LOD R9, (R12)+{R_BASE + 16 * j}           // r_{j}k everywhere
    MUL.FP32 R6, R9, R5                       // r_{j}k * q_{j}[lane]
    SUB.FP32 R2, R2, R6                       // project
""")
    chunks.append("    STOP\n")
    text = "".join(chunks)
    if pad_hazards:
        text = auto_nop(text, n_threads=256)
    return text


def qrd_asm_loop(pad_hazards: bool = True) -> str:
    """Compact loop variant with residual write-back (true MGS)."""
    text = f"""
    // ---- setup ----
    LOD R1, #4                 // shift constant
    LOD R11, #1
    LOD R13, #0                // j = 0
    TDX R3                     // row (lane)
    TDY R12                    // col (wavefront)
    LSL.INT32 R15, R12, R1
    NOP
    NOP
    ADD.INT32 R15, R15, R3     // tid
    NOP
    NOP
    LOD R2, (R15)+{A_BASE}     // residual element a[row,col]
    INIT 16
mgs_top:
    LSL.INT32 R6, R13, R1      // 16j
    NOP
    NOP
    ADD.INT32 R10, R6, R3      // 16j + lane
    ADD.INT32 R14, R6, R12     // 16j + wave
    NOP
    NOP
    LOD R5, (R10)+{A_BASE}     // residual a_j[lane] everywhere (written back)
    DOT.FP32 R6, R5, R2        // s_k = <a_j, a_k> -> lane0
    STO R6, (R12)+{DOT_BASE} {{w1,dfull}}
    LOD R7, (R13)+{DOT_BASE} {{w1,d1}}      // thread0: s_j = ||a_j||^2
    INVSQR.FP32 R8, R7 {{w1,d1}}
    STO R8, (R0)+{RECIP} {{w1,d1}}          // single-cycle norm writeback
    LOD R8, (R0)+{RECIP}       // recip everywhere
    LOD R9, (R12)+{DOT_BASE}   // s_k everywhere
    MUL.FP32 R4, R5, R8        // q_j[lane] everywhere
    MUL.FP32 R9, R9, R8        // r_jk
    STO R4, (R10)+{Q_BASE} {{w16,d1}}       // Q column j (wave 0 has q too)
    STO R9, (R14)+{R_BASE} {{w1,dfull}}     // R row j
    MUL.FP32 R6, R9, R4        // r_jk * q_j[lane]
    SUB.FP32 R2, R2, R6        // project
    STO R2, (R15)+{A_BASE}     // write residual back for next broadcast
    ADD.INT32 R13, R13, R11    // j++
    LOOP mgs_top
    STOP
"""
    if pad_hazards:
        text = auto_nop(text, n_threads=256)
    return text


def qrd_program(loop: bool = False, **kw) -> Program:
    return assemble(qrd_asm_loop(**kw) if loop else qrd_asm(**kw))


def qrd_kernel(loop: bool = False) -> Kernel:
    """16x16 MGS QRD as a ``Kernel`` (256 threads, 16x16 thread space) for
    multi-program launches; pair with per-block ``qrd_shmem`` images.

    Note the unrolled variant needs ``SMConfig(imem_depth=1024)`` on the
    device; the ``loop=True`` variant fits the default 512-word I-MEM.
    """
    return Kernel(program=qrd_program(loop), block=256, dim_x=16,
                  name="qrd16")


def qrd_shmem(a: np.ndarray, depth: int = 1024) -> np.ndarray:
    if a.shape != (16, 16):
        raise ValueError("the paper's benchmark is a 16x16 matrix")
    img = np.zeros(depth, dtype=np.float32)
    img[A_BASE:A_BASE + 256] = np.asarray(a, np.float32).T.reshape(-1)  # col-major
    return img


def run_qrd(a: np.ndarray, loop: bool = False, **kw):
    """Run the eGPU MGS QRD; returns (Q, R, final_state)."""
    cfg = SMConfig(n_threads=256, dim_x=16, shmem_depth=1024,
                   imem_depth=1024, max_steps=200_000)
    state = run(cfg, qrd_program(loop, **kw), qrd_shmem(a, cfg.shmem_depth))
    mem = np.asarray(shmem_f32(state))
    q = mem[Q_BASE:Q_BASE + 256].reshape(16, 16).T  # col-major -> (i,k)
    r = mem[R_BASE:R_BASE + 256].reshape(16, 16)    # row-major
    return q, r, state


def run_qrd_batch(As: np.ndarray, device: DeviceConfig | None = None,
                  loop: bool = False, backend: str | None = None,
                  schedule: str | None = None,
                  **kw) -> tuple[np.ndarray, np.ndarray, LaunchResult]:
    """Batched 16x16 MGS QRD on the device layer: one matrix per block.

    ``As`` is (batch, 16, 16); each factorization runs in its own block's
    private shared memory, scheduled onto the SMs in waves. Returns
    (Q batch, R batch, LaunchResult).
    """
    As = np.asarray(As)
    batch = int(As.shape[0])
    if device is None:
        device = DeviceConfig(sm=SMConfig(shmem_depth=1024, imem_depth=1024,
                                          max_steps=200_000))
    images = np.stack([qrd_shmem(As[b], device.sm.shmem_depth)
                       for b in range(batch)])
    res = launch(device, qrd_program(loop, **kw), grid=(batch,), block=256,
                 shmem=images, dim_x=16, backend=backend,
                 schedule=schedule)
    mem = np.asarray(res.shmem_f32())
    q = mem[:, Q_BASE:Q_BASE + 256].reshape(batch, 16, 16).transpose(0, 2, 1)
    r = mem[:, R_BASE:R_BASE + 256].reshape(batch, 16, 16)
    return q, r, res
