"""SAXPY on the eGPU: z = alpha*x + y. The 'hello world' program.

Two variants:

``saxpy_asm``/``run_saxpy`` — the single-SM original. Layout: x at [0, n),
y at [n, 2n), z at [2n, 3n); alpha broadcast from shared memory slot 3n
(an FP32 immediate cannot be encoded in 15 bits).

``saxpy_grid_asm``/``launch_saxpy`` — the CUDA-style grid version on the
multi-SM device layer: data lives in GLOBAL memory, each thread computes
``gid = BID*block + TDX`` and processes one element via GLD/GST, and the
grid is scheduled onto the device's SMs in waves. This is the canonical
launch-API demo.
"""
from __future__ import annotations

import numpy as np

from ..assembler import Program, assemble, auto_nop
from ..device import DeviceConfig, Kernel, LaunchResult, launch
from ..executor import run
from ..machine import SMConfig, shmem_f32


def saxpy_asm(n: int) -> str:
    return f"""
    TDX R1
    LOD R4, (R0)+{3 * n}      // alpha (broadcast: every thread, same addr)
    LOD R2, (R1)+0            // x[tid]
    LOD R3, (R1)+{n}          // y[tid]
    NOP
    NOP
    NOP
    MUL.FP32 R5, R2, R4
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    ADD.FP32 R6, R5, R3
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    STO R6, (R1)+{2 * n}
    STOP
"""


def saxpy_program(n: int) -> Program:
    return assemble(saxpy_asm(n))


def run_saxpy(alpha: float, x: np.ndarray, y: np.ndarray):
    n = int(x.shape[0])
    if n % 16 or n > 512:
        raise ValueError("length must be a multiple of 16, <= 512")
    cfg = SMConfig(n_threads=n, dim_x=n, shmem_depth=3 * n + 16,
                   max_steps=10_000)
    img = np.zeros(cfg.shmem_depth, np.float32)
    img[:n] = x
    img[n:2 * n] = y
    img[3 * n] = alpha
    state = run(cfg, saxpy_program(n), img)
    z = np.asarray(shmem_f32(state))[2 * n:3 * n].copy()
    return z, state


# ---------------------------------------------------------------------------
# grid/block version on the device layer
# ---------------------------------------------------------------------------

def saxpy_grid_asm(n: int, block: int) -> str:
    """Grid SAXPY: one element per thread, ``n / block`` thread blocks.

    Global-memory layout (matches ``device.buffer_layout`` for the buffers
    dict built by ``launch_saxpy``): x at [0, n), y at [n, 2n), z at
    [2n, 3n), alpha at 3n. Offsets are GLD/GST immediates, so n <= 5461
    (3n must fit the signed 14-bit immediate).
    """
    text = f"""
    BID R7                    // block index within the launch grid
    TDX R1                    // thread index within the block
    LOD R8, #{block}
    MUL.INT32 R9, R7, R8      // bid * block
    ADD.INT32 R1, R9, R1      // gid
    GLD R4, (R0)+{3 * n}      // alpha (broadcast: every thread, same addr)
    GLD R2, (R1)+0            // x[gid]
    GLD R3, (R1)+{n}          // y[gid]
    MUL.FP32 R5, R2, R4
    ADD.FP32 R6, R5, R3
    GST R6, (R1)+{2 * n}      // z[gid]
    STOP
"""
    return auto_nop(text, n_threads=block)


def saxpy_grid_program(n: int, block: int) -> Program:
    return assemble(saxpy_grid_asm(n, block))


def saxpy_kernel(n: int, block: int = 512) -> Kernel:
    """Grid SAXPY as a ``Kernel`` for multi-program launches."""
    block = min(block, n)
    return Kernel(program=saxpy_grid_program(n, block), block=block,
                  name=f"saxpy{n}")


def launch_saxpy(alpha: float, x: np.ndarray, y: np.ndarray,
                 device: DeviceConfig | None = None,
                 block: int = 512, backend: str | None = None,
                 schedule: str | None = None
                 ) -> tuple[np.ndarray, LaunchResult]:
    """z = alpha*x + y over a launch grid; any n that is a multiple of 16.

    Blocks beyond ``device.n_sms`` queue and run in subsequent waves.
    """
    n = int(x.shape[0])
    if n % 16:
        raise ValueError("length must be a multiple of 16")
    block = min(block, n)
    if n % block:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    if 3 * n >= 1 << 14:
        raise ValueError(f"n={n} too large for immediate addressing")
    if device is None:
        device = DeviceConfig(global_mem_depth=max(3 * n + 16, 64),
                              sm=SMConfig(max_steps=10_000))
    buffers = {
        "x": np.asarray(x, np.float32),
        "y": np.asarray(y, np.float32),
        "z": np.zeros(n, np.float32),
        "alpha": np.asarray([alpha], np.float32),
    }
    res = launch(device, saxpy_grid_program(n, block),
                 grid=(n // block,), block=block, buffers=buffers,
                 backend=backend, schedule=schedule)
    z = np.asarray(res.buffer("z")).copy()
    return z, res
