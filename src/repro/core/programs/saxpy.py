"""SAXPY on the eGPU: z = alpha*x + y. The 'hello world' program.

Layout: x at [0, n), y at [n, 2n), z at [2n, 3n); alpha broadcast from
shared memory slot 3n (an FP32 immediate cannot be encoded in 15 bits).
"""
from __future__ import annotations

import numpy as np

from ..assembler import Program, assemble
from ..executor import run
from ..machine import SMConfig, shmem_f32


def saxpy_asm(n: int) -> str:
    return f"""
    TDX R1
    LOD R4, (R0)+{3 * n}      // alpha (broadcast: every thread, same addr)
    LOD R2, (R1)+0            // x[tid]
    LOD R3, (R1)+{n}          // y[tid]
    NOP
    NOP
    NOP
    MUL.FP32 R5, R2, R4
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    ADD.FP32 R6, R5, R3
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    STO R6, (R1)+{2 * n}
    STOP
"""


def saxpy_program(n: int) -> Program:
    return assemble(saxpy_asm(n))


def run_saxpy(alpha: float, x: np.ndarray, y: np.ndarray):
    n = int(x.shape[0])
    if n % 16 or n > 512:
        raise ValueError("length must be a multiple of 16, <= 512")
    cfg = SMConfig(n_threads=n, dim_x=n, shmem_depth=3 * n + 16,
                   max_steps=10_000)
    img = np.zeros(cfg.shmem_depth, np.float32)
    img[:n] = x
    img[n:2 * n] = y
    img[3 * n] = alpha
    state = run(cfg, saxpy_program(n), img)
    z = np.asarray(shmem_f32(state))[2 * n:3 * n].copy()
    return z, state
