"""eGPU assembly programs: the paper's benchmarks + extras.

Each module also exposes a ``*_kernel`` helper packaging the program as a
``device.Kernel`` for multi-program launches; ``mixed.launch_fft_qrd`` is
the canonical heterogeneous demo and ``reduction.launch_reduction``'s
``fused=True`` form shows dependent kernels (barrier) in one launch.
"""
from .cholesky import (
    cholesky_asm,
    cholesky_kernel,
    cholesky_shmem,
    run_cholesky,
    run_cholesky_batch,
)
from .fft import bitrev_indices, fft_asm, fft_kernel, fft_shmem, run_fft
from .masked_reduction import launch_masked_reduction, masked_reduction_asm
from .mixed import launch_fft_qrd, mixed_device
from .qrd import qrd_asm, qrd_kernel, qrd_shmem, run_qrd
from .reduction import launch_reduction, reduction_asm, run_reduction
from .saxpy import launch_saxpy, run_saxpy, saxpy_asm, saxpy_kernel

__all__ = [
    "bitrev_indices", "fft_asm", "fft_kernel", "fft_shmem", "run_fft",
    "cholesky_asm", "cholesky_kernel", "cholesky_shmem", "run_cholesky",
    "run_cholesky_batch",
    "launch_fft_qrd", "mixed_device",
    "launch_masked_reduction", "masked_reduction_asm",
    "qrd_asm", "qrd_kernel", "qrd_shmem", "run_qrd",
    "launch_reduction", "reduction_asm", "run_reduction",
    "launch_saxpy", "saxpy_asm", "saxpy_kernel", "run_saxpy",
]
