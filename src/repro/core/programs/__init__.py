"""eGPU assembly programs: the paper's benchmarks + extras."""
from .fft import bitrev_indices, fft_asm, fft_shmem, run_fft
from .qrd import qrd_asm, qrd_shmem, run_qrd
from .reduction import reduction_asm, run_reduction
from .saxpy import run_saxpy, saxpy_asm

__all__ = [
    "bitrev_indices", "fft_asm", "fft_shmem", "run_fft",
    "qrd_asm", "qrd_shmem", "run_qrd",
    "reduction_asm", "run_reduction",
    "saxpy_asm", "run_saxpy",
]
