"""16x16 predicated Cholesky factorization + triangular solve.

The paper's motivating domain is small dense linear algebra for MIMO
receivers — least-squares solves over normal equations ``A = G^H G``.
Unlike QRD/FFT, a pivoted Cholesky is *branchy*: each step divides by the
current diagonal pivot, and a semi-definite input (rank-deficient
normal-equations matrix) must SKIP the column instead of dividing by
zero. On the eGPU that data-dependent decision cannot steer the scalar
sequencer (the instruction stream is static); it runs as SIMT
*predication* instead:

  * ``SETP.GT.FP32 {w1,d1}`` tests the pivot on thread 0, and the SFU
    reciprocal-sqrt runs under that guard (``@Rp INVSQR``) over a zeroed
    default — a skipped pivot yields ``inv = 0`` and the whole column
    folds to zero through ordinary arithmetic;
  * ``SETP.GE.INT32`` builds the *triangular* lane mask ``row >= j``, and
    the L-column writebacks are masked stores (``@Rp STO {w16,d1}``) —
    lanes above the diagonal never touch shared memory, which is what
    keeps L exactly lower-triangular without a second pass.

Thread mapping mirrors the QRD benchmark: 256 threads, thread t holds
residual element ``A[row, col]`` (row = t % 16 = lane, col = t // 16 =
wavefront) in R2 for the whole factorization. Per unrolled iteration j
(right-looking outer-product form):

  1. wave 0 snoops residual column j out of wavefront j's registers;
  2. the raw column is mask-stored to L (lanes >= j), landing the pivot
     ``d = A[j,j]`` where thread 0 can read it back;
  3. thread 0: ``inv = d > 0 ? 1/sqrt(d) : 0`` (predicated SFU), recorded
     to the recip table with the paper's single-cycle ``STO {w1,d1}``;
  4. wave 0 scales the column and mask-stores L[:,j] = a_j * inv;
  5. every thread rank-1-updates its residual:
     ``A[i,k] -= L[i,j] * L[k,j]`` (two shared-memory broadcasts, one
     indexed by lane, one by wavefront). Skipped columns make this a
     no-op, so the residual of a PSD input is left intact for inspection.

The optional solve stage forward-substitutes ``L y = b`` (the first
triangular solve of an LS solve; the back-substitution has the same
shape) reusing the recip table: ``y_j = b_res[j] * inv_j`` — a skipped
pivot contributes ``y_j = 0``, the minimum-norm convention.

Shared-memory layout:
    [0   .. 256)   A, column-major (A[i,k] at 16k+i)
    [256 .. 512)   L, column-major (zero-initialized; masked stores keep
                   the strict upper triangle zero)
    [512 .. 528)   b / residual b (solve stage)
    [528 .. 544)   y (solve stage)
    [544 .. 560)   recip table: inv_j = d_j > 0 ? 1/sqrt(d_j) : 0
"""
from __future__ import annotations

import numpy as np

from ..assembler import Program, assemble, auto_nop
from ..device import DeviceConfig, Kernel, LaunchResult, launch
from ..executor import run
from ..machine import SMConfig, shmem_f32

A_BASE, L_BASE, B_BASE, Y_BASE, RECIPS = 0, 256, 512, 528, 544


def cholesky_asm(solve: bool = True, pad_hazards: bool = True) -> str:
    """Unrolled predicated Cholesky (+ forward substitution)."""
    chunks = [f"""
    // ---- setup: R3=lane(row), R12=wave(col), R15=tid, R2=A element ----
    LOD R1, #4
    TDX R3
    TDY R12
    LSL.INT32 R15, R12, R1
    NOP
    NOP
    ADD.INT32 R15, R15, R3
    NOP
    NOP
    LOD R2, (R15)+{A_BASE}
"""]
    for j in range(16):
        col = L_BASE + 16 * j
        chunks.append(f"""
    // ======== Cholesky iteration j={j} ========
    LOD R13, #{j}
    ADD.FP32 R5, R2@{j}, R0@{j} {{d1}}      // wave 0: residual col {j}
    SETP.GE.INT32 R11, R3, R13              // triangular mask: row >= {j}
    @R11 STO R5, (R3)+{col} {{w16,d1}}      // stage col (masked: upper tri
                                            // lanes write NOTHING)
    LOD R6, (R0)+{col + j} {{w1,d1}}        // thread 0: pivot d = A[{j},{j}]
    LOD.FP32 R8, #0 {{w1,d1}}               // default inv = 0 (skip case)
    SETP.GT.FP32 R10, R6, R0 {{w1,d1}}      // pivot guard: d > 0 ?
    @R10 INVSQR.FP32 R8, R6 {{w1,d1}}       // predicated SFU
    STO R8, (R0)+{RECIPS + j} {{w1,d1}}     // single-cycle recip writeback
    LOD R8, (R0)+{RECIPS + j} {{w16,d1}}    // recip -> wave 0 lanes
    MUL.FP32 R5, R5, R8 {{d1}}              // L column {j} in wave 0
    @R11 STO R5, (R3)+{col} {{w16,d1}}      // masked L writeback
    LOD R5, (R3)+{col}                      // L[lane,{j}] everywhere
    LOD R9, (R12)+{col}                     // L[wave,{j}] everywhere
    MUL.FP32 R9, R9, R5                     // L[i,{j}] * L[k,{j}]
    SUB.FP32 R2, R2, R9                     // rank-1 residual update
""")
    if solve:
        for j in range(16):
            col = L_BASE + 16 * j
            chunks.append(f"""
    // ---- forward substitution step j={j}: y_{j} = b_res[{j}] * inv_{j} ----
    LOD R6, (R0)+{B_BASE + j} {{w1,d1}}
    LOD R8, (R0)+{RECIPS + j} {{w1,d1}}
    MUL.FP32 R6, R6, R8 {{w1,d1}}           // skipped pivot -> y_{j} = 0
    STO R6, (R0)+{Y_BASE + j} {{w1,d1}}
    LOD R7, (R0)+{Y_BASE + j} {{w16,d1}}    // broadcast y_{j} to wave 0
    LOD R5, (R3)+{col} {{w16,d1}}           // L[lane,{j}]
    MUL.FP32 R5, R5, R7 {{w16,d1}}
    LOD R9, (R3)+{B_BASE} {{w16,d1}}
    SUB.FP32 R9, R9, R5 {{w16,d1}}
    STO R9, (R3)+{B_BASE} {{w16,d1}}        // b_res -= L[:,{j}] * y_{j}
""")
    chunks.append("    STOP\n")
    text = "".join(chunks)
    if pad_hazards:
        text = auto_nop(text, n_threads=256)
    return text


def cholesky_program(solve: bool = True, **kw) -> Program:
    return assemble(cholesky_asm(solve, **kw))


def cholesky_imem_depth(solve: bool = True) -> int:
    """I-MEM depth the unrolled program needs: the factor stage fits the
    QRD-class 1024-word I-MEM (2 M20K); the solve stage's serial
    single-thread substitution chain NOP-pads past it (4 M20K)."""
    return 2048 if solve else 1024


def cholesky_kernel(solve: bool = True) -> Kernel:
    """Predicated Cholesky as a ``Kernel`` (256 threads, 16x16 thread
    space). Needs ``SMConfig(imem_depth=cholesky_imem_depth(solve),
    shmem_depth=1024)``."""
    return Kernel(program=cholesky_program(solve), block=256, dim_x=16,
                  name="cholesky16")


def cholesky_shmem(a: np.ndarray, b: np.ndarray | None = None,
                   depth: int = 1024) -> np.ndarray:
    if a.shape != (16, 16):
        raise ValueError("the kernel factors a 16x16 matrix")
    img = np.zeros(depth, dtype=np.float32)
    img[A_BASE:A_BASE + 256] = np.asarray(a, np.float32).T.reshape(-1)
    if b is not None:
        img[B_BASE:B_BASE + 16] = np.asarray(b, np.float32).reshape(16)
    return img


def _unpack(mem: np.ndarray):
    el = mem[L_BASE:L_BASE + 256].reshape(16, 16).T   # col-major -> (i,j)
    y = mem[Y_BASE:Y_BASE + 16]
    return el, y


def run_cholesky(a: np.ndarray, b: np.ndarray | None = None, **kw):
    """Factor ``a`` (and forward-solve ``L y = b``) on one SM.

    Returns (L, y, final_state); ``y`` is zeros when ``b`` is None.
    Positive-definite ``a`` gives ``L @ L.T == a``; a PSD input with an
    exactly-singular leading structure (zero row/column) skips that pivot,
    zeroing the L column and leaving its residual untouched.
    """
    solve = kw.pop("solve", b is not None)
    cfg = SMConfig(n_threads=256, dim_x=16, shmem_depth=1024,
                   imem_depth=cholesky_imem_depth(solve),
                   max_steps=200_000)
    state = run(cfg, cholesky_program(solve=solve, **kw),
                cholesky_shmem(a, b, cfg.shmem_depth))
    el, y = _unpack(np.asarray(shmem_f32(state)))
    return el, y, state


def run_cholesky_batch(As: np.ndarray, bs: np.ndarray | None = None,
                       device: DeviceConfig | None = None,
                       backend: str | None = None,
                       schedule: str | None = None,
                       **kw) -> tuple[np.ndarray, np.ndarray, LaunchResult]:
    """Batched predicated Cholesky/LS on the device layer: one matrix
    (and optional right-hand side) per block. Returns (L batch, y batch,
    LaunchResult)."""
    As = np.asarray(As)
    batch = int(As.shape[0])
    solve = kw.pop("solve", bs is not None)
    if device is None:
        device = DeviceConfig(sm=SMConfig(
            shmem_depth=1024, imem_depth=cholesky_imem_depth(solve),
            max_steps=200_000))
    images = np.stack([
        cholesky_shmem(As[i], None if bs is None else bs[i],
                       device.sm.shmem_depth)
        for i in range(batch)])
    res = launch(device, cholesky_program(solve=solve, **kw),
                 grid=(batch,), block=256, shmem=images, dim_x=16,
                 backend=backend, schedule=schedule)
    mem = np.asarray(res.shmem_f32())
    el = mem[:, L_BASE:L_BASE + 256].reshape(batch, 16, 16) \
        .transpose(0, 2, 1)
    y = mem[:, Y_BASE:Y_BASE + 16]
    return el, y, res
