"""Radix-2 decimation-in-frequency FFT for the eGPU (paper §IV.A).

One butterfly per thread (paper: "we map each butterfly to its own
thread"), so an N-point FFT uses N/2 threads: 16 (one wavefront) for N=32,
128 (eight wavefronts) for N=256.

Shared-memory layout (32-bit words):
    [0 .. 2N)      interleaved complex data (re, im per point)
    [2N .. 3N)     interleaved twiddles W_N^k = exp(-2*pi*i*k/N), k < N/2

Addressing reproduces the paper's listing: per pass with half-span H,
    upper = tid & maskhi        (block bits;   maskhi = ~(H-1))
    pos   = tid & masklo        (in-block pos; masklo =  H-1 )
    a     = pos + (upper << 1)  (first butterfly input index)
    addrA = 2*a                 (interleaved complex)
    addrB = addrA + 2*H
    twid  = pos << (pass+1)     (+ 2N base, via the LOD offset field)
The per-pass NOP in the address chain is the RAW hazard the paper calls
out ("we handle [it] by inserting a NOP"). DIF output is in bit-reversed
order; ``run_fft`` undoes the permutation on the host.

Register map: R0=0, R1=tid, R2=addrA, R3=maskhi, R4=masklo, R5=1,
R9=twiddle shift, R10=2H, R11=addrB, R12=twiddle offset,
R6/R7/R8/R13/R14/R15 data & temps.
"""
from __future__ import annotations

import numpy as np

from ..assembler import Program, assemble
from ..device import DeviceConfig, Kernel, LaunchResult, launch
from ..executor import run
from ..machine import SMConfig, shmem_f32


def _butterfly_block(tw_base: int) -> str:
    return f"""
    // butterfly: u = a+b -> A;  v = (a-b)*W -> B
    LOD R6, (R2)+0            // a_re
    LOD R7, (R2)+1            // a_im
    LOD R13, (R11)+0          // b_re
    LOD R14, (R11)+1          // b_im
    ADD.FP32 R8, R6, R13      // u_re
    SUB.FP32 R6, R6, R13      // t_re
    STO R8, (R2)+0
    ADD.FP32 R8, R7, R14      // u_im
    SUB.FP32 R7, R7, R14      // t_im
    STO R8, (R2)+1
    LOD R13, (R12)+{tw_base}      // w_re
    LOD R14, (R12)+{tw_base + 1}  // w_im
    MUL.FP32 R8, R6, R13      // t_re*w_re
    MUL.FP32 R15, R7, R14     // t_im*w_im
    SUB.FP32 R8, R8, R15      // v_re
    STO R8, (R11)+0
    MUL.FP32 R8, R6, R14      // t_re*w_im
    MUL.FP32 R15, R7, R13     // t_im*w_re
    ADD.FP32 R8, R8, R15      // v_im
    STO R8, (R11)+1
"""


def _addr_block(nops_addr: int) -> str:
    nops = "\n".join(["    NOP"] * nops_addr)
    return f"""
    // per-thread butterfly addressing (paper's listing, generalized)
    AND.INT32 R6, R1, R3      // upper = tid & maskhi
    AND.INT32 R7, R1, R4      // pos   = tid & masklo
    LSL.INT32 R8, R6, R5      // upper << 1
{nops}
    ADD.INT32 R6, R7, R8      // a = pos + (upper<<1)
    NOP                        // the paper's RAW-hazard NOP
    ADD.INT32 R2, R6, R6      // addrA = 2a (interleaved complex)
    LSL.INT32 R12, R7, R9     // twiddle offset = pos << (pass+1)
    ADD.INT32 R11, R2, R10    // addrB = addrA + 2H
"""


def fft_asm(n: int, unroll: bool = False, pad_hazards: bool = True) -> str:
    """Generate eGPU assembly for an n-point radix-2 DIF FFT.

    ``unroll=False``: compact zero-overhead-loop version (~45 words) —
    per-pass constants derived with shifts/XOR.
    ``unroll=True``: the paper's style — eight unrolled passes, per-pass
    constants from immediate loads, the butterfly as a JSR subroutine
    (program size lands at the paper's "135 instructions" scale).
    """
    if n & (n - 1) or n < 4:
        raise ValueError("n must be a power of two >= 4")
    log2n = n.bit_length() - 1
    n_threads = n // 2
    tw_base = 2 * n
    setup = f"""
    // ---- setup ----
    TDX R1                    // tid (one butterfly per thread)
    LOD R3, #0                // maskhi (pass 0: single block)
    LOD R4, #{n // 2 - 1}     // masklo = H-1
    LOD R5, #1
    LOD R9, #1                // twiddle shift = pass+1
    LOD R10, #{n}             // 2H
"""
    body = _addr_block(1) + _butterfly_block(tw_base)
    if not unroll:
        update = """
    // ---- next pass constants ----
    LSR.INT32 R8, R4, R5      // masklo >> 1
    XOR.INT32 R7, R4, R8      // the bit that moved out
    OR.INT32  R3, R3, R7      // maskhi |= bit
    OR.INT32  R4, R8, R0      // masklo = shifted
    ADD.INT32 R9, R9, R5      // twiddle shift += 1
    LSR.INT32 R10, R10, R5    // 2H >>= 1
"""
        text = setup + f"    INIT {log2n}\npass_top:\n" + body + update \
            + "    LOOP pass_top\n    STOP\n"
    else:
        chunks = [setup]
        for p in range(log2n):
            h = n // 2 >> p
            maskhi = (~(h - 1)) & (n // 2 - 1)
            chunks.append(f"""
    // ---- pass {p} (H={h}) ----
    LOD R3, #{maskhi}
    LOD R4, #{h - 1}
    LOD R9, #{p + 1}
    LOD R10, #{2 * h}
""")
            chunks.append(_addr_block(1))
            chunks.append("    JSR butterfly\n")
        chunks.append("    STOP\nbutterfly:\n")
        chunks.append(_butterfly_block(tw_base))
        chunks.append("    RTS\n")
        text = "".join(chunks)
    if pad_hazards:
        from ..assembler import auto_nop

        text = auto_nop(text, n_threads)
    return text


def fft_program(n: int, unroll: bool = False, pad_hazards: bool = True) -> Program:
    return assemble(fft_asm(n, unroll, pad_hazards))


def fft_kernel(n: int, unroll: bool = False) -> Kernel:
    """n-point FFT as a ``Kernel`` (block of n/2 butterfly threads) for
    multi-program launches; pair with per-block ``fft_shmem`` images."""
    return Kernel(program=fft_program(n, unroll), block=n // 2,
                  name=f"fft{n}")


def bitrev_indices(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    out = np.zeros(n, dtype=np.int64)
    for b in range(bits):
        out |= ((idx >> b) & 1) << (bits - 1 - b)
    return out


def fft_shmem(x: np.ndarray, depth: int = 3072) -> np.ndarray:
    """Build the shared-memory image: interleaved data + twiddle table."""
    n = x.shape[0]
    img = np.zeros(depth, dtype=np.float32)
    img[0:2 * n:2] = np.real(x).astype(np.float32)
    img[1:2 * n:2] = np.imag(x).astype(np.float32)
    k = np.arange(n // 2)
    w = np.exp(-2j * np.pi * k / n)
    img[2 * n:3 * n:2] = np.real(w).astype(np.float32)
    img[2 * n + 1:3 * n:2] = np.imag(w).astype(np.float32)
    return img


def run_fft(x: np.ndarray, unroll: bool = False, pad_hazards: bool = True):
    """Run the eGPU FFT; returns (X, final_state)."""
    n = int(x.shape[0])
    n_threads = n // 2
    cfg = SMConfig(n_threads=n_threads, dim_x=n_threads,
                   shmem_depth=max(3 * n, 64), max_steps=200_000)
    prog = fft_program(n, unroll, pad_hazards)
    state = run(cfg, prog, fft_shmem(x, cfg.shmem_depth))
    mem = np.asarray(shmem_f32(state))
    out_br = mem[0:2 * n:2] + 1j * mem[1:2 * n:2]
    out = np.empty(n, dtype=np.complex64)
    out[bitrev_indices(n)] = out_br  # undo DIF bit-reversal
    return out, state


def run_fft_batch(xs: np.ndarray, device: DeviceConfig | None = None,
                  unroll: bool = False, backend: str | None = None,
                  schedule: str | None = None
                  ) -> tuple[np.ndarray, LaunchResult]:
    """Batched FFT on the device layer: one n-point FFT per thread block.

    ``xs`` is (batch, n) complex; each signal becomes one block's private
    shared-memory image and the grid is scheduled onto the device's SMs in
    waves — the §III.E packed-sector deployment (four independent FFTs per
    sector) generalized to any batch. Returns (X batch, LaunchResult).
    """
    xs = np.asarray(xs)
    batch, n = int(xs.shape[0]), int(xs.shape[1])
    n_threads = n // 2
    if device is None:
        device = DeviceConfig(sm=SMConfig(shmem_depth=max(3 * n, 64),
                                          max_steps=200_000))
    prog = fft_program(n, unroll)
    images = np.stack([fft_shmem(xs[b], device.sm.shmem_depth)
                       for b in range(batch)])
    res = launch(device, prog, grid=(batch,), block=n_threads,
                 shmem=images, backend=backend, schedule=schedule)
    mem = np.asarray(res.shmem_f32())
    out_br = mem[:, 0:2 * n:2] + 1j * mem[:, 1:2 * n:2]
    out = np.empty((batch, n), dtype=np.complex64)
    out[:, bitrev_indices(n)] = out_br
    return out, res
