"""Mixed-program launches: independent kernels sharing one device.

The scalable eGPU follow-up (arXiv 2401.04261) motivates dynamic block
dispatch with exactly this deployment: a packed sector serving several
*different* small-DSP workloads at once. ``launch_fft_qrd`` runs a batch
of n-point FFTs and a batch of 16x16 MGS QRDs as ONE launch — the two
programs' blocks interleave in the grid and each SM pulls whichever block
is next the moment it retires its current one, so the short FFT blocks
backfill around the long QRD blocks instead of idling a lockstep wave.

This is the canonical heterogeneous-launch demo: the acceptance test and
the benchmark smoke both drive it, and ``LaunchResult.profile()`` shows
non-zero per-SM occupancy for both programs.

Functionally the launch runs on the trace engine's MERGED heterogeneous
waves (``core.trace_engine.MergedTraceSchedule``): FFT and QRD blocks of
the same wave execute in one scan over the merged pre-decoded schedule,
padded to the longer QRD trace — ``profile()["trace_merge"]`` reports
the padding overhead per wave, and ``benchmarks/engine_bench.py`` gates
the merged path at >= 1.2x the step machine's wall clock on this very
launch.
"""
from __future__ import annotations

import numpy as np

from ..device import DeviceConfig, LaunchResult, launch
from ..machine import SMConfig
from .fft import bitrev_indices, fft_kernel, fft_shmem
from .qrd import Q_BASE, R_BASE, qrd_kernel, qrd_shmem


def mixed_device(n_fft: int, n_sms: int = 4,
                 backend: str | None = None) -> DeviceConfig:
    """A device sized for an FFT-n + QRD-16 mix: shared memory covers both
    layouts, I-MEM the unrolled QRD program."""
    depth = max(3 * n_fft, 1024)
    return DeviceConfig(
        n_sms=n_sms,
        sm=SMConfig(shmem_depth=depth, imem_depth=1024, max_steps=200_000),
        **({"backend": backend} if backend else {}))


def launch_fft_qrd(xs: np.ndarray, As: np.ndarray,
                   device: DeviceConfig | None = None,
                   schedule: str | None = None, backend: str | None = None,
                   interleave: bool = True,
                   priorities: tuple[int, int] | None = None,
                   engine: str | None = None,
                   packing: str | None = None
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                              LaunchResult]:
    """Run ``xs`` (batch_f, n) complex FFTs and ``As`` (batch_q, 16, 16)
    QRDs in one multi-program launch. Returns (X, Q, R, LaunchResult).

    ``interleave=True`` round-robins the two programs' blocks in the
    dispatch order (the imbalanced-grid case dynamic scheduling exists
    for); ``False`` queues all FFT blocks first. ``priorities`` sets the
    (fft, qrd) ``Kernel.priority`` pair for the dynamic dispatch queue —
    e.g. ``(0, 1)`` drains the long QRD blocks first so they don't
    straggle behind a queue of short FFTs. ``engine`` forwards to
    ``launch`` ("step" | "trace" | None for the device default), as does
    ``packing`` ("grid" | "length" | "auto") — ``"length"`` stops the
    merged trace waves padding short FFT schedules to the long QRD one
    wherever the grid shape allows pure waves.
    """
    xs, As = np.asarray(xs), np.asarray(As)
    batch_f, n = int(xs.shape[0]), int(xs.shape[1])
    batch_q = int(As.shape[0])
    if device is None:
        device = mixed_device(n, backend=backend)
    fft_images = np.stack([fft_shmem(xs[b], device.sm.shmem_depth)
                           for b in range(batch_f)])
    qrd_images = np.stack([qrd_shmem(As[b], device.sm.shmem_depth)
                           for b in range(batch_q)])
    if interleave:
        grid_map: list[int] = []
        for i in range(max(batch_f, batch_q)):
            if i < batch_f:
                grid_map.append(0)
            if i < batch_q:
                grid_map.append(1)
    else:
        grid_map = [0] * batch_f + [1] * batch_q
    kernels = [fft_kernel(n), qrd_kernel()]
    if priorities is not None:
        import dataclasses

        kernels = [dataclasses.replace(k, priority=p)
                   for k, p in zip(kernels, priorities)]
    res = launch(device, programs=kernels,
                 grid_map=grid_map, shmem=[fft_images, qrd_images],
                 backend=backend, schedule=schedule, engine=engine,
                 packing=packing)

    # unpack per-program results: blocks are in grid_map order; program-
    # local order is preserved within it
    gmap = np.asarray(res.grid_map)
    mem = np.asarray(res.shmem_f32())
    fmem = mem[gmap == 0]
    out_br = fmem[:, 0:2 * n:2] + 1j * fmem[:, 1:2 * n:2]
    X = np.empty((batch_f, n), dtype=np.complex64)
    X[:, bitrev_indices(n)] = out_br
    qmem = mem[gmap == 1]
    Q = qmem[:, Q_BASE:Q_BASE + 256].reshape(batch_q, 16, 16) \
        .transpose(0, 2, 1)
    R = qmem[:, R_BASE:R_BASE + 256].reshape(batch_q, 16, 16)
    return X, Q, R, res
