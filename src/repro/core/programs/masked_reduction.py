"""Clipped/masked grid reduction: predicated SIMT data selection.

The classic two-level reduction (``programs.reduction``) sums everything
it loads. Real streaming kernels rarely do: they clip outliers and sum
only the lanes matching a data-dependent filter. On the eGPU that filter
cannot branch (the instruction stream is static) — it runs as per-lane
predication:

  * clipping is two ``SETP``/``@P SELP`` pairs
    (``y = x < lo ? lo : x``, then ``y = y > hi ? hi : y``);
  * the filter ``y > t`` is a third ``SETP``, ANDed (predicates are
    ordinary 0/1 registers, so the combine is a plain bitwise ``AND``)
    with a ``gid < n`` validity predicate that masks the zero-padded
    grid tail;
  * the wavefront reduction itself runs under the guard
    (``@P SUM.FP32``): masked-off lanes contribute nothing, and a
    wavefront with no enabled lane leaves its partial at zero — no
    select-then-sum round trip;
  * the matching element count rides the same mask: ``@P SUM.FP32``
    over a register pinned to 1.0f.

Stage 1 blocks fold their chunk to a (sum, count) partial pair and
commit both with single-cycle ``GST {w1,d1}`` stores; the partial
arrays are laid out back-to-back, so stage 2 is the STOCK
``reduction.reduction_grid_asm`` program on a 2-block grid — block 0
folds the sums, block 1 the counts (``gid = BID * n2 + TDX`` walks
straight from one array into the next).

``launch_masked_reduction(x, threshold, clip=(lo, hi))`` returns
``(sum, count, LaunchResult)`` where
``sum = Σ { clip(x_i) : clip(x_i) > threshold }``.
"""
from __future__ import annotations

import numpy as np

from ..assembler import Program, assemble, auto_nop
from ..device import DeviceConfig, Kernel, LaunchResult, launch
from .reduction import reduction_grid_asm


def masked_reduction_asm(n_threads: int, src_base: int, par_base: int,
                         prm_base: int, meta_base: int, n2: int) -> str:
    """One stage-1 block: clip + filter + masked fold of its chunk.

    Loads ``x[gid]`` from ``src_base`` (``gid = BID*n_threads + TDX``),
    the fp32 params ``[t, lo, hi]`` from ``prm_base`` and the int32 valid
    length ``n`` from ``meta_base``, and GSTs the block's (sum, count)
    partials to ``par_base + BID`` / ``par_base + n2 + BID``.
    """
    n_waves = max(1, n_threads // 16)
    lines = [
        "    BID R10",
        "    TDX R1",
        f"    LOD R11, #{n_threads}",
        "    MUL.INT32 R12, R10, R11",
        "    ADD.INT32 R1, R12, R1            // gid",
        f"    GLD R2, (R1)+{src_base}          // x[gid]",
        f"    GLD R13, (R0)+{prm_base}         // t  (one address, all lanes)",
        f"    GLD R14, (R0)+{prm_base + 1}     // lo",
        f"    GLD R15, (R0)+{prm_base + 2}     // hi",
        f"    GLD R7, (R0)+{meta_base}         // n (valid length)",
        "    // ---- clip: y = min(max(x, lo), hi) via predicated selects ----",
        "    SETP.LT.FP32 R4, R2, R14",
        "    @R4 SELP R2, R14, R2             // y = x < lo ? lo : x",
        "    SETP.GT.FP32 R4, R2, R15",
        "    @R4 SELP R2, R15, R2             // y = y > hi ? hi : y",
        "    // ---- filter mask: (y > t) AND (gid < n) ----",
        "    SETP.GT.FP32 R4, R2, R13",
        "    SETP.LT.INT32 R6, R1, R7",
        "    AND R4, R4, R6                   // predicates are 0/1 registers",
        "    LOD.FP32 R5, #1                  // 1.0f per lane (count unit)",
        "    @R4 SUM.FP32 R3, R2, R0          // masked sum -> lane 0",
        # the destinations (R3, R9) are never written before the SUM, so
        # a fully-masked wavefront KEEPS its zero lane-0 partial — summing
        # into the 1.0f-pinned unit register would leak 1.0 per empty wave
        "    @R4 SUM.FP32 R9, R5, R0          // masked count -> lane 0",
    ]

    def fold(src: int, accs: list[int]) -> int:
        """Snooping fold of per-wavefront lane-0 partials in R``src``."""
        n_chains = min(len(accs), max(1, n_waves // 2))
        for c in range(n_chains):
            w0 = 2 * c
            if 2 * c + 1 < n_waves:
                lines.append(f"    ADD.FP32 R{accs[c]}, R{src}@{w0}, "
                             f"R{src}@{2 * c + 1} {{d1}}")
            else:
                lines.append(f"    ADD.FP32 R{accs[c]}, R{src}@{w0}, "
                             f"R0@{w0} {{d1}}")
        for w in range(2 * n_chains, n_waves):
            c = w % n_chains
            lines.append(f"    ADD.FP32 R{accs[c]}, R{accs[c]}, "
                         f"R{src}@{w} {{d1}}")
        live = accs[:n_chains]
        while len(live) > 1:
            nxt = []
            for i in range(0, len(live) - 1, 2):
                lines.append(f"    ADD.FP32 R{live[i]}, R{live[i]}, "
                             f"R{live[i + 1]} {{w1,d1}}")
                nxt.append(live[i])
            if len(live) % 2:
                nxt.append(live[-1])
            live = nxt
        return live[0]

    # R3 (sums) folds into R6/R7 chains, R9 (counts) into R8/R11 (the
    # n_threads constant is dead by now); the two folds interleave to
    # hide each other's RAW windows
    s = fold(3, [6, 7])
    c = fold(9, [8, 11])
    lines.append(f"    GST R{s}, (R10)+{par_base} {{w1,d1}}       // sum partial")
    lines.append(f"    GST R{c}, (R10)+{par_base + n2} {{w1,d1}}  // count partial")
    lines.append("    STOP")
    return auto_nop("\n".join(lines), n_threads)


def masked_reduction_program(n_threads: int, src_base: int, par_base: int,
                             prm_base: int, meta_base: int, n2: int
                             ) -> Program:
    return assemble(masked_reduction_asm(n_threads, src_base, par_base,
                                         prm_base, meta_base, n2))


def launch_masked_reduction(x: np.ndarray, threshold: float,
                            clip: tuple[float, float] = (-np.inf, np.inf),
                            device: DeviceConfig | None = None,
                            block: int = 256, backend: str | None = None,
                            schedule: str | None = None
                            ) -> tuple[float, int, LaunchResult]:
    """Sum-and-count the clipped elements of ``x`` above ``threshold``.

    One fused launch: a grid of stage-1 blocks (predicated clip + filter
    + masked fold) and one barrier-fenced stage-2 2-block grid reusing
    the stock reduction fold. Returns (sum, count, LaunchResult).
    """
    from ..device import buffer_layout
    from ..machine import SMConfig

    x = np.asarray(x, np.float32).reshape(-1)
    n = x.shape[0]
    block = min(block, max(16, -(-n // 16) * 16))
    n_blocks = max(1, -(-n // block))
    n2 = -(-n_blocks // 16) * 16         # stage-2 block (and array stride)
    x_pad = np.zeros(n_blocks * block, np.float32)
    x_pad[:n] = x
    lo, hi = float(clip[0]), float(clip[1])
    buffers = {
        "x": x_pad,
        "params": np.array([threshold, lo, hi], np.float32),
        "meta": np.array([n], np.int32),
        "partials": np.zeros(2 * n2, np.float32),
        "result": np.zeros(16, np.float32),
    }
    layout = buffer_layout(buffers)
    if layout["result"][0] + layout["result"][1] >= 1 << 14:
        raise ValueError(f"n={n} too large for immediate addressing")
    src, prm, meta, par, res_off = (
        layout[k][0] for k in ("x", "params", "meta", "partials", "result"))
    if device is None:
        depth = layout["result"][0] + layout["result"][1]
        device = DeviceConfig(global_mem_depth=max(depth, 64),
                              sm=SMConfig(max_steps=50_000))
    stage1 = masked_reduction_program(block, src, par, prm, meta, n2)
    # stage 2: the STOCK fold on a 2-block grid — BID 0 walks the sum
    # partials, BID 1 the count partials (gid = BID*n2 + TDX)
    stage2 = assemble(reduction_grid_asm(n2, par, res_off, True))
    res = launch(
        device,
        programs=[Kernel(stage1, block=block, name="masked.stage1"),
                  Kernel(stage2, block=n2, name="masked.stage2",
                         barrier=True)],
        grid_map=[0] * n_blocks + [1, 1], buffers=buffers,
        backend=backend, schedule=schedule)
    out = np.asarray(res.buffer("result"))
    return float(out[0]), int(round(float(out[1]))), res
