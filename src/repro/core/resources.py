"""Analytical resource / Fmax model (paper Tables I & V, §III.E, §V).

There is no RTL here — a JAX program has no Fmax. This module encodes the
paper's published block inventory and the sector-packing arithmetic so the
benchmarks can *reproduce the paper's numbers* and so configuration
variants (shared-memory depth, optional dot/SFU units, SM count) get a
first-order resource estimate by the same method the paper uses.

Paper ground truth (Agilex AGFB014R24A1E1V, Quartus 22.4.0 Pro):

  Table V                 ALM   Registers  DSP   M20K
    Instruction section    235      540      0     2
    SM (1x16SP)           5372    14996     24    48
    SP                     267      794    1.5     2
    INT ALU                114      249    0.5     0

  Table I: eGPU = 5K ALM / 24 DSP / 771 MHz  (FGPU 57K/48/250,
           FlexGrip 100K/300/100)

  §V: 771 MHz unconstrained (DSP FP32 MAC critical path), 831 MHz soft
      logic alone, 738 MHz (~5% penalty) for the quad-packed sector.

  §III.E sector: 16,400 ALMs / 164 DSP / 237 M20K; 4 SMs per sector =>
      96 DSP + 128 M20K used by SMs, 27 shared-memory M20Ks per eGPU
      (quad-read-port => 4 copies => 6-deep of 512 = 3K words = 12 KiB),
      16 DSP per eGPU for the dot-product unit, 4100 ALM budget per eGPU.
"""
from __future__ import annotations

import dataclasses

from .machine import N_SP, SMConfig

# ---- process/device constants (Agilex, paper §V) ----------------------------
FMAX_DSP_FP32_MHZ = 771.0     # DSP Block FP32 multiply-add mode limit
FMAX_SOFT_LOGIC_MHZ = 831.0   # INT ALU & control, best achieved
QUAD_PACK_DERATE = 0.957      # 771 -> 738 MHz observed (~5%)

# ---- Agilex sector contents (§III.E, [22]) ----------------------------------
SECTOR_ALMS = 16_400
SECTOR_DSPS = 164
SECTOR_M20KS = 237
M20K_BITS = 20 * 1024
M20K_WORDS_32B = 512          # 512 x 32b (or 512 x 40b for I-MEM)

# ---- per-block inventory (Table V) ------------------------------------------
SP_ALM = 267
SP_REGS = 794
SP_DSP = 1.5                  # 1 DSP for FP MAC + half for the INT 16x16 mul
SP_M20K = 2                   # register file: 512x32 as 2R1W needs 2 copies
INT_ALU_ALM = 114
INT_ALU_REGS = 249
INT_ALU_DSP = 0.5
INSTR_ALM = 235
INSTR_REGS = 540
INSTR_M20K = 2                # I-MEM (parameterizable; 2 x 512x40 default)
SM_ALM = 5372                 # measured whole-SM numbers (> 16*SP: includes
SM_REGS = 14996               # sequencer, shared-memory interconnect, etc.)
SM_DSP = 24                   # 16 FP + 8 (16 x 0.5) INT
SM_M20K = 48                  # 32 regfile + 16 (shared memory + I-MEM)
DOT_UNIT_DSP = 16             # §III.E: dot-product core per eGPU


@dataclasses.dataclass(frozen=True)
class ResourceReport:
    alms: float
    registers: float
    dsps: float
    m20ks: float

    def __add__(self, o: "ResourceReport") -> "ResourceReport":
        return ResourceReport(self.alms + o.alms, self.registers + o.registers,
                              self.dsps + o.dsps, self.m20ks + o.m20ks)

    def scale(self, k: float) -> "ResourceReport":
        return ResourceReport(self.alms * k, self.registers * k,
                              self.dsps * k, self.m20ks * k)


def sp_report() -> ResourceReport:
    return ResourceReport(SP_ALM, SP_REGS, SP_DSP, SP_M20K)


def int_alu_report() -> ResourceReport:
    return ResourceReport(INT_ALU_ALM, INT_ALU_REGS, INT_ALU_DSP, 0)


def instruction_report(imem_m20ks: int = INSTR_M20K) -> ResourceReport:
    return ResourceReport(INSTR_ALM, INSTR_REGS, 0, imem_m20ks)


def shared_memory_m20ks(depth_words: int) -> int:
    """Quad-read-port shared memory = 4 identical copies (paper §III.A)."""
    per_copy = -(-depth_words // M20K_WORDS_32B)  # ceil
    return 4 * per_copy


def sm_report(cfg: SMConfig | None = None) -> ResourceReport:
    """Whole-SM resources. With the default config this returns the paper's
    measured Table V row; config variants get a first-order estimate built
    from the block inventory."""
    if cfg is None:
        cfg = SMConfig()
    base = ResourceReport(SM_ALM, SM_REGS, SM_DSP, 0)
    m20k = 2 * N_SP                                  # register files
    m20k += shared_memory_m20ks(cfg.shmem_depth)     # 3072 words -> 24... see note
    m20k += -(-cfg.imem_depth // M20K_WORDS_32B)     # I-MEM (per 512x40)
    dsp = base.dsps + (DOT_UNIT_DSP if cfg.with_dot else 0)
    # Table V's 48 M20K = 32 regfile + 14 shared (1.75K words quad-ported)
    # + 2 I-MEM; the *benchmarked* single-SM build used a shallower shared
    # memory than the §III.E sector budget. We report the configured value.
    return ResourceReport(base.alms, base.registers, dsp, m20k)


def table_v() -> dict[str, ResourceReport]:
    """The paper's measured Table V, verbatim (oracle for tests)."""
    return {
        "Instruction": ResourceReport(INSTR_ALM, INSTR_REGS, 0, 2),
        "SM": ResourceReport(SM_ALM, SM_REGS, SM_DSP, SM_M20K),
        "SP": ResourceReport(SP_ALM, SP_REGS, SP_DSP, SP_M20K),
        "INT ALU": ResourceReport(INT_ALU_ALM, INT_ALU_REGS, INT_ALU_DSP, 0),
    }


def table_i() -> dict[str, dict]:
    """Table I comparison (eGPU row derived from our model: the base
    1SMx16SP build — no dot-product extension, as benchmarked in §V)."""
    return {
        "FGPU":     {"config": "2CUx8PE",  "alm": 57_000, "dsp": 48,  "fmax_mhz": 250},
        "FlexGrip": {"config": "1SMx16PE", "alm": 100_000, "dsp": 300, "fmax_mhz": 100},
        "eGPU":     {"config": "1SMx16SP", "alm": SM_ALM, "dsp": SM_DSP,
                     "fmax_mhz": round(fmax_mhz(n_instances=1))},
    }


def fmax_mhz(n_instances: int = 1, use_dsp_fp32: bool = True) -> float:
    """Fmax model: DSP FP32 mode limits an unconstrained single-core compile
    to 771 MHz; soft logic alone reaches 831; quad-sector packing costs ~5%."""
    base = FMAX_DSP_FP32_MHZ if use_dsp_fp32 else FMAX_SOFT_LOGIC_MHZ
    return base if n_instances <= 1 else base * QUAD_PACK_DERATE


@dataclasses.dataclass(frozen=True)
class SectorPacking:
    """§III.E packing arithmetic for N SMs in one Agilex sector."""

    sms_per_sector: int
    regfile_m20ks: int
    dsps_for_sms: int
    m20ks_left: int
    shared_copies_per_egpu: int     # 512x32 memories per eGPU (quad-ported)
    shared_depth_words: int
    shared_bytes: int
    dsps_left: int
    dot_dsps_per_egpu: int
    alm_budget_per_egpu: int


def pack_sector(sms: int = 4) -> SectorPacking:
    regfile = 2 * N_SP * sms                   # 128 for 4 SMs
    dsp_sm = SM_DSP * sms                      # 96
    m20k_left = SECTOR_M20KS - regfile         # 109
    shared_copies = m20k_left // sms           # 27 per eGPU
    # quad read port => 4 copies; depth = (copies // 4) * 512 words
    depth = (shared_copies // 4) * M20K_WORDS_32B   # 6 deep -> 3072 words
    dsp_left = SECTOR_DSPS - dsp_sm            # 68
    # a dot-product core needs one DSP per lane: 16 (17 remain per eGPU)
    dot = min(DOT_UNIT_DSP, dsp_left // sms)
    return SectorPacking(
        sms_per_sector=sms,
        regfile_m20ks=regfile,
        dsps_for_sms=dsp_sm,
        m20ks_left=m20k_left,
        shared_copies_per_egpu=shared_copies,
        shared_depth_words=depth,
        shared_bytes=depth * 4,
        dsps_left=dsp_left,
        dot_dsps_per_egpu=dot,
        alm_budget_per_egpu=SECTOR_ALMS // sms,  # 4100
    )


def peak_gflops(n_sms: int = 1, fmax: float | None = None,
                with_dot: bool = True) -> float:
    """Peak FP32 throughput of the modelled machine (for the benchmark
    efficiency numbers): 16 SP MACs (2 flops) + optionally the dot unit's
    16 mul + 15 add per cycle."""
    f = (fmax if fmax is not None else fmax_mhz(n_sms)) * 1e6
    flops_per_cycle = N_SP * 2 + (31 if with_dot else 0)
    return n_sms * flops_per_cycle * f / 1e9
