"""eGPU ISA: 40-bit I-word encoding (paper Fig. 3, Table II).

Bit layout (paper numbers bits [40:1]; we use 0-indexed positions [39:0]):

    [39:38] WIDTH    wavefront width:  0=full(16) 1=half(8) 2=quarter(4) 3=single(1)
    [37:36] DEPTH    block depth:      0=full     1=half    2=quarter    3=single wavefront
    [35:30] OPCODE   6 bits (64 possible; 23 implemented + NOP)
    [29:28] TYPE     0=INT32 1=UINT32 2=FP32
    [27:24] RD       destination register
    [23:20] RA       source register A (or address register for LOD/STO)
    [19:16] RB       source register B
    [15]    X        thread-snooping enable
    [14:0]  IMM      15-bit immediate (sign-extended), or when X=1 the two
                     5-bit register-address extensions: [14:10]=EXT_A, [9:5]=EXT_B

The WIDTH/DEPTH pair is the paper's "Variable" field ([40:37]): the flexible
ISA that resizes the thread block per instruction with no flush.

Predication extension (SIMT divergence): the architectural 40-bit word is
full, so the per-instruction predicate rides in an *extension byte* above
bit 40 (the same move the device extension made in opcode space for
GLD/GST/BID/PID):

    [45]    PNEG     predicate negate: guard on !P instead of P
    [44]    PEN      predicate enable (0 = legacy word, unconditional)
    [43:40] PREG     predicate register (a general register; LSB is the
                     predicate value, SETP writes exactly 0/1)

A lane executes a predicated instruction only when its effective mask —
flexible-ISA active shape AND (``regs[preg] & 1) ^ pneg`` — is set: masked
lanes write no register/shmem/gmem state and masked gmem lanes generate no
global-port traffic. Legacy encodings have zeros above bit 40, so PEN=0 and
every pre-existing program is bit-for-bit unchanged. Control-flow ops
(JMP/JSR/RTS/LOOP/INIT/STOP/NOP) cannot be predicated: the sequencer is
scalar and the issued instruction stream must stay static (that staticness
is what keeps every cycle count in this repo exact).
"""
from __future__ import annotations

import dataclasses
import enum

WORD_BITS = 40

# ---- field positions (lsb, nbits) ------------------------------------------
F_IMM = (0, 15)
F_X = (15, 1)
F_RB = (16, 4)
F_RA = (20, 4)
F_RD = (24, 4)
F_TYPE = (28, 2)
F_OPCODE = (30, 6)
F_DEPTH = (36, 2)
F_WIDTH = (38, 2)

# snoop sub-fields inside IMM
F_EXT_A = (10, 5)  # within the 40-bit word: bits [14:10]
F_EXT_B = (5, 5)   # bits [9:5]

# predication extension byte, above the architectural 40-bit word
F_PREG = (40, 4)
F_PEN = (44, 1)
F_PNEG = (45, 1)


class Op(enum.IntEnum):
    """Opcodes. 23 architectural instructions (Table II) + NOP, plus the
    multi-SM device extension (GLD/GST/BID): a global-memory segment shared
    by every SM in a packed sector, and the block index for CUDA-style
    grid/block addressing (the multi-eGPU packing of §III.E / the scalable
    follow-up paper)."""

    NOP = 0
    # Arithmetic (typed: INT32 / UINT32 / FP32)
    ADD = 1
    SUB = 2
    MUL = 3
    # Logic
    AND = 4
    OR = 5
    XOR = 6
    NOT = 7
    LSL = 8
    LSR = 9
    # Memory (shared)
    LOD = 10   # LOD Rd (Ra)+offset
    STO = 11   # STO Rd (Ra)+offset
    # Immediate
    LODI = 12  # LOD Rd #Imm
    # Thread
    TDX = 13
    TDY = 14
    # Extension units
    DOT = 15     # wavefront dot product -> lane 0 of each active wavefront
    SUM = 16     # wavefront reduction of (Ra + Rb) -> lane 0
    INVSQR = 17  # SFU: 1/sqrt, lane 0 of wavefront 0
    # Control
    JMP = 18
    JSR = 19
    RTS = 20
    LOOP = 21
    INIT = 22
    STOP = 23
    # Multi-SM device extension (not in the single-SM paper ISA)
    GLD = 24   # GLD Rd (Ra)+offset — global-memory load (shared across SMs)
    GST = 25   # GST Rd (Ra)+offset — global-memory store
    BID = 26   # BID Rd — thread-block index within the program's grid
    PID = 27   # PID Rd — program index within a multi-program launch
    # Predication extension (SIMT divergence; no data-dependent *control*
    # flow — divergence is per-lane masking, the instruction stream is
    # still static)
    SETP = 28  # SETP.cond.typ Rd, Ra, Rb — per-lane compare -> 0/1 in Rd
    SELP = 29  # SELP Rd, Ra, Rb — Rd = pred ? Ra : Rb (pred from @Rp)


class Cond(enum.IntEnum):
    """SETP compare conditions (carried in imm[2:0] — SETP cannot snoop)."""

    EQ = 0
    NE = 1
    LT = 2
    LE = 3
    GT = 4
    GE = 5


class Typ(enum.IntEnum):
    INT32 = 0
    UINT32 = 1
    FP32 = 2


class Width(enum.IntEnum):
    FULL = 0      # 16 threads / wavefront
    HALF = 1      # 8
    QUARTER = 2   # 4
    SINGLE = 3    # 1


class Depth(enum.IntEnum):
    FULL = 0      # all initialized wavefronts
    HALF = 1
    QUARTER = 2
    SINGLE = 3    # one wavefront ("single cycle")


WIDTH_THREADS = {Width.FULL: 16, Width.HALF: 8, Width.QUARTER: 4, Width.SINGLE: 1}

# instruction classes for the cycle profile (Tables III / IV rows)
CLASS_NAMES = (
    "NOP",        # 0
    "LOD_IMM",    # 1
    "LOGIC",      # 2
    "INT",        # 3  (INT32/UINT32 arith + TDx/TDy address generation)
    "LOD_IDX",    # 4
    "FP_ADDSUB",  # 5
    "FP_MUL",     # 6
    "FP_DOT",     # 7
    "FP_SFU",     # 8
    "STO_IDX",    # 9
    "CONTROL",    # 10 (JMP/JSR/RTS/LOOP/INIT/STOP)
    "GMEM",       # 11 (GLD/GST: single-port global memory, shared by SMs)
)
NUM_CLASSES = len(CLASS_NAMES)

# opcodes whose immediate is an unsigned I-MEM address (decode does not
# sign-extend these); everything else carries a signed 14-bit immediate
CONTROL_IMM_OPS = frozenset({Op.JMP, Op.JSR, Op.LOOP, Op.INIT})


def _check(val: int, nbits: int, name: str) -> int:
    if not 0 <= val < (1 << nbits):
        raise ValueError(f"{name}={val} does not fit in {nbits} bits")
    return val


def _put(word: int, field: tuple[int, int], val: int, name: str) -> int:
    lsb, nbits = field
    return word | (_check(val, nbits, name) << lsb)


def get(word: int, field: tuple[int, int]) -> int:
    lsb, nbits = field
    return (word >> lsb) & ((1 << nbits) - 1)


@dataclasses.dataclass(frozen=True)
class Instr:
    """Decoded instruction (assembler-side representation)."""

    op: Op
    typ: Typ = Typ.INT32
    rd: int = 0
    ra: int = 0
    rb: int = 0
    imm: int = 0          # signed, -(2**14) .. 2**14-1 (or unsigned address)
    x: int = 0            # snoop enable
    ext_a: int = 0        # snoop wavefront index for RA (0..31)
    ext_b: int = 0        # snoop wavefront index for RB
    width: Width = Width.FULL
    depth: Depth = Depth.FULL
    pen: int = 0          # predicate enable (0 = unconditional, legacy)
    preg: int = 0         # predicate register (LSB = predicate value)
    pneg: int = 0         # guard on !P instead of P

    def encode(self) -> int:
        word = 0
        if self.pen:
            if self.op in CONTROL_IMM_OPS or self.op in (
                    Op.RTS, Op.STOP, Op.NOP):
                raise ValueError(
                    f"{self.op.name} cannot be predicated: the sequencer "
                    f"is scalar and the instruction stream must stay static")
            word = _put(word, F_PEN, 1, "pen")
            word = _put(word, F_PREG, self.preg, "preg")
            word = _put(word, F_PNEG, self.pneg, "pneg")
        elif self.preg or self.pneg:
            raise ValueError("preg/pneg set without pen=1")
        if self.op == Op.SETP:
            if self.x:
                raise ValueError(
                    "SETP cannot snoop: the condition lives in imm[2:0]")
            Cond(self.imm)  # raises on an out-of-range condition
        word = _put(word, F_WIDTH, int(self.width), "width")
        word = _put(word, F_DEPTH, int(self.depth), "depth")
        word = _put(word, F_OPCODE, int(self.op), "opcode")
        word = _put(word, F_TYPE, int(self.typ), "type")
        word = _put(word, F_RD, self.rd, "rd")
        word = _put(word, F_RA, self.ra, "ra")
        word = _put(word, F_RB, self.rb, "rb")
        word = _put(word, F_X, self.x, "x")
        if self.x:
            if self.imm:
                raise ValueError("snooping (X=1) reuses the immediate field")
            word = _put(word, F_EXT_A, self.ext_a, "ext_a")
            word = _put(word, F_EXT_B, self.ext_b, "ext_b")
        else:
            imm = self.imm
            if self.op in CONTROL_IMM_OPS:
                # control-flow addresses: unsigned, full 15 bits
                if not 0 <= imm < (1 << 15):
                    raise ValueError(
                        f"control address {imm} out of range for 15 bits")
            elif not -(1 << 14) <= imm < (1 << 14):
                # signed immediates: decode sign-extends bit 14, so encode
                # must reject [2^14, 2^15) or the value round-trips negative
                raise ValueError(
                    f"immediate {imm} out of range for signed 15 bits")
            word = _put(word, F_IMM, imm & 0x7FFF, "imm")
        return word

    @staticmethod
    def decode(word: int) -> "Instr":
        x = get(word, F_X)
        raw_imm = get(word, F_IMM)
        imm = raw_imm - (1 << 15) if (raw_imm & (1 << 14)) else raw_imm
        op = Op(get(word, F_OPCODE))
        # control-flow addresses are unsigned
        if op in CONTROL_IMM_OPS:
            imm = raw_imm
        pen = get(word, F_PEN)
        return Instr(
            pen=pen,
            preg=get(word, F_PREG) if pen else 0,
            pneg=get(word, F_PNEG) if pen else 0,
            op=op,
            typ=Typ(get(word, F_TYPE)),
            rd=get(word, F_RD),
            ra=get(word, F_RA),
            rb=get(word, F_RB),
            imm=0 if x else imm,
            x=x,
            ext_a=get(word, F_EXT_A) if x else 0,
            ext_b=get(word, F_EXT_B) if x else 0,
            width=Width(get(word, F_WIDTH)),
            depth=Depth(get(word, F_DEPTH)),
        )


# opcode -> profile class (operand-type dependent ops resolved at decode time)
def instr_class(op: Op, typ: Typ) -> int:
    if op == Op.NOP:
        return 0
    if op == Op.LODI:
        return 1
    if op in (Op.AND, Op.OR, Op.XOR, Op.NOT, Op.LSL, Op.LSR):
        return 2
    if op in (Op.ADD, Op.SUB, Op.MUL):
        if typ == Typ.FP32:
            return 6 if op == Op.MUL else 5
        return 3
    if op in (Op.TDX, Op.TDY, Op.BID, Op.PID):
        return 3
    if op == Op.SETP:
        # the compare rides the arithmetic pipes: FP compare on the
        # FP add/sub unit, integer compare on the INT pipe
        return 5 if typ == Typ.FP32 else 3
    if op == Op.SELP:
        return 3  # a mux: INT-pipe occupancy regardless of operand type
    if op == Op.LOD:
        return 4
    if op == Op.STO:
        return 9
    if op in (Op.DOT, Op.SUM):
        return 7
    if op == Op.INVSQR:
        return 8
    if op in (Op.GLD, Op.GST):
        return 11
    return 10  # control


# latency (pipeline occupancy) of the result, in cycles, for hazard checking.
# Paper: 9-stage pipeline for both INT and FP operations; loads/stores have
# their own (sequencer-dominated) latencies.
RESULT_LATENCY = 9
