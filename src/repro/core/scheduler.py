"""Block schedulers for the multi-SM device: static waves vs dynamic queue.

The scalable eGPU follow-up (arXiv 2401.04261) makes dynamic block dispatch
across SMs its headline feature: instead of launching blocks in lockstep
waves, every SM runs its own sequencer and *pulls* the next ready block
from a device-level work queue the moment it retires its current one. This
module models both disciplines over the static per-block instruction
traces of ``cycles.program_trace`` (exact, because the ISA has no
data-dependent control flow):

``static``
    The PR-1 wave schedule: blocks ``[w*n_sms, (w+1)*n_sms)`` issue in
    lockstep; a wave ends when its slowest block retires, and every global
    access holds all ``wave_n`` sequencers for the serialized port drain
    (``trace.static_cycles(wave_n)``). For a homogeneous launch this
    reproduces the lockstep device simulation cycle for cycle.

``dynamic``
    Work-queue dispatch with per-SM sequencers. Blocks are queued in grid
    order (or by descending ``Kernel.priority``, FIFO within a priority
    level); an SM pulls the head block when idle, executes its trace, and
    only stalls when the single device-wide global-memory port is busy.
    Port arbitration is FIFO by request time (ties broken by SM index), so
    the simulation is deterministic. Port queueing appears as per-SM
    *wait* time rather than an inflated instruction cost — the makespan of
    an imbalanced or mixed-program grid is therefore never worse than the
    wave schedule's, which idles every SM until the slowest block of each
    wave retires.

The scheduler decides *timing only*. Functional results are computed by
the lockstep batch machinery in ``device.launch`` in a canonical,
schedule-independent order (program-major, then block order), so a
launch's architectural state is invariant to the dispatch discipline —
``tests/test_scheduler.py`` property-tests this.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

import numpy as np

from .cycles import ProgramTrace
from .packing import WavePacking

SCHEDULES = ("static", "dynamic")


@dataclasses.dataclass
class Schedule:
    """Timing of one launch: who ran what, when, and what it cost."""

    mode: str                       # "static" | "dynamic"
    n_sms: int
    makespan: int                   # device cycles, launch start to last retire
    block_sm: np.ndarray            # (n_blocks,) SM that ran each block
    block_start: np.ndarray         # (n_blocks,) issue cycle
    block_finish: np.ndarray        # (n_blocks,) retire cycle
    block_busy: np.ndarray          # (n_blocks,) sequencer-busy cycles
    block_wait: np.ndarray          # (n_blocks,) gmem-port stall cycles
    block_gmem: np.ndarray          # (n_blocks,) gmem-port occupancy cycles
    wave_cycles: np.ndarray         # (n_waves,) static mode; empty for dynamic

    @property
    def n_blocks(self) -> int:
        return int(self.block_sm.shape[0])

    @property
    def sm_busy(self) -> np.ndarray:
        """(n_sms,) cycles each SM spent issuing instructions."""
        out = np.zeros(self.n_sms, np.int64)
        np.add.at(out, self.block_sm, self.block_busy)
        return out

    @property
    def sm_wait(self) -> np.ndarray:
        """(n_sms,) cycles each SM stalled on the global-memory port."""
        out = np.zeros(self.n_sms, np.int64)
        np.add.at(out, self.block_sm, self.block_wait)
        return out

    @property
    def sm_idle(self) -> np.ndarray:
        """(n_sms,) cycles each SM had no block to run."""
        return self.makespan - self.sm_busy - self.sm_wait

    @property
    def sm_blocks(self) -> np.ndarray:
        """(n_sms,) blocks retired per SM."""
        out = np.zeros(self.n_sms, np.int64)
        np.add.at(out, self.block_sm, 1)
        return out

    @property
    def port_busy(self) -> int:
        """Total cycles the device-wide global-memory port transferred."""
        return int(self.block_gmem.sum())

    @property
    def port_wait(self) -> int:
        """Total SM-cycles queued behind the port."""
        return int(self.block_wait.sum())


def schedule_blocks(traces: Sequence[ProgramTrace], n_sms: int,
                    mode: str,
                    phase_of: Sequence[int] | None = None,
                    priority_of: Sequence[int] | None = None,
                    packing: WavePacking | None = None,
                    start_cycle: int = 0) -> Schedule:
    """Schedule ``traces[b]`` (one per block, in grid order) onto ``n_sms``
    SMs under the given discipline.

    ``phase_of[b]`` (non-negative ints) expresses kernel dependencies: a
    block dispatches only after every block of all lower phases retired —
    a device-wide barrier between phases (the CUDA-stream semantic for
    dependent kernels, e.g. a two-level reduction fused into one launch).
    Within a phase, blocks keep their grid order. Default: one phase.

    ``priority_of[b]`` orders the DYNAMIC ready queue within a phase: an
    idle SM pulls the highest-priority ready block; ties keep FIFO grid
    order, so all-equal priorities (the default) reproduce the plain FIFO
    schedule exactly. The static wave schedule ignores priority — waves
    are grid order by definition.

    ``packing`` (a :class:`core.packing.WavePacking`) overrides the
    grid-order wave rule with an explicit membership decision: the
    static schedule runs exactly ``packing.waves`` (each wave's members
    lockstep, every member charged the whole wave's port drain), and the
    dynamic FIFO tiebreak becomes the packed dispatch order — BOTH
    disciplines must consume the same packing, or ``dynamic <= static``
    stops being a like-for-like comparison (list dispatch in order X
    never loses to serial waves chunked from order X, but it can lose to
    waves chunked from a different one). ``packing=None`` is grid order,
    bit-identical to the pre-packing scheduler.

    ``start_cycle`` (non-negative) delays the whole launch: no block
    issues before it. This is the host-dispatch model of the serving
    front door (arXiv 2401.04261 measures exactly this launch-queue
    latency): ``device.launch`` converts its launch-queue depth into a
    start offset, so the stall shows up as SM *idle* time at the head of
    the schedule and in the makespan — never as per-block busy or port
    cycles. ``start_cycle=0`` (the default) is bit-identical to the
    pre-serving scheduler.
    """
    if mode not in SCHEDULES:
        raise ValueError(f"schedule mode {mode!r} not in {SCHEDULES}")
    if n_sms < 1:
        raise ValueError(f"n_sms={n_sms} must be >= 1")
    if start_cycle < 0:
        raise ValueError(f"start_cycle={start_cycle} must be >= 0")
    n_blocks = len(traces)
    if priority_of is None:
        prio = np.zeros(n_blocks, np.int64)
    else:
        prio = np.asarray(list(priority_of), np.int64)
        if prio.shape != (n_blocks,):
            raise ValueError(f"priority_of has shape {prio.shape}, want "
                             f"({n_blocks},)")
    if phase_of is not None:
        phase = np.asarray(list(phase_of), np.int64)
        if phase.shape != (n_blocks,):
            raise ValueError(f"phase_of has shape {phase.shape}, want "
                             f"({n_blocks},)")
    if packing is not None:
        if packing.n_blocks != n_blocks:
            raise ValueError(f"packing covers {packing.n_blocks} blocks, "
                             f"schedule has {n_blocks}")
        if packing.n_sms != n_sms:
            raise ValueError(f"packing was built for {packing.n_sms} SMs, "
                             f"schedule has {n_sms}")
        if phase_of is not None:
            # the packing must respect THIS schedule's fences: a packed
            # wave that mixed phases (or ran out of phase order) would
            # let the packed static path model blocks from both sides of
            # a barrier as concurrent
            last_ph = None
            for wave in packing.waves:
                phs = {int(phase[b]) for b in wave}
                if len(phs) != 1:
                    raise ValueError(f"packed wave {wave} spans barrier "
                                     f"phases {sorted(phs)}")
                ph = phs.pop()
                if last_ph is not None and ph < last_ph:
                    raise ValueError("packed waves run out of barrier-"
                                     "phase order")
                last_ph = ph
        if mode == "static":
            # the packed wave rule: membership comes from the packing,
            # waves run back to back in packed (phase-major) order
            return _shift(_schedule_static(traces, n_sms,
                                           waves=packing.waves),
                          start_cycle)
        # dynamic: the packed order replaces grid order as the FIFO
        # tiebreak; rank[b] = b's position in the packed dispatch order
        rank = np.empty(n_blocks, np.int64)
        rank[packing.order] = np.arange(n_blocks)
    else:
        rank = np.arange(n_blocks, dtype=np.int64)
    if mode == "static":
        sim = lambda tr, n, _p, _r: _schedule_static(tr, n)  # noqa: E731
    else:
        sim = _schedule_dynamic
    if phase_of is None:
        return _shift(sim(traces, n_sms, prio, rank), start_cycle)
    parts = [np.flatnonzero(phase == p) for p in np.unique(phase)]
    sm = np.zeros(n_blocks, np.int64)
    start = np.zeros(n_blocks, np.int64)
    finish = np.zeros(n_blocks, np.int64)
    busy = np.zeros(n_blocks, np.int64)
    wait = np.zeros(n_blocks, np.int64)
    gmem = np.zeros(n_blocks, np.int64)
    waves: list[int] = []
    t0 = int(start_cycle)
    for idx in parts:
        s = sim([traces[i] for i in idx], n_sms, prio[idx], rank[idx])
        sm[idx] = s.block_sm
        start[idx] = s.block_start + t0
        finish[idx] = s.block_finish + t0
        busy[idx] = s.block_busy
        wait[idx] = s.block_wait
        gmem[idx] = s.block_gmem
        waves.extend(int(c) for c in s.wave_cycles)
        t0 += s.makespan
    return Schedule(mode=mode, n_sms=n_sms, makespan=t0,
                    block_sm=sm, block_start=start, block_finish=finish,
                    block_busy=busy, block_wait=wait, block_gmem=gmem,
                    wave_cycles=np.asarray(waves, np.int64))


def merge_schedules(parts: Sequence[tuple[Schedule, np.ndarray, int]],
                    n_sms: int, n_blocks: int) -> Schedule:
    """Union per-device schedules into one fleet-level :class:`Schedule`.

    ``parts`` is a sequence of ``(schedule, blocks, sm_offset)`` triples:
    ``schedule`` covers the fleet blocks listed in ``blocks`` (fleet
    block index per local block, in the schedule's local order) and its
    SM indices are shifted by ``sm_offset`` — device ``d`` of a fleet
    owns SMs ``[d * per_device, (d+1) * per_device)``. A fleet block may
    appear in exactly one part. The merged makespan is the latest retire
    over all parts (devices run concurrently; per-phase serialization is
    already baked into each part's ``start_cycle``), and ``wave_cycles``
    concatenates in part order (device-major). All parts must share one
    ``mode``.
    """
    if not parts:
        raise ValueError("merge_schedules needs at least one part")
    modes = {s.mode for s, _, _ in parts}
    if len(modes) != 1:
        raise ValueError(f"cannot merge schedules of mixed modes {modes}")
    sm = np.zeros(n_blocks, np.int64)
    start = np.zeros(n_blocks, np.int64)
    finish = np.zeros(n_blocks, np.int64)
    busy = np.zeros(n_blocks, np.int64)
    wait = np.zeros(n_blocks, np.int64)
    gmem = np.zeros(n_blocks, np.int64)
    seen = np.zeros(n_blocks, bool)
    waves: list[int] = []
    makespan = 0
    for s, blocks, sm_off in parts:
        idx = np.asarray(blocks, np.int64)
        if idx.shape != (s.n_blocks,):
            raise ValueError(f"part covers {s.n_blocks} blocks but maps "
                             f"{idx.shape[0]} fleet indices")
        if seen[idx].any():
            raise ValueError("parts overlap: a fleet block was scheduled "
                             "on two devices")
        seen[idx] = True
        sm[idx] = s.block_sm + int(sm_off)
        start[idx] = s.block_start
        finish[idx] = s.block_finish
        busy[idx] = s.block_busy
        wait[idx] = s.block_wait
        gmem[idx] = s.block_gmem
        waves.extend(int(c) for c in s.wave_cycles)
        makespan = max(makespan, s.makespan)
    if not seen.all():
        raise ValueError("parts leave fleet blocks unscheduled")
    return Schedule(mode=modes.pop(), n_sms=n_sms, makespan=makespan,
                    block_sm=sm, block_start=start, block_finish=finish,
                    block_busy=busy, block_wait=wait, block_gmem=gmem,
                    wave_cycles=np.asarray(waves, np.int64))


def _shift(s: Schedule, start_cycle: int) -> Schedule:
    """Delay a whole schedule by ``start_cycle`` host-dispatch cycles:
    every block's issue/retire moves right, the makespan absorbs the
    stall as leading SM idle time, and per-block busy/wait/gmem are
    untouched (the host, not the port, is what's slow)."""
    if not start_cycle:
        return s
    return dataclasses.replace(
        s, makespan=s.makespan + int(start_cycle),
        block_start=s.block_start + int(start_cycle),
        block_finish=s.block_finish + int(start_cycle))


def _schedule_static(traces: Sequence[ProgramTrace], n_sms: int,
                     waves: Sequence[tuple[int, ...]] | None = None
                     ) -> Schedule:
    """The lockstep wave schedule. ``waves`` (tuples of block indices,
    run back to back in order) overrides the default grid-order chunks —
    the packed static path; a packed wave never crosses a phase fence,
    so the sequential wave order preserves the barrier semantic."""
    n_blocks = len(traces)
    sm = np.zeros(n_blocks, np.int64)
    start = np.zeros(n_blocks, np.int64)
    finish = np.zeros(n_blocks, np.int64)
    busy = np.zeros(n_blocks, np.int64)
    wait = np.zeros(n_blocks, np.int64)
    gmem = np.asarray([t.gmem_cycles for t in traces], np.int64)
    if waves is None:
        waves = [tuple(range(w0, min(w0 + n_sms, n_blocks)))
                 for w0 in range(0, n_blocks, n_sms)]
    wave_cycles = []
    t0 = 0
    for wave in waves:
        wave_gmem = sum(int(gmem[b]) for b in wave)
        wave_c = 0
        for i, b in enumerate(wave):
            # lockstep wave rule: a block's sequencer is additionally held
            # while the port drains every OTHER wave member's accesses —
            # for a homogeneous wave of n this is the classic
            # (n-1) * gmem_cycles charge, bit-identical to the lockstep
            # device machine
            cost = traces[b].cycles + wave_gmem - int(gmem[b])
            sm[b] = i
            start[b] = t0
            finish[b] = t0 + cost
            busy[b] = traces[b].cycles
            wait[b] = cost - busy[b]
            wave_c = max(wave_c, cost)
        wave_cycles.append(wave_c)
        t0 += wave_c
    return Schedule(mode="static", n_sms=n_sms, makespan=t0,
                    block_sm=sm, block_start=start, block_finish=finish,
                    block_busy=busy, block_wait=wait, block_gmem=gmem,
                    wave_cycles=np.asarray(wave_cycles, np.int64))


def _segments(trace: ProgramTrace) -> list[tuple[int, int]]:
    """Split a trace into (compute_cycles, gmem_cycles) runs; the final
    segment has gmem_cycles == 0 (the tail after the last port access)."""
    segs: list[tuple[int, int]] = []
    comp = 0
    for t in trace.instrs:
        if t.gmem:
            segs.append((comp, t.cycles))
            comp = 0
        else:
            comp += t.cycles
    segs.append((comp, 0))
    return segs


_PULL, _PORT = 0, 1


def _schedule_dynamic(traces: Sequence[ProgramTrace], n_sms: int,
                      priority: np.ndarray | None = None,
                      rank: np.ndarray | None = None) -> Schedule:
    n_blocks = len(traces)
    sm = np.zeros(n_blocks, np.int64)
    start = np.zeros(n_blocks, np.int64)
    finish = np.zeros(n_blocks, np.int64)
    busy = np.asarray([t.cycles for t in traces], np.int64)
    wait = np.zeros(n_blocks, np.int64)

    if priority is None:
        priority = np.zeros(n_blocks, np.int64)
    if rank is None:
        rank = np.arange(n_blocks, dtype=np.int64)
    # ready queue ordered by (priority desc, dispatch order): the FIFO
    # tiebreak is the packed dispatch rank — grid order when no packing
    # is in play — so all-equal priorities pop exactly that order
    queue: list[tuple[int, int, int]] = [(-int(priority[b]), int(rank[b]),
                                          b) for b in range(n_blocks)]
    heapq.heapify(queue)
    segs_of = [_segments(t) for t in traces]
    # per-SM cursor: current block, its segments, next segment index
    cur_block = [-1] * n_sms
    cur_segs: list[list[tuple[int, int]]] = [[] for _ in range(n_sms)]
    cur_i = [0] * n_sms
    kind = [_PULL] * n_sms
    port_free = 0

    heap: list[tuple[int, int]] = [(0, s) for s in range(n_sms)]
    heapq.heapify(heap)

    def run_from(s: int, t: int) -> None:
        """Advance SM ``s`` from time ``t`` through its current compute
        segment, to either its next port request or block retirement
        (a pull event); both are arbitrated through the event heap."""
        comp, g = cur_segs[s][cur_i[s]]
        t += comp
        if g > 0:
            kind[s] = _PORT
        else:
            finish[cur_block[s]] = t
            kind[s] = _PULL
        heapq.heappush(heap, (t, s))

    while heap:
        t, s = heapq.heappop(heap)
        if kind[s] == _PULL:
            if not queue:
                continue                      # SM retires: queue drained
            _, _, b = heapq.heappop(queue)
            cur_block[s] = b
            cur_segs[s] = segs_of[b]
            cur_i[s] = 0
            sm[b] = s
            start[b] = t
            run_from(s, t)
        else:                                 # _PORT: request made at t
            g = cur_segs[s][cur_i[s]][1]
            grant = max(t, port_free)
            port_free = grant + g
            wait[cur_block[s]] += grant - t
            cur_i[s] += 1
            run_from(s, grant + g)

    makespan = int(finish.max()) if n_blocks else 0
    return Schedule(mode="dynamic", n_sms=n_sms, makespan=makespan,
                    block_sm=sm, block_start=start, block_finish=finish,
                    block_busy=busy, block_wait=wait,
                    block_gmem=np.asarray([t.gmem_cycles for t in traces],
                                          np.int64),
                    wave_cycles=np.zeros((0,), np.int64))
