"""Device fleet: N simulated eGPUs behind the one ``launch()`` front door.

The eGPU paper closes on the claim that "multiple eGPUs can also be
tightly packed together into a single Agilex FPGA logic region, with
minimal speed penalty", and the scalable follow-up (arXiv 2401.04261)
makes the device count a first-class scaling axis next to the SM count.
This module models that axis: :class:`FleetConfig` describes ``n_devices``
identical eGPUs (each a full ``DeviceConfig`` sector — its own SMs, its
own global-memory port), and :func:`launch_fleet` routes one grid across
them.

Contracts, in order of importance:

* **Bit-identical function.** A fleet launch computes exactly what the
  single-device ``device.launch`` computes on the same grid, for every
  ``n_devices`` — blocks keep their fleet-level ``BID`` no matter which
  device they land on (the ``launch(block_ids=)`` router seam), barrier
  phases stay device-wide fences (a phase retires on EVERY device before
  the next issues anywhere), and per-device global-memory images are
  diff-merged against the phase's base image in device order. Under the
  standard launch contract (same-phase blocks don't race through gmem)
  the merge is exact: each device's sub-launch changes disjoint words.
  ``fleet(n_devices=1)`` simply IS the plain launch (delegation, not
  re-implementation).

* **A NUMA tier in the cycle model.** Each simulated device owns a local
  slice of the shared global memory; blocks routed off
  ``FleetConfig(home_device=)`` pay ``remote_gmem_latency`` extra cycles
  per global access (their static traces are re-priced before
  scheduling, so the charge flows through the same static/dynamic
  machinery, the makespan, and ``cycles_by_class`` — golden-pinnable
  like every other cycle). The default latency of 0 models the paper's
  tightly-packed single-region fleet.

* **Real JAX devices underneath.** When the workload is uniform enough
  (one program, one phase, a halting trace, equal per-device block
  counts) and jax exposes enough devices, the functional execution runs
  as ONE ``shard_map`` over the ``"fleet"`` mesh axis
  (``launch.mesh.make_fleet_mesh`` + ``launch.shardings.fleet_spec``):
  every simulated eGPU executes its block slice on its own XLA device
  against its own gmem replica, and the replicas diff-merge exactly like
  the host path. ``placement="auto"`` (default) uses it when it can and
  records why not when it can't (``profile()["fleet"]["placement"]`` /
  ``["placement_reason"]``); ``"host"`` forces the per-device host loop;
  ``"shard_map"`` raises when the preconditions fail instead of
  silently degrading.

Timing: the fleet schedule is the union of per-device schedules
(``scheduler.merge_schedules``) — device ``d`` owns SMs
``[d*n_sms, (d+1)*n_sms)`` of the fleet view, each phase starts
everywhere at the previous phase's fleet-wide retire (max over devices),
and the makespan is the last retire anywhere. Near-linear throughput
scaling on mixed grids is pinned by ``benchmarks/fleet_bench.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import trace_engine
from .cycles import ProgramTrace
from .device import (
    _U32,
    DeviceConfig,
    LaunchResult,
    _kernel_shmem,
    _lower_kernels,
    _normalize_grid,
    _resolve_engine,
    _resolve_schedule,
    as_u32_image,
    launch,
    pack_buffers,
)
from .isa import NUM_CLASSES
from .machine import MAX_THREADS, N_REGS
from .packing import pack_waves
from .scheduler import merge_schedules, schedule_blocks

ROUTES = ("block", "kernel")
PLACEMENTS = ("auto", "host", "shard_map")

_I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """N identical simulated eGPUs sharing one launch front door.

    ``device`` is the per-device sector configuration (every device is
    identical — the paper packs copies of one layout). ``route`` picks
    the block router: ``"block"`` splits each barrier phase's blocks
    into ``n_devices`` contiguous grid-order ranges (balanced to within
    one block); ``"kernel"`` sends program ``k``'s blocks to device
    ``k % n_devices`` (whole kernels stay device-local — the natural
    router for mixed grids whose programs shouldn't share a port).
    ``remote_gmem_latency`` is the NUMA tier: extra cycles per global
    access for blocks running off ``home_device``. ``placement`` picks
    where the functional execution runs (see module docstring).
    """

    n_devices: int = 1
    device: DeviceConfig = dataclasses.field(default_factory=DeviceConfig)
    remote_gmem_latency: int = 0
    home_device: int = 0
    route: str = "block"
    placement: str = "auto"

    def __post_init__(self):
        if self.n_devices < 1:
            raise ValueError(f"n_devices={self.n_devices} must be >= 1")
        if self.remote_gmem_latency < 0:
            raise ValueError(f"remote_gmem_latency="
                             f"{self.remote_gmem_latency} must be >= 0")
        if not 0 <= self.home_device < self.n_devices:
            raise ValueError(f"home_device={self.home_device} outside "
                             f"[0, {self.n_devices})")
        if self.route not in ROUTES:
            raise ValueError(f"route={self.route!r} must be one of "
                             f"{ROUTES}")
        if self.placement not in PLACEMENTS:
            raise ValueError(f"placement={self.placement!r} must be one "
                             f"of {PLACEMENTS}")

    @property
    def n_sms(self) -> int:
        """Total SMs across the fleet."""
        return self.n_devices * self.device.n_sms


def _remote_trace(trace: ProgramTrace, lat: int) -> ProgramTrace:
    """Re-price a static trace for a non-home device: every global-port
    access costs ``lat`` extra cycles (the NUMA tier). The re-priced
    trace flows through the ordinary static/dynamic schedulers and
    ``cycles_by_class`` — the charge is just cycles, not a new
    mechanism."""
    if lat == 0:
        return trace
    instrs = tuple(dataclasses.replace(i, cycles=i.cycles + lat)
                   if i.gmem else i for i in trace.instrs)
    return dataclasses.replace(trace, instrs=instrs)


def _route_blocks(fcfg: FleetConfig, gmap: np.ndarray,
                  block_phase: np.ndarray) -> np.ndarray:
    """(n_blocks,) device index per block. Contiguous grid-order ranges
    per phase ("block"), or program-keyed ("kernel")."""
    n_blocks = gmap.shape[0]
    device_of = np.zeros(n_blocks, np.int64)
    if fcfg.route == "kernel":
        device_of[:] = gmap % fcfg.n_devices
        return device_of
    for p in np.unique(block_phase):
        idx = np.flatnonzero(block_phase == p)
        for d, chunk in enumerate(np.array_split(idx, fcfg.n_devices)):
            device_of[chunk] = d
    return device_of


def _resolve_placement(fcfg: FleetConfig, kernels, gmap, block_phase,
                       traces, eng: str) -> tuple[str, str]:
    """Decide host vs shard_map; returns ``(placement, reason)``."""
    if fcfg.placement == "host":
        return "host", "requested"
    n = fcfg.n_devices
    reasons = []
    if len({int(k) for k in gmap}) != 1:
        reasons.append("mixed-program grid")
    if np.unique(block_phase).size != 1:
        reasons.append("multi-phase (barrier) launch")
    if not all(t.halted for t in traces):
        reasons.append("fuel-limited trace")
    if gmap.shape[0] % n != 0:
        reasons.append(f"{gmap.shape[0]} blocks not divisible by "
                       f"{n} devices")
    if fcfg.route != "block":
        reasons.append(f"route={fcfg.route!r} is not block-contiguous")
    if n > len(jax.devices()):
        reasons.append(f"jax exposes {len(jax.devices())} device(s) < "
                       f"{n}")
    if not reasons:
        return "shard_map", "uniform single-program single-phase grid"
    reason = "; ".join(reasons)
    if fcfg.placement == "shard_map":
        raise ValueError(f"placement='shard_map' unavailable: {reason}")
    return "host", reason


def _run_shard_map(fcfg: FleetConfig, backend: str, cfg, words,
                   gmap, local_bid, device_of, sh_batch, gm):
    """The real-JAX-devices path: one ``shard_map`` over the "fleet"
    mesh axis; each simulated eGPU runs its contiguous block slice on
    its own XLA device, waves of ``n_sms`` back to back against its own
    gmem replica. Returns device-major stacked
    ``(order, regs, shmem, gmems, oob, halted)``."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..launch.mesh import make_fleet_mesh
    from ..launch.shardings import fleet_spec

    n_dev = fcfg.n_devices
    n_sms = fcfg.device.n_sms
    n_blocks = gmap.shape[0]
    per = n_blocks // n_dev
    sched = trace_engine.compile_program(words, cfg)
    # route="block" on a single phase is contiguous by construction
    order = np.concatenate([np.flatnonzero(device_of == d)
                            for d in range(n_dev)])
    bid = jnp.asarray(local_bid[order], _I32).reshape(n_dev, per)
    pid = jnp.zeros((n_dev, per), _I32)
    if sh_batch is None:
        sh0 = jnp.zeros((n_dev, per, cfg.shmem_depth), _U32)
    else:
        sh0 = jnp.asarray(sh_batch)[local_bid[order]] \
            .reshape(n_dev, per, -1)
    regs0 = jnp.zeros((n_dev, per, MAX_THREADS, N_REGS), _U32)
    oob0 = jnp.zeros((n_dev, per), jnp.bool_)
    gm0 = jnp.broadcast_to(gm, (n_dev,) + gm.shape)

    mesh = make_fleet_mesh(n_dev)
    spec = fleet_spec()

    def body(bidx, pidx, regs, sh, gmem, oob):
        bidx, pidx = bidx[0], pidx[0]
        regs, sh, gmem, oob = regs[0], sh[0], gmem[0], oob[0]
        # the device's waves run back to back sharing its gmem replica —
        # the same chunking as the single-device homogeneous path
        for w0 in range(0, per, n_sms):
            w1 = min(w0 + n_sms, per)
            r, s, gmem, o = trace_engine._run_schedule(
                cfg, backend, sched.xs, bidx[w0:w1], pidx[w0:w1],
                regs[w0:w1], sh[w0:w1], gmem, oob[w0:w1])
            regs = regs.at[w0:w1].set(r)
            sh = sh.at[w0:w1].set(s)
            oob = oob.at[w0:w1].set(o)
        return regs[None], sh[None], gmem[None], oob[None]

    regs, sh, gmems, oob = shard_map(
        body, mesh=mesh,
        in_specs=(spec,) * 6, out_specs=(spec,) * 4)(
            bid, pid, regs0, sh0, gm0, oob0)
    return order, regs, sh, gmems, oob, sched.halted


def launch_fleet(fcfg: FleetConfig, program=None, grid=None,
                 block: int | None = None, *,
                 programs: Sequence[Any] | None = None,
                 grid_map: Sequence[int] | None = None,
                 buffers: Mapping[str, Any] | None = None,
                 shmem: Any = None, gmem: Any = None,
                 backend: str | None = None, dim_x: int | None = None,
                 schedule: str | None = None,
                 engine: str | None = None,
                 packing: str | None = None,
                 queue_depth: int = 0) -> LaunchResult:
    """CUDA-style launch across a fleet of simulated eGPUs.

    Same two grid forms, same keyword surface, and bit-identical
    functional results as :func:`core.device.launch` on one device —
    the fleet only changes where blocks run and what the cycle model
    charges. The returned :class:`LaunchResult` carries the fleet view
    in ``result.fleet`` / ``profile()["fleet"]``: per-device occupancy,
    the routing, the resolved placement, and the NUMA charge.
    """
    dcfg = fcfg.device
    if fcfg.n_devices == 1:
        res = launch(dcfg, program, grid, block, programs=programs,
                     grid_map=grid_map, buffers=buffers, shmem=shmem,
                     gmem=gmem, backend=backend, dim_x=dim_x,
                     schedule=schedule, engine=engine, packing=packing,
                     queue_depth=queue_depth)
        t = res.timing
        res.fleet = {
            "n_devices": 1, "route": fcfg.route, "placement": "host",
            "placement_reason": "single-device fleet is the plain device",
            "remote_gmem_latency": int(fcfg.remote_gmem_latency),
            "remote_gmem_cycles": 0,
            "per_device": [{
                "device": 0, "home": fcfg.home_device == 0,
                "blocks": res.n_blocks,
                "busy": int(t.sm_busy.sum()) if t is not None else 0,
                "wait": int(t.sm_wait.sum()) if t is not None else 0,
                "idle": int(t.sm_idle.sum()) if t is not None else 0,
                "makespan": int(res.cycles),
            }],
        }
        return res

    # ---- normalize + lower exactly like the single device ---------------
    kernels, gmap, shmems = _normalize_grid(dcfg, program, grid, block,
                                            dim_x, programs, grid_map,
                                            shmem)
    n_blocks = int(gmap.shape[0])
    backend = backend or dcfg.backend
    mode = _resolve_schedule(schedule, dcfg, len(kernels))
    names, cfgs, imems, traces, word_arrays = _lower_kernels(dcfg, kernels)
    eng, eng_fallback = _resolve_engine(engine, dcfg, traces)

    if queue_depth < 0:
        raise ValueError(f"queue_depth={queue_depth} must be >= 0")
    host_latency = dcfg.dispatch_latency + dcfg.queue_latency * queue_depth
    host_dispatch = None
    if dcfg.dispatch_latency or dcfg.queue_latency:
        host_dispatch = {
            "queue_depth": int(queue_depth),
            "dispatch_cycles": int(dcfg.dispatch_latency),
            "queue_cycles": int(dcfg.queue_latency * queue_depth),
            "latency_cycles": int(host_latency),
        }

    phase_of_kernel = np.cumsum([int(k.barrier) for k in kernels])
    block_phase = phase_of_kernel[gmap]
    device_of = _route_blocks(fcfg, gmap, block_phase)
    local_bid = np.zeros(n_blocks, np.int64)
    for k in range(len(kernels)):
        pos = np.flatnonzero(gmap == k)
        local_bid[pos] = np.arange(pos.size)
    placement, placement_reason = _resolve_placement(
        fcfg, kernels, gmap, block_phase, traces, eng)

    # ---- global-memory image --------------------------------------------
    offsets = None
    if buffers is not None:
        if gmem is not None:
            raise ValueError("pass either buffers= or gmem=, not both")
        gm, offsets = pack_buffers(buffers, dcfg.global_mem_depth)
    elif gmem is not None:
        gm = as_u32_image(gmem, dcfg.global_mem_depth, "global-memory")
    else:
        gm = jnp.zeros((dcfg.global_mem_depth,), _U32)

    # fleet-level per-kernel shmem batches (program-local block order)
    counts = [int((gmap == k).sum()) for k in range(len(kernels))]
    sh_batches = [_kernel_shmem(shmems[k], cfgs[k].shmem_depth,
                                counts[k], k) if counts[k] else None
                  for k in range(len(kernels))]

    # ---- functional execution -------------------------------------------
    regs_slots: list[Any] = [None] * n_blocks
    shmem_slots: list[Any] = [None] * n_blocks
    oob_slots: list[Any] = [None] * n_blocks
    halted = True
    shmem_pad = dcfg.sm.shmem_depth
    sub_engine = eng
    if placement == "shard_map":
        order, regs_d, sh_d, gmems_d, oob_d, sm_halted = _run_shard_map(
            fcfg, backend, cfgs[0], word_arrays[0], gmap, local_bid,
            device_of, sh_batches[0], gm)
        # per-device replicas diff-merge against the launch image in
        # device order — exact under the no-race launch contract
        merged = gm
        for d in range(fcfg.n_devices):
            changed = gmems_d[d] != gm
            merged = jnp.where(changed, gmems_d[d], merged)
        gm = merged
        per = n_blocks // fcfg.n_devices
        flat_regs = regs_d.reshape(n_blocks, MAX_THREADS, N_REGS)
        flat_sh = sh_d.reshape(n_blocks, -1)
        flat_oob = oob_d.reshape(n_blocks)
        for i, b in enumerate(order):
            regs_slots[b] = flat_regs[i]
            shmem_slots[b] = flat_sh[i]
            oob_slots[b] = flat_oob[i]
        if flat_sh.shape[1] < shmem_pad:
            pad = shmem_pad - flat_sh.shape[1]
            for b in range(n_blocks):
                shmem_slots[b] = jnp.pad(shmem_slots[b], (0, pad))
        halted = bool(sm_halted)
        sub_engine = "trace"        # the mapped body runs the scanned
        eng_fallback = None         # schedule; engines are bit-identical
    else:
        # host path: phase-by-phase, per-device sub-launches against the
        # phase's base gmem, diff-merged in device order
        for p in np.unique(block_phase):
            pblocks = np.flatnonzero(block_phase == p)
            base = gm
            merged = gm
            for d in range(fcfg.n_devices):
                bd = pblocks[device_of[pblocks] == d]
                if bd.size == 0:
                    continue
                sub_shmems: list[Any] = []
                for k in range(len(kernels)):
                    batch = sh_batches[k]
                    mine = bd[gmap[bd] == k]
                    if batch is None or mine.size == 0:
                        sub_shmems.append(None)
                    else:
                        sub_shmems.append(np.asarray(
                            batch[local_bid[mine]]))
                sub = launch(dcfg, programs=kernels,
                             grid_map=gmap[bd], shmem=sub_shmems,
                             gmem=base, backend=backend, schedule=mode,
                             engine=sub_engine, packing=packing,
                             block_ids=local_bid[bd])
                changed = sub.gmem != base
                merged = jnp.where(changed, sub.gmem, merged)
                for i, b in enumerate(bd):
                    regs_slots[b] = sub.regs[i]
                    shmem_slots[b] = sub.shmem[i]
                    oob_slots[b] = sub.oob[i]
                halted = halted and sub.halted
            gm = merged

    # ---- fleet timing: per-device schedules, merged ----------------------
    lat = int(fcfg.remote_gmem_latency)
    remote_traces = [_remote_trace(t, lat) for t in traces]

    def _trace_of(b: int, d: int) -> ProgramTrace:
        return (traces if d == fcfg.home_device
                else remote_traces)[int(gmap[b])]

    block_priority = np.asarray([kernels[k].priority for k in gmap],
                                np.int64)
    policy = packing if packing is not None else dcfg.packing
    resolved_packing = "grid"

    def _fleet_schedule(sched_mode: str):
        nonlocal resolved_packing
        parts = []
        t0 = int(host_latency)
        for p in np.unique(block_phase):
            pblocks = np.flatnonzero(block_phase == p)
            span = t0
            for d in range(fcfg.n_devices):
                bd = pblocks[device_of[pblocks] == d]
                if bd.size == 0:
                    continue
                trs = [_trace_of(b, d) for b in bd]
                wp = pack_waves([t.data_steps for t in trs],
                                dcfg.n_sms, policy=policy)
                if wp.policy == "length":
                    resolved_packing = "length"
                s = schedule_blocks(trs, dcfg.n_sms, sched_mode,
                                    priority_of=block_priority[bd],
                                    packing=wp, start_cycle=t0)
                parts.append((s, bd, d * dcfg.n_sms))
                span = max(span, s.makespan)
            t0 = span
        return merge_schedules(parts, fcfg.n_sms, n_blocks)

    timing = _fleet_schedule(mode)
    static_span = timing.makespan if mode == "static" \
        else _fleet_schedule("static").makespan

    # ---- aggregate counters ---------------------------------------------
    steps = 0
    by_class = np.zeros((NUM_CLASSES,), np.int64)
    remote_gmem_cycles = 0
    for b in range(n_blocks):
        t = _trace_of(b, int(device_of[b]))
        steps += t.steps
        by_class += np.asarray(t.cycles_by_class(), np.int64)
        if int(device_of[b]) != fcfg.home_device:
            remote_gmem_cycles += t.gmem_cycles \
                - traces[int(gmap[b])].gmem_cycles

    per_device = []
    for d in range(fcfg.n_devices):
        lo, hi = d * dcfg.n_sms, (d + 1) * dcfg.n_sms
        mine = device_of == d
        dev_finish = int(timing.block_finish[mine].max()) \
            if mine.any() else 0
        per_device.append({
            "device": int(d), "home": d == fcfg.home_device,
            "blocks": int(mine.sum()),
            "busy": int(timing.sm_busy[lo:hi].sum()),
            "wait": int(timing.sm_wait[lo:hi].sum()),
            "idle": int(timing.sm_idle[lo:hi].sum()),
            "makespan": dev_finish,
        })

    fleet_info = {
        "n_devices": int(fcfg.n_devices),
        "route": fcfg.route,
        "placement": placement,
        "placement_reason": placement_reason,
        "remote_gmem_latency": lat,
        "remote_gmem_cycles": int(remote_gmem_cycles),
        "per_device": per_device,
    }

    return LaunchResult(
        grid=(n_blocks,),
        block=cfgs[0].n_threads if len(kernels) == 1
        else tuple(c.n_threads for c in cfgs),
        n_waves=len(timing.wave_cycles),
        regs=jnp.stack(regs_slots, axis=0),
        shmem=jnp.stack(shmem_slots, axis=0),
        gmem=gm,
        oob=jnp.stack(oob_slots, axis=0),
        halted=halted,
        steps=int(steps),
        cycles=int(timing.makespan),
        wave_cycles=np.asarray(timing.wave_cycles, np.int64),
        cycles_by_class=by_class.astype(np.int64),
        buffer_offsets=offsets,
        schedule=mode,
        engine=sub_engine,
        engine_fallback=eng_fallback,
        program_names=tuple(names),
        grid_map=gmap,
        timing=timing,
        static_cycles=int(static_span),
        trace_merge=None,
        packing=resolved_packing,
        wave_packing=None,
        host_dispatch=host_dispatch,
        priority_respected=(mode == "dynamic")
        or not any(k.priority for k in kernels),
        fleet=fleet_info,
    )
