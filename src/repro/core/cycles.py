"""Sequencer cycle-cost model (paper §III.A / §III.C).

The eGPU sequencer issues one instruction to the SPs as a sequence of
wavefronts. Costs:

  * FP/INT operation .... one cycle per active wavefront (16 SPs issue one
    wavefront per clock).
  * LOD (indexed) ....... one clock per FOUR threads: the shared memory has
    4 read ports feeding 16 SPs in a 4-phase sequence.
  * STO (indexed) ....... one clock per thread: single write port, 16-phase
    writeback per wavefront. This is the bandwidth bottleneck the flexible
    ISA exists to mitigate.
  * LOD #imm ............ one cycle per active wavefront (broadcast through
    the SP write port).
  * DOT/SUM ............. one cycle per active wavefront (the dot-product
    unit consumes a full wavefront per clock, writing lane 0).
  * INVSQR .............. one cycle (single-lane SFU).
  * TDx/TDy ............. one cycle per active wavefront.
  * control ............. single cycle (zero-overhead loops: INIT and LOOP
    are one cycle each; JMP/JSR/RTS/STOP likewise).
  * NOP ................. one cycle.

The flexible Variable field scales "active": width w in {16,8,4,1} threads,
depth d in {full, half, quarter, single} wavefronts. Active wavefronts =
d(block), active threads = wavefronts * w. A full 512-thread block therefore
pays 32 cycles for an op, 128 for a load, 512 for a store — and a
{w1,d1}-masked store pays exactly 1 (paper: "the norm writeback only
requires a single clock cycle").

Multi-SM device extension (GLD/GST): the global-memory segment lives
outside the SMs, reached over the sector interconnect through a SINGLE
read port and a SINGLE write port shared by every SM in the packed sector
(the same single-port discipline as the shared-memory write path, but now
device-wide). A global access therefore costs one cycle per active thread
— and when ``n_sms`` SMs issue the access in lockstep, the port serializes
them: ``n_sms * active_threads`` cycles. This is the packed-sector
contention model used by the device-level cycle accounting in
``device.py``.
"""
from __future__ import annotations

from .isa import Depth, Instr, Op, Width, WIDTH_THREADS


def active_shape(width: Width, depth: Depth, n_threads: int) -> tuple[int, int]:
    """(active_wavefronts, active_threads_per_wavefront)."""
    n_waves = max(1, (n_threads + 15) // 16)
    waves = {Depth.FULL: n_waves,
             Depth.HALF: max(1, n_waves // 2),
             Depth.QUARTER: max(1, n_waves // 4),
             Depth.SINGLE: 1}[depth]
    return waves, WIDTH_THREADS[width]


def instr_cycles(ins: Instr, n_threads: int, n_sms: int = 1) -> int:
    """Sequencer occupancy of one instruction.

    ``n_sms`` models packed-sector contention: SMs executing in lockstep
    share the single global-memory port, so GLD/GST serialize across SMs.
    All other instruction classes use per-SM resources and are unaffected.

    This is the host-side statement of the cost model; the traced
    equivalent lives in ``device._device_step`` (it cannot call back into
    Python on decoded fields). ``tests/test_device.py`` pins the two
    together per instruction class.
    """
    waves, wthreads = active_shape(ins.width, ins.depth, n_threads)
    threads = waves * wthreads
    op = ins.op
    if op in (Op.NOP, Op.JMP, Op.JSR, Op.RTS, Op.LOOP, Op.INIT, Op.STOP,
              Op.INVSQR):
        return 1
    if op == Op.LOD:
        return max(1, (threads + 3) // 4)   # 4 read ports
    if op == Op.STO:
        return threads                       # 1 write port
    if op in (Op.GLD, Op.GST):
        return threads * max(1, n_sms)       # 1 global port, device-wide
    # everything else is wavefront-paced: ALU, LODI, TDx/TDy/BID, DOT, SUM
    return waves
