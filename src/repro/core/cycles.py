"""Sequencer cycle-cost model (paper §III.A / §III.C).

The eGPU sequencer issues one instruction to the SPs as a sequence of
wavefronts. Costs:

  * FP/INT operation .... one cycle per active wavefront (16 SPs issue one
    wavefront per clock).
  * LOD (indexed) ....... one clock per FOUR threads: the shared memory has
    4 read ports feeding 16 SPs in a 4-phase sequence.
  * STO (indexed) ....... one clock per thread: single write port, 16-phase
    writeback per wavefront. This is the bandwidth bottleneck the flexible
    ISA exists to mitigate.
  * LOD #imm ............ one cycle per active wavefront (broadcast through
    the SP write port).
  * DOT/SUM ............. one cycle per active wavefront (the dot-product
    unit consumes a full wavefront per clock, writing lane 0).
  * INVSQR .............. one cycle (single-lane SFU).
  * TDx/TDy ............. one cycle per active wavefront.
  * control ............. single cycle (zero-overhead loops: INIT and LOOP
    are one cycle each; JMP/JSR/RTS/STOP likewise).
  * NOP ................. one cycle.

The flexible Variable field scales "active": width w in {16,8,4,1} threads,
depth d in {full, half, quarter, single} wavefronts. Active wavefronts =
d(block), active threads = wavefronts * w. A full 512-thread block therefore
pays 32 cycles for an op, 128 for a load, 512 for a store — and a
{w1,d1}-masked store pays exactly 1 (paper: "the norm writeback only
requires a single clock cycle").

Multi-SM device extension (GLD/GST): the global-memory segment lives
outside the SMs, reached over the sector interconnect through a SINGLE
read port and a SINGLE write port shared by every SM in the packed sector
(the same single-port discipline as the shared-memory write path, but now
device-wide). A global access occupies the port for one cycle per active
thread. Under the *static wave* schedule SMs execute in lockstep, so every
SM's sequencer is held for the full serialized drain:
``n_sms * active_threads`` cycles (``instr_cycles(..., n_sms=...)``).
Under the *dynamic* schedule (``core.scheduler``) each SM's sequencer is
occupied only for its own ``active_threads`` access; queueing behind other
SMs shows up as per-SM port-wait time in the scheduler simulation instead
of an inflated instruction cost.

Predication (SIMT divergence)
-----------------------------
Predicated instructions (``@Rp``/``@!Rp``, plus SETP/SELP themselves)
change WHAT a lane writes, never WHEN the sequencer issues: a masked-off
lane still occupies its issue/drain slot as a bubble — the SP pipelines
and the shared/global port phase sequences are clocked by the sequencer
regardless of the per-lane write enable (the FPGA datapath has no
lane-skip). So ``instr_cycles`` is mask-independent, the instruction
stream stays static, and every trace/schedule/packing/NUMA number below
is exact for divergent programs too. SETP/SELP are wavefront-paced ALU
ops (the default arm).

Static program traces
---------------------
The eGPU ISA has no data-dependent control flow — JMP/JSR/LOOP/INIT/RTS
targets and trip counts are immediates, STOP is unconditional (predication
gates lane *writes*, not the sequencer: see above) — so the
sequence of instructions a sequencer issues (and hence the block's cycle
cost) is a *static* property of the program. ``program_trace`` walks a
program with a host-side sequencer (the same pc/loop-stack/return-stack
semantics as ``device._device_step``, pinned together by
``tests/test_device.py`` and ``tests/test_scheduler.py``) and returns the
issued-instruction trace with per-instruction cycle costs. The device
layer's block scheduler consumes these traces for per-SM timing.
"""
from __future__ import annotations

import dataclasses
import functools

from .isa import (
    Depth,
    Instr,
    NUM_CLASSES,
    Op,
    Width,
    WIDTH_THREADS,
    instr_class,
)
from .machine import LOOP_STACK_DEPTH, RET_STACK_DEPTH


def active_shape(width: Width, depth: Depth, n_threads: int) -> tuple[int, int]:
    """(active_wavefronts, active_threads_per_wavefront)."""
    n_waves = max(1, (n_threads + 15) // 16)
    waves = {Depth.FULL: n_waves,
             Depth.HALF: max(1, n_waves // 2),
             Depth.QUARTER: max(1, n_waves // 4),
             Depth.SINGLE: 1}[depth]
    return waves, WIDTH_THREADS[width]


def instr_cycles(ins: Instr, n_threads: int, n_sms: int = 1) -> int:
    """Sequencer occupancy of one instruction.

    ``n_sms`` models packed-sector contention: SMs executing in lockstep
    share the single global-memory port, so GLD/GST serialize across SMs.
    All other instruction classes use per-SM resources and are unaffected.

    This is the host-side statement of the cost model; the traced
    equivalent lives in ``device._device_step`` (it cannot call back into
    Python on decoded fields). ``tests/test_device.py`` pins the two
    together per instruction class.
    """
    waves, wthreads = active_shape(ins.width, ins.depth, n_threads)
    threads = waves * wthreads
    op = ins.op
    if op in (Op.NOP, Op.JMP, Op.JSR, Op.RTS, Op.LOOP, Op.INIT, Op.STOP,
              Op.INVSQR):
        return 1
    if op == Op.LOD:
        return max(1, (threads + 3) // 4)   # 4 read ports
    if op == Op.STO:
        return threads                       # 1 write port
    if op in (Op.GLD, Op.GST):
        return threads * max(1, n_sms)       # 1 global port, device-wide
    # everything else is wavefront-paced: ALU, LODI, TDx/TDy/BID/PID,
    # DOT, SUM
    return waves


# ---------------------------------------------------------------------------
# static program traces (the host-side per-SM sequencer)
# ---------------------------------------------------------------------------

# ops with NO architectural data effect (sequencer bookkeeping only);
# the complement is exactly the ops executor.DATA_SEL_OF_OP dispatches
# to a data handler — trace_engine._compile_cached asserts the two
# definitions agree on every lowered program
_SEQUENCER_ONLY = frozenset(
    (Op.NOP, Op.JMP, Op.JSR, Op.RTS, Op.LOOP, Op.INIT, Op.STOP))


@dataclasses.dataclass(frozen=True)
class TraceInstr:
    """One issued instruction in a block's static trace."""

    op: Op
    klass: int        # profile class (isa.CLASS_NAMES row)
    cycles: int       # sequencer occupancy, n_sms=1 (= port occupancy
                      # for GLD/GST: one word per cycle)
    gmem: bool        # goes through the device-wide global-memory port
    pc: int = 0       # I-MEM address issued from (lets the trace engine
                      # re-read the full 40-bit word at lowering time)


@dataclasses.dataclass(frozen=True)
class ProgramTrace:
    """The full issued-instruction trace of one thread block.

    Exact — not an approximation — because the ISA has no data-dependent
    control flow: every block running this program at this ``n_threads``
    issues exactly this sequence.
    """

    instrs: tuple[TraceInstr, ...]
    halted: bool                    # reached STOP (vs. fuel / pc runaway)
    n_threads: int

    @property
    def steps(self) -> int:
        return len(self.instrs)

    @functools.cached_property
    def cycles(self) -> int:
        """Busy cycles of the issuing sequencer (gmem at port occupancy)."""
        return sum(t.cycles for t in self.instrs)

    @functools.cached_property
    def gmem_cycles(self) -> int:
        """Cycles spent occupying the global-memory port."""
        return sum(t.cycles for t in self.instrs if t.gmem)

    @functools.cached_property
    def data_steps(self) -> int:
        """Issued instructions with an architectural data effect — the
        rows of the trace engine's pre-decoded schedule
        (``TraceSchedule.n_steps`` pins the two equal), and therefore
        the schedule length the wave packer bins on. NOP and control
        instructions are sequencer-only: the trace engine compiles them
        out, so they contribute no scan rows and no merge padding."""
        return sum(1 for t in self.instrs if t.op not in _SEQUENCER_ONLY)

    def static_cycles(self, wave_n: int) -> int:
        """Cycle cost in a HOMOGENEOUS lockstep wave: ``wave_n`` SMs issue
        each global access simultaneously and the single port serializes
        them, so every sequencer is held ``wave_n * threads`` per access.

        This is the special case of the general wave rule (every block's
        accesses drain behind every other wave member's:
        ``cycles + other_gmem``, see ``scheduler._schedule_static``) for
        ``wave_n`` identical traces.
        """
        return self.cycles + (wave_n - 1) * self.gmem_cycles

    def cycles_by_class(self, wave_n: int = 1) -> list[int]:
        """Per-class cycle totals (GMEM scaled by the wave width)."""
        by = [0] * NUM_CLASSES
        for t in self.instrs:
            by[t.klass] += t.cycles * (wave_n if t.gmem else 1)
        return by


def _trace_walk(words: tuple[int, ...], n_threads: int, imem_depth: int,
                max_steps: int) -> ProgramTrace:
    decoded = [Instr.decode(w) for w in words]
    stop = Instr(op=Op.STOP)                 # pack_imem pads I-MEM with STOP
    ret_stack = [0] * RET_STACK_DEPTH
    loop_ctr = [0] * LOOP_STACK_DEPTH
    ret_sp = loop_sp = 0
    pc = steps = 0
    halted = False
    out: list[TraceInstr] = []

    def clip(i: int, depth: int) -> int:
        return min(max(i, 0), depth - 1)

    while not halted and steps < max_steps and 0 <= pc < imem_depth:
        ins = decoded[pc] if pc < len(decoded) else stop
        out.append(TraceInstr(
            op=ins.op, klass=instr_class(ins.op, ins.typ),
            cycles=instr_cycles(ins, n_threads),
            gmem=ins.op in (Op.GLD, Op.GST), pc=pc))
        steps += 1
        op = ins.op
        # mirror device._device_step's h_ctl exactly (incl. index clipping)
        if op == Op.JMP:
            pc = ins.imm
        elif op == Op.JSR:
            ret_stack[clip(ret_sp, RET_STACK_DEPTH)] = pc + 1
            ret_sp += 1
            pc = ins.imm
        elif op == Op.RTS:
            pc = ret_stack[clip(ret_sp - 1, RET_STACK_DEPTH)]
            ret_sp -= 1
        elif op == Op.LOOP:
            lsp = clip(loop_sp - 1, LOOP_STACK_DEPTH)
            top = loop_ctr[lsp]
            loop_ctr[lsp] = top - 1
            if top > 1:
                pc = ins.imm
            else:
                pc += 1
                loop_sp -= 1
        elif op == Op.INIT:
            loop_ctr[clip(loop_sp, LOOP_STACK_DEPTH)] = ins.imm
            loop_sp += 1
            pc += 1
        elif op == Op.STOP:
            halted = True
            pc += 1
        else:
            pc += 1
    return ProgramTrace(instrs=tuple(out), halted=halted,
                        n_threads=n_threads)


@functools.lru_cache(maxsize=256)
def _trace_cached(words: tuple[int, ...], n_threads: int, imem_depth: int,
                  max_steps: int) -> ProgramTrace:
    # second tier behind the in-process LRU: the opt-in persistent
    # compile cache (core.compile_cache), so a production cold start
    # loads the walk instead of re-sequencing the program. A corrupt or
    # foreign entry loads as None (a miss) and is overwritten below.
    from . import compile_cache

    ckey = compile_cache.key_for(
        "trace", words, (n_threads, imem_depth, max_steps))
    hit = compile_cache.load(ckey)
    if isinstance(hit, ProgramTrace):
        return hit
    tr = _trace_walk(words, n_threads, imem_depth, max_steps)
    compile_cache.store(ckey, tr)
    return tr


def program_trace(program, n_threads: int, *, imem_depth: int = 512,
                  max_steps: int = 100_000) -> ProgramTrace:
    """Statically trace one block's execution of ``program``.

    ``program`` is an assembled ``Program`` or an array of encoded 40-bit
    words. The walk reproduces the device sequencer (STOP-padded I-MEM,
    clipped loop/return stacks, fuel limit), so ``trace.cycles`` equals the
    cycles a 1-SM wave reports and ``trace.static_cycles(n)`` equals an
    ``n``-block lockstep wave's — ``tests/test_scheduler.py`` pins both.
    """
    words = program.words if hasattr(program, "words") else program
    key = tuple(int(w) for w in words)
    return _trace_cached(key, int(n_threads), int(imem_depth),
                         int(max_steps))
