"""Multi-SM eGPU device layer: grid/block launches over a packed sector.

The paper closes with "multiple eGPUs can also be tightly packed together
into a single Agilex FPGA logic region" (§III.E quad-packs four SMs per
sector); the scalable follow-up (arXiv 2401.04261) makes the SM count the
headline parameter. This module is that device abstraction:

  * ``DeviceConfig(n_sms, global_mem_depth, ...)`` wraps the single-SM
    ``SMConfig`` with the sector-level parameters;
  * ``launch(dcfg, program, grid=(n_blocks,), block=n_threads, ...)`` is a
    CUDA-style launch; ``launch(dcfg, programs=[...], grid_map=[...])``
    launches SEVERAL programs at once (e.g. FFT and QRD blocks mixed in
    one grid), each block tagged with its program (``PID``) and its index
    within that program's grid (``BID``);
  * blocks are dispatched under one of two disciplines (``schedule=``):
    **static** lockstep waves of ``n_sms`` blocks (the PR-1 model, exact
    fast path for single-program launches), or **dynamic** work-queue
    dispatch (``core.scheduler``) where every SM runs its own sequencer
    and pulls the next ready block as soon as it retires its current one
    — SMs no longer idle waiting for the slowest block of a wave;
  * every SM keeps its private shared memory, and all SMs reach one
    **global-memory segment** (GLD/GST in ``isa.py``) through a single
    device-wide port — under the static schedule the serialization shows
    up as an inflated instruction cost
    (``cycles.instr_cycles(..., n_sms=...)``), under the dynamic schedule
    as per-SM port-wait time in ``LaunchResult.profile()``.

Lockstep execution
------------------
The eGPU ISA has *no data-dependent control flow*: JMP/JSR/LOOP/INIT/RTS
targets and trip counts are immediates, and STOP is unconditional. Blocks
running the same program therefore execute the identical PC trace, so one
wave is simulated as a single batched machine: ONE shared sequencer state
(pc, loop/return stacks, halt flag, cycle counters) plus per-SM data state
(registers, shared memory) and the one shared global memory. This is exact
— not an approximation — and it is what lets the whole per-step execute
stage (ALU + LOD/STO/GLD/GST data path) run as one ``(n_sms, 512)`` batch
through a pluggable backend (``executor.ExecBackend``): the inline jnp
path or the Pallas ``simt_alu``/``simt_step`` kernels as grids over the
SM batch. Functional waves run on one of two bit-identical ENGINES
(``launch(..., engine=)``): the stepping machine below, or the
trace-compiled scan of ``core.trace_engine`` (decode-once schedules; the
default via ``"auto"``).

The same property makes each block's *timing* a static function of its
program (``cycles.program_trace``), which is how dynamic scheduling stays
exact: ``core.scheduler`` replays the per-block traces against per-SM
sequencers and the single global port for timing, while architectural
results are still computed by the lockstep batch machine per program in a
canonical order (program-major, block order). Functional state is
therefore invariant to the dispatch discipline; only the cycle accounting
differs.

Global-memory semantics (the packed-sector memory model):

  * reads (GLD) see the segment as of the start of the cycle;
  * writes (GST) drain through the single port sequentially in
    (sm, thread) order, so on address collisions the LAST writer — highest
    thread of the highest SM — wins, mirroring the shared-memory
    single-write-port determinism;
  * waves run back to back: a later wave sees every earlier wave's global
    writes (this is how grid-wide reductions hand partials forward).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import isa, trace_engine
from .cycles import ProgramTrace, program_trace
from .isa import NUM_CLASSES, Op
from .packing import PACKINGS, WavePacking, pack_waves
from .scheduler import SCHEDULES, Schedule, schedule_blocks
from .machine import (
    LOOP_STACK_DEPTH,
    MAX_THREADS,
    N_REGS,
    N_SP,
    RET_STACK_DEPTH,
    MachineState,
    SMConfig,
    as_u32_image,
)
from .executor import (
    _CLASS_OF,
    _G_CTL,
    _G_GLD,
    _G_GST,
    _G_LOD,
    _G_NOP,
    _G_SFU,
    _G_STO,
    _GROUP_OF_OP,
    DATA_SEL_OF_OP,
    _decode,
    get_execute_backend,
    make_data_handlers,
    pack_imem,
)

_U32 = jnp.uint32
_I32 = jnp.int32
_F32 = jnp.float32


def _bitcast_f32(x):
    return jax.lax.bitcast_convert_type(x, _F32)


# ---------------------------------------------------------------------------
# configuration + state
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceConfig:
    """Sector-level machine parameters wrapping the per-SM ``SMConfig``."""

    n_sms: int = 4                    # SMs packed in the sector (§III.E: 4)
    global_mem_depth: int = 4096      # words of the shared global segment
    sm: SMConfig = SMConfig()         # per-SM template (block size is set
                                      # per launch; the rest is inherited;
                                      # imem/shmem depth are the CEILING for
                                      # per-Kernel overrides)
    backend: str = "inline"           # default execute backend
    schedule: str = "auto"            # default block-dispatch discipline:
                                      # "static" waves | "dynamic" queue |
                                      # "auto" (static iff one program)
    engine: str = "auto"              # default functional engine:
                                      # "step" while-loop machine | "trace"
                                      # decode-once scan | "auto" (trace
                                      # whenever the static trace halts)
    packing: str = "grid"             # default wave-packing policy:
                                      # "grid" chunks (opt-in-stable
                                      # default) | "length" pad-minimal
                                      # waves | "auto" (length for mixed
                                      # grids — see core.packing)
    dispatch_latency: int = 0         # host cycles to dispatch one launch
                                      # (arXiv 2401.04261's host dispatch
                                      # latency; 0 = free, the pre-serving
                                      # model)
    queue_latency: int = 0            # extra host cycles per entry sitting
                                      # in the launch queue at dispatch
                                      # time (launch(queue_depth=) — the
                                      # LaunchServer wires this up)

    def __post_init__(self):
        if self.n_sms < 1:
            raise ValueError(f"n_sms={self.n_sms} must be >= 1")
        if self.global_mem_depth < 1:
            raise ValueError("global_mem_depth must be >= 1")
        if self.dispatch_latency < 0 or self.queue_latency < 0:
            raise ValueError("dispatch_latency/queue_latency must be >= 0")
        if self.schedule not in SCHEDULES + ("auto",):
            raise ValueError(f"schedule={self.schedule!r} must be one of "
                             f"{SCHEDULES + ('auto',)}")
        if self.engine not in trace_engine.ENGINES + ("auto",):
            raise ValueError(f"engine={self.engine!r} must be one of "
                             f"{trace_engine.ENGINES + ('auto',)}")
        if self.packing not in PACKINGS:
            raise ValueError(f"packing={self.packing!r} must be one of "
                             f"{PACKINGS}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeviceState:
    """One wave's batched machine state (a JAX pytree).

    Data state is per-SM (leading ``n_sms`` axis); sequencer state is
    shared across the lockstep batch; global memory is one segment.
    """

    regs: jax.Array        # (n_sms, MAX_THREADS, N_REGS) uint32
    shmem: jax.Array       # (n_sms, shmem_depth) uint32
    gmem: jax.Array        # (global_mem_depth,) uint32 — SHARED
    pc: jax.Array          # () int32
    ret_stack: jax.Array   # (RET_STACK_DEPTH,) int32
    ret_sp: jax.Array      # () int32
    loop_ctr: jax.Array    # (LOOP_STACK_DEPTH,) int32
    loop_sp: jax.Array     # () int32
    halted: jax.Array      # () bool
    oob: jax.Array         # (n_sms,) bool — per-SM out-of-range access
    steps: jax.Array       # () int32
    cycles: jax.Array      # () int32 — wave cycles incl. gmem contention
    cycles_by_class: jax.Array  # (NUM_CLASSES,) int32

    def replace(self, **kw) -> "DeviceState":
        return dataclasses.replace(self, **kw)

    @property
    def n_sms(self) -> int:
        return self.regs.shape[0]


def init_device_state(cfg: SMConfig, n_sms: int, gmem_depth: int = 64,
                      shmem: Any = None, gmem: Any = None) -> DeviceState:
    """Fresh wave state. ``shmem`` may be None, one image (broadcast to all
    SMs), or an (n_sms, ...) batch of per-SM images."""
    if shmem is None:
        sh = jnp.zeros((n_sms, cfg.shmem_depth), _U32)
    else:
        sh = as_u32_image(shmem, cfg.shmem_depth, "shared-memory")
        if sh.ndim == 1:
            sh = jnp.broadcast_to(sh, (n_sms, cfg.shmem_depth))
        elif sh.shape[0] != n_sms:
            raise ValueError(f"shared-memory batch of {sh.shape[0]} images "
                             f"!= n_sms={n_sms}")
    if gmem is None:
        gm = jnp.zeros((gmem_depth,), _U32)
    else:
        gm = as_u32_image(gmem, gmem_depth, "global-memory")
    return DeviceState(
        regs=jnp.zeros((n_sms, MAX_THREADS, N_REGS), _U32),
        shmem=sh,
        gmem=gm,
        pc=jnp.zeros((), _I32),
        ret_stack=jnp.zeros((RET_STACK_DEPTH,), _I32),
        ret_sp=jnp.zeros((), _I32),
        loop_ctr=jnp.zeros((LOOP_STACK_DEPTH,), _I32),
        loop_sp=jnp.zeros((), _I32),
        halted=jnp.zeros((), jnp.bool_),
        oob=jnp.zeros((n_sms,), jnp.bool_),
        steps=jnp.zeros((), _I32),
        cycles=jnp.zeros((), _I32),
        cycles_by_class=jnp.zeros((NUM_CLASSES,), _I32),
    )


def lift_machine_state(state: MachineState, gmem_depth: int = 64) -> DeviceState:
    """Wrap a legacy single-SM ``MachineState`` as a 1-SM wave."""
    return DeviceState(
        regs=state.regs[None], shmem=state.shmem[None],
        gmem=jnp.zeros((gmem_depth,), _U32),
        pc=state.pc, ret_stack=state.ret_stack, ret_sp=state.ret_sp,
        loop_ctr=state.loop_ctr, loop_sp=state.loop_sp,
        halted=state.halted, oob=jnp.reshape(state.oob, (1,)),
        steps=state.steps, cycles=state.cycles,
        cycles_by_class=state.cycles_by_class,
    )


def squeeze_device_state(s: DeviceState) -> MachineState:
    """Project a 1-SM wave back to the legacy ``MachineState`` view."""
    return MachineState(
        regs=s.regs[0], shmem=s.shmem[0], pc=s.pc,
        ret_stack=s.ret_stack, ret_sp=s.ret_sp,
        loop_ctr=s.loop_ctr, loop_sp=s.loop_sp,
        halted=s.halted, oob=s.oob[0], steps=s.steps, cycles=s.cycles,
        cycles_by_class=s.cycles_by_class,
    )


# ---------------------------------------------------------------------------
# the batched device step
# ---------------------------------------------------------------------------

def _device_step(cfg: SMConfig, backend, imem_lo, imem_hi, block_idx,
                 prog_idx, s: DeviceState) -> DeviceState:
    n_sms = s.regs.shape[0]
    d = _decode(imem_lo[s.pc], imem_hi[s.pc])
    tid = jnp.arange(MAX_THREADS, dtype=_I32)
    lane = tid % N_SP
    wave = tid // N_SP

    # ---- flexible-ISA active mask (identical across the lockstep batch) ----
    n_waves = cfg.n_waves
    depth_table = jnp.array(
        [n_waves, max(1, n_waves // 2), max(1, n_waves // 4), 1], _I32)
    width_table = jnp.array([16, 8, 4, 1], _I32)
    act_waves = depth_table[d["depth"]]
    act_wthreads = width_table[d["width"]]
    active = (lane < act_wthreads) & (wave < act_waves) & (tid < cfg.n_threads)

    op = d["opcode"]

    # ---- data path: the shared execute stage (executor.make_data_handlers) --
    handlers = make_data_handlers(cfg, backend, d, active, block_idx,
                                  prog_idx)
    sel = jnp.asarray(DATA_SEL_OF_OP)[op]
    regs, shmem, gmem, oob = jax.lax.switch(
        sel, handlers, (s.regs, s.shmem, s.gmem, s.oob))

    # ---- sequencer: control flow (unconditional scalar math — non-control
    # opcodes match none of the branches, so stacks stay put and pc += 1) ----
    imm = d["imm_raw"]
    pc1 = s.pc + 1
    # LOOP: decrement top counter; jump while > 1, pop at 1
    lsp = jnp.clip(s.loop_sp - 1, 0, LOOP_STACK_DEPTH - 1)
    top = s.loop_ctr[lsp]
    loop_taken = top > 1
    pc = jnp.select(
        [op == int(Op.JMP), op == int(Op.JSR), op == int(Op.RTS),
         op == int(Op.LOOP)],
        [imm, imm,
         s.ret_stack[jnp.clip(s.ret_sp - 1, 0, RET_STACK_DEPTH - 1)],
         jnp.where(loop_taken, imm, pc1)],
        pc1)
    ret_stack = jnp.where(
        op == int(Op.JSR),
        s.ret_stack.at[jnp.clip(s.ret_sp, 0, RET_STACK_DEPTH - 1)].set(pc1),
        s.ret_stack)
    ret_sp = s.ret_sp + jnp.where(op == int(Op.JSR), 1, 0) \
        - jnp.where(op == int(Op.RTS), 1, 0)
    loop_ctr = jnp.where(
        op == int(Op.INIT),
        s.loop_ctr.at[jnp.clip(s.loop_sp, 0, LOOP_STACK_DEPTH - 1)].set(imm),
        jnp.where(op == int(Op.LOOP),
                  s.loop_ctr.at[lsp].set(top - 1), s.loop_ctr))
    loop_sp = s.loop_sp \
        + jnp.where(op == int(Op.INIT), 1, 0) \
        - jnp.where((op == int(Op.LOOP)) & ~loop_taken, 1, 0)
    halted = s.halted | (op == int(Op.STOP))
    group = jnp.asarray(_GROUP_OF_OP)[op]

    # ---- cycle accounting ----------------------------------------------------
    # Per-SM resources (ALU, shared memory, extension units) run concurrently
    # across the lockstep batch; the single global-memory port serializes the
    # batch, so GLD/GST pay n_sms * active_threads (cycles.py).
    act_threads = act_waves * act_wthreads
    one = jnp.int32(1)
    is_gmem = (group == _G_GLD) | (group == _G_GST)
    cyc = jnp.select(
        [group == _G_LOD, group == _G_STO, is_gmem,
         (group == _G_NOP) | (group == _G_CTL) | (group == _G_SFU)],
        [jnp.maximum(one, (act_threads + 3) // 4), act_threads,
         act_threads * n_sms, one],
        act_waves)
    klass = jnp.asarray(_CLASS_OF)[op, d["typ"]]
    return DeviceState(
        regs=regs, shmem=shmem, gmem=gmem, pc=pc,
        ret_stack=ret_stack, ret_sp=ret_sp,
        loop_ctr=loop_ctr, loop_sp=loop_sp,
        halted=halted, oob=oob,
        steps=s.steps + 1,
        cycles=s.cycles + cyc,
        cycles_by_class=s.cycles_by_class.at[klass].add(cyc),
    )


@functools.partial(jax.jit, static_argnums=(0, 1))
def run_wave(cfg: SMConfig, backend: str, imem_lo, imem_hi, block_idx,
             prog_idx, state: DeviceState) -> DeviceState:
    """Run one wave of blocks to completion (jitted ``lax.while_loop``).

    This is the STEP engine: fetch/decode/dispatch per instruction. The
    trace engine (``core.trace_engine``) is the decode-once fast path;
    this machine survives as the differential oracle and the executor of
    legacy ``run``/``run_many`` shims.
    """
    execute = get_execute_backend(backend)

    def cond(s):
        return (~s.halted) & (s.steps < cfg.max_steps) \
            & (s.pc >= 0) & (s.pc < cfg.imem_depth)

    def body(s):
        return _device_step(cfg, execute, imem_lo, imem_hi, block_idx,
                            prog_idx, s)

    return jax.lax.while_loop(cond, body, state)


# ---------------------------------------------------------------------------
# buffers: named global-memory segments
# ---------------------------------------------------------------------------

def buffer_layout(buffers: Mapping[str, Any]) -> dict[str, tuple[int, int]]:
    """Deterministic gmem layout: name -> (offset, length) in 32-bit words,
    packed in insertion order from offset 0. Program builders call this to
    derive addresses; ``launch`` uses the same layout to fill gmem."""
    layout: dict[str, tuple[int, int]] = {}
    off = 0
    for name, arr in buffers.items():
        n = int(np.asarray(arr).reshape(-1).shape[0])
        layout[name] = (off, n)
        off += n
    return layout


def pack_buffers(buffers: Mapping[str, Any], depth: int
                 ) -> tuple[jax.Array, dict[str, tuple[int, int]]]:
    """Pack named host arrays into one global-memory image of ``depth``."""
    layout = buffer_layout(buffers)
    used = sum(n for _, n in layout.values())
    if used > depth:
        raise ValueError(f"buffers need {used} words but global_mem_depth "
                         f"is {depth}")
    img = jnp.zeros((depth,), _U32)
    for name, arr in buffers.items():
        off, n = layout[name]
        img = img.at[off:off + n].set(
            as_u32_image(np.asarray(arr).reshape(-1), n, name))
    return img, layout


# ---------------------------------------------------------------------------
# the launch API
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Kernel:
    """One program of a (possibly multi-program) launch.

    ``launch(..., programs=[...])`` accepts Kernels, assembled Programs, or
    raw word arrays; a bare program gets the device defaults. ``block`` is
    threads per block, ``dim_x`` the TDX/TDY x-extent (defaults to
    ``block``: flat 1-D indexing), ``name`` labels the program in
    ``LaunchResult.profile()``. ``barrier=True`` makes this program's
    blocks wait until every block of all earlier-listed programs retired
    (a device-wide dependency fence — the stream semantic for dependent
    kernels such as the two stages of a grid reduction).

    ``imem_depth``/``shmem_depth`` override the device-wide ``SMConfig``
    defaults for THIS program only (e.g. a small kernel that wants tight
    out-of-range checking, or a long unrolled one that needs the full
    I-MEM); both are validated against the device ceiling — an SM cannot
    grow memory past what the sector floorplan gives it. Blocks with a
    shallower shared memory are zero-padded back to the device depth in
    ``LaunchResult.shmem`` so mixed launches still stack.

    ``priority`` orders the DYNAMIC dispatch queue: ready blocks of a
    higher-priority program are pulled first; ties keep FIFO grid order
    (priority 0, the default, is plain FIFO — bit-identical scheduling to
    a priority-free launch). The static wave schedule ignores priority
    (waves are grid order by definition), and functional results are
    schedule-invariant either way.
    """

    program: Any                      # Program | encoded 40-bit word array
    block: int | None = None
    dim_x: int | None = None
    name: str | None = None
    barrier: bool = False
    imem_depth: int | None = None
    shmem_depth: int | None = None
    priority: int = 0


def as_kernel(p: Any) -> Kernel:
    return p if isinstance(p, Kernel) else Kernel(program=p)


@dataclasses.dataclass
class LaunchResult:
    """Per-block results + aggregate device profile of one launch."""

    grid: tuple[int, ...]
    block: int | tuple[int, ...]  # threads/block (per program if mixed)
    n_waves: int                # scheduling rounds (0 for dynamic dispatch)
    regs: jax.Array             # (n_blocks, MAX_THREADS, N_REGS) uint32
    shmem: jax.Array            # (n_blocks, shmem_depth) uint32
    gmem: jax.Array             # (global_mem_depth,) uint32 — final
    oob: jax.Array              # (n_blocks,) bool
    halted: bool                # every block ran to STOP
    steps: int                  # instructions issued (per sequencer)
    cycles: int                 # modeled device cycles for the launch
    wave_cycles: np.ndarray     # (n_waves,) per-round cycles (static only)
    cycles_by_class: np.ndarray  # (NUM_CLASSES,) sequencer occupancy
    buffer_offsets: dict[str, tuple[int, int]] | None = None
    # scheduling (None only for results built by legacy external code)
    schedule: str = "static"            # "static" | "dynamic"
    engine: str = "step"                # "step" | "trace" functional engine
    engine_fallback: str | None = None  # why "auto" degraded to "step"
    program_names: tuple[str, ...] = ("k0",)
    grid_map: np.ndarray | None = None  # (n_blocks,) block -> program idx
    timing: Schedule | None = None      # per-SM / per-block timeline
    static_cycles: int | None = None    # wave-schedule baseline makespan
    trace_merge: dict[str, Any] | None = None  # heterogeneous-wave stats
    packing: str = "grid"               # resolved wave-packing policy
    wave_packing: WavePacking | None = None  # the membership decision
    host_dispatch: dict[str, int] | None = None  # launch-queue/dispatch
                                        # latency model (non-None exactly
                                        # when the device models it)
    priority_respected: bool = True     # False iff Kernel(priority=) was
                                        # requested but the static wave
                                        # schedule ignored it
    fleet: dict[str, Any] | None = None  # multi-device fleet view
                                        # (core.fleet.launch_fleet):
                                        # per-device occupancy, routing,
                                        # placement, NUMA charges

    @property
    def n_blocks(self) -> int:
        return int(self.grid[0])

    def shmem_f32(self) -> jax.Array:
        return _bitcast_f32(self.shmem)

    def gmem_f32(self) -> jax.Array:
        return _bitcast_f32(self.gmem)

    def buffer(self, name: str, dtype=jnp.float32) -> jax.Array:
        """Final contents of a named gmem buffer (bitcast to ``dtype``)."""
        if not self.buffer_offsets or name not in self.buffer_offsets:
            raise KeyError(f"no buffer {name!r} in this launch")
        off, n = self.buffer_offsets[name]
        seg = self.gmem[off:off + n]
        if dtype in (jnp.uint32, np.uint32):
            return seg
        return jax.lax.bitcast_convert_type(seg, dtype)

    def profile(self) -> dict[str, Any]:
        """Aggregate cycle profile (Tables III/IV view + the GMEM row),
        extended with the scheduler's per-SM / per-program occupancy view.

        ``per_sm[i]``: busy (issuing), wait (stalled on the global port),
        idle (no block to run) cycles and blocks retired for SM ``i``.
        ``per_program[name]``: blocks, busy cycles, port-wait cycles, and
        the per-SM busy split — the occupancy fractions are of the
        launch's total modeled cycles. ``gmem_port`` summarizes the single
        device-wide port: occupancy, queueing, and utilization.

        ``engine_fallback`` is non-None exactly when ``engine="auto"``
        degraded to the step machine (never silently); ``packing`` is
        the resolved wave-packing policy; ``trace_merge`` appears when
        the trace engine batched heterogeneous waves and reports the
        packing policy, per-wave merge padding, and the launch-level
        ``pad_overhead_total`` aggregate.
        """
        by = np.asarray(self.cycles_by_class)
        total = int(by.sum())
        out: dict[str, Any] = {
            "total_cycles": int(self.cycles),
            "instructions": int(self.steps),
            "schedule": self.schedule,
            "engine": self.engine,
            "engine_fallback": self.engine_fallback,
            "packing": self.packing,
            "priority_respected": self.priority_respected,
            "n_waves": self.n_waves,
            "wave_cycles": [int(c) for c in self.wave_cycles],
            "by_class": {n: int(c) for n, c in zip(isa.CLASS_NAMES, by)},
            "pct_by_class": {n: (100.0 * int(c) / total if total else 0.0)
                             for n, c in zip(isa.CLASS_NAMES, by)},
        }
        if self.trace_merge is not None:
            out["trace_merge"] = self.trace_merge
        if self.host_dispatch is not None:
            out["host_dispatch"] = dict(self.host_dispatch)
        if self.fleet is not None:
            out["fleet"] = dict(self.fleet)
        t = self.timing
        if t is None:
            return out
        span = max(int(self.cycles), 1)
        busy, wait, idle = t.sm_busy, t.sm_wait, t.sm_idle
        out["per_sm"] = [
            {"busy": int(busy[i]), "wait": int(wait[i]),
             "idle": int(idle[i]), "blocks": int(t.sm_blocks[i]),
             "occupancy": int(busy[i]) / span}
            for i in range(t.n_sms)]
        gmap = np.asarray(self.grid_map)
        per_prog: dict[str, Any] = {}
        for k, name in enumerate(self.program_names):
            mine = gmap == k
            sm_busy_k = np.zeros(t.n_sms, np.int64)
            np.add.at(sm_busy_k, t.block_sm[mine], t.block_busy[mine])
            per_prog[name] = {
                "blocks": int(mine.sum()),
                "busy_cycles": int(t.block_busy[mine].sum()),
                "gmem_wait": int(t.block_wait[mine].sum()),
                "sm_busy": [int(c) for c in sm_busy_k],
                "sm_occupancy": [int(c) / span for c in sm_busy_k],
            }
        out["per_program"] = per_prog
        out["gmem_port"] = {
            "busy": t.port_busy,
            "wait": t.port_wait,
            "utilization": t.port_busy / span,
        }
        out["static_cycles"] = int(self.static_cycles) \
            if self.static_cycles is not None else int(self.cycles)
        return out


_STATIC_PRIORITY_WARNED = False


def _warn_static_priority() -> None:
    """Warn (once per process) that Kernel(priority=) was silently lost:
    the static wave schedule dispatches in grid order by definition, so a
    prioritized launch run static gets FIFO treatment. The condition is
    also surfaced per launch as profile()["priority_respected"]."""
    global _STATIC_PRIORITY_WARNED
    if _STATIC_PRIORITY_WARNED:
        return
    _STATIC_PRIORITY_WARNED = True
    warnings.warn(
        "Kernel(priority=) is ignored under schedule='static': waves "
        "dispatch in grid order. Use schedule='dynamic' (or 'auto' on a "
        "multi-program grid) for priority-aware dispatch; see "
        "LaunchResult.profile()['priority_respected'].",
        UserWarning, stacklevel=3)


def _resolve_schedule(schedule: str | None, dcfg: DeviceConfig,
                      n_programs: int) -> str:
    mode = schedule if schedule is not None else dcfg.schedule
    if mode == "auto":
        return "static" if n_programs == 1 else "dynamic"
    if mode not in SCHEDULES:
        raise ValueError(f"schedule={mode!r} must be one of "
                         f"{SCHEDULES + ('auto',)}")
    return mode


def _kernel_shmem(sh: Any, depth: int, count: int, k: int):
    """Normalize one program's shared-memory init: None, one image
    (broadcast to the program's blocks), or a (count, ...) batch indexed by
    the program-local block index."""
    if sh is None:
        return None
    batch = as_u32_image(sh, depth, f"shared-memory (program {k})")
    if batch.ndim == 1:
        return jnp.broadcast_to(batch, (count, depth))
    if batch.shape[0] != count:
        raise ValueError(f"shared-memory batch of {batch.shape[0]} images "
                         f"!= {count} blocks of program {k}")
    return batch


def _normalize_grid(dcfg: DeviceConfig, program, grid, block, dim_x,
                    programs, grid_map, shmem
                    ) -> tuple[list[Kernel], np.ndarray, list[Any]]:
    """Normalize the two launch forms to ``(kernels, gmap, shmems)`` —
    shared by ``launch`` and the fleet router (``core.fleet``), so both
    front doors accept exactly the same grids."""
    if programs is not None:
        if program is not None or grid is not None or block is not None \
                or dim_x is not None:
            raise ValueError("pass either program/grid/block/dim_x or "
                             "programs=/grid_map=, not both")
        if grid_map is None:
            raise ValueError("programs= requires grid_map=")
        kernels = [as_kernel(p) for p in programs]
        gmap = np.asarray(list(grid_map), np.int64)
        if gmap.ndim != 1 or gmap.shape[0] < 1:
            raise ValueError("grid_map must be a non-empty 1-D sequence")
        if gmap.min() < 0 or gmap.max() >= len(kernels):
            raise ValueError(f"grid_map references programs outside "
                             f"[0, {len(kernels)})")
        shmems = list(shmem) if shmem is not None else [None] * len(kernels)
        if len(shmems) != len(kernels):
            raise ValueError(f"shmem sequence of {len(shmems)} != "
                             f"{len(kernels)} programs")
    else:
        if program is None or grid is None:
            raise ValueError("launch needs program+grid or programs+grid_map")
        grid = (int(grid),) if isinstance(grid, int) \
            else tuple(map(int, grid))
        if len(grid) != 1 or grid[0] < 1:
            raise ValueError(f"grid={grid} must be a positive (n_blocks,)")
        kernels = [Kernel(program=program, block=block, dim_x=dim_x)]
        gmap = np.zeros((grid[0],), np.int64)
        shmems = [shmem]
    return kernels, gmap, shmems


def _lower_kernels(dcfg: DeviceConfig, kernels: Sequence[Kernel]
                   ) -> tuple[list[str], list[SMConfig],
                              list[tuple[jax.Array, jax.Array]],
                              list[ProgramTrace], list[np.ndarray]]:
    """Per-program static resources: unique names, per-kernel SMConfigs
    (with validated imem/shmem overrides), packed I-MEM images, exact
    static traces, and the raw word arrays. Shared by ``launch`` and the
    fleet router so every device in a fleet lowers identically."""
    names: list[str] = []
    cfgs: list[SMConfig] = []
    imems: list[tuple[jax.Array, jax.Array]] = []
    traces: list[ProgramTrace] = []
    word_arrays: list[np.ndarray] = []
    for k, kern in enumerate(kernels):
        blk = int(kern.block) if kern.block is not None \
            else dcfg.sm.n_threads
        overrides = {}
        for field, ceiling in (("imem_depth", dcfg.sm.imem_depth),
                               ("shmem_depth", dcfg.sm.shmem_depth)):
            val = getattr(kern, field)
            if val is None:
                continue
            val = int(val)
            if val < 1:
                raise ValueError(f"{field}={val} of program {k} must be "
                                 f">= 1")
            if val > ceiling:
                raise ValueError(
                    f"{field}={val} of program {k} exceeds the device "
                    f"ceiling {ceiling} (DeviceConfig.sm.{field})")
            overrides[field] = val
        cfg = dataclasses.replace(
            dcfg.sm, n_threads=blk,
            dim_x=kern.dim_x if kern.dim_x is not None else blk,
            **overrides)
        words = kern.program.words if hasattr(kern.program, "words") \
            else np.asarray(kern.program)
        lo, hi = pack_imem(words, cfg.imem_depth)
        cfgs.append(cfg)
        word_arrays.append(np.asarray(words))
        imems.append((jnp.asarray(lo), jnp.asarray(hi)))
        traces.append(program_trace(words, blk, imem_depth=cfg.imem_depth,
                                    max_steps=cfg.max_steps))
        name = kern.name or f"k{k}"
        while name in names:
            name = f"{name}.{k}"
        names.append(name)
    return names, cfgs, imems, traces, word_arrays


def _resolve_engine(engine: str | None, dcfg: DeviceConfig,
                    traces: Sequence[ProgramTrace]
                    ) -> tuple[str, str | None]:
    """Resolve the functional engine; returns ``(engine, fallback)``.

    ``fallback`` is non-None exactly when ``"auto"`` degraded from its
    first-choice engine — ``"auto"`` never degrades silently; the reason
    is surfaced as ``LaunchResult.profile()["engine_fallback"]``. The
    auto ladder is megakernel (fused segments, fastest on schedules with
    real fusible runs) -> trace (scanned schedule, when a program's
    schedule exceeds the megakernel unroll cap) -> step (O(1) schedule
    memory, when a fuel-limited trace means a runaway program; ALSO the
    fallback when every program is too short for fusion to pay —
    compiled-engine dispatch glue dominates tiny schedules, see
    ``trace_engine.MEGAKERNEL_MIN_FUSED_ROWS``).
    """
    mode = engine if engine is not None else dcfg.engine
    if mode == "auto":
        # the trace/megakernel engines materialize the full issued
        # schedule; a fuel-limited (non-halting) trace means a runaway
        # program, where the step machine's O(1) schedule memory is the
        # right tool
        if not all(t.halted for t in traces):
            return "step", "fuel-limited-trace"
        if max(t.data_steps for t in traces) \
                > trace_engine.MEGAKERNEL_UNROLL_CAP:
            return "trace", "megakernel-unroll-cap"
        # plan-time cost cutoff: residual rows = data rows that are not
        # global-port accesses, i.e. what the megakernel can actually
        # fuse. When even the longest program is below the threshold
        # there is nothing to amortize the compiled-engine overhead
        # against and the step machine wins (BENCH_engine.json,
        # saxpy256_b64: megakernel 0.811x vs step)
        residual = max(t.data_steps
                       - sum(1 for i in t.instrs if i.gmem)
                       for t in traces)
        if residual < trace_engine.MEGAKERNEL_MIN_FUSED_ROWS:
            return "step", "megakernel-too-small"
        return "megakernel", None
    if mode not in trace_engine.ENGINES:
        raise ValueError(f"engine={mode!r} must be one of "
                         f"{trace_engine.ENGINES + ('auto',)}")
    return mode, None


def launch(dcfg: DeviceConfig, program=None, grid=None,
           block: int | None = None, *,
           programs: Sequence[Any] | None = None,
           grid_map: Sequence[int] | None = None,
           buffers: Mapping[str, Any] | None = None,
           shmem: Any = None, gmem: Any = None,
           backend: str | None = None, dim_x: int | None = None,
           schedule: str | None = None,
           engine: str | None = None,
           packing: str | None = None,
           queue_depth: int = 0,
           block_ids: Sequence[int] | None = None) -> LaunchResult:
    """CUDA-style kernel launch on the multi-SM device.

    Two forms:

    * single-program: ``launch(dcfg, program, grid=(n_blocks,), block=n)``
      — the PR-1 API, unchanged;
    * multi-program: ``launch(dcfg, programs=[...], grid_map=[...])`` —
      ``programs`` is a list of ``Kernel``s (or bare programs) and
      ``grid_map[b]`` names the program block ``b`` runs. Blocks are
      dispatched to the SM work queues in ``grid_map`` order; each block's
      ``BID`` is its index *within its own program's grid* and ``PID`` its
      program index, so concurrently-launched kernels address their own
      data.

    Args:
      dcfg: the device (sector) configuration.
      program: an assembled ``Program`` or encoded 40-bit word array.
      grid: number of thread blocks, as ``(n_blocks,)`` or an int.
      block: threads per block (<= 512); defaults to ``dcfg.sm.n_threads``.
      programs: the multi-program form (mutually exclusive with
        ``program``/``grid``/``block``/``dim_x``).
      grid_map: (n_blocks,) program index per block, in dispatch order.
      buffers: named host arrays packed into global memory from offset 0 in
        insertion order (layout via ``buffer_layout``); mutually exclusive
        with ``gmem``, a raw initial global-memory image.
      shmem: shared-memory initializer. Single-program: one image broadcast
        to all blocks, or an ``(n_blocks, ...)`` batch. Multi-program: a
        sequence aligned with ``programs`` whose entries are None, one
        image, or an ``(n_blocks_of_program, ...)`` batch.
      backend: execute backend ("inline" | "pallas"); default from dcfg.
      dim_x: the 2-D thread-space x extent (TDX/TDY); defaults to ``block``
        (flat 1-D indexing, the CUDA idiom).
      schedule: "static" (lockstep waves of ``n_sms`` blocks), "dynamic"
        (per-SM sequencers pulling from the block work queue), or "auto"
        (default: static when all blocks share one program — the exact
        lockstep fast path — dynamic otherwise).
      engine: functional execution engine. "step" is the classic
        fetch/decode/dispatch ``lax.while_loop`` machine; "trace" lowers
        each program once into a pre-decoded structure-of-arrays schedule
        and runs it as a single jitted ``lax.scan`` (no runtime decode, no
        dynamic pc, NOP/control steps compiled out — see
        ``core.trace_engine``); "megakernel" further fuses each segment
        between global-port accesses into one kernel with host-constant
        fields and masks (no per-row switch; the Pallas backend keeps
        registers/shmem VMEM-resident across the fused steps). On a
        heterogeneous grid both compiled engines MERGE the programs into
        shared waves: the trace engine scans one padded merged schedule
        (``profile()["trace_merge"]`` reports the padding), the
        megakernel dispatches fused segments per live slot with the gmem
        rows globally ordered (``trace_merge`` gains per-segment
        ``fusion`` stats instead — no padded rows execute). "auto"
        (default) picks "megakernel" whenever every program's static
        trace terminates and fits the unroll cap, degrading to "trace"
        above the cap and to "step" for runaway/fuel-limited programs —
        never silently: ``profile()["engine_fallback"]`` names the
        reason. All engines are bit-identical on every backend; timing
        is engine-independent.
      packing: wave-packing policy deciding WHICH blocks share a wave
        within each barrier phase (``core.packing``). "grid" (the
        default) chunks blocks in grid order — byte-identical to the
        pre-packing device. "length" stably sorts each phase by
        descending schedule length and picks pad-minimal wave
        boundaries, so a mixed grid's merged waves stop padding short
        programs to long ones. "auto" resolves to "length" exactly when
        a phase mixes schedule lengths. One packing feeds every layer:
        the merged functional waves, the static wave timing, and the
        dynamic queue's FIFO tiebreak — so ``cycles``/``wave_cycles``
        describe the waves that actually ran and dynamic-vs-static stays
        a like-for-like comparison.

    Timing comes from ``core.scheduler`` over the programs' static traces;
    architectural results are computed by exact lockstep batch machines.
    The step machine runs a canonical program-major order; the trace
    engine's merged heterogeneous waves follow the wave packing (grid
    order within each barrier phase under the default policy). The two
    coincide — and results are invariant to the dispatch discipline, to
    the packing policy, and to ``grid_map`` permutations of equal-program
    blocks — under the standard launch contract that blocks which may run
    concurrently (same phase) do not race through global memory; use
    ``Kernel(barrier=True)`` to fence cross-block dataflow. Packing
    therefore only changes which blocks share a wave (and with it the
    modeled timing and merge padding), never observable state.

    ``block_ids`` is the fleet router seam (``core.fleet``): a
    ``(n_blocks,)`` override of each block's program-local ``BID``. A
    fleet sub-launch runs only its device's slice of the grid, but every
    block must still see its FLEET-level block id — saxpy's
    ``gid = BID*block + TDX`` has to address the same global elements no
    matter which device the block landed on. Default (None): block ``b``'s
    BID is its index within its own program's grid, the single-device
    behaviour, bit-identical to the pre-fleet device.

    ``queue_depth`` is the launch-queue depth at dispatch time — how many
    launches (including this one) the host had queued when it dispatched
    this one. The launch is charged ``dcfg.dispatch_latency +
    dcfg.queue_latency * queue_depth`` host cycles before any block
    issues (``scheduler.schedule_blocks(start_cycle=)``), modeling the
    dispatch path arXiv 2401.04261 measures; the charge is surfaced as
    ``profile()["host_dispatch"]``. The serving front door
    (``serve.LaunchServer``) wires its admission-queue depth in here;
    with the default zero latencies the model is free and the profile key
    is absent — bit-identical to the pre-serving device.
    """
    # ---- normalize to kernels + grid_map --------------------------------
    kernels, gmap, shmems = _normalize_grid(dcfg, program, grid, block,
                                            dim_x, programs, grid_map,
                                            shmem)
    n_blocks = int(gmap.shape[0])
    bids = None
    if block_ids is not None:
        bids = np.asarray(list(block_ids), np.int64)
        if bids.shape != (n_blocks,):
            raise ValueError(f"block_ids has shape {bids.shape}, want "
                             f"({n_blocks},)")
        if (bids < 0).any():
            raise ValueError("block_ids must be non-negative")
    backend = backend or dcfg.backend
    mode = _resolve_schedule(schedule, dcfg, len(kernels))

    # ---- host dispatch latency (the launch-queue model) ------------------
    if queue_depth < 0:
        raise ValueError(f"queue_depth={queue_depth} must be >= 0")
    host_latency = dcfg.dispatch_latency + dcfg.queue_latency * queue_depth
    host_dispatch = None
    if dcfg.dispatch_latency or dcfg.queue_latency:
        host_dispatch = {
            "queue_depth": int(queue_depth),
            "dispatch_cycles": int(dcfg.dispatch_latency),
            "queue_cycles": int(dcfg.queue_latency * queue_depth),
            "latency_cycles": int(host_latency),
        }

    # ---- priority visibility: static waves ignore Kernel(priority=) -----
    prioritized = any(k.priority for k in kernels)
    priority_respected = (mode == "dynamic") or not prioritized
    if prioritized and mode == "static":
        _warn_static_priority()

    # ---- per-program static resources -----------------------------------
    names, cfgs, imems, traces, word_arrays = _lower_kernels(dcfg, kernels)
    eng, eng_fallback = _resolve_engine(engine, dcfg, traces)
    present = [k for k in range(len(kernels)) if (gmap == k).any()]
    # heterogeneous grids take the MERGED path on both compiled engines:
    # blocks of different programs share one wave, executed either as a
    # single scan over the padded merged schedule
    # (trace_engine.MergedTraceSchedule) or as per-slot fused segments
    # with globally-ordered gmem rows (MergedMegakernelPlan)
    use_merged = eng in ("trace", "megakernel") and len(present) > 1
    # lower only the kernels that actually own blocks in this grid (the
    # merged path lowers through the same per-program compile cache)
    scheds = [trace_engine.compile_program(w, c)
              if eng == "trace" and not use_merged and (gmap == k).any()
              else None
              for k, (w, c) in enumerate(zip(word_arrays, cfgs))]
    plans = [trace_engine.compile_megakernel(w, c)
             if eng == "megakernel" and not use_merged
             and (gmap == k).any() else None
             for k, (w, c) in enumerate(zip(word_arrays, cfgs))]

    # ---- wave packing: one membership decision for every layer ----------
    # the packer keys on each block's pre-decoded schedule length
    # (``trace.data_steps`` — the scan rows a merged wave pads to, cached
    # on the trace so repeated launches pay nothing); the SAME
    # WavePacking then shapes the merged functional waves, the static
    # wave timing, and the dynamic queue's dispatch order
    phase_of_kernel = np.cumsum([int(k.barrier) for k in kernels])
    block_phase = phase_of_kernel[gmap]
    wp = pack_waves([traces[k].data_steps for k in gmap], dcfg.n_sms,
                    policy=packing if packing is not None
                    else dcfg.packing,
                    phase_of=block_phase)

    # ---- the schedule (timing) ------------------------------------------
    block_priority = np.asarray([kernels[k].priority for k in gmap],
                                np.int64)
    block_traces = [traces[k] for k in gmap]
    timing = schedule_blocks(block_traces, dcfg.n_sms, mode,
                             phase_of=block_phase,
                             priority_of=block_priority,
                             packing=wp, start_cycle=host_latency)
    if mode == "static":
        static_span = timing.makespan
    else:
        static_span = schedule_blocks(block_traces, dcfg.n_sms, "static",
                                      phase_of=block_phase,
                                      packing=wp,
                                      start_cycle=host_latency).makespan

    # ---- global-memory image --------------------------------------------
    offsets = None
    if buffers is not None:
        if gmem is not None:
            raise ValueError("pass either buffers= or gmem=, not both")
        gm, offsets = pack_buffers(buffers, dcfg.global_mem_depth)
    elif gmem is not None:
        gm = as_u32_image(gmem, dcfg.global_mem_depth, "global-memory")
    else:
        gm = jnp.zeros((dcfg.global_mem_depth,), _U32)

    # ---- functional execution (exact lockstep batches) -------------------
    regs_slots: list[Any] = [None] * n_blocks
    shmem_slots: list[Any] = [None] * n_blocks
    oob_slots: list[Any] = [None] * n_blocks
    wave_cycles, wave_steps = [], []
    machine_by = np.zeros((NUM_CLASSES,), np.int64)
    halted = True
    shmem_pad = dcfg.sm.shmem_depth
    merge_stats: dict[str, Any] | None = None
    if use_merged:
        # Heterogeneous waves: the wave packing decides which blocks
        # share a wave (grid order within each barrier phase under the
        # default policy; pad-minimal membership under "length" — a
        # merged wave never spans a fence either way) and each wave runs
        # as ONE merged scan. Cross-program global-memory interactions
        # inside a wave resolve in device order (per-step, program-slot
        # then (sm, thread) drain); as on real hardware, blocks that may
        # run concurrently must not race through global memory —
        # Kernel(barrier=True) is the fence for cross-block dataflow,
        # and under that contract results are bit-identical to the step
        # machine's canonical program-major order for EVERY packing
        # (pinned by tests/test_conformance.py).
        local_bid = np.zeros(n_blocks, np.int64)
        sh_batches: dict[int, Any] = {}
        for k in present:
            pos = np.flatnonzero(gmap == k)
            local_bid[pos] = np.arange(pos.size)
            sh_batches[k] = _kernel_shmem(shmems[k], cfgs[k].shmem_depth,
                                          pos.size, k)
        # one merged schedule per wave SIGNATURE (the programs present):
        # memoized here so the wave loop never re-keys the word arrays;
        # the packed membership decides which signatures (multisets of
        # (program, SMConfig) pairs) ever get compiled
        msched_of: dict[tuple[int, ...], Any] = {}

        def merged_sched(sig):
            if sig not in msched_of:
                progs = [word_arrays[k] for k in sig]
                cs = [cfgs[k] for k in sig]
                msched_of[sig] = \
                    trace_engine.compile_merged_megakernel(progs, cs) \
                    if eng == "megakernel" \
                    else trace_engine.compile_merged(progs, cs)
            return msched_of[sig]

        per_wave: list[dict[str, Any]] = []
        for wave_ids in wp.waves:
            wave = np.asarray(wave_ids, np.int64)
            sig = tuple(sorted({int(gmap[b]) for b in wave}))
            msched = merged_sched(sig)
            slot = np.asarray([sig.index(int(gmap[b])) for b in wave])
            # slot-major member order: each program's dispatch runs on
            # a contiguous sub-batch (grid order kept within a slot)
            order = np.argsort(slot, kind="stable")
            blocks, slot = wave[order], slot[order]
            counts = np.bincount(slot, minlength=len(sig))
            n = blocks.size
            pids = gmap[blocks]
            # per-slot shared-memory init, padded to the device depth
            # and concatenated along the slot-major member order
            segs, off = [], 0
            for j, k in enumerate(sig):
                c = int(counts[j])
                batch = sh_batches[k]
                if batch is None:
                    segs.append(jnp.zeros((c, shmem_pad), _U32))
                else:
                    img = batch[local_bid[blocks[off:off + c]]]
                    if img.shape[1] < shmem_pad:
                        img = jnp.pad(
                            img,
                            ((0, 0), (0, shmem_pad - img.shape[1])))
                    segs.append(img)
                off += c
            sh0 = jnp.concatenate(segs, axis=0)
            run_merged = trace_engine.run_wave_merged_megakernel \
                if eng == "megakernel" else trace_engine.run_wave_merged
            engine_bid = bids if bids is not None else local_bid
            regs_f, sh_f, gm, oob_f = run_merged(
                backend, msched, counts, engine_bid[blocks], pids,
                jnp.zeros((n, MAX_THREADS, N_REGS), _U32), sh0, gm,
                jnp.zeros((n,), jnp.bool_))
            for i, b in enumerate(blocks):
                regs_slots[b] = regs_f[i]
                shmem_slots[b] = sh_f[i]
                oob_slots[b] = oob_f[i]
            halted = halted and msched.halted
            rec = {
                "programs": [names[k] for k in sig],
                "width": int(n),
                "scan_steps": int(msched.n_steps),
            }
            if eng == "megakernel":
                # fused segments execute no padded rows: short members
                # simply stop fusing earlier, so the merge's only
                # cross-slot cost is the globally-ordered gmem drains —
                # surfaced as per-wave fusion stats instead
                rec.update(padded_steps=0, pad_overhead=0.0,
                           fusion=msched.stats())
            else:
                pad = int(msched.padded_steps(slot))
                rows = int(msched.n_steps) * n
                rec.update(padded_steps=pad,
                           pad_overhead=(pad / rows) if rows else 0.0)
            per_wave.append(rec)
        merge_stats = trace_engine.merge_profile(per_wave, wp.policy)
    else:
        # homogeneous path: exact lockstep batches per program,
        # program-major
        for k, kern in enumerate(kernels):
            pos = np.flatnonzero(gmap == k)
            if pos.size == 0:
                continue
            cfg, (lo, hi) = cfgs[k], imems[k]
            sh_batch = _kernel_shmem(shmems[k], cfg.shmem_depth, pos.size,
                                     k)
            for w0 in range(0, pos.size, dcfg.n_sms):
                w1 = min(w0 + dcfg.n_sms, pos.size)
                n = w1 - w0
                st = init_device_state(
                    cfg, n, gmem_depth=dcfg.global_mem_depth,
                    shmem=None if sh_batch is None else sh_batch[w0:w1],
                    gmem=gm)
                bidx = jnp.arange(w0, w1, dtype=_I32) if bids is None \
                    else jnp.asarray(bids[pos[w0:w1]], _I32)  # local BID
                pidx = jnp.full((n,), k, dtype=_I32)
                if eng == "trace":
                    fin = trace_engine.run_wave_trace(
                        cfg, backend, scheds[k], bidx, pidx, st)
                elif eng == "megakernel":
                    fin = trace_engine.run_wave_megakernel(
                        backend, plans[k], bidx, pidx, st)
                else:
                    fin = run_wave(cfg, backend, lo, hi, bidx, pidx, st)
                gm = fin.gmem               # batches run back to back
                fin_shmem = fin.shmem
                if cfg.shmem_depth < shmem_pad:
                    # per-Kernel shmem_depth override: pad back to the
                    # device depth so mixed launches still stack in
                    # LaunchResult
                    fin_shmem = jnp.pad(
                        fin_shmem,
                        ((0, 0), (0, shmem_pad - cfg.shmem_depth)))
                for i, b in enumerate(pos[w0:w1]):
                    regs_slots[b] = fin.regs[i]
                    shmem_slots[b] = fin_shmem[i]
                    oob_slots[b] = fin.oob[i]
                wave_cycles.append(int(fin.cycles))
                wave_steps.append(int(fin.steps))
                machine_by += np.asarray(fin.cycles_by_class, np.int64)
                halted = halted and bool(fin.halted)

    # ---- aggregate counters ---------------------------------------------
    if mode == "static" and len(kernels) == 1:
        # the lockstep fast path: one program, shared sequencer per wave —
        # report the batch machine's own counters (bit-identical to PR 1;
        # the host-dispatch charge precedes the first wave)
        cycles = int(sum(wave_cycles)) + int(host_latency)
        steps = int(sum(wave_steps))
        by_class = machine_by
        waves_out = np.asarray(wave_cycles, np.int64)
    else:
        # per-SM sequencers: every block issues its own trace
        cycles = timing.makespan
        steps = sum(t.steps for t in block_traces)
        by_class = np.zeros((NUM_CLASSES,), np.int64)
        for t in block_traces:
            by_class += np.asarray(t.cycles_by_class(), np.int64)
        waves_out = timing.wave_cycles

    return LaunchResult(
        grid=(n_blocks,),
        block=cfgs[0].n_threads if len(kernels) == 1
        else tuple(c.n_threads for c in cfgs),
        n_waves=len(waves_out),
        regs=jnp.stack(regs_slots, axis=0),
        shmem=jnp.stack(shmem_slots, axis=0),
        gmem=gm,
        oob=jnp.stack(oob_slots, axis=0),
        halted=halted,
        steps=steps,
        cycles=cycles,
        wave_cycles=np.asarray(waves_out, np.int64),
        cycles_by_class=by_class.astype(np.int64),
        buffer_offsets=offsets,
        schedule=mode,
        engine=eng,
        engine_fallback=eng_fallback,
        program_names=tuple(names),
        grid_map=gmap,
        timing=timing,
        static_cycles=static_span,
        trace_merge=merge_stats,
        packing=wp.policy,
        wave_packing=wp,
        host_dispatch=host_dispatch,
        priority_respected=priority_respected,
    )
