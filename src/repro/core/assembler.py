"""Two-pass assembler for the eGPU ISA.

Syntax (one instruction per line, ``//`` or ``;`` comments, ``label:`` lines):

    ADD.INT32 R6, R1, R3          // typed 3-operand ALU op
    MUL.FP32  R2, R4, R5 {w1,d1}  // flexible-ISA: single thread
    AND       R7, R1, R4          // logic ops are untyped (bitwise)
    NOT       R3, R1
    LOD       R2, (R1)+5          // shared-memory indexed load
    STO       R2, (R3)+0          // shared-memory indexed store
    GLD       R2, (R1)+5          // GLOBAL-memory load (shared across SMs)
    GST       R2, (R3)+0          // GLOBAL-memory store
    BID       R7                  // block index within the program's grid
    PID       R6                  // program index (multi-program launch)
    LOD       R4, #128            // immediate load
    LOD.FP32  R4, #3              // immediate load, converted to 3.0f
    TDX       R1                  // thread id x -> R1
    DOT.FP32  R9, R2, R2 {d1}     // wavefront dot product -> lane 0
    INVSQR.FP32 R8, R9 {w1,d1}    // SFU
    ADD.FP32  R1, R2@3, R3@0      // thread snooping (X=1): wavefront exts
    INIT      8
    loop_top:
    LOOP      loop_top
    JSR       subroutine
    RTS
    JMP       end
    NOP
    STOP

Flexible-ISA modifiers ``{...}``: ``w16|w8|w4|w1`` (or wfull/whalf/wquarter/
wsingle) and ``d32|d16|d8|d1`` (or dfull/dhalf/dquarter/dsingle). ``d`` counts
are relative to a 32-wavefront (512-thread) full block; the encoding is the
2-bit code, so they mean full/half/quarter/single of the *initialized* block.
"""
from __future__ import annotations

import functools
import re
from dataclasses import dataclass, field

import numpy as np

from .isa import (
    RESULT_LATENCY,
    Cond,
    Depth,
    Instr,
    Op,
    Typ,
    Width,
    instr_class,
)

_WIDTH_ALIASES = {
    "w16": Width.FULL, "wfull": Width.FULL,
    "w8": Width.HALF, "whalf": Width.HALF,
    "w4": Width.QUARTER, "wquarter": Width.QUARTER,
    "w1": Width.SINGLE, "wsingle": Width.SINGLE,
}
_DEPTH_ALIASES = {
    "d32": Depth.FULL, "dfull": Depth.FULL,
    "d16": Depth.HALF, "dhalf": Depth.HALF,
    "d8": Depth.QUARTER, "dquarter": Depth.QUARTER,
    "d1": Depth.SINGLE, "dsingle": Depth.SINGLE,
}

_THREE_OP = {Op.ADD, Op.SUB, Op.MUL, Op.AND, Op.OR, Op.XOR, Op.LSL, Op.LSR,
             Op.DOT, Op.SUM, Op.SELP}
_TWO_OP = {Op.NOT, Op.INVSQR}
_REG = re.compile(r"^R(\d+)(?:@(\d+))?$", re.IGNORECASE)
_MEM = re.compile(r"^\(R(\d+)\)\+(-?\d+)$", re.IGNORECASE)
_LABEL = re.compile(r"^([A-Za-z_][A-Za-z0-9_]*):$")
_PRED = re.compile(r"^@(!?)R(\d+)$", re.IGNORECASE)
# ops the sequencer handles scalar-side: never predicable (the instruction
# stream must stay static — divergence is per-lane masking only)
_NO_PRED = {Op.JMP, Op.JSR, Op.RTS, Op.LOOP, Op.INIT, Op.STOP, Op.NOP}


class AsmError(ValueError):
    def __init__(self, msg: str, lineno: int | None = None, line: str = ""):
        self.lineno = lineno
        super().__init__(f"line {lineno}: {msg}  [{line.strip()}]"
                         if lineno is not None else msg)


@dataclass
class Program:
    """Assembled program: words + source map + static metadata."""

    words: np.ndarray                 # (n,) int64
    instrs: list[Instr]
    labels: dict[str, int]
    source: list[str] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.instrs)


def _parse_reg(tok: str, lineno: int, line: str) -> tuple[int, int | None]:
    m = _REG.match(tok)
    if not m:
        raise AsmError(f"expected register, got {tok!r}", lineno, line)
    r = int(m.group(1))
    if not 0 <= r < 16:
        raise AsmError(f"register R{r} out of range (16 regs/thread)", lineno, line)
    ext = int(m.group(2)) if m.group(2) is not None else None
    if ext is not None and not 0 <= ext < 32:
        raise AsmError(f"snoop wavefront @{ext} out of range (32)", lineno, line)
    return r, ext


def _parse_modifiers(mod: str, lineno: int, line: str) -> tuple[Width, Depth]:
    width, depth = Width.FULL, Depth.FULL
    for part in (p.strip().lower() for p in mod.split(",") if p.strip()):
        if part in _WIDTH_ALIASES:
            width = _WIDTH_ALIASES[part]
        elif part in _DEPTH_ALIASES:
            depth = _DEPTH_ALIASES[part]
        else:
            raise AsmError(f"unknown modifier {part!r}", lineno, line)
    return width, depth


def assemble_line(line: str, labels: dict[str, int], lineno: int = 0) -> Instr | None:
    """Assemble one source line (labels must already be resolved)."""
    code = line.split("//")[0].split(";")[0].strip()
    if not code or _LABEL.match(code):
        return None

    mod = ""
    if "{" in code:
        code, _, rest = code.partition("{")
        mod = rest.rstrip().rstrip("}")
        code = code.strip()

    pred: tuple[int, int] | None = None    # (preg, pneg)
    if code.startswith("@"):
        ptok, *prest = code.split(None, 1)
        m = _PRED.match(ptok)
        if not m:
            raise AsmError(f"expected @Rp or @!Rp predicate guard, got "
                           f"{ptok!r}", lineno, line)
        preg = int(m.group(2))
        if not 0 <= preg < 16:
            raise AsmError(f"predicate register R{preg} out of range",
                           lineno, line)
        if not prest:
            raise AsmError("predicate guard with no instruction",
                           lineno, line)
        pred = (preg, 1 if m.group(1) else 0)
        code = prest[0]

    head, *rest = code.split(None, 1)
    operands = [t.strip() for t in rest[0].split(",")] if rest else []

    mnemonic, _, typ_s = head.partition(".")
    mnemonic = mnemonic.upper()
    cond: Cond | None = None
    if mnemonic == "SETP":
        # SETP.cond[.typ]: the condition rides imm[2:0]
        cond_s, _, typ_s = typ_s.partition(".")
        try:
            cond = Cond[cond_s.upper()]
        except KeyError:
            raise AsmError(f"SETP needs a condition (SETP.LT.FP32 ...), got "
                           f"{cond_s!r}", lineno, line) from None
    try:
        op = Op[mnemonic]
    except KeyError:
        raise AsmError(f"unknown mnemonic {mnemonic!r}", lineno, line) from None
    typ = Typ[typ_s.upper()] if typ_s else Typ.INT32
    width, depth = _parse_modifiers(mod, lineno, line)

    kw: dict = dict(op=op, typ=typ, width=width, depth=depth)
    if pred is not None:
        if op in _NO_PRED:
            raise AsmError(f"{op.name} cannot be predicated (scalar "
                           f"sequencer op)", lineno, line)
        kw.update(pen=1, preg=pred[0], pneg=pred[1])

    if op == Op.SETP:
        if len(operands) != 3:
            raise AsmError("SETP.cond[.typ] Rd, Ra, Rb", lineno, line)
        rd, _ = _parse_reg(operands[0], lineno, line)
        ra, ea = _parse_reg(operands[1], lineno, line)
        rb, eb = _parse_reg(operands[2], lineno, line)
        if ea is not None or eb is not None:
            raise AsmError("SETP cannot snoop (cond lives in imm[2:0])",
                           lineno, line)
        kw.update(rd=rd, ra=ra, rb=rb, imm=int(cond))
    elif op in _THREE_OP:
        if len(operands) != 3:
            raise AsmError(f"{op.name} needs 3 operands", lineno, line)
        rd, _ = _parse_reg(operands[0], lineno, line)
        ra, ea = _parse_reg(operands[1], lineno, line)
        rb, eb = _parse_reg(operands[2], lineno, line)
        kw.update(rd=rd, ra=ra, rb=rb)
        if ea is not None or eb is not None:
            kw.update(x=1, ext_a=ea or 0, ext_b=eb or 0)
    elif op in _TWO_OP:
        if len(operands) != 2:
            raise AsmError(f"{op.name} needs 2 operands", lineno, line)
        rd, _ = _parse_reg(operands[0], lineno, line)
        ra, ea = _parse_reg(operands[1], lineno, line)
        kw.update(rd=rd, ra=ra)
        if ea is not None:
            kw.update(x=1, ext_a=ea)
    elif op in (Op.LOD, Op.STO, Op.GLD, Op.GST):
        if len(operands) != 2:
            raise AsmError(f"{op.name} needs 2 operands", lineno, line)
        rd, _ = _parse_reg(operands[0], lineno, line)
        kw.update(rd=rd)
        tgt = operands[1]
        if tgt.startswith("#"):
            if op != Op.LOD:
                raise AsmError(f"{op.name} has no immediate form", lineno, line)
            kw.update(op=Op.LODI, imm=int(tgt[1:], 0))
        else:
            m = _MEM.match(tgt)
            if not m:
                raise AsmError(f"expected (Ra)+off or #imm, got {tgt!r}", lineno, line)
            kw.update(ra=int(m.group(1)), imm=int(m.group(2)))
    elif op == Op.LODI:
        if len(operands) != 2 or not operands[1].startswith("#"):
            raise AsmError("LODI Rd, #imm", lineno, line)
        rd, _ = _parse_reg(operands[0], lineno, line)
        kw.update(rd=rd, imm=int(operands[1][1:], 0))
    elif op in (Op.TDX, Op.TDY, Op.BID, Op.PID):
        if len(operands) != 1:
            raise AsmError(f"{op.name} needs 1 operand", lineno, line)
        rd, _ = _parse_reg(operands[0], lineno, line)
        kw.update(rd=rd)
    elif op in (Op.JMP, Op.JSR, Op.LOOP):
        if len(operands) != 1:
            raise AsmError(f"{op.name} needs a target", lineno, line)
        tgt = operands[0]
        if tgt in labels:
            kw.update(imm=labels[tgt])
        else:
            try:
                kw.update(imm=int(tgt, 0))
            except ValueError:
                raise AsmError(f"undefined label {tgt!r}", lineno, line) from None
    elif op == Op.INIT:
        if len(operands) != 1:
            raise AsmError("INIT needs a loop count", lineno, line)
        kw.update(imm=int(operands[0], 0))
    elif op in (Op.RTS, Op.STOP, Op.NOP):
        if operands:
            raise AsmError(f"{op.name} takes no operands", lineno, line)
    else:  # pragma: no cover
        raise AsmError(f"unhandled opcode {op}", lineno, line)

    return Instr(**kw)


@functools.lru_cache(maxsize=512)
def assemble(text: str) -> Program:
    """Two-pass assemble of a full program.

    Memoized on the source text: assembly is pure, and the program
    builders (FFT/QRD/saxpy) re-emit identical source every launch —
    without the cache, re-assembly dominates warm launch time. Treat the
    returned ``Program`` (and its ``words``) as immutable.
    """
    lines = text.splitlines()
    # pass 1: label addresses
    labels: dict[str, int] = {}
    addr = 0
    for i, raw in enumerate(lines):
        code = raw.split("//")[0].split(";")[0].strip()
        if not code:
            continue
        m = _LABEL.match(code)
        if m:
            if m.group(1) in labels:
                raise AsmError(f"duplicate label {m.group(1)!r}", i + 1, raw)
            labels[m.group(1)] = addr
        else:
            addr += 1
    # pass 2: encode
    instrs: list[Instr] = []
    srcs: list[str] = []
    for i, raw in enumerate(lines):
        ins = assemble_line(raw, labels, i + 1)
        if ins is not None:
            instrs.append(ins)
            srcs.append(raw.strip())
    words = np.array([ins.encode() for ins in instrs], dtype=np.int64)
    return Program(words=words, instrs=instrs, labels=labels, source=srcs)


def disassemble(word: int) -> str:
    ins = Instr.decode(int(word))
    p = f"@{'!' if ins.pneg else ''}R{ins.preg} " if ins.pen else ""
    return p + _disasm_body(ins)


def _disasm_body(ins: Instr) -> str:
    op = ins.op
    t = f".{ins.typ.name}" if op in (Op.ADD, Op.SUB, Op.MUL, Op.DOT, Op.SUM,
                                     Op.INVSQR, Op.LODI, Op.SETP) else ""
    if op == Op.SETP:
        return (f"SETP.{Cond(ins.imm).name}{t} "
                f"R{ins.rd}, R{ins.ra}, R{ins.rb}")
    mods = []
    if ins.width != Width.FULL:
        mods.append(f"w{ {0: 16, 1: 8, 2: 4, 3: 1}[int(ins.width)] }".replace(" ", ""))
    if ins.depth != Depth.FULL:
        mods.append({1: "dhalf", 2: "dquarter", 3: "d1"}[int(ins.depth)])
    m = (" {" + ",".join(mods) + "}") if mods else ""

    def reg(r: int, ext: int) -> str:
        return f"R{r}@{ext}" if ins.x else f"R{r}"

    if op in _THREE_OP:
        return f"{op.name}{t} R{ins.rd}, {reg(ins.ra, ins.ext_a)}, {reg(ins.rb, ins.ext_b)}{m}"
    if op in _TWO_OP:
        return f"{op.name}{t} R{ins.rd}, {reg(ins.ra, ins.ext_a)}{m}"
    if op in (Op.LOD, Op.GLD):
        return f"{op.name}{t} R{ins.rd}, (R{ins.ra})+{ins.imm}{m}"
    if op in (Op.STO, Op.GST):
        return f"{op.name} R{ins.rd}, (R{ins.ra})+{ins.imm}{m}"
    if op == Op.LODI:
        return f"LOD{t} R{ins.rd}, #{ins.imm}{m}"
    if op in (Op.TDX, Op.TDY, Op.BID, Op.PID):
        return f"{op.name} R{ins.rd}{m}"
    if op in (Op.JMP, Op.JSR, Op.LOOP):
        return f"{op.name} {ins.imm}"
    if op == Op.INIT:
        return f"INIT {ins.imm}"
    return op.name


# ---------------------------------------------------------------------------
# Static hazard checker (paper §III: "Hazards have to be managed by the
# programmer; there are no hardware interlocks.")
# ---------------------------------------------------------------------------

def check_hazards(program: Program, n_threads: int = 512) -> list[str]:
    """RAW-hazard scan over straight-line code segments.

    The eGPU pipeline is 9 deep; an instruction's result is not readable
    until RESULT_LATENCY cycles after issue. An instruction occupies the
    sequencer for its class-dependent cycle count, so with enough active
    wavefronts hazards hide themselves (paper: "typically only exposed for
    small thread blocks"). Returns human-readable warnings; control-flow
    boundaries reset the window (conservative in the benign direction).
    """
    from .cycles import instr_cycles  # late import to avoid a cycle

    warnings: list[str] = []
    window: list[tuple[int, int, int]] = []  # (pc, rd, ready_cycle)
    mem_ready = 0                            # shared-mem store->load fence
    gmem_ready = 0                           # global-mem store->load fence
    now = 0
    for pc, ins in enumerate(program.instrs):
        if ins.op in (Op.JMP, Op.JSR, Op.RTS, Op.LOOP, Op.STOP):
            window.clear()
            now += 1
            continue
        reads = []
        if ins.op in _THREE_OP or ins.op == Op.SETP:
            reads = [ins.ra, ins.rb]
        elif ins.op in _TWO_OP or ins.op in (Op.LOD, Op.STO, Op.GLD, Op.GST):
            reads = [ins.ra]
            if ins.op in (Op.STO, Op.GST):
                reads.append(ins.rd)  # stores read the stored register
        if ins.pen:
            reads.append(ins.preg)  # the guard reads its predicate register
        src = program.source[pc] if pc < len(program.source) else ""
        for (wpc, wrd, ready) in window:
            if wrd in reads and now < ready:
                warnings.append(
                    f"pc={pc}: reads R{wrd} written at pc={wpc}, ready at "
                    f"cycle {ready} but issued at {now} "
                    f"(insert {ready - now} NOP-cycles)  [{src}]")
        if ins.op == Op.LOD and now < mem_ready:
            warnings.append(
                f"pc={pc}: LOD issued at {now} before a prior STO commits at "
                f"{mem_ready} (insert {mem_ready - now} NOP-cycles)  [{src}]")
        if ins.op == Op.GLD and now < gmem_ready:
            warnings.append(
                f"pc={pc}: GLD issued at {now} before a prior GST commits at "
                f"{gmem_ready} (insert {gmem_ready - now} NOP-cycles)  [{src}]")
        cyc = instr_cycles(ins, n_threads)
        if ins.op == Op.STO:
            mem_ready = max(mem_ready, now + RESULT_LATENCY)
        if ins.op == Op.GST:
            gmem_ready = max(gmem_ready, now + RESULT_LATENCY)
        if ins.op not in (Op.NOP, Op.STO, Op.GST):
            window.append((pc, ins.rd, now + RESULT_LATENCY))
        window = [w for w in window if w[2] > now]
        now += cyc
    return warnings


_WARN_PC = re.compile(r"pc=(\d+):.*insert (\d+) NOP-cycles")


@functools.lru_cache(maxsize=512)
def auto_nop(text: str, n_threads: int = 512, max_iter: int = 64) -> str:
    """Insert NOPs until ``check_hazards`` is clean (the programmer's job on
    real eGPU hardware — no interlocks). Returns the padded source.
    Memoized like ``assemble`` (pure text -> text)."""
    for _ in range(max_iter):
        prog = assemble(text)
        warns = check_hazards(prog, n_threads)
        if not warns:
            return text
        # collect every flagged pc; map instruction index -> source line
        need: dict[int, int] = {}
        for w in warns:
            m = _WARN_PC.search(w)
            if m:
                pc, n = int(m.group(1)), int(m.group(2))
                need[pc] = max(need.get(pc, 0), n)
        lines = text.splitlines()
        pc_to_line: dict[int, int] = {}
        idx = -1
        for ln, raw in enumerate(lines):
            code = raw.split("//")[0].split(";")[0].strip()
            if not code or _LABEL.match(code):
                continue
            idx += 1
            if idx in need:
                pc_to_line[idx] = ln
        if len(pc_to_line) != len(need):  # pragma: no cover
            raise AsmError("auto_nop: cannot locate flagged pcs")
        # patch bottom-up so earlier line indices stay valid
        for pc in sorted(need, reverse=True):
            ln = pc_to_line[pc]
            lines[ln:ln] = ["    NOP"] * need[pc]
        text = "\n".join(lines)
    raise AsmError("auto_nop: did not converge")
