"""eGPU core: the paper's contribution as a composable JAX module.

Public API:
    SMConfig, MachineState, init_state  — machine model
    assemble, disassemble, check_hazards — assembler
    run, run_many                        — jitted ISS
    profile                              — Table III/IV-style cycle profile
    resources                            — Tables I/V + §III.E analytic model
"""
from .assembler import AsmError, Program, assemble, check_hazards, disassemble
from .executor import pack_imem, run, run_many
from .isa import CLASS_NAMES, Depth, Instr, Op, Typ, Width
from .machine import (
    MachineState,
    SMConfig,
    init_state,
    profile,
    regs_f32,
    regs_i32,
    shmem_f32,
    shmem_i32,
)
from . import resources

__all__ = [
    "AsmError", "Program", "assemble", "check_hazards", "disassemble",
    "pack_imem", "run", "run_many",
    "CLASS_NAMES", "Depth", "Instr", "Op", "Typ", "Width",
    "MachineState", "SMConfig", "init_state", "profile",
    "regs_f32", "regs_i32", "shmem_f32", "shmem_i32",
    "resources",
]
