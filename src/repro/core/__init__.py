"""eGPU core: the paper's contribution as a composable JAX module.

Public API:
    SMConfig, MachineState, init_state   — single-SM machine model
    DeviceConfig, launch, LaunchResult   — multi-SM device layer (grid/block
                                           launches, global memory; single-
                                           or multi-program via Kernel)
    program_trace, schedule_blocks       — static block traces + the
                                           static-wave / dynamic-queue
                                           block schedulers
    assemble, disassemble, check_hazards — assembler
    run, run_many                        — jitted ISS (single-wave shims)
    execute_backends, ExecBackend        — pluggable execute-stage backends
                                           (ALU + LOD/STO/GLD/GST data path)
    TraceSchedule, compile_program       — trace-compiled execution engine
                                           (decode-once lax.scan pipelines;
                                           launch(..., engine="trace"))
    MergedTraceSchedule, compile_merged  — heterogeneous-wave schedules
                                           (mixed grids as one padded scan)
    MegakernelPlan, compile_megakernel   — segment-megakernel engine
    MergedMegakernelPlan,                  (fused gmem-free runs, partial
    compile_merged_megakernel              evaluation; engine="megakernel")
    compile_cache                        — persistent on-disk compile cache
                                           (EGPU_CACHE_DIR / configure())
    WavePacking, pack_waves              — schedule-aware wave packing
                                           (which blocks share a wave;
                                           launch(..., packing="length"))
    FleetConfig, launch_fleet            — N simulated eGPUs behind one
                                           launch front door (NUMA gmem
                                           tier; shard_map over real JAX
                                           devices when uniform)
    profile                              — Table III/IV-style cycle profile
    resources                            — Tables I/V + §III.E analytic model
"""
from .assembler import AsmError, Program, assemble, check_hazards, disassemble
from .cycles import ProgramTrace, instr_cycles, program_trace
from .device import (
    DeviceConfig,
    DeviceState,
    Kernel,
    LaunchResult,
    buffer_layout,
    launch,
    pack_buffers,
)
from .fleet import PLACEMENTS, ROUTES, FleetConfig, launch_fleet
from .packing import PACKINGS, WavePacking, pack_waves
from .scheduler import Schedule, merge_schedules, schedule_blocks
from .executor import (
    ExecBackend,
    execute_backends,
    get_execute_backend,
    pack_imem,
    register_backend,
    register_execute_backend,
    run,
    run_many,
)
from .trace_engine import (
    ENGINES,
    MegakernelPlan,
    MergedMegakernelPlan,
    MergedTraceSchedule,
    TraceSchedule,
    compile_megakernel,
    compile_merged,
    compile_merged_megakernel,
    compile_program,
)
from . import compile_cache
from .isa import CLASS_NAMES, Depth, Instr, Op, Typ, Width
from .machine import (
    MachineState,
    SMConfig,
    init_state,
    profile,
    regs_f32,
    regs_i32,
    shmem_f32,
    shmem_i32,
)
from . import resources

__all__ = [
    "AsmError", "Program", "assemble", "check_hazards", "disassemble",
    "ProgramTrace", "instr_cycles", "program_trace",
    "DeviceConfig", "DeviceState", "Kernel", "LaunchResult", "buffer_layout",
    "launch", "pack_buffers",
    "Schedule", "merge_schedules", "schedule_blocks",
    "PLACEMENTS", "ROUTES", "FleetConfig", "launch_fleet",
    "PACKINGS", "WavePacking", "pack_waves",
    "ENGINES", "MergedTraceSchedule", "TraceSchedule", "compile_merged",
    "compile_program",
    "MegakernelPlan", "MergedMegakernelPlan", "compile_megakernel",
    "compile_merged_megakernel", "compile_cache",
    "pack_imem", "run", "run_many",
    "ExecBackend", "execute_backends", "get_execute_backend",
    "register_backend", "register_execute_backend",
    "CLASS_NAMES", "Depth", "Instr", "Op", "Typ", "Width",
    "MachineState", "SMConfig", "init_state", "profile",
    "regs_f32", "regs_i32", "shmem_f32", "shmem_i32",
    "resources",
]
