"""eGPU instruction-set simulator: decode machinery + execute backends.

Faithful to the paper's SM microarchitecture:

  * 16 SPs; thread ``t`` runs on SP ``t % 16`` (its *lane*), in wavefront
    ``t // 16``. SP ``l``'s register file (two M20Ks, 512x32 each as 2R1W)
    holds registers for threads ``{l, 16+l, 32+l, ...}``.
  * Flexible ISA: per-instruction WIDTH/DEPTH resize the active thread
    block with no flush — implemented as an active-thread mask.
  * Thread snooping (X=1): source operands read ``regs[ext*16 + lane]``,
    letting wavefront-0 threads address any register in their lane.
  * DOT/SUM extension units reduce each active wavefront and write lane 0;
    INVSQR is a single-lane SFU on wavefront 0 / lane 0.
  * Shared memory: quad read port (cycle model: 4 threads/clock on LOD),
    single write port (1 thread/clock on STO; writeback is sequential in
    thread order, so the *last* active thread wins on address collisions —
    we reproduce that determinism exactly).
  * Zero-overhead loops (INIT/LOOP), JSR/RTS return stack, STOP flag.
  * No hardware interlocks: the ISS executes architecturally (every read
    sees the latest architectural write). Timing hazards are a *static*
    property checked by ``assembler.check_hazards``; the paper's NOP
    mitigation is reproduced in the benchmark programs.

Since the multi-SM refactor the stepping loop itself lives in
``device.py`` and operates on a whole SM *batch* in lockstep; this module
owns the pieces every step needs:

  * ``pack_imem`` / ``_decode`` — the 40-bit I-word field extraction;
  * the opcode -> handler-group and opcode -> profile-class tables;
  * the **shared execute stage** (``make_data_handlers``): the data-path
    handlers of every instruction group, dispatched by BOTH engines — the
    stepping machine (``device._device_step``) and the trace-compiled
    scan (``core.trace_engine``) — so the two are bit-identical by
    construction;
  * the **pluggable execute backends** (``ExecBackend``). Since the
    trace-engine refactor the seam covers the whole execute stage: the
    ALU column plus the LOD/STO quad-read/single-write-port
    gather/scatter and the GLD/GST global accesses. Two implementations
    ship:

      - ``"inline"``  — straight jnp (the ``kernels.ref`` oracle + the
        scatter-max port-serialization trick);
      - ``"pallas"``  — the ``kernels.simt_alu`` ALU kernel and the
        ``kernels.simt_step`` gather/scatter kernels, so a multi-SM
        step's data path executes as Pallas grids over the SM batch
        (interpreted on CPU, compiled on TPU).

    Both are bit-exact by construction and selected per run via
    ``run(..., backend=...)`` / ``DeviceConfig.backend``.

``run`` and ``run_many`` are preserved as single-wave shims over the
device layer (always on the step machine); new code should use
``device.launch``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import isa
from .isa import Op
from .machine import MAX_THREADS, MAX_WAVES, N_SP, MachineState, SMConfig

_U32 = jnp.uint32
_I32 = jnp.int32
_F32 = jnp.float32


def pack_imem(words: np.ndarray, depth: int) -> tuple[np.ndarray, np.ndarray]:
    """Split I-words into (lo32, hi) uint32 arrays of ``depth``.

    ``hi`` carries the architectural bits [39:32] plus the predication
    extension byte [45:40] (pen/preg/pneg — zero on every legacy word)."""
    w = np.asarray(words, dtype=np.int64)
    if w.shape[0] > depth:
        raise ValueError(f"program of {w.shape[0]} words exceeds I-MEM depth {depth}")
    lo = (w & 0xFFFFFFFF).astype(np.uint32)
    hi = ((w >> 32) & 0x3FFF).astype(np.uint32)
    pad = depth - w.shape[0]
    # pad with STOP so runaway PCs halt
    stop_word = isa.Instr(op=Op.STOP).encode()
    lo = np.concatenate([lo, np.full((pad,), stop_word & 0xFFFFFFFF, np.uint32)])
    hi = np.concatenate([hi, np.full((pad,), (stop_word >> 32) & 0x3FFF, np.uint32)])
    return lo, hi


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _decode(lo: jax.Array, hi: jax.Array) -> dict[str, jax.Array]:
    imm_raw = (lo & 0x7FFF).astype(_I32)
    imm_sext = jnp.where(imm_raw & 0x4000, imm_raw - (1 << 15), imm_raw)
    return dict(
        imm_raw=imm_raw,
        imm=imm_sext,
        x=((lo >> 15) & 1).astype(_I32),
        rb=((lo >> 16) & 0xF).astype(_I32),
        ra=((lo >> 20) & 0xF).astype(_I32),
        rd=((lo >> 24) & 0xF).astype(_I32),
        typ=((lo >> 28) & 0x3).astype(_I32),
        opcode=(((lo >> 30) & 0x3) | ((hi & 0xF) << 2)).astype(_I32),
        depth=((hi >> 4) & 0x3).astype(_I32),
        width=((hi >> 6) & 0x3).astype(_I32),
        ext_a=((lo >> 10) & 0x1F).astype(_I32),
        ext_b=((lo >> 5) & 0x1F).astype(_I32),
        # predication extension byte (word bits [45:40] = hi bits [13:8])
        preg=((hi >> 8) & 0xF).astype(_I32),
        pen=((hi >> 12) & 0x1).astype(_I32),
        pneg=((hi >> 13) & 0x1).astype(_I32),
    )


# opcode -> handler group
(_G_NOP, _G_ALU, _G_LOD, _G_STO, _G_LODI, _G_TD, _G_RED, _G_SFU, _G_CTL,
 _G_GLD, _G_GST, _G_SETP, _G_SELP) = range(13)
_GROUP_OF_OP = np.zeros((64,), np.int32)
for _op, _g in {
    Op.NOP: _G_NOP,
    Op.ADD: _G_ALU, Op.SUB: _G_ALU, Op.MUL: _G_ALU, Op.AND: _G_ALU,
    Op.OR: _G_ALU, Op.XOR: _G_ALU, Op.NOT: _G_ALU, Op.LSL: _G_ALU,
    Op.LSR: _G_ALU,
    Op.LOD: _G_LOD, Op.STO: _G_STO, Op.LODI: _G_LODI,
    Op.TDX: _G_TD, Op.TDY: _G_TD, Op.BID: _G_TD, Op.PID: _G_TD,
    Op.DOT: _G_RED, Op.SUM: _G_RED, Op.INVSQR: _G_SFU,
    Op.JMP: _G_CTL, Op.JSR: _G_CTL, Op.RTS: _G_CTL, Op.LOOP: _G_CTL,
    Op.INIT: _G_CTL, Op.STOP: _G_CTL,
    Op.GLD: _G_GLD, Op.GST: _G_GST,
    Op.SETP: _G_SETP, Op.SELP: _G_SELP,
}.items():
    _GROUP_OF_OP[int(_op)] = _g

# opcode -> profile class, per operand type (rows of Tables III/IV + GMEM)
_CLASS_OF = np.zeros((64, 3), np.int32)
for _op in Op:
    for _t in isa.Typ:
        _CLASS_OF[int(_op), int(_t)] = isa.instr_class(_op, _t)


def _setp_compare(cond, typ, a_u, b_u) -> jax.Array:
    """Per-lane SETP compare -> bool tile.

    ``cond``/``typ`` may be traced i32 scalars (step/trace engines) or
    Python ints (megakernel fused rows): every comparison is exact, so
    the traced select chain and the host-constant branch compute
    identical bits. NaN note: FP32 ordered compares are all-false on
    NaN operands, so GT/GE are computed directly (never as ~LE/~LT)."""
    a_i = jax.lax.bitcast_convert_type(a_u, _I32)
    b_i = jax.lax.bitcast_convert_type(b_u, _I32)
    a_f = jax.lax.bitcast_convert_type(a_u, _F32)
    b_f = jax.lax.bitcast_convert_type(b_u, _F32)
    is_fp = typ == int(isa.Typ.FP32)
    is_int = typ == int(isa.Typ.INT32)

    def pick(f):
        return jnp.where(is_fp, f(a_f, b_f),
                         jnp.where(is_int, f(a_i, b_i), f(a_u, b_u)))

    eq = pick(lambda a, b: a == b)
    lt = pick(lambda a, b: a < b)
    le = pick(lambda a, b: a <= b)
    gt = pick(lambda a, b: a > b)
    ge = pick(lambda a, b: a >= b)
    C = isa.Cond
    return jnp.where(cond == int(C.EQ), eq,
                     jnp.where(cond == int(C.NE), ~eq,
                               jnp.where(cond == int(C.LT), lt,
                                         jnp.where(cond == int(C.LE), le,
                                                   jnp.where(cond == int(C.GT),
                                                             gt, ge)))))


# ---------------------------------------------------------------------------
# pluggable execute backends (the whole per-step execute stage)
# ---------------------------------------------------------------------------
#
# A backend implements the data-path operations of one instruction over an
# SM batch. Since the trace-engine refactor the seam covers the WHOLE
# execute stage, not just the ALU:
#
#   alu(op, typ, a, b, mask, old)   -> (n_sms, 512) destination column
#   lod(shmem, addr, mask, old)     -> (n_sms, 512) quad-port gather
#   sto(shmem, addr, vals, do)      -> (n_sms, depth) single-port scatter
#                                      (last active thread wins)
#   gld(gmem, addr, mask, old)      -> (n_sms, 512) global gather
#   gst(gmem, addr, vals, do)       -> (gdepth,) device-wide scatter
#                                      (last (sm, thread) writer wins)
#
# ``op``/``typ`` are traced i32 scalars (decoded fields), ``a``/``b``
# pre-gathered source-operand tiles, ``mask``/``do`` the flexible-ISA
# active-thread mask (with out-of-range lanes already dropped), ``addr``
# pre-clipped to the memory depth for the gathers and raw for the scatters.
# All five ops must be bit-exact across backends; the stepping machine
# and the trace engine drive them through ``make_data_handlers`` below,
# and the megakernel engine's fused rows (``_apply_row_cols``) are
# decoded from the same tables — so functional semantics are shared by
# construction.

ExecuteOp = Callable[..., jax.Array]


# ---------------------------------------------------------------------------
# fused segments (the megakernel engine's unit of work)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusedRow:
    """One pre-decoded data instruction, fully resolved on the host.

    Unlike the trace engine's scanned schedule — where the decoded fields
    are traced i32 scalars selected per step — a fused row carries its
    fields as HOST constants (``sel`` the data-switch branch, ``d`` numpy
    i32 scalars, ``active`` the (512,) numpy thread mask). Constant
    fields let XLA fold the per-row dispatch, masks and operand selects
    at trace time, which is the megakernel speedup: the 10-way
    ``lax.switch`` and the mask/branch arithmetic disappear from the
    compiled body entirely.
    """

    sel: int                   # data-switch branch (never 0/8/9 in a
                               # fused run: identity rows are dropped and
                               # global-port rows break segments)
    d: dict                    # decoded fields as np.int32 scalars
    active: np.ndarray         # (512,) bool flexible-ISA thread mask
    act_waves: int             # flexible-ISA depth (active wavefronts) —
    act_wthreads: int          # ... and width; `active` is derived from
                               # these, but the fused body rebuilds the
                               # traced mask from iota comparisons so a
                               # Pallas kernel never captures a constant
                               # array (Pallas rejects captured consts)


def _apply_row_cols(cfg, backend: "ExecBackend", row: FusedRow, cols,
                    shmem, oob, block_idx, prog_idx,
                    shmem_depth: int | None):
    """One fused row over UNPACKED register columns.

    ``cols`` is the mutable list of 16 per-register (n_sms, 512) tiles.
    This is the same data path as the matching ``make_data_handlers``
    handler — same backend seam ops (``backend.alu``/``lod``/``sto``),
    same mask/clip/trap formulas — specialized for host-constant fields:
    a register write is a zero-copy column rebinding instead of a
    (n_sms, 512, 16) scatter, a no-snoop operand read is the column
    itself instead of a dynamic gather, and the select chains collapse
    to the one taken branch (which computes the identical values).
    Bit-identity vs the packed handlers is pinned by the engine
    conformance matrix.
    """
    from .isa import Typ

    d = row.d
    sel = row.sel
    op, typ = int(d["opcode"]), int(d["typ"])
    rd, ra, rb = int(d["rd"]), int(d["ra"]), int(d["rb"])
    imm = int(d["imm"])
    snoop = int(d["x"]) == 1
    n_sms = cols[0].shape[0]
    # the traced mask and snoop indices are rebuilt from iota comparisons
    # against Python-int fields: XLA folds them to constants at compile
    # time, and a Pallas kernel tracing this body captures no constant
    # arrays (which pallas_call rejects)
    tid_t = jnp.arange(MAX_THREADS, dtype=_I32)
    lane_t = tid_t % N_SP
    active = ((lane_t < row.act_wthreads)
              & (tid_t // N_SP < row.act_waves)
              & (tid_t < cfg.n_threads))

    # SIMT predication: PEN is a HOST constant here (legacy rows pay
    # nothing), but the predicate VALUE is runtime — read from the guard
    # register column, never captured as a constant array (Pallas-safe).
    # ``eff`` replaces ``active`` in every write/port mask; ``psel`` is
    # the raw predicate (SELP's selector). Same formulas as the
    # ``make_data_handlers`` handlers, so bit-identity is preserved.
    pen = int(d.get("pen", 0))
    if pen:
        psel = (cols[int(d["preg"])] & 1) != 0             # (n_sms, 512)
        if int(d.get("pneg", 0)):
            psel = ~psel
        eff = active[None] & psel
    else:
        psel = None
        eff = active

    def read(r, ext):
        # snoop (X=1) gathers regs[ext*16 + lane]; without it the
        # operand IS the register column — no gather at all
        if snoop:
            return jnp.take(cols[r], int(ext) * N_SP + lane_t, axis=1)
        return cols[r]

    def addr_of():
        a_u = read(ra, d["ext_a"])
        return jax.lax.bitcast_convert_type(a_u, _I32) + imm

    if sel == 1:                                           # ALU
        a_u, b_u = read(ra, d["ext_a"]), read(rb, d["ext_b"])
        old = cols[rd]
        mask = jnp.broadcast_to(eff, old.shape)
        cols[rd] = backend.alu(d["opcode"], d["typ"], a_u, b_u, mask, old)
    elif sel == 2:                                         # LOD
        depth = shmem_depth if shmem_depth is not None else shmem.shape[1]
        addr = addr_of()
        bad = eff & ((addr < 0) | (addr >= depth))
        safe = jnp.clip(addr, 0, depth - 1)
        mask = eff & ~bad
        cols[rd] = backend.lod(shmem, safe, mask, cols[rd])
        oob = oob | bad.any(axis=1)
    elif sel == 3:                                         # STO
        depth = shmem_depth if shmem_depth is not None else shmem.shape[1]
        addr = addr_of()
        bad = eff & ((addr < 0) | (addr >= depth))
        shmem = backend.sto(shmem, addr, cols[rd], eff & ~bad)
        oob = oob | bad.any(axis=1)
    elif sel == 4:                                         # LODI
        if typ == int(Typ.FP32):
            val = int(np.float32(imm).view(np.uint32))     # host bitcast
        else:
            val = imm & 0xFFFFFFFF
        vals = jnp.full((n_sms, MAX_THREADS), val, _U32)
        cols[rd] = jnp.where(eff, vals, cols[rd])
    elif sel == 5:                                         # TDX/TDY/BID/PID
        if op == int(Op.TDX):
            vals = jnp.broadcast_to((tid_t % cfg.dim_x).astype(_U32)[None],
                                    (n_sms, MAX_THREADS))
        elif op == int(Op.TDY):
            vals = jnp.broadcast_to(
                (tid_t // cfg.dim_x).astype(_U32)[None],
                (n_sms, MAX_THREADS))
        elif op == int(Op.BID):
            vals = jnp.broadcast_to(block_idx.astype(_U32)[:, None],
                                    (n_sms, MAX_THREADS))
        else:
            vals = jnp.broadcast_to(prog_idx.astype(_U32)[:, None],
                                    (n_sms, MAX_THREADS))
        cols[rd] = jnp.where(eff, vals, cols[rd])
    elif sel == 6:                                         # DOT/SUM
        a_u, b_u = read(ra, d["ext_a"]), read(rb, d["ext_b"])
        a2 = jax.lax.bitcast_convert_type(a_u, _F32) \
            .reshape(n_sms, MAX_WAVES, N_SP)
        b2 = jax.lax.bitcast_convert_type(b_u, _F32) \
            .reshape(n_sms, MAX_WAVES, N_SP)
        prod = a2 * b2 if op == int(Op.DOT) else a2 + b2
        dest = jnp.arange(MAX_WAVES, dtype=_I32) * N_SP    # lane 0 per wave
        cur = cols[rd][:, ::N_SP]
        if pen:
            # predicated-off lanes contribute nothing; a wavefront with
            # no enabled lane keeps its old lane-0 value
            lane_eff = eff.reshape(n_sms, MAX_WAVES, N_SP)
            red = jnp.sum(jnp.where(lane_eff, prod, 0.0), axis=2)
            new = jnp.where(lane_eff.any(axis=2),
                            jax.lax.bitcast_convert_type(red, _U32), cur)
        else:
            lane_active = active.reshape(MAX_WAVES, N_SP)
            red = jnp.sum(jnp.where(lane_active[None], prod, 0.0), axis=2)
            new = jnp.where(lane_active.any(axis=1)[None],
                            jax.lax.bitcast_convert_type(red, _U32), cur)
        cols[rd] = cols[rd].at[:, dest].set(new)
    elif sel == 7:                                         # SFU (INVSQR)
        src = int(d["ext_a"]) * N_SP if snoop else 0
        val = jax.lax.bitcast_convert_type(cols[ra][:, src], _F32)
        new = jax.lax.bitcast_convert_type(jax.lax.rsqrt(val), _U32)
        if pen:
            # the SFU issues from thread 0: its predicate gates the write
            new = jnp.where(psel[:, 0], new, cols[rd][:, 0])
        cols[rd] = cols[rd].at[:, 0].set(new)
    elif sel == 10:                                        # SETP
        a_u, b_u = read(ra, d["ext_a"]), read(rb, d["ext_b"])
        res = _setp_compare(imm, typ, a_u, b_u)
        cols[rd] = jnp.where(eff, res.astype(_U32), cols[rd])
    elif sel == 11:                                        # SELP
        a_u, b_u = read(ra, d["ext_a"]), read(rb, d["ext_b"])
        vals = jnp.where(psel, a_u, b_u) if pen else a_u
        cols[rd] = jnp.where(active, vals, cols[rd])
    else:
        raise AssertionError(
            f"fused row with non-SM-local handler sel={sel}")
    return cols, shmem, oob


def apply_segment_rows(cfg, backend: "ExecBackend", rows, block_idx,
                       prog_idx, regs, shmem, oob, *,
                       shmem_depth: int | None = None):
    """Unroll one fused segment body-to-body over an SM batch.

    ``rows`` is a tuple of ``FusedRow`` containing only SM-local data ops
    (ALU/LOD/STO/LODI/TD/RED/SFU — global-port rows delimit segments, so
    GLD/GST never appear here). The register file is unpacked into 16
    per-register columns for the whole segment, every row executes the
    shared backend seam ops with host-constant fields via
    ``_apply_row_cols``, and the file repacks once at the segment end —
    so a K-row segment pays 2 register-file copies instead of K.

    Both megakernel backends stage this one helper: "inline" (and any
    backend without a fused implementation, via ``exec_segment``) calls
    it directly; "pallas" runs it inside a single ``pallas_call`` that
    keeps the batch's registers/shmem resident across the fused steps
    (``kernels.simt_step.simt_segment``).
    """
    cols = [regs[:, :, r] for r in range(regs.shape[2])]
    for r in rows:
        cols, shmem, oob = _apply_row_cols(cfg, backend, r, cols, shmem,
                                           oob, block_idx, prog_idx,
                                           shmem_depth)
    return jnp.stack(cols, axis=2), shmem, oob


# ---------------------------------------------------------------------------
# plan-time partial evaluation (the megakernel's compile-time optimizer)
# ---------------------------------------------------------------------------
#
# Every wave starts from the architecturally-defined init state
# (``device.init_device_state``: all registers zero), and the flexible
# ISA has no data-dependent control flow — so at PLAN time (on the host,
# outside jit) the evaluator can thread exact register-column values
# through the fused rows. A column stays "known" (a concrete (512,)
# value) until a shared/global-memory load or a mixed write makes it
# runtime. Three rewrites fall out:
#
#   * rows whose operands and destination are all known FOLD AWAY —
#     evaluated eagerly at plan time by the SAME ``_apply_row_cols``
#     body (same jax ops, run eagerly: bit-identical by construction).
#     TDX/TDY/LODI chains and all address arithmetic vanish from the
#     compiled kernel.
#   * LOD rows with a known address column become STATIC GATHERS —
#     clip/trap/mask all resolved on the host, leaving one constant-
#     index gather plus a masked select.
#   * STO rows with a known address column become STATIC SCATTERS —
#     the single-port last-writer-wins arbitration resolves on the host
#     (the winning thread per address is a plan-time constant), leaving
#     one sorted unique-index set instead of a runtime scatter-max.
#
# The residual program assumes the zero-init contract: it is only valid
# for waves starting from ``init_device_state`` (which is how the device
# layer always launches). Backends opt in with ``fold_constants`` — only
# the reference "inline" backend does; custom backends keep the generic
# per-op seam (they must observe every ``alu``/``lod``/``sto`` call),
# and the Pallas backend runs its own fused kernel over the raw rows.

@dataclasses.dataclass(frozen=True)
class FusedSegment:
    """One fused segment: the raw row run plus its partial evaluation.

    ``rows`` feeds the generic and Pallas paths; ``residual`` (the ops
    left after plan-time constant folding, with host-resolved gather/
    scatter plans) feeds ``apply_segment_residual`` on fold-capable
    backends; ``final_consts`` are the register columns whose value is
    fully known at segment end (materialized once at repack).
    """

    rows: tuple                # FusedRow run (generic/Pallas path)
    residual: tuple            # (kind, row, data, consts) residual ops
    final_consts: tuple        # ((reg, (512,) np.uint32), ...)
    n_folded: int              # rows evaluated away entirely at plan time


# register indices each handler reads (operands + read-modify-write dest)
_ROW_READS = {1: ("ra", "rb", "rd"), 2: ("ra", "rd"), 3: ("ra", "rd"),
              4: ("rd",), 5: ("rd",), 6: ("ra", "rb", "rd"),
              7: ("ra", "rd"), 10: ("ra", "rb", "rd"),
              11: ("ra", "rb", "rd")}


def _fold_row(cfg, row: FusedRow, const_cols, depth: int) -> np.ndarray:
    """Evaluate one fully-known row eagerly (host): run the SAME
    ``_apply_row_cols`` body on (1, 512) tiles of the known columns and
    return the new destination column. Eager jax == jitted jax for
    these elementwise/reduce ops, so folding is bit-exact."""
    cols = [jnp.asarray(c)[None] if c is not None
            else jnp.zeros((1, MAX_THREADS), _U32) for c in const_cols]
    z = jnp.zeros((1,), _I32)
    cols, _, _ = _apply_row_cols(
        cfg, get_execute_backend("inline"), row, cols,
        jnp.zeros((1, 1), _U32), jnp.zeros((1,), jnp.bool_), z, z, depth)
    return np.asarray(cols[int(row.d["rd"])][0])


def _fold_addr(row: FusedRow, a_col: np.ndarray, depth: int):
    """Resolve a LOD/STO address column on the host: (clipped addresses,
    enabled-thread mask, any-trap flag) — the same clip/trap/mask
    formulas as the runtime handlers, on the known column."""
    a_u = np.asarray(a_col)
    if int(row.d["x"]) == 1:                       # snoop gather
        lane = np.arange(MAX_THREADS) % N_SP
        a_u = a_u[int(row.d["ext_a"]) * N_SP + lane]
    addr = a_u.astype(np.int32) + int(row.d["imm"])
    active = np.asarray(row.active)
    bad = active & ((addr < 0) | (addr >= depth))
    safe = np.clip(addr, 0, depth - 1).astype(np.int32)
    return safe, (active & ~bad), bool(bad.any())


def eval_segment_rows(cfg, rows, const_cols, depth: int):
    """Partially evaluate one fused segment (host, plan time).

    ``const_cols`` is the per-register known-value state entering the
    segment (list of (512,) np.uint32 or None = runtime). Returns
    ``(FusedSegment, const_cols_out)``; the evaluator folds what it can
    and annotates every residual op with the known columns it touches
    that changed since segment entry (``dirty``), so the trace-time
    executor can materialize exactly those as literals.
    """
    from .isa import Op as _Op

    const_cols = list(const_cols)
    dirty: set[int] = set()
    residual = []
    n_folded = 0

    def consts_for(regs):
        return tuple((r, const_cols[r]) for r in sorted(set(regs))
                     if const_cols[r] is not None and r in dirty)

    # every write mask includes ``tid < n_threads`` and registers start
    # zeroed, so lanes >= n_threads stay zero through the whole run — a
    # row whose mask covers ALL of [0, n_threads) therefore fully
    # determines its destination even when the old column is runtime
    # (``_fold_row`` substitutes the invariant zeros for unknown lanes)
    full_mask = np.arange(MAX_THREADS) < cfg.n_threads

    for row in rows:
        sel, d = row.sel, row.d
        rd, ra, rb = int(d["rd"]), int(d["ra"]), int(d["rb"])
        op = int(d["opcode"])
        pen = int(d.get("pen", 0))
        known = [const_cols[r] is not None for r in range(len(const_cols))]
        w_all = known[rd] or np.array_equal(np.asarray(row.active),
                                            full_mask)

        # a predicated row is MAY-WRITE: which lanes commit depends on a
        # runtime register, so it never folds, never becomes a static
        # gather/scatter, and its destination column goes runtime below
        foldable = not pen and (
            (sel == 1 and known[ra] and known[rb] and w_all)
            or (sel == 4 and w_all)
            or (sel == 5 and op in (int(_Op.TDX), int(_Op.TDY))
                and w_all)
            or (sel == 6 and known[ra] and known[rb] and known[rd])
            or (sel == 7 and known[ra] and known[rd])
            or (sel in (10, 11) and known[ra] and known[rb] and w_all))
        if foldable:
            const_cols[rd] = _fold_row(cfg, row, const_cols, depth)
            dirty.add(rd)
            n_folded += 1
            continue

        if sel == 2 and known[ra] and not pen:     # static-address LOD
            safe, mask, bad_any = _fold_addr(row, const_cols[ra], depth)
            residual.append(("lod", row, (safe, mask, bad_any),
                             consts_for((rd,))))
            const_cols[rd] = None
            continue

        if sel == 3 and known[ra] and not pen:     # static-address STO
            safe, do, bad_any = _fold_addr(row, const_cols[ra], depth)
            # single-port arbitration on the host: ascending thread
            # order, last enabled writer per address wins (exactly
            # ``_last_writer_write``'s order=tid rule)
            win: dict[int, int] = {}
            for t in np.flatnonzero(do):           # do-masked ⇒ in range
                win[int(safe[t])] = int(t)
            targets = np.array(sorted(win), np.int32)
            winners = np.array([win[a] for a in sorted(win)], np.int32)
            residual.append(("sto", row, (targets, winners, bad_any),
                             consts_for((rd,))))
            continue

        # generic runtime row (known operands materialize as literals)
        reads = tuple({"ra": ra, "rb": rb, "rd": rd}[f]
                      for f in _ROW_READS[sel])
        if pen:
            reads = reads + (int(d["preg"]),)      # the guard is a read
        residual.append(("exec", row, None, consts_for(reads)))
        if sel != 3:                               # STO writes no register
            const_cols[rd] = None

    final = tuple((r, const_cols[r]) for r in sorted(dirty)
                  if const_cols[r] is not None)
    return (FusedSegment(rows=tuple(rows), residual=tuple(residual),
                         final_consts=final, n_folded=n_folded),
            const_cols)


def apply_segment_residual(cfg, backend: "ExecBackend", seg: FusedSegment,
                           block_idx, prog_idx, regs, shmem, oob, *,
                           shmem_depth: int | None = None):
    """Execute one partially-evaluated segment (trace time).

    Residual ops run over unpacked columns like ``apply_segment_rows``;
    folded columns materialize as literals only where read or at the
    final repack. Valid only under the zero-init wave contract (see the
    module comment above ``FusedSegment``)."""
    n = regs.shape[0]
    cols = [regs[:, :, r] for r in range(regs.shape[2])]

    def mat(v):
        return jnp.broadcast_to(jnp.asarray(v)[None], (n, MAX_THREADS))

    for kind, row, data, consts in seg.residual:
        for r, v in consts:
            cols[r] = mat(v)
        if kind == "exec":
            cols, shmem, oob = _apply_row_cols(
                cfg, backend, row, cols, shmem, oob, block_idx, prog_idx,
                shmem_depth)
        elif kind == "lod":
            safe, mask, bad_any = data
            rd = int(row.d["rd"])
            vals = jnp.take(shmem, jnp.asarray(safe), axis=1)
            cols[rd] = jnp.where(jnp.asarray(mask), vals, cols[rd])
            if bad_any:
                oob = oob | jnp.bool_(True)
        else:                                      # static-address STO
            targets, winners, bad_any = data
            rd = int(row.d["rd"])
            if len(targets):
                shmem = shmem.at[:, jnp.asarray(targets)].set(
                    cols[rd][:, winners], unique_indices=True,
                    indices_are_sorted=True)
            if bad_any:
                oob = oob | jnp.bool_(True)
    for r, v in seg.final_consts:
        cols[r] = mat(v)
    return jnp.stack(cols, axis=2), shmem, oob


def exec_segment(backend: "ExecBackend", cfg, seg, block_idx, prog_idx,
                 regs, shmem, oob, *, shmem_depth: int | None = None):
    """Run one fused segment on ``backend``: its own fused implementation
    when it ships one, else the partially-evaluated residual on
    fold-capable (reference-semantics) backends, else the generic
    unrolled chain over the backend's per-op seam (so ALU-only custom
    backends keep their ALU semantics under the megakernel engine).

    ``seg`` is a ``FusedSegment``; a raw row tuple is accepted for the
    generic paths (no residual available)."""
    rows = seg.rows if isinstance(seg, FusedSegment) else tuple(seg)
    if backend.segment is not None:
        return backend.segment(cfg, rows, block_idx, prog_idx, regs,
                               shmem, oob, shmem_depth=shmem_depth)
    if backend.fold_constants and isinstance(seg, FusedSegment):
        return apply_segment_residual(cfg, backend, seg, block_idx,
                                      prog_idx, regs, shmem, oob,
                                      shmem_depth=shmem_depth)
    return apply_segment_rows(cfg, backend, rows, block_idx, prog_idx,
                              regs, shmem, oob, shmem_depth=shmem_depth)


def _pallas_segment(cfg, rows, block_idx, prog_idx, regs, shmem, oob, *,
                    shmem_depth: int | None = None):
    """Pallas fused segment: ONE kernel per segment, registers/shmem
    resident in VMEM across every fused step (no per-instruction
    round-trip)."""
    from ..kernels import ops
    from ..kernels.simt_step import simt_segment

    return simt_segment(cfg, rows, block_idx, prog_idx, regs, shmem, oob,
                        shmem_depth=shmem_depth,
                        interpret=ops.interpret_mode())


def _last_writer_write(mem, addr, vals, do, order):
    """Serialized single-port store: among enabled writers to the same
    address, the one latest in ``order`` wins (thread order within an SM;
    (sm, thread)-major order device-wide for global memory). Implemented
    with a commutative scatter-max so it is deterministic under jit."""
    depth = mem.shape[0]
    slot = jnp.where(do, addr, depth)                    # park masked writes
    winner = jnp.full((depth + 1,), -1, _I32).at[slot].max(order)
    write = do & (winner[slot] == order)
    return mem.at[jnp.where(write, addr, depth)].set(vals, mode="drop")


def _inline_alu(op, typ, a, b, mask, old) -> jax.Array:
    """Straight-jnp ALU stage (the ``kernels.ref`` oracle)."""
    from ..kernels.ref import alu_ref

    return jnp.where(mask, alu_ref(op, typ, a, b), old)


def _inline_lod(shmem, addr, mask, old) -> jax.Array:
    return jnp.where(mask, jnp.take_along_axis(shmem, addr, axis=1), old)


def _inline_sto(shmem, addr, vals, do) -> jax.Array:
    tid = jnp.arange(addr.shape[1], dtype=_I32)
    return jax.vmap(_last_writer_write, in_axes=(0, 0, 0, 0, None))(
        shmem, addr, vals, do, tid)


def _inline_gld(gmem, addr, mask, old) -> jax.Array:
    return jnp.where(mask, gmem[addr], old)


def _inline_gst(gmem, addr, vals, do) -> jax.Array:
    order = jnp.arange(addr.size, dtype=_I32)
    return _last_writer_write(gmem, addr.reshape(-1), vals.reshape(-1),
                              do.reshape(-1), order)


@dataclasses.dataclass(frozen=True)
class ExecBackend:
    """One named implementation of the execute-stage data path.

    ``segment`` is the fused-segment entry point the megakernel engine
    drives (via ``exec_segment``): a whole run of SM-local rows executed
    as one unit (``(cfg, rows, block_idx, prog_idx, regs, shmem, oob, *,
    shmem_depth) -> (regs, shmem, oob)``). None (the default) means the
    generic unrolled chain ``apply_segment_rows`` over this backend's
    own per-op seam; the Pallas backend overrides it with a single fused
    ``pallas_call`` staging the SAME chain, so fused execution is
    bit-identical across backends by construction.
    """

    name: str
    alu: ExecuteOp = _inline_alu
    lod: ExecuteOp = _inline_lod
    sto: ExecuteOp = _inline_sto
    gld: ExecuteOp = _inline_gld
    gst: ExecuteOp = _inline_gst
    segment: Callable | None = None
    # reference-semantics backends opt in to the megakernel's plan-time
    # partial evaluation (folded rows never reach the per-op seam, so a
    # backend that needs to SEE every op must leave this False)
    fold_constants: bool = False


_EXECUTE_BACKENDS: dict[str, ExecBackend] = {}


def register_backend(backend: ExecBackend) -> ExecBackend:
    _EXECUTE_BACKENDS[backend.name] = backend
    return backend


def register_execute_backend(name: str):
    """Back-compat decorator: register an ALU-only backend; the memory
    ops inherit the inline jnp implementations."""
    def deco(fn: ExecuteOp) -> ExecuteOp:
        register_backend(ExecBackend(name=name, alu=fn))
        return fn
    return deco


def get_execute_backend(name: str) -> ExecBackend:
    try:
        return _EXECUTE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown execute backend {name!r}; "
            f"available: {sorted(_EXECUTE_BACKENDS)}") from None


def execute_backends() -> tuple[str, ...]:
    return tuple(sorted(_EXECUTE_BACKENDS))


register_backend(ExecBackend(name="inline", fold_constants=True))


def _pallas_alu(op, typ, a, b, mask, old) -> jax.Array:
    """Pallas ALU stage: one ``simt_alu`` grid over the SM batch."""
    from ..kernels import ops
    from ..kernels.simt_alu import simt_alu

    n_sm = a.shape[0]
    # largest tile that divides the batch, capped at 8 SMs (80 KiB VMEM)
    block_sm = max(d for d in range(1, min(8, n_sm) + 1) if n_sm % d == 0)
    return simt_alu(op.astype(_I32), typ.astype(_I32), a, b,
                    mask.astype(_U32), old,
                    interpret=ops.interpret_mode(), block_sm=block_sm)


def _pallas_lod(shmem, addr, mask, old) -> jax.Array:
    from ..kernels import ops
    from ..kernels.simt_step import simt_gather

    return simt_gather(shmem, addr, mask.astype(_U32), old,
                       interpret=ops.interpret_mode())


def _pallas_sto(shmem, addr, vals, do) -> jax.Array:
    from ..kernels import ops
    from ..kernels.simt_step import simt_scatter

    return simt_scatter(shmem, addr, vals, do.astype(_U32),
                        interpret=ops.interpret_mode())


def _pallas_gld(gmem, addr, mask, old) -> jax.Array:
    from ..kernels import ops
    from ..kernels.simt_step import simt_gather_shared

    return simt_gather_shared(gmem, addr, mask.astype(_U32), old,
                              interpret=ops.interpret_mode())


def _pallas_gst(gmem, addr, vals, do) -> jax.Array:
    from ..kernels import ops
    from ..kernels.simt_step import simt_scatter_shared

    return simt_scatter_shared(gmem, addr, vals, do.astype(_U32),
                               interpret=ops.interpret_mode())


register_backend(ExecBackend(
    name="pallas", alu=_pallas_alu, lod=_pallas_lod, sto=_pallas_sto,
    gld=_pallas_gld, gst=_pallas_gst, segment=_pallas_segment))


# ---------------------------------------------------------------------------
# the shared execute stage (step + trace engines dispatch into these
# handlers; the megakernel's fused rows replay the same semantics)
# ---------------------------------------------------------------------------
#
# The data path of one instruction over a lockstep SM batch, factored out
# of the stepping machine so the trace engine executes the IDENTICAL
# handler graph: ``device._device_step`` (decode-per-step) and
# ``trace_engine`` (decode-once ``lax.scan``) both build their dispatch
# from ``make_data_handlers``. Handler order is fixed; ``DATA_SEL_OF_GROUP``
# maps a handler group to its 1-based switch branch (0 = no data effect:
# NOP and control, whose sequencer effects the engines handle themselves).

# handler-group -> data-switch branch (0 = identity)
DATA_SEL_OF_GROUP = np.zeros((13,), np.int32)
for _g, _sel in {_G_ALU: 1, _G_LOD: 2, _G_STO: 3, _G_LODI: 4, _G_TD: 5,
                 _G_RED: 6, _G_SFU: 7, _G_GLD: 8, _G_GST: 9,
                 _G_SETP: 10, _G_SELP: 11}.items():
    DATA_SEL_OF_GROUP[_g] = _sel

# opcode -> data-switch branch
DATA_SEL_OF_OP = DATA_SEL_OF_GROUP[_GROUP_OF_OP]


def make_data_handlers(cfg, backend: ExecBackend, d: dict,
                       active: jax.Array, block_idx: jax.Array,
                       prog_idx: jax.Array, *,
                       shmem_depth: int | None = None):
    """Build the 12-way data-path switch body for one decoded instruction.

    ``d`` holds the decoded fields as traced i32 scalars (the dict from
    ``_decode`` or one step of the trace engine's pre-decoded schedule);
    ``active`` is the (512,) flexible-ISA thread mask, shared by the whole
    SM batch — every engine dispatches on lockstep batches of one program
    (the trace engine's merged heterogeneous waves slice each program's
    contiguous SM sub-batch before dispatching here). Returns a list of
    handlers over the data-state tuple ``(regs, shmem, gmem, oob)`` —
    index it with ``DATA_SEL_OF_GROUP[group]`` (branch 0 is the identity
    for NOP/control). Sequencer state (pc, stacks, halt) is each engine's
    own business.

    ``shmem_depth`` bounds LOD/STO addressing; it defaults to the shared-
    memory array's own depth and only differs in merged heterogeneous
    waves, where programs with a shallower ``Kernel(shmem_depth=)``
    override share one device-depth batch: accesses in
    ``[shmem_depth, array depth)`` still trap/drop exactly as they do when
    the program runs alone on a ``shmem_depth``-deep SM.
    """

    tid = jnp.arange(MAX_THREADS, dtype=_I32)
    lane = tid % N_SP

    snoop = d["x"] == 1
    ra_tid = jnp.where(snoop, d["ext_a"] * N_SP + lane, tid)
    rb_tid = jnp.where(snoop, d["ext_b"] * N_SP + lane, tid)
    op, typ = d["opcode"], d["typ"]
    is_fp = typ == int(isa.Typ.FP32)

    # SIMT predication. ``pgate`` is the predicate gate alone — all-true
    # on legacy PEN=0 words (the fields are traced scalars here, so the
    # gate is computed either way; the megakernel's host-constant rows
    # skip it entirely). ``eff`` replaces the flexible-ISA mask in every
    # write/port mask below: predicated-off lanes write no register/
    # shmem/gmem state and generate no port transaction (no trap, no
    # store, no last-writer slot). Cycle accounting is untouched —
    # masked lanes still occupy their issue/drain slots as bubbles, so
    # the static traces (and with them scheduler/packing/fleet pricing)
    # stay exact.
    def pgate(regs):
        p = (jnp.take(regs, d["preg"], axis=2) & 1) != 0   # (n_sms, 512)
        p = jnp.where(d["pneg"] == 1, ~p, p)
        return jnp.where(d["pen"] == 1, p, True)

    def eff(regs):
        return active[None] & pgate(regs)

    def col(regs, rd):
        return jnp.take(regs, rd, axis=2)     # (n_sms, 512)

    def set_col(regs, rd, vals):
        return regs.at[:, :, rd].set(vals)

    def write_active(regs, rd, vals, mask):
        return set_col(regs, rd, jnp.where(mask, vals, col(regs, rd)))

    def operands(regs):
        a_u = regs[:, ra_tid, d["ra"]]        # (n_sms, 512)
        b_u = regs[:, rb_tid, d["rb"]]
        return a_u, b_u

    def addr_of(regs):
        a_u, _ = operands(regs)
        return jax.lax.bitcast_convert_type(a_u, _I32) + d["imm"]

    def h_identity(s):
        return s

    def h_alu(s):
        regs, shmem, gmem, oob = s
        a_u, b_u = operands(regs)
        old = col(regs, d["rd"])
        mask = eff(regs)
        res = backend.alu(op, typ, a_u, b_u, mask, old)
        return set_col(regs, d["rd"], res), shmem, gmem, oob

    def h_lod(s):
        regs, shmem, gmem, oob = s
        depth = shmem_depth if shmem_depth is not None else shmem.shape[1]
        m = eff(regs)
        addr = addr_of(regs)
        bad = m & ((addr < 0) | (addr >= depth))
        safe = jnp.clip(addr, 0, depth - 1)
        old = col(regs, d["rd"])
        mask = m & ~bad
        vals = backend.lod(shmem, safe, mask, old)
        return (set_col(regs, d["rd"], vals), shmem, gmem,
                oob | bad.any(axis=1))

    def h_sto(s):
        regs, shmem, gmem, oob = s
        depth = shmem_depth if shmem_depth is not None else shmem.shape[1]
        m = eff(regs)
        addr = addr_of(regs)
        bad = m & ((addr < 0) | (addr >= depth))
        vals = col(regs, d["rd"])
        shmem = backend.sto(shmem, addr, vals, m & ~bad)
        return regs, shmem, gmem, oob | bad.any(axis=1)

    def h_lodi(s):
        regs, shmem, gmem, oob = s
        as_f = jax.lax.bitcast_convert_type(d["imm"].astype(_F32), _U32)
        val = jnp.where(is_fp, as_f, d["imm"].astype(_U32))
        vals = jnp.broadcast_to(val, (regs.shape[0], MAX_THREADS))
        return (write_active(regs, d["rd"], vals, eff(regs)),
                shmem, gmem, oob)

    def h_td(s):
        regs, shmem, gmem, oob = s
        n_sms = regs.shape[0]
        x = (tid % cfg.dim_x).astype(_U32)[None]            # (1, 512)
        y = (tid // cfg.dim_x).astype(_U32)[None]
        bid = jnp.broadcast_to(block_idx.astype(_U32)[:, None],
                               (n_sms, MAX_THREADS))
        pid = jnp.broadcast_to(prog_idx.astype(_U32)[:, None],
                               (n_sms, MAX_THREADS))
        vals = jnp.where(op == int(Op.TDX), x,
                         jnp.where(op == int(Op.TDY), y,
                                   jnp.where(op == int(Op.BID), bid, pid)))
        return (write_active(regs, d["rd"], vals, eff(regs)),
                shmem, gmem, oob)

    def h_red(s):
        # DOT/SUM: reduce each active wavefront across its active lanes,
        # write the result to lane 0 of that wavefront (the first SP).
        # Predicated-off lanes contribute nothing and a wavefront with no
        # enabled lane keeps its old lane-0 value.
        regs, shmem, gmem, oob = s
        n_sms = regs.shape[0]
        a_u, b_u = operands(regs)
        lane_eff = eff(regs).reshape(n_sms, MAX_WAVES, N_SP)
        a2 = jax.lax.bitcast_convert_type(a_u, _F32) \
            .reshape(n_sms, MAX_WAVES, N_SP)
        b2 = jax.lax.bitcast_convert_type(b_u, _F32) \
            .reshape(n_sms, MAX_WAVES, N_SP)
        prod = jnp.where(op == int(Op.DOT), a2 * b2, a2 + b2)
        red = jnp.sum(jnp.where(lane_eff, prod, 0.0), axis=2)
        wave_active = lane_eff.any(axis=2)                  # (n_sms, waves)
        dest = jnp.arange(MAX_WAVES, dtype=_I32) * N_SP     # lane 0 per wave
        cur = regs[:, dest, d["rd"]]                        # (n_sms, waves)
        new = jnp.where(wave_active,
                        jax.lax.bitcast_convert_type(red, _U32), cur)
        return regs.at[:, dest, d["rd"]].set(new), shmem, gmem, oob

    def h_sfu(s):
        # single-lane SFU: 1/sqrt of wavefront-0 lane-0 (snoopable source);
        # the issuing thread-0 predicate gates the write
        regs, shmem, gmem, oob = s
        src_tid = jnp.where(snoop, d["ext_a"] * N_SP, 0)
        val = jax.lax.bitcast_convert_type(
            regs[:, src_tid, d["ra"]], _F32)                # (n_sms,)
        r = jax.lax.bitcast_convert_type(jax.lax.rsqrt(val), _U32)
        new = jnp.where(pgate(regs)[:, 0], r, regs[:, 0, d["rd"]])
        return regs.at[:, 0, d["rd"]].set(new), shmem, gmem, oob

    def h_gld(s):
        regs, shmem, gmem, oob = s
        gdepth = gmem.shape[0]
        m = eff(regs)
        addr = addr_of(regs)
        bad = m & ((addr < 0) | (addr >= gdepth))
        safe = jnp.clip(addr, 0, gdepth - 1)
        old = col(regs, d["rd"])
        mask = m & ~bad
        vals = backend.gld(gmem, safe, mask, old)
        return (set_col(regs, d["rd"], vals), shmem, gmem,
                oob | bad.any(axis=1))

    def h_gst(s):
        regs, shmem, gmem, oob = s
        gdepth = gmem.shape[0]
        m = eff(regs)
        addr = addr_of(regs)
        bad = m & ((addr < 0) | (addr >= gdepth))
        vals = col(regs, d["rd"])
        # the single device-wide port drains in (sm, thread) order
        gmem = backend.gst(gmem, addr, vals, m & ~bad)
        return regs, shmem, gmem, oob | bad.any(axis=1)

    def h_setp(s):
        regs, shmem, gmem, oob = s
        a_u, b_u = operands(regs)
        res = _setp_compare(d["imm"], typ, a_u, b_u)
        return (write_active(regs, d["rd"], res.astype(_U32), eff(regs)),
                shmem, gmem, oob)

    def h_selp(s):
        # Rd = P ? Ra : Rb — the @-guard is the SELECTOR here, not a
        # write gate: SELP writes on every active lane (PEN=0 selects Ra)
        regs, shmem, gmem, oob = s
        a_u, b_u = operands(regs)
        vals = jnp.where(pgate(regs), a_u, b_u)
        return (write_active(regs, d["rd"], vals,
                             jnp.broadcast_to(active, vals.shape)),
                shmem, gmem, oob)

    return [h_identity, h_alu, h_lod, h_sto, h_lodi, h_td, h_red, h_sfu,
            h_gld, h_gst, h_setp, h_selp]


# ---------------------------------------------------------------------------
# public entry points (single-wave shims over the device layer)
# ---------------------------------------------------------------------------

def run(cfg: SMConfig, program, shmem: np.ndarray | None = None,
        state: MachineState | None = None, *,
        backend: str = "inline") -> MachineState:
    """Assemble-and-run convenience wrapper: ONE SM, one thread block.

    ``program`` is a Program or an ndarray of encoded 40-bit words.
    Implemented as a single-block wave on the device layer; use
    ``device.launch`` for grids, global memory, and multi-SM runs.
    """
    from . import device

    words = program.words if hasattr(program, "words") else np.asarray(program)
    lo, hi = pack_imem(words, cfg.imem_depth)
    if state is None:
        dstate = device.init_device_state(cfg, n_sms=1, shmem=shmem)
    else:
        dstate = device.lift_machine_state(state)
    fin = device.run_wave(cfg, backend, jnp.asarray(lo), jnp.asarray(hi),
                          jnp.zeros((1,), _I32), jnp.zeros((1,), _I32),
                          dstate)
    return device.squeeze_device_state(fin)


def run_many(cfg: SMConfig, program, shmem_batch: np.ndarray, *,
             backend: str = "inline") -> MachineState:
    """Multi-SM execution: one eGPU instance per shared-memory image (the
    quad-packed sector of §III.E, generalized to N instances).

    Backward-compatibility shim over ``device.launch``: every instance runs
    the same program as one device wave, and the returned ``MachineState``
    carries a leading batch axis on every field (the historical vmapped
    layout). New code should call ``device.launch`` directly.
    """
    from . import device

    shmem_batch = jnp.asarray(shmem_batch)
    n_sms = int(shmem_batch.shape[0])
    words = program.words if hasattr(program, "words") else np.asarray(program)
    lo, hi = pack_imem(words, cfg.imem_depth)
    dstate = device.init_device_state(cfg, n_sms=n_sms, shmem=shmem_batch)
    fin = device.run_wave(cfg, backend, jnp.asarray(lo), jnp.asarray(hi),
                          jnp.arange(n_sms, dtype=_I32),
                          jnp.zeros((n_sms,), _I32), dstate)
    # historical layout: every field vmapped over the SM batch
    b = lambda x: jnp.broadcast_to(x, (n_sms,) + x.shape)
    return MachineState(
        regs=fin.regs, shmem=fin.shmem,
        pc=b(fin.pc), ret_stack=b(fin.ret_stack), ret_sp=b(fin.ret_sp),
        loop_ctr=b(fin.loop_ctr), loop_sp=b(fin.loop_sp),
        halted=b(fin.halted), oob=fin.oob,
        steps=b(fin.steps), cycles=b(fin.cycles),
        cycles_by_class=b(fin.cycles_by_class),
    )
