"""eGPU instruction-set simulator: decode machinery + execute backends.

Faithful to the paper's SM microarchitecture:

  * 16 SPs; thread ``t`` runs on SP ``t % 16`` (its *lane*), in wavefront
    ``t // 16``. SP ``l``'s register file (two M20Ks, 512x32 each as 2R1W)
    holds registers for threads ``{l, 16+l, 32+l, ...}``.
  * Flexible ISA: per-instruction WIDTH/DEPTH resize the active thread
    block with no flush — implemented as an active-thread mask.
  * Thread snooping (X=1): source operands read ``regs[ext*16 + lane]``,
    letting wavefront-0 threads address any register in their lane.
  * DOT/SUM extension units reduce each active wavefront and write lane 0;
    INVSQR is a single-lane SFU on wavefront 0 / lane 0.
  * Shared memory: quad read port (cycle model: 4 threads/clock on LOD),
    single write port (1 thread/clock on STO; writeback is sequential in
    thread order, so the *last* active thread wins on address collisions —
    we reproduce that determinism exactly).
  * Zero-overhead loops (INIT/LOOP), JSR/RTS return stack, STOP flag.
  * No hardware interlocks: the ISS executes architecturally (every read
    sees the latest architectural write). Timing hazards are a *static*
    property checked by ``assembler.check_hazards``; the paper's NOP
    mitigation is reproduced in the benchmark programs.

Since the multi-SM refactor the stepping loop itself lives in
``device.py`` and operates on a whole SM *batch* in lockstep; this module
owns the pieces every step needs:

  * ``pack_imem`` / ``_decode`` — the 40-bit I-word field extraction;
  * the opcode -> handler-group and opcode -> profile-class tables;
  * the **pluggable execute backends** for the ALU stage. The execute
    stage consumes pre-gathered ``(n_sms, 512)`` uint32 operand tiles and
    produces the masked destination column. Two implementations ship:

      - ``"inline"``  — straight jnp (the ``kernels.ref`` oracle);
      - ``"pallas"``  — the ``kernels.simt_alu`` Pallas TPU kernel, so a
        multi-SM step executes as ONE Pallas grid over the SM batch
        (interpreted on CPU, compiled on TPU).

    Both are bit-exact by construction and selected per run via
    ``run(..., backend=...)`` / ``DeviceConfig.backend``.

``run`` and ``run_many`` are preserved as single-wave shims over the
device layer; new code should use ``device.launch``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import isa
from .isa import Op
from .machine import MachineState, SMConfig

_U32 = jnp.uint32
_I32 = jnp.int32
_F32 = jnp.float32


def pack_imem(words: np.ndarray, depth: int) -> tuple[np.ndarray, np.ndarray]:
    """Split 40-bit I-words into (lo32, hi8) uint32 arrays of ``depth``."""
    w = np.asarray(words, dtype=np.int64)
    if w.shape[0] > depth:
        raise ValueError(f"program of {w.shape[0]} words exceeds I-MEM depth {depth}")
    lo = (w & 0xFFFFFFFF).astype(np.uint32)
    hi = ((w >> 32) & 0xFF).astype(np.uint32)
    pad = depth - w.shape[0]
    # pad with STOP so runaway PCs halt
    stop_word = isa.Instr(op=Op.STOP).encode()
    lo = np.concatenate([lo, np.full((pad,), stop_word & 0xFFFFFFFF, np.uint32)])
    hi = np.concatenate([hi, np.full((pad,), (stop_word >> 32) & 0xFF, np.uint32)])
    return lo, hi


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _decode(lo: jax.Array, hi: jax.Array) -> dict[str, jax.Array]:
    imm_raw = (lo & 0x7FFF).astype(_I32)
    imm_sext = jnp.where(imm_raw & 0x4000, imm_raw - (1 << 15), imm_raw)
    return dict(
        imm_raw=imm_raw,
        imm=imm_sext,
        x=((lo >> 15) & 1).astype(_I32),
        rb=((lo >> 16) & 0xF).astype(_I32),
        ra=((lo >> 20) & 0xF).astype(_I32),
        rd=((lo >> 24) & 0xF).astype(_I32),
        typ=((lo >> 28) & 0x3).astype(_I32),
        opcode=(((lo >> 30) & 0x3) | ((hi & 0xF) << 2)).astype(_I32),
        depth=((hi >> 4) & 0x3).astype(_I32),
        width=((hi >> 6) & 0x3).astype(_I32),
        ext_a=((lo >> 10) & 0x1F).astype(_I32),
        ext_b=((lo >> 5) & 0x1F).astype(_I32),
    )


# opcode -> handler group
(_G_NOP, _G_ALU, _G_LOD, _G_STO, _G_LODI, _G_TD, _G_RED, _G_SFU, _G_CTL,
 _G_GLD, _G_GST) = range(11)
_GROUP_OF_OP = np.zeros((64,), np.int32)
for _op, _g in {
    Op.NOP: _G_NOP,
    Op.ADD: _G_ALU, Op.SUB: _G_ALU, Op.MUL: _G_ALU, Op.AND: _G_ALU,
    Op.OR: _G_ALU, Op.XOR: _G_ALU, Op.NOT: _G_ALU, Op.LSL: _G_ALU,
    Op.LSR: _G_ALU,
    Op.LOD: _G_LOD, Op.STO: _G_STO, Op.LODI: _G_LODI,
    Op.TDX: _G_TD, Op.TDY: _G_TD, Op.BID: _G_TD, Op.PID: _G_TD,
    Op.DOT: _G_RED, Op.SUM: _G_RED, Op.INVSQR: _G_SFU,
    Op.JMP: _G_CTL, Op.JSR: _G_CTL, Op.RTS: _G_CTL, Op.LOOP: _G_CTL,
    Op.INIT: _G_CTL, Op.STOP: _G_CTL,
    Op.GLD: _G_GLD, Op.GST: _G_GST,
}.items():
    _GROUP_OF_OP[int(_op)] = _g

# opcode -> profile class, per operand type (rows of Tables III/IV + GMEM)
_CLASS_OF = np.zeros((64, 3), np.int32)
for _op in Op:
    for _t in isa.Typ:
        _CLASS_OF[int(_op), int(_t)] = isa.instr_class(_op, _t)


# ---------------------------------------------------------------------------
# pluggable execute backends (the per-step ALU execute stage)
# ---------------------------------------------------------------------------
#
# An execute backend implements one SIMT ALU instruction over an SM batch:
#
#     fn(op, typ, a, b, mask, old) -> (n_sms, 512) uint32
#
# ``op``/``typ`` are traced i32 scalars (the decoded fields), ``a``/``b``
# pre-gathered source-operand tiles, ``mask`` the flexible-ISA active-thread
# mask, ``old`` the current destination column (inactive threads keep it).

ExecuteBackend = Callable[..., jax.Array]

_EXECUTE_BACKENDS: dict[str, ExecuteBackend] = {}


def register_execute_backend(name: str):
    def deco(fn: ExecuteBackend) -> ExecuteBackend:
        _EXECUTE_BACKENDS[name] = fn
        return fn
    return deco


def get_execute_backend(name: str) -> ExecuteBackend:
    try:
        return _EXECUTE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown execute backend {name!r}; "
            f"available: {sorted(_EXECUTE_BACKENDS)}") from None


def execute_backends() -> tuple[str, ...]:
    return tuple(sorted(_EXECUTE_BACKENDS))


@register_execute_backend("inline")
def _inline_execute(op, typ, a, b, mask, old) -> jax.Array:
    """Straight-jnp execute stage (the ``kernels.ref`` oracle)."""
    from ..kernels.ref import alu_ref

    return jnp.where(mask, alu_ref(op, typ, a, b), old)


@register_execute_backend("pallas")
def _pallas_execute(op, typ, a, b, mask, old) -> jax.Array:
    """Pallas execute stage: one ``simt_alu`` grid over the SM batch."""
    from ..kernels import ops
    from ..kernels.simt_alu import simt_alu

    n_sm = a.shape[0]
    # largest tile that divides the batch, capped at 8 SMs (80 KiB VMEM)
    block_sm = max(d for d in range(1, min(8, n_sm) + 1) if n_sm % d == 0)
    return simt_alu(op.astype(_I32), typ.astype(_I32), a, b,
                    mask.astype(_U32), old,
                    interpret=ops.INTERPRET, block_sm=block_sm)


# ---------------------------------------------------------------------------
# public entry points (single-wave shims over the device layer)
# ---------------------------------------------------------------------------

def run(cfg: SMConfig, program, shmem: np.ndarray | None = None,
        state: MachineState | None = None, *,
        backend: str = "inline") -> MachineState:
    """Assemble-and-run convenience wrapper: ONE SM, one thread block.

    ``program`` is a Program or an ndarray of encoded 40-bit words.
    Implemented as a single-block wave on the device layer; use
    ``device.launch`` for grids, global memory, and multi-SM runs.
    """
    from . import device

    words = program.words if hasattr(program, "words") else np.asarray(program)
    lo, hi = pack_imem(words, cfg.imem_depth)
    if state is None:
        dstate = device.init_device_state(cfg, n_sms=1, shmem=shmem)
    else:
        dstate = device.lift_machine_state(state)
    fin = device.run_wave(cfg, backend, jnp.asarray(lo), jnp.asarray(hi),
                          jnp.zeros((1,), _I32), jnp.zeros((1,), _I32),
                          dstate)
    return device.squeeze_device_state(fin)


def run_many(cfg: SMConfig, program, shmem_batch: np.ndarray, *,
             backend: str = "inline") -> MachineState:
    """Multi-SM execution: one eGPU instance per shared-memory image (the
    quad-packed sector of §III.E, generalized to N instances).

    Backward-compatibility shim over ``device.launch``: every instance runs
    the same program as one device wave, and the returned ``MachineState``
    carries a leading batch axis on every field (the historical vmapped
    layout). New code should call ``device.launch`` directly.
    """
    from . import device

    shmem_batch = jnp.asarray(shmem_batch)
    n_sms = int(shmem_batch.shape[0])
    words = program.words if hasattr(program, "words") else np.asarray(program)
    lo, hi = pack_imem(words, cfg.imem_depth)
    dstate = device.init_device_state(cfg, n_sms=n_sms, shmem=shmem_batch)
    fin = device.run_wave(cfg, backend, jnp.asarray(lo), jnp.asarray(hi),
                          jnp.arange(n_sms, dtype=_I32),
                          jnp.zeros((n_sms,), _I32), dstate)
    # historical layout: every field vmapped over the SM batch
    b = lambda x: jnp.broadcast_to(x, (n_sms,) + x.shape)
    return MachineState(
        regs=fin.regs, shmem=fin.shmem,
        pc=b(fin.pc), ret_stack=b(fin.ret_stack), ret_sp=b(fin.ret_sp),
        loop_ctr=b(fin.loop_ctr), loop_sp=b(fin.loop_sp),
        halted=b(fin.halted), oob=fin.oob,
        steps=b(fin.steps), cycles=b(fin.cycles),
        cycles_by_class=b(fin.cycles_by_class),
    )
