"""eGPU instruction-set simulator: decode machinery + execute backends.

Faithful to the paper's SM microarchitecture:

  * 16 SPs; thread ``t`` runs on SP ``t % 16`` (its *lane*), in wavefront
    ``t // 16``. SP ``l``'s register file (two M20Ks, 512x32 each as 2R1W)
    holds registers for threads ``{l, 16+l, 32+l, ...}``.
  * Flexible ISA: per-instruction WIDTH/DEPTH resize the active thread
    block with no flush — implemented as an active-thread mask.
  * Thread snooping (X=1): source operands read ``regs[ext*16 + lane]``,
    letting wavefront-0 threads address any register in their lane.
  * DOT/SUM extension units reduce each active wavefront and write lane 0;
    INVSQR is a single-lane SFU on wavefront 0 / lane 0.
  * Shared memory: quad read port (cycle model: 4 threads/clock on LOD),
    single write port (1 thread/clock on STO; writeback is sequential in
    thread order, so the *last* active thread wins on address collisions —
    we reproduce that determinism exactly).
  * Zero-overhead loops (INIT/LOOP), JSR/RTS return stack, STOP flag.
  * No hardware interlocks: the ISS executes architecturally (every read
    sees the latest architectural write). Timing hazards are a *static*
    property checked by ``assembler.check_hazards``; the paper's NOP
    mitigation is reproduced in the benchmark programs.

Since the multi-SM refactor the stepping loop itself lives in
``device.py`` and operates on a whole SM *batch* in lockstep; this module
owns the pieces every step needs:

  * ``pack_imem`` / ``_decode`` — the 40-bit I-word field extraction;
  * the opcode -> handler-group and opcode -> profile-class tables;
  * the **shared execute stage** (``make_data_handlers``): the data-path
    handlers of every instruction group, dispatched by BOTH engines — the
    stepping machine (``device._device_step``) and the trace-compiled
    scan (``core.trace_engine``) — so the two are bit-identical by
    construction;
  * the **pluggable execute backends** (``ExecBackend``). Since the
    trace-engine refactor the seam covers the whole execute stage: the
    ALU column plus the LOD/STO quad-read/single-write-port
    gather/scatter and the GLD/GST global accesses. Two implementations
    ship:

      - ``"inline"``  — straight jnp (the ``kernels.ref`` oracle + the
        scatter-max port-serialization trick);
      - ``"pallas"``  — the ``kernels.simt_alu`` ALU kernel and the
        ``kernels.simt_step`` gather/scatter kernels, so a multi-SM
        step's data path executes as Pallas grids over the SM batch
        (interpreted on CPU, compiled on TPU).

    Both are bit-exact by construction and selected per run via
    ``run(..., backend=...)`` / ``DeviceConfig.backend``.

``run`` and ``run_many`` are preserved as single-wave shims over the
device layer (always on the step machine); new code should use
``device.launch``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import isa
from .isa import Op
from .machine import MachineState, SMConfig

_U32 = jnp.uint32
_I32 = jnp.int32
_F32 = jnp.float32


def pack_imem(words: np.ndarray, depth: int) -> tuple[np.ndarray, np.ndarray]:
    """Split 40-bit I-words into (lo32, hi8) uint32 arrays of ``depth``."""
    w = np.asarray(words, dtype=np.int64)
    if w.shape[0] > depth:
        raise ValueError(f"program of {w.shape[0]} words exceeds I-MEM depth {depth}")
    lo = (w & 0xFFFFFFFF).astype(np.uint32)
    hi = ((w >> 32) & 0xFF).astype(np.uint32)
    pad = depth - w.shape[0]
    # pad with STOP so runaway PCs halt
    stop_word = isa.Instr(op=Op.STOP).encode()
    lo = np.concatenate([lo, np.full((pad,), stop_word & 0xFFFFFFFF, np.uint32)])
    hi = np.concatenate([hi, np.full((pad,), (stop_word >> 32) & 0xFF, np.uint32)])
    return lo, hi


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _decode(lo: jax.Array, hi: jax.Array) -> dict[str, jax.Array]:
    imm_raw = (lo & 0x7FFF).astype(_I32)
    imm_sext = jnp.where(imm_raw & 0x4000, imm_raw - (1 << 15), imm_raw)
    return dict(
        imm_raw=imm_raw,
        imm=imm_sext,
        x=((lo >> 15) & 1).astype(_I32),
        rb=((lo >> 16) & 0xF).astype(_I32),
        ra=((lo >> 20) & 0xF).astype(_I32),
        rd=((lo >> 24) & 0xF).astype(_I32),
        typ=((lo >> 28) & 0x3).astype(_I32),
        opcode=(((lo >> 30) & 0x3) | ((hi & 0xF) << 2)).astype(_I32),
        depth=((hi >> 4) & 0x3).astype(_I32),
        width=((hi >> 6) & 0x3).astype(_I32),
        ext_a=((lo >> 10) & 0x1F).astype(_I32),
        ext_b=((lo >> 5) & 0x1F).astype(_I32),
    )


# opcode -> handler group
(_G_NOP, _G_ALU, _G_LOD, _G_STO, _G_LODI, _G_TD, _G_RED, _G_SFU, _G_CTL,
 _G_GLD, _G_GST) = range(11)
_GROUP_OF_OP = np.zeros((64,), np.int32)
for _op, _g in {
    Op.NOP: _G_NOP,
    Op.ADD: _G_ALU, Op.SUB: _G_ALU, Op.MUL: _G_ALU, Op.AND: _G_ALU,
    Op.OR: _G_ALU, Op.XOR: _G_ALU, Op.NOT: _G_ALU, Op.LSL: _G_ALU,
    Op.LSR: _G_ALU,
    Op.LOD: _G_LOD, Op.STO: _G_STO, Op.LODI: _G_LODI,
    Op.TDX: _G_TD, Op.TDY: _G_TD, Op.BID: _G_TD, Op.PID: _G_TD,
    Op.DOT: _G_RED, Op.SUM: _G_RED, Op.INVSQR: _G_SFU,
    Op.JMP: _G_CTL, Op.JSR: _G_CTL, Op.RTS: _G_CTL, Op.LOOP: _G_CTL,
    Op.INIT: _G_CTL, Op.STOP: _G_CTL,
    Op.GLD: _G_GLD, Op.GST: _G_GST,
}.items():
    _GROUP_OF_OP[int(_op)] = _g

# opcode -> profile class, per operand type (rows of Tables III/IV + GMEM)
_CLASS_OF = np.zeros((64, 3), np.int32)
for _op in Op:
    for _t in isa.Typ:
        _CLASS_OF[int(_op), int(_t)] = isa.instr_class(_op, _t)


# ---------------------------------------------------------------------------
# pluggable execute backends (the whole per-step execute stage)
# ---------------------------------------------------------------------------
#
# A backend implements the data-path operations of one instruction over an
# SM batch. Since the trace-engine refactor the seam covers the WHOLE
# execute stage, not just the ALU:
#
#   alu(op, typ, a, b, mask, old)   -> (n_sms, 512) destination column
#   lod(shmem, addr, mask, old)     -> (n_sms, 512) quad-port gather
#   sto(shmem, addr, vals, do)      -> (n_sms, depth) single-port scatter
#                                      (last active thread wins)
#   gld(gmem, addr, mask, old)      -> (n_sms, 512) global gather
#   gst(gmem, addr, vals, do)       -> (gdepth,) device-wide scatter
#                                      (last (sm, thread) writer wins)
#
# ``op``/``typ`` are traced i32 scalars (decoded fields), ``a``/``b``
# pre-gathered source-operand tiles, ``mask``/``do`` the flexible-ISA
# active-thread mask (with out-of-range lanes already dropped), ``addr``
# pre-clipped to the memory depth for the gathers and raw for the scatters.
# All five ops must be bit-exact across backends; both engines (the
# stepping machine and the trace engine) drive them through
# ``make_data_handlers`` below, so functional semantics are shared by
# construction.

ExecuteOp = Callable[..., jax.Array]


def _last_writer_write(mem, addr, vals, do, order):
    """Serialized single-port store: among enabled writers to the same
    address, the one latest in ``order`` wins (thread order within an SM;
    (sm, thread)-major order device-wide for global memory). Implemented
    with a commutative scatter-max so it is deterministic under jit."""
    depth = mem.shape[0]
    slot = jnp.where(do, addr, depth)                    # park masked writes
    winner = jnp.full((depth + 1,), -1, _I32).at[slot].max(order)
    write = do & (winner[slot] == order)
    return mem.at[jnp.where(write, addr, depth)].set(vals, mode="drop")


def _inline_alu(op, typ, a, b, mask, old) -> jax.Array:
    """Straight-jnp ALU stage (the ``kernels.ref`` oracle)."""
    from ..kernels.ref import alu_ref

    return jnp.where(mask, alu_ref(op, typ, a, b), old)


def _inline_lod(shmem, addr, mask, old) -> jax.Array:
    return jnp.where(mask, jnp.take_along_axis(shmem, addr, axis=1), old)


def _inline_sto(shmem, addr, vals, do) -> jax.Array:
    tid = jnp.arange(addr.shape[1], dtype=_I32)
    return jax.vmap(_last_writer_write, in_axes=(0, 0, 0, 0, None))(
        shmem, addr, vals, do, tid)


def _inline_gld(gmem, addr, mask, old) -> jax.Array:
    return jnp.where(mask, gmem[addr], old)


def _inline_gst(gmem, addr, vals, do) -> jax.Array:
    order = jnp.arange(addr.size, dtype=_I32)
    return _last_writer_write(gmem, addr.reshape(-1), vals.reshape(-1),
                              do.reshape(-1), order)


@dataclasses.dataclass(frozen=True)
class ExecBackend:
    """One named implementation of the execute-stage data path."""

    name: str
    alu: ExecuteOp = _inline_alu
    lod: ExecuteOp = _inline_lod
    sto: ExecuteOp = _inline_sto
    gld: ExecuteOp = _inline_gld
    gst: ExecuteOp = _inline_gst


_EXECUTE_BACKENDS: dict[str, ExecBackend] = {}


def register_backend(backend: ExecBackend) -> ExecBackend:
    _EXECUTE_BACKENDS[backend.name] = backend
    return backend


def register_execute_backend(name: str):
    """Back-compat decorator: register an ALU-only backend; the memory
    ops inherit the inline jnp implementations."""
    def deco(fn: ExecuteOp) -> ExecuteOp:
        register_backend(ExecBackend(name=name, alu=fn))
        return fn
    return deco


def get_execute_backend(name: str) -> ExecBackend:
    try:
        return _EXECUTE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown execute backend {name!r}; "
            f"available: {sorted(_EXECUTE_BACKENDS)}") from None


def execute_backends() -> tuple[str, ...]:
    return tuple(sorted(_EXECUTE_BACKENDS))


register_backend(ExecBackend(name="inline"))


def _pallas_alu(op, typ, a, b, mask, old) -> jax.Array:
    """Pallas ALU stage: one ``simt_alu`` grid over the SM batch."""
    from ..kernels import ops
    from ..kernels.simt_alu import simt_alu

    n_sm = a.shape[0]
    # largest tile that divides the batch, capped at 8 SMs (80 KiB VMEM)
    block_sm = max(d for d in range(1, min(8, n_sm) + 1) if n_sm % d == 0)
    return simt_alu(op.astype(_I32), typ.astype(_I32), a, b,
                    mask.astype(_U32), old,
                    interpret=ops.INTERPRET, block_sm=block_sm)


def _pallas_lod(shmem, addr, mask, old) -> jax.Array:
    from ..kernels import ops
    from ..kernels.simt_step import simt_gather

    return simt_gather(shmem, addr, mask.astype(_U32), old,
                       interpret=ops.INTERPRET)


def _pallas_sto(shmem, addr, vals, do) -> jax.Array:
    from ..kernels import ops
    from ..kernels.simt_step import simt_scatter

    return simt_scatter(shmem, addr, vals, do.astype(_U32),
                        interpret=ops.INTERPRET)


def _pallas_gld(gmem, addr, mask, old) -> jax.Array:
    from ..kernels import ops
    from ..kernels.simt_step import simt_gather_shared

    return simt_gather_shared(gmem, addr, mask.astype(_U32), old,
                              interpret=ops.INTERPRET)


def _pallas_gst(gmem, addr, vals, do) -> jax.Array:
    from ..kernels import ops
    from ..kernels.simt_step import simt_scatter_shared

    return simt_scatter_shared(gmem, addr, vals, do.astype(_U32),
                               interpret=ops.INTERPRET)


register_backend(ExecBackend(
    name="pallas", alu=_pallas_alu, lod=_pallas_lod, sto=_pallas_sto,
    gld=_pallas_gld, gst=_pallas_gst))


# ---------------------------------------------------------------------------
# the shared execute stage (both engines dispatch into these handlers)
# ---------------------------------------------------------------------------
#
# The data path of one instruction over a lockstep SM batch, factored out
# of the stepping machine so the trace engine executes the IDENTICAL
# handler graph: ``device._device_step`` (decode-per-step) and
# ``trace_engine`` (decode-once ``lax.scan``) both build their dispatch
# from ``make_data_handlers``. Handler order is fixed; ``DATA_SEL_OF_GROUP``
# maps a handler group to its 1-based switch branch (0 = no data effect:
# NOP and control, whose sequencer effects the engines handle themselves).

# handler-group -> data-switch branch (0 = identity)
DATA_SEL_OF_GROUP = np.zeros((11,), np.int32)
for _g, _sel in {_G_ALU: 1, _G_LOD: 2, _G_STO: 3, _G_LODI: 4, _G_TD: 5,
                 _G_RED: 6, _G_SFU: 7, _G_GLD: 8, _G_GST: 9}.items():
    DATA_SEL_OF_GROUP[_g] = _sel

# opcode -> data-switch branch
DATA_SEL_OF_OP = DATA_SEL_OF_GROUP[_GROUP_OF_OP]


def make_data_handlers(cfg, backend: ExecBackend, d: dict,
                       active: jax.Array, block_idx: jax.Array,
                       prog_idx: jax.Array, *,
                       shmem_depth: int | None = None):
    """Build the 10-way data-path switch body for one decoded instruction.

    ``d`` holds the decoded fields as traced i32 scalars (the dict from
    ``_decode`` or one step of the trace engine's pre-decoded schedule);
    ``active`` is the (512,) flexible-ISA thread mask, shared by the whole
    SM batch — every engine dispatches on lockstep batches of one program
    (the trace engine's merged heterogeneous waves slice each program's
    contiguous SM sub-batch before dispatching here). Returns a list of
    handlers over the data-state tuple ``(regs, shmem, gmem, oob)`` —
    index it with ``DATA_SEL_OF_GROUP[group]`` (branch 0 is the identity
    for NOP/control). Sequencer state (pc, stacks, halt) is each engine's
    own business.

    ``shmem_depth`` bounds LOD/STO addressing; it defaults to the shared-
    memory array's own depth and only differs in merged heterogeneous
    waves, where programs with a shallower ``Kernel(shmem_depth=)``
    override share one device-depth batch: accesses in
    ``[shmem_depth, array depth)`` still trap/drop exactly as they do when
    the program runs alone on a ``shmem_depth``-deep SM.
    """
    from .machine import MAX_THREADS, MAX_WAVES, N_SP

    tid = jnp.arange(MAX_THREADS, dtype=_I32)
    lane = tid % N_SP

    snoop = d["x"] == 1
    ra_tid = jnp.where(snoop, d["ext_a"] * N_SP + lane, tid)
    rb_tid = jnp.where(snoop, d["ext_b"] * N_SP + lane, tid)
    op, typ = d["opcode"], d["typ"]
    is_fp = typ == int(isa.Typ.FP32)

    def col(regs, rd):
        return jnp.take(regs, rd, axis=2)     # (n_sms, 512)

    def set_col(regs, rd, vals):
        return regs.at[:, :, rd].set(vals)

    def write_active(regs, rd, vals, mask):
        return set_col(regs, rd, jnp.where(mask, vals, col(regs, rd)))

    def operands(regs):
        a_u = regs[:, ra_tid, d["ra"]]        # (n_sms, 512)
        b_u = regs[:, rb_tid, d["rb"]]
        return a_u, b_u

    def addr_of(regs):
        a_u, _ = operands(regs)
        return jax.lax.bitcast_convert_type(a_u, _I32) + d["imm"]

    def h_identity(s):
        return s

    def h_alu(s):
        regs, shmem, gmem, oob = s
        a_u, b_u = operands(regs)
        old = col(regs, d["rd"])
        mask = jnp.broadcast_to(active, old.shape)
        res = backend.alu(op, typ, a_u, b_u, mask, old)
        return set_col(regs, d["rd"], res), shmem, gmem, oob

    def h_lod(s):
        regs, shmem, gmem, oob = s
        depth = shmem_depth if shmem_depth is not None else shmem.shape[1]
        addr = addr_of(regs)
        bad = active & ((addr < 0) | (addr >= depth))
        safe = jnp.clip(addr, 0, depth - 1)
        old = col(regs, d["rd"])
        mask = active & ~bad
        vals = backend.lod(shmem, safe, mask, old)
        return (set_col(regs, d["rd"], vals), shmem, gmem,
                oob | bad.any(axis=1))

    def h_sto(s):
        regs, shmem, gmem, oob = s
        depth = shmem_depth if shmem_depth is not None else shmem.shape[1]
        addr = addr_of(regs)
        bad = active & ((addr < 0) | (addr >= depth))
        vals = col(regs, d["rd"])
        shmem = backend.sto(shmem, addr, vals, active & ~bad)
        return regs, shmem, gmem, oob | bad.any(axis=1)

    def h_lodi(s):
        regs, shmem, gmem, oob = s
        as_f = jax.lax.bitcast_convert_type(d["imm"].astype(_F32), _U32)
        val = jnp.where(is_fp, as_f, d["imm"].astype(_U32))
        vals = jnp.broadcast_to(val, (regs.shape[0], MAX_THREADS))
        return (write_active(regs, d["rd"], vals, active), shmem, gmem, oob)

    def h_td(s):
        regs, shmem, gmem, oob = s
        n_sms = regs.shape[0]
        x = (tid % cfg.dim_x).astype(_U32)[None]            # (1, 512)
        y = (tid // cfg.dim_x).astype(_U32)[None]
        bid = jnp.broadcast_to(block_idx.astype(_U32)[:, None],
                               (n_sms, MAX_THREADS))
        pid = jnp.broadcast_to(prog_idx.astype(_U32)[:, None],
                               (n_sms, MAX_THREADS))
        vals = jnp.where(op == int(Op.TDX), x,
                         jnp.where(op == int(Op.TDY), y,
                                   jnp.where(op == int(Op.BID), bid, pid)))
        return (write_active(regs, d["rd"], vals, active), shmem, gmem, oob)

    def h_red(s):
        # DOT/SUM: reduce each active wavefront across its active lanes,
        # write the result to lane 0 of that wavefront (the first SP).
        regs, shmem, gmem, oob = s
        n_sms = regs.shape[0]
        a_u, b_u = operands(regs)
        lane_active = active.reshape(MAX_WAVES, N_SP)
        a2 = jax.lax.bitcast_convert_type(a_u, _F32) \
            .reshape(n_sms, MAX_WAVES, N_SP)
        b2 = jax.lax.bitcast_convert_type(b_u, _F32) \
            .reshape(n_sms, MAX_WAVES, N_SP)
        prod = jnp.where(op == int(Op.DOT), a2 * b2, a2 + b2)
        red = jnp.sum(jnp.where(lane_active[None], prod, 0.0), axis=2)
        wave_active = lane_active.any(axis=1)               # (waves,)
        dest = jnp.arange(MAX_WAVES, dtype=_I32) * N_SP     # lane 0 per wave
        cur = regs[:, dest, d["rd"]]                        # (n_sms, waves)
        new = jnp.where(wave_active[None],
                        jax.lax.bitcast_convert_type(red, _U32), cur)
        return regs.at[:, dest, d["rd"]].set(new), shmem, gmem, oob

    def h_sfu(s):
        # single-lane SFU: 1/sqrt of wavefront-0 lane-0 (snoopable source)
        regs, shmem, gmem, oob = s
        src_tid = jnp.where(snoop, d["ext_a"] * N_SP, 0)
        val = jax.lax.bitcast_convert_type(
            regs[:, src_tid, d["ra"]], _F32)                # (n_sms,)
        r = jax.lax.rsqrt(val)
        return (regs.at[:, 0, d["rd"]].set(
            jax.lax.bitcast_convert_type(r, _U32)), shmem, gmem, oob)

    def h_gld(s):
        regs, shmem, gmem, oob = s
        gdepth = gmem.shape[0]
        addr = addr_of(regs)
        bad = active & ((addr < 0) | (addr >= gdepth))
        safe = jnp.clip(addr, 0, gdepth - 1)
        old = col(regs, d["rd"])
        mask = active & ~bad
        vals = backend.gld(gmem, safe, mask, old)
        return (set_col(regs, d["rd"], vals), shmem, gmem,
                oob | bad.any(axis=1))

    def h_gst(s):
        regs, shmem, gmem, oob = s
        gdepth = gmem.shape[0]
        addr = addr_of(regs)
        bad = active & ((addr < 0) | (addr >= gdepth))
        vals = col(regs, d["rd"])
        # the single device-wide port drains in (sm, thread) order
        gmem = backend.gst(gmem, addr, vals, active & ~bad)
        return regs, shmem, gmem, oob | bad.any(axis=1)

    return [h_identity, h_alu, h_lod, h_sto, h_lodi, h_td, h_red, h_sfu,
            h_gld, h_gst]


# ---------------------------------------------------------------------------
# public entry points (single-wave shims over the device layer)
# ---------------------------------------------------------------------------

def run(cfg: SMConfig, program, shmem: np.ndarray | None = None,
        state: MachineState | None = None, *,
        backend: str = "inline") -> MachineState:
    """Assemble-and-run convenience wrapper: ONE SM, one thread block.

    ``program`` is a Program or an ndarray of encoded 40-bit words.
    Implemented as a single-block wave on the device layer; use
    ``device.launch`` for grids, global memory, and multi-SM runs.
    """
    from . import device

    words = program.words if hasattr(program, "words") else np.asarray(program)
    lo, hi = pack_imem(words, cfg.imem_depth)
    if state is None:
        dstate = device.init_device_state(cfg, n_sms=1, shmem=shmem)
    else:
        dstate = device.lift_machine_state(state)
    fin = device.run_wave(cfg, backend, jnp.asarray(lo), jnp.asarray(hi),
                          jnp.zeros((1,), _I32), jnp.zeros((1,), _I32),
                          dstate)
    return device.squeeze_device_state(fin)


def run_many(cfg: SMConfig, program, shmem_batch: np.ndarray, *,
             backend: str = "inline") -> MachineState:
    """Multi-SM execution: one eGPU instance per shared-memory image (the
    quad-packed sector of §III.E, generalized to N instances).

    Backward-compatibility shim over ``device.launch``: every instance runs
    the same program as one device wave, and the returned ``MachineState``
    carries a leading batch axis on every field (the historical vmapped
    layout). New code should call ``device.launch`` directly.
    """
    from . import device

    shmem_batch = jnp.asarray(shmem_batch)
    n_sms = int(shmem_batch.shape[0])
    words = program.words if hasattr(program, "words") else np.asarray(program)
    lo, hi = pack_imem(words, cfg.imem_depth)
    dstate = device.init_device_state(cfg, n_sms=n_sms, shmem=shmem_batch)
    fin = device.run_wave(cfg, backend, jnp.asarray(lo), jnp.asarray(hi),
                          jnp.arange(n_sms, dtype=_I32),
                          jnp.zeros((n_sms,), _I32), dstate)
    # historical layout: every field vmapped over the SM batch
    b = lambda x: jnp.broadcast_to(x, (n_sms,) + x.shape)
    return MachineState(
        regs=fin.regs, shmem=fin.shmem,
        pc=b(fin.pc), ret_stack=b(fin.ret_stack), ret_sp=b(fin.ret_sp),
        loop_ctr=b(fin.loop_ctr), loop_sp=b(fin.loop_sp),
        halted=b(fin.halted), oob=fin.oob,
        steps=b(fin.steps), cycles=b(fin.cycles),
        cycles_by_class=b(fin.cycles_by_class),
    )
