"""eGPU instruction-set simulator: a jitted ``lax.while_loop`` over I-MEM.

Faithful to the paper's SM microarchitecture:

  * 16 SPs; thread ``t`` runs on SP ``t % 16`` (its *lane*), in wavefront
    ``t // 16``. SP ``l``'s register file (two M20Ks, 512x32 each as 2R1W)
    holds registers for threads ``{l, 16+l, 32+l, ...}``.
  * Flexible ISA: per-instruction WIDTH/DEPTH resize the active thread
    block with no flush — implemented as an active-thread mask.
  * Thread snooping (X=1): source operands read ``regs[ext*16 + lane]``,
    letting wavefront-0 threads address any register in their lane.
  * DOT/SUM extension units reduce each active wavefront and write lane 0;
    INVSQR is a single-lane SFU on wavefront 0 / lane 0.
  * Shared memory: quad read port (cycle model: 4 threads/clock on LOD),
    single write port (1 thread/clock on STO; writeback is sequential in
    thread order, so the *last* active thread wins on address collisions —
    we reproduce that determinism exactly).
  * Zero-overhead loops (INIT/LOOP), JSR/RTS return stack, STOP flag.
  * No hardware interlocks: the ISS executes architecturally (every read
    sees the latest architectural write). Timing hazards are a *static*
    property checked by ``assembler.check_hazards``; the paper's NOP
    mitigation is reproduced in the benchmark programs.

The cycle counters implement ``cycles.py`` and produce the Table III/IV
profiles directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import isa
from .isa import Op
from .machine import (
    LOOP_STACK_DEPTH,
    MAX_THREADS,
    MAX_WAVES,
    N_SP,
    RET_STACK_DEPTH,
    MachineState,
    SMConfig,
    init_state,
)

_U32 = jnp.uint32
_I32 = jnp.int32
_F32 = jnp.float32


def _bitcast_f32(x):
    return jax.lax.bitcast_convert_type(x, _F32)


def _bitcast_u32(x):
    return jax.lax.bitcast_convert_type(x, _U32)


def _sext16(x_u32):
    """Sign-extend the low 16 bits (the INT ALU multiplier is 16x16->32)."""
    low = x_u32 & 0xFFFF
    sign = (low >> 15) & 1
    return low | (sign * jnp.uint32(0xFFFF0000))


def pack_imem(words: np.ndarray, depth: int) -> tuple[np.ndarray, np.ndarray]:
    """Split 40-bit I-words into (lo32, hi8) uint32 arrays of ``depth``."""
    w = np.asarray(words, dtype=np.int64)
    if w.shape[0] > depth:
        raise ValueError(f"program of {w.shape[0]} words exceeds I-MEM depth {depth}")
    lo = (w & 0xFFFFFFFF).astype(np.uint32)
    hi = ((w >> 32) & 0xFF).astype(np.uint32)
    pad = depth - w.shape[0]
    # pad with STOP so runaway PCs halt
    stop_word = isa.Instr(op=Op.STOP).encode()
    lo = np.concatenate([lo, np.full((pad,), stop_word & 0xFFFFFFFF, np.uint32)])
    hi = np.concatenate([hi, np.full((pad,), (stop_word >> 32) & 0xFF, np.uint32)])
    return lo, hi


# ---------------------------------------------------------------------------
# one sequencer step
# ---------------------------------------------------------------------------

def _decode(lo: jax.Array, hi: jax.Array) -> dict[str, jax.Array]:
    imm_raw = (lo & 0x7FFF).astype(_I32)
    imm_sext = jnp.where(imm_raw & 0x4000, imm_raw - (1 << 15), imm_raw)
    return dict(
        imm_raw=imm_raw,
        imm=imm_sext,
        x=((lo >> 15) & 1).astype(_I32),
        rb=((lo >> 16) & 0xF).astype(_I32),
        ra=((lo >> 20) & 0xF).astype(_I32),
        rd=((lo >> 24) & 0xF).astype(_I32),
        typ=((lo >> 28) & 0x3).astype(_I32),
        opcode=(((lo >> 30) & 0x3) | ((hi & 0xF) << 2)).astype(_I32),
        depth=((hi >> 4) & 0x3).astype(_I32),
        width=((hi >> 6) & 0x3).astype(_I32),
        ext_a=((lo >> 10) & 0x1F).astype(_I32),
        ext_b=((lo >> 5) & 0x1F).astype(_I32),
    )


# opcode -> handler group
_G_NOP, _G_ALU, _G_LOD, _G_STO, _G_LODI, _G_TD, _G_RED, _G_SFU, _G_CTL = range(9)
_GROUP_OF_OP = np.zeros((64,), np.int32)
for _op, _g in {
    Op.NOP: _G_NOP,
    Op.ADD: _G_ALU, Op.SUB: _G_ALU, Op.MUL: _G_ALU, Op.AND: _G_ALU,
    Op.OR: _G_ALU, Op.XOR: _G_ALU, Op.NOT: _G_ALU, Op.LSL: _G_ALU,
    Op.LSR: _G_ALU,
    Op.LOD: _G_LOD, Op.STO: _G_STO, Op.LODI: _G_LODI,
    Op.TDX: _G_TD, Op.TDY: _G_TD,
    Op.DOT: _G_RED, Op.SUM: _G_RED, Op.INVSQR: _G_SFU,
    Op.JMP: _G_CTL, Op.JSR: _G_CTL, Op.RTS: _G_CTL, Op.LOOP: _G_CTL,
    Op.INIT: _G_CTL, Op.STOP: _G_CTL,
}.items():
    _GROUP_OF_OP[int(_op)] = _g

# opcode -> profile class, per operand type (NUM_CLASSES rows of Table III/IV)
_CLASS_OF = np.zeros((64, 3), np.int32)
for _op in Op:
    for _t in isa.Typ:
        _CLASS_OF[int(_op), int(_t)] = isa.instr_class(_op, _t)


def _step(cfg: SMConfig, imem_lo, imem_hi, s: MachineState,
          alu_fn=None) -> MachineState:
    d = _decode(imem_lo[s.pc], imem_hi[s.pc])
    tid = jnp.arange(MAX_THREADS, dtype=_I32)
    lane = tid % N_SP
    wave = tid // N_SP

    # ---- flexible-ISA active mask -----------------------------------------
    n_waves = cfg.n_waves
    depth_table = jnp.array(
        [n_waves, max(1, n_waves // 2), max(1, n_waves // 4), 1], _I32)
    width_table = jnp.array([16, 8, 4, 1], _I32)
    act_waves = depth_table[d["depth"]]
    act_wthreads = width_table[d["width"]]
    active = (lane < act_wthreads) & (wave < act_waves) & (tid < cfg.n_threads)

    # ---- operand reads (with thread snooping) ------------------------------
    snoop = d["x"] == 1
    ra_tid = jnp.where(snoop, d["ext_a"] * N_SP + lane, tid)
    rb_tid = jnp.where(snoop, d["ext_b"] * N_SP + lane, tid)
    a_u = s.regs[ra_tid, d["ra"]]
    b_u = s.regs[rb_tid, d["rb"]]
    a_f, b_f = _bitcast_f32(a_u), _bitcast_f32(b_u)

    op, typ = d["opcode"], d["typ"]
    is_fp = typ == int(isa.Typ.FP32)

    # ---- group handlers -----------------------------------------------------
    def write_active(regs, rd, vals_u32, mask):
        cur = regs[tid, rd]
        return regs.at[tid, rd].set(jnp.where(mask, vals_u32, cur))

    def h_nop(s):
        return s

    def h_alu(s):
        if alu_fn is not None:
            res = alu_fn(op, typ, a_u, b_u)
        else:
            # integer lane computed in uint32 (wrapping semantics)
            add_u = a_u + b_u
            sub_u = a_u - b_u
            mul_int = _sext16(a_u) * _sext16(b_u)     # 16x16 signed
            mul_uint = (a_u & 0xFFFF) * (b_u & 0xFFFF)  # 16x16 unsigned
            mul_u = jnp.where(typ == int(isa.Typ.UINT32), mul_uint, mul_int)
            sh = b_u & 31
            res_int = jnp.select(
                [op == int(Op.ADD), op == int(Op.SUB), op == int(Op.MUL),
                 op == int(Op.AND), op == int(Op.OR), op == int(Op.XOR),
                 op == int(Op.NOT), op == int(Op.LSL)],
                [add_u, sub_u, mul_u, a_u & b_u, a_u | b_u, a_u ^ b_u,
                 ~a_u, a_u << sh],
                a_u >> sh)  # LSR
            # FP32 lane (IEEE 754 single via the DSP-block FP ALU)
            res_fp = _bitcast_u32(jnp.select(
                [op == int(Op.ADD), op == int(Op.SUB)],
                [a_f + b_f, a_f - b_f], a_f * b_f))
            fp_op = is_fp & ((op == int(Op.ADD)) | (op == int(Op.SUB))
                             | (op == int(Op.MUL)))
            res = jnp.where(fp_op, res_fp, res_int)
        return s.replace_regs(write_active(s.regs, d["rd"], res, active))

    def h_lod(s):
        addr = jax.lax.bitcast_convert_type(a_u, _I32) + d["imm"]
        bad = active & ((addr < 0) | (addr >= cfg.shmem_depth))
        safe = jnp.clip(addr, 0, cfg.shmem_depth - 1)
        vals = s.shmem[safe]
        regs = write_active(s.regs, d["rd"], vals, active)
        return s.replace(regs=regs, oob=s.oob | bad.any())

    def h_sto(s):
        addr = jax.lax.bitcast_convert_type(a_u, _I32) + d["imm"]
        bad = active & ((addr < 0) | (addr >= cfg.shmem_depth))
        vals = s.regs[tid, d["rd"]]
        # single write port, sequential in thread order => last active
        # thread writing an address wins. Keep only each address's last
        # active writer, then scatter (indices now unique).
        same = addr[:, None] == addr[None, :]
        later = tid[:, None] < tid[None, :]
        superseded = (same & later & active[None, :]).any(axis=1)
        do_write = active & ~superseded & ~bad
        safe = jnp.where(do_write, addr, cfg.shmem_depth)  # drop slot
        shmem = s.shmem.at[safe].set(vals, mode="drop")
        return s.replace(shmem=shmem, oob=s.oob | bad.any())

    def h_lodi(s):
        as_f = _bitcast_u32(d["imm"].astype(_F32))
        val = jnp.where(is_fp, as_f, d["imm"].astype(_U32))
        vals = jnp.broadcast_to(val, (MAX_THREADS,))
        return s.replace_regs(write_active(s.regs, d["rd"], vals, active))

    def h_td(s):
        x = (tid % cfg.dim_x).astype(_U32)
        y = (tid // cfg.dim_x).astype(_U32)
        vals = jnp.where(op == int(Op.TDX), x, y)
        return s.replace_regs(write_active(s.regs, d["rd"], vals, active))

    def h_red(s):
        # DOT/SUM: reduce each active wavefront across its active lanes,
        # write the result to lane 0 of that wavefront (the first SP).
        lane_active = active.reshape(MAX_WAVES, N_SP)
        a2 = a_f.reshape(MAX_WAVES, N_SP)
        b2 = b_f.reshape(MAX_WAVES, N_SP)
        prod = jnp.where(op == int(Op.DOT), a2 * b2, a2 + b2)
        red = jnp.sum(jnp.where(lane_active, prod, 0.0), axis=1)  # (waves,)
        wave_active = lane_active.any(axis=1)
        dest = jnp.arange(MAX_WAVES, dtype=_I32) * N_SP  # lane 0 of each wave
        cur = s.regs[dest, d["rd"]]
        new = jnp.where(wave_active, _bitcast_u32(red), cur)
        return s.replace_regs(s.regs.at[dest, d["rd"]].set(new))

    def h_sfu(s):
        # single-lane SFU: 1/sqrt of wavefront-0 lane-0 (snoopable source)
        src_tid = jnp.where(snoop, d["ext_a"] * N_SP, 0)
        val = _bitcast_f32(s.regs[src_tid, d["ra"]])
        r = jax.lax.rsqrt(val)
        return s.replace_regs(s.regs.at[0, d["rd"]].set(_bitcast_u32(r)))

    def h_ctl(s):
        imm = d["imm_raw"]
        pc1 = s.pc + 1
        # LOOP: decrement top counter; jump while > 1, pop at 1
        lsp = jnp.clip(s.loop_sp - 1, 0, LOOP_STACK_DEPTH - 1)
        top = s.loop_ctr[lsp]
        loop_taken = top > 1
        new_pc = jnp.select(
            [op == int(Op.JMP), op == int(Op.JSR), op == int(Op.RTS),
             op == int(Op.LOOP)],
            [imm, imm,
             s.ret_stack[jnp.clip(s.ret_sp - 1, 0, RET_STACK_DEPTH - 1)],
             jnp.where(loop_taken, imm, pc1)],
            pc1)
        ret_stack = jnp.where(
            op == int(Op.JSR),
            s.ret_stack.at[jnp.clip(s.ret_sp, 0, RET_STACK_DEPTH - 1)].set(pc1),
            s.ret_stack)
        ret_sp = s.ret_sp + jnp.where(op == int(Op.JSR), 1, 0) \
            - jnp.where(op == int(Op.RTS), 1, 0)
        loop_ctr = jnp.where(
            op == int(Op.INIT),
            s.loop_ctr.at[jnp.clip(s.loop_sp, 0, LOOP_STACK_DEPTH - 1)].set(imm),
            jnp.where(op == int(Op.LOOP),
                      s.loop_ctr.at[lsp].set(top - 1), s.loop_ctr))
        loop_sp = s.loop_sp \
            + jnp.where(op == int(Op.INIT), 1, 0) \
            - jnp.where((op == int(Op.LOOP)) & ~loop_taken, 1, 0)
        halted = s.halted | (op == int(Op.STOP))
        return s.replace(pc=new_pc, ret_stack=ret_stack, ret_sp=ret_sp,
                         loop_ctr=loop_ctr, loop_sp=loop_sp, halted=halted,
                         _skip_pc=True)

    # MachineState is a frozen-ish dataclass pytree; add tiny helpers
    handlers = [h_nop, h_alu, h_lod, h_sto, h_lodi, h_td, h_red, h_sfu, h_ctl]
    group = jnp.asarray(_GROUP_OF_OP)[op]
    s2 = jax.lax.switch(group, handlers, s)

    # ---- pc advance (control group already set it) --------------------------
    is_ctl = group == _G_CTL
    pc = jnp.where(is_ctl, s2.pc, s.pc + 1)

    # ---- cycle accounting ----------------------------------------------------
    act_threads = act_waves * act_wthreads
    one = jnp.int32(1)
    cyc = jnp.select(
        [group == _G_LOD, group == _G_STO,
         (group == _G_NOP) | (group == _G_CTL) | (group == _G_SFU)],
        [jnp.maximum(one, (act_threads + 3) // 4), act_threads, one],
        act_waves)
    klass = jnp.asarray(_CLASS_OF)[op, typ]
    return MachineState(
        regs=s2.regs, shmem=s2.shmem, pc=pc,
        ret_stack=s2.ret_stack, ret_sp=s2.ret_sp,
        loop_ctr=s2.loop_ctr, loop_sp=s2.loop_sp,
        halted=s2.halted, oob=s2.oob,
        steps=s.steps + 1,
        cycles=s.cycles + cyc,
        cycles_by_class=s.cycles_by_class.at[klass].add(cyc),
    )


# small pytree-update helpers on MachineState ---------------------------------

def _ms_replace(self: MachineState, _skip_pc: bool = False, **kw) -> MachineState:
    import dataclasses
    return dataclasses.replace(self, **kw)


def _ms_replace_regs(self: MachineState, regs) -> MachineState:
    import dataclasses
    return dataclasses.replace(self, regs=regs)


MachineState.replace = _ms_replace          # type: ignore[attr-defined]
MachineState.replace_regs = _ms_replace_regs  # type: ignore[attr-defined]


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,))
def _run_jit(cfg: SMConfig, imem_lo, imem_hi, state: MachineState) -> MachineState:
    def cond(s):
        return (~s.halted) & (s.steps < cfg.max_steps) \
            & (s.pc >= 0) & (s.pc < cfg.imem_depth)

    def body(s):
        return _step(cfg, imem_lo, imem_hi, s)

    return jax.lax.while_loop(cond, body, state)


def run(cfg: SMConfig, program, shmem: np.ndarray | None = None,
        state: MachineState | None = None) -> MachineState:
    """Assemble-and-run convenience wrapper. ``program`` is a Program or
    an ndarray of encoded 40-bit words."""
    words = program.words if hasattr(program, "words") else np.asarray(program)
    lo, hi = pack_imem(words, cfg.imem_depth)
    if state is None:
        state = init_state(cfg, shmem)
    return _run_jit(cfg, jnp.asarray(lo), jnp.asarray(hi), state)


def run_many(cfg: SMConfig, program, shmem_batch: np.ndarray) -> MachineState:
    """vmapped multi-SM execution: one eGPU instance per shared-memory image
    (the quad-packed sector of §III.E, generalized to N instances)."""
    words = program.words if hasattr(program, "words") else np.asarray(program)
    lo, hi = pack_imem(words, cfg.imem_depth)
    lo, hi = jnp.asarray(lo), jnp.asarray(hi)
    states = jax.vmap(lambda sh: init_state(cfg, sh))(jnp.asarray(shmem_batch))
    return jax.jit(jax.vmap(lambda st: _run_jit(cfg, lo, hi, st)))(states)
