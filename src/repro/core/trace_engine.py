"""Trace-compiled execution engine: decode-once ``lax.scan`` pipelines.

The eGPU ISA has no data-dependent control flow — the sequence of
instructions a block issues is a *static* property of the program
(``cycles.program_trace``, exact). The stepping machine in ``device.py``
nevertheless re-fetches the 40-bit I-word, re-extracts every field, and
re-dispatches the handler switch on every ``lax.while_loop`` iteration,
and spends iterations on NOPs (hazard padding) and control flow that have
no architectural data effect. Following the soft-GPGPU compilation
argument (arXiv 2406.03227: close the gap to hand-built pipelines by
compiling the schedule ahead of time; arXiv 2401.04261: hoist dispatch
work off the per-cycle path), this module lowers a program ONCE into a
pre-decoded structure-of-arrays instruction schedule and executes it as a
single jitted ``lax.scan`` over the ``(n_sms, 512)`` lockstep batch:

  * decode happens at trace time, on the host: every issued instruction's
    fields (opcode, registers, immediates, snoop extensions, flexible-ISA
    active shape, handler id) become one row of the schedule;
  * control flow and NOPs vanish from the executed pipeline — their
    sequencer effects are pre-resolved by the trace walk, and their cycle
    costs are a static property already carried by ``ProgramTrace``;
  * the scan body dispatches straight into the shared execute stage
    (``executor.make_data_handlers``), the SAME handler graph the stepping
    machine uses, so the two engines are bit-identical by construction —
    on every backend ("inline" jnp and the "pallas" kernel path alike);
  * one compiled artifact exists per ``(program, SMConfig)``: schedules
    are held in a keyed cache (device-resident arrays, so repeated
    launches skip the host decode AND the host->device transfer), and
    XLA's jit cache keys the compiled scan on (config, backend, shapes).

``device.launch(..., engine="trace")`` routes every functional wave here
while the scheduler/timing layer is fed unchanged — cycle counters come
from the static trace (``trace.static_cycles`` / ``cycles_by_class``),
which the golden-cycle suite pins bit-equal to the stepping machine's.

Heterogeneous waves
-------------------
A mixed ``programs=[Kernel(...), ...]`` grid packs blocks of *different*
programs into one wave (the tight-packing deployment of arXiv
2401.04261). Per-program schedules are merged into ONE padded schedule
(``MergedTraceSchedule``): each program's structure-of-arrays columns are
padded to the longest participant with masked no-op rows and stacked into
``(n_steps, n_programs)`` matrices, so the whole ``(n_sms, 512)`` wave
still runs as a single jitted ``lax.scan``. Wave members are ordered
slot-major; each scan step dispatches every LIVE program's pre-decoded
instruction, in program-slot order, on that program's own contiguous SM
sub-batch — through the SAME ``executor.make_data_handlers`` execute
stage, so inline and Pallas backends work unchanged and step-vs-trace
bit-identity is preserved for every launch whose concurrently-resident
blocks do not race through global memory (the CUDA contract;
``Kernel(barrier=True)`` is the fence for cross-block dataflow, and
merged waves never span a barrier phase).
The merge cache is keyed on the multiset of ``(program, SMConfig)`` pairs
present in the wave; XLA's jit cache then keys the compiled scan on
(slot configs, backend, schedule length, wave width). Padding overhead is
surfaced per wave in ``LaunchResult.profile()["trace_merge"]``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .cycles import ProgramTrace, program_trace
from .executor import (
    DATA_SEL_OF_OP,
    _decode,
    exec_segment,
    get_execute_backend,
    make_data_handlers,
)
from .machine import MAX_THREADS, N_SP, SMConfig

_I32 = jnp.int32

ENGINES = ("step", "trace", "megakernel")

# "auto" only picks the megakernel engine for programs whose schedules it
# can unroll body-to-body without exploding trace/compile time; longer
# schedules fall back to the scanned trace engine (engine_fallback =
# "megakernel-unroll-cap"). An explicit engine="megakernel" ignores the
# cap — the caller owns the compile-time trade.
MEGAKERNEL_UNROLL_CAP = 4096

# ...and only when there is enough fusible work to amortize the plan:
# the megakernel's win is keeping registers/shmem resident across fused
# gmem-free runs, but a short program (BENCH_engine.json's saxpy256_b64:
# 7 residual data rows after partial evaluation) spends its time in
# dispatch glue, measuring 0.81x vs the step machine. Below this many
# residual (non-gmem) data rows in the LONGEST program of the launch,
# "auto" falls back to "step" (engine_fallback = "megakernel-too-small").
# Step, not trace: the same artifact shows trace also losing to step on
# that shape (0.874x mega-vs-trace with mega at 0.811x of step), and the
# ISSUE's acceptance gate holds auto to >= 0.95x of the BEST fixed
# engine. An explicit engine= choice ignores the threshold.
MEGAKERNEL_MIN_FUSED_ROWS = 16

# decoded-field columns of the structure-of-arrays schedule, in the order
# they are packed into the (n_steps, len(_FIELDS)) i32 matrix
_FIELDS = ("sel", "opcode", "typ", "rd", "ra", "rb", "imm", "x",
           "ext_a", "ext_b", "pen", "preg", "pneg",
           "act_waves", "act_wthreads")


@dataclasses.dataclass(frozen=True)
class TraceSchedule:
    """One program lowered to a pre-decoded instruction schedule.

    ``xs[f]`` is the (n_steps,) i32 column for decoded field ``f`` — one
    row per *data* instruction of the issued trace (NOP/control rows are
    compiled out). ``trace`` keeps the full issued trace for timing;
    ``by_class_base``/``by_class_gmem`` pre-reduce its per-class cycle
    totals so per-wave counters are O(classes), not O(steps).
    """

    cfg: SMConfig
    trace: ProgramTrace
    xs: dict[str, jax.Array]
    by_class_base: np.ndarray       # (NUM_CLASSES,) trace.cycles_by_class(1)
    by_class_gmem: np.ndarray       # (NUM_CLASSES,) gmem-only cycle rows

    @property
    def n_steps(self) -> int:
        """Data instructions executed per block (decode-free scan length)."""
        return int(self.xs["sel"].shape[0])

    @property
    def halted(self) -> bool:
        return self.trace.halted

    def cycles_by_class(self, wave_n: int) -> np.ndarray:
        """== ``trace.cycles_by_class(wave_n)`` (GMEM scaled by the wave
        width), from the precomputed reductions."""
        return self.by_class_base + (wave_n - 1) * self.by_class_gmem


def _decode_words(words: np.ndarray) -> dict[str, np.ndarray]:
    """Decode an array of 40-bit I-words at lowering time, through the
    SAME ``executor._decode`` the stepping machine runs per step — one
    bit-layout definition, so the engines cannot drift (the trace engine
    must see exactly the stepping machine's fields, including the
    signed-immediate view of snoop extension bits)."""
    w = np.asarray(words, np.int64)
    lo = jnp.asarray(w & 0xFFFFFFFF, jnp.uint32)
    hi = jnp.asarray((w >> 32) & 0x3FFF, jnp.uint32)
    return {k: np.asarray(v) for k, v in _decode(lo, hi).items()}


@functools.lru_cache(maxsize=256)
def _compile_cached(words_key: tuple, cfg: SMConfig) -> TraceSchedule:
    from . import compile_cache

    ckey = compile_cache.key_for("lowering", words_key, cfg)
    payload = compile_cache.load(ckey)
    # a payload written before a _FIELDS extension (e.g. the predicate
    # columns) is stale — treat it as a miss and re-lower, or the scan
    # body KeyErrors on the missing column
    if payload is not None and set(_FIELDS) <= set(payload["cols"]):
        trace, cols = payload["trace"], payload["cols"]
    else:
        trace = program_trace(np.asarray(words_key, np.int64),
                              cfg.n_threads, imem_depth=cfg.imem_depth,
                              max_steps=cfg.max_steps)
        # data steps only: rows whose handler has an architectural data
        # effect
        sel_of = DATA_SEL_OF_OP
        pcs = np.asarray([t.pc for t in trace.instrs
                          if sel_of[int(t.op)] != 0], np.int64)
        # the wave packer bins on trace.data_steps; it must equal the rows
        # lowered here or "length" packing minimizes the wrong metric
        assert pcs.size == trace.data_steps, \
            "cycles.ProgramTrace.data_steps disagrees with DATA_SEL_OF_OP"
        # every data pc addresses a real program word (STOP padding is
        # control)
        assert pcs.size == 0 or pcs.max() < len(words_key), \
            "data instruction issued from STOP-padded I-MEM"
        words = np.asarray(words_key, np.int64)[pcs] if pcs.size \
            else np.zeros((0,), np.int64)
        d = _decode_words(words)
        n_waves = cfg.n_waves
        depth_table = np.array(
            [n_waves, max(1, n_waves // 2), max(1, n_waves // 4), 1],
            np.int64)
        width_table = np.array([16, 8, 4, 1], np.int64)
        cols = dict(
            sel=sel_of[d["opcode"]],
            opcode=d["opcode"], typ=d["typ"],
            rd=d["rd"], ra=d["ra"], rb=d["rb"],
            imm=d["imm"], x=d["x"], ext_a=d["ext_a"], ext_b=d["ext_b"],
            pen=d["pen"], preg=d["preg"], pneg=d["pneg"],
            act_waves=depth_table[d["depth"]],
            act_wthreads=width_table[d["width"]],
        )
        cols = {f: np.asarray(cols[f], np.int32) for f in _FIELDS}
        compile_cache.store(ckey, {"trace": trace, "cols": cols})
    xs = {f: jnp.asarray(cols[f]) for f in _FIELDS}
    from .isa import NUM_CLASSES

    by_base = np.asarray(trace.cycles_by_class(1), np.int64)
    by_gmem = np.zeros((NUM_CLASSES,), np.int64)
    for t in trace.instrs:
        if t.gmem:
            by_gmem[t.klass] += t.cycles
    return TraceSchedule(cfg=cfg, trace=trace, xs=xs,
                         by_class_base=by_base, by_class_gmem=by_gmem)


def compile_program(program, cfg: SMConfig) -> TraceSchedule:
    """Lower ``program`` (a Program or encoded word array) for ``cfg``.

    Idempotent and cached: the keyed compile cache holds one schedule per
    ``(program words, SMConfig)``; XLA's jit cache then holds one compiled
    scan per (SMConfig, backend, batch shape).
    """
    words = program.words if hasattr(program, "words") else program
    key = tuple(int(w) for w in words)
    return _compile_cached(key, cfg)


def compile_cache_info():
    return _compile_cached.cache_info()


def compile_cache_clear() -> None:
    _compile_cached.cache_clear()
    _merge_cached.cache_clear()
    _megakernel_cached.cache_clear()
    _megakernel_runner.cache_clear()
    _merged_megakernel_cached.cache_clear()
    _merged_megakernel_runner.cache_clear()


@functools.partial(jax.jit, static_argnums=(0, 1))
def _run_schedule(cfg: SMConfig, backend_name: str, xs, block_idx,
                  prog_idx, regs, shmem, gmem, oob):
    """Execute a pre-decoded schedule: ONE fixed-length scan, no decode,
    no dynamic pc, no halt test — dispatch is a 10-way switch on the
    precompiled handler id into the shared execute stage."""
    backend = get_execute_backend(backend_name)
    tid = jnp.arange(MAX_THREADS, dtype=_I32)
    lane = tid % N_SP
    wave = tid // N_SP

    def step(carry, x):
        active = (lane < x["act_wthreads"]) & (wave < x["act_waves"]) \
            & (tid < cfg.n_threads)
        handlers = make_data_handlers(cfg, backend, x, active, block_idx,
                                      prog_idx)
        return jax.lax.switch(x["sel"], handlers, carry), None

    # unroll=2 halves the scan's per-step loop overhead (measured ~8% on
    # the QRD schedule); deeper unrolls regress compile AND run time
    carry, _ = jax.lax.scan(step, (regs, shmem, gmem, oob), xs, unroll=2)
    return carry


# ---------------------------------------------------------------------------
# heterogeneous waves: merged multi-program schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MergedTraceSchedule:
    """Several programs' schedules merged into one padded scan.

    ``xs[f]`` is the (n_steps, n_programs) i32 matrix for decoded field
    ``f``: column ``k`` is program ``k``'s schedule, padded to the longest
    participant with ``sel=0`` rows (the identity handler — a masked
    no-op, architecturally invisible). One scan over the rows executes a
    whole mixed wave; each step dispatches the participating programs in
    slot order, masked to the SMs running them.
    """

    cfgs: tuple[SMConfig, ...]          # per program slot
    parts: tuple[TraceSchedule, ...]    # the merged per-program schedules
    xs: dict[str, jax.Array]            # (n_steps, n_programs) i32
    # scan segments (start, end, live slots): the scan is split at every
    # program's schedule end, so a finished program drops out of the
    # dispatch loop instead of burning masked no-op dispatches — the
    # padded column rows past a program's end are never executed
    segments: tuple[tuple[int, int, tuple[int, ...]], ...]

    @property
    def n_steps(self) -> int:
        return int(self.xs["sel"].shape[0])

    @property
    def n_programs(self) -> int:
        return len(self.parts)

    @property
    def halted(self) -> bool:
        return all(p.halted for p in self.parts)

    def padded_steps(self, slot_idx) -> int:
        """Scan rows during which a wave member's program is already
        finished (the SM idles while the wave drains its longest
        participant), for a wave running the slots in ``slot_idx`` — the
        merge's padding overhead."""
        return sum(self.n_steps - self.parts[int(s)].n_steps
                   for s in slot_idx)


def merge_profile(per_wave: list, policy: str) -> dict:
    """Aggregate the per-wave merge records into the
    ``LaunchResult.profile()["trace_merge"]`` dict.

    ``per_wave`` entries carry each wave's ``scan_steps`` (merged
    schedule rows), ``width`` (members) and ``padded_steps`` (masked
    no-op rows of members shorter than the wave's longest participant).
    ``policy`` is the RESOLVED wave-packing policy that chose the
    membership (``core.packing``). ``pad_overhead_total`` is the
    launch-level aggregate the packer minimizes: the total padded scan
    steps summed over every merged wave (the per-wave ``padded_steps``
    aggregated); ``pad_overhead`` is that total as a fraction of all
    scheduled scan rows.
    """
    scanned = sum(w["scan_steps"] * w["width"] for w in per_wave)
    padded = sum(w["padded_steps"] for w in per_wave)
    out = {
        "policy": policy,
        "n_waves": len(per_wave),
        "scan_steps": scanned,          # scheduled scan rows x width
        "pad_overhead_total": padded,   # masked no-op rows of those —
                                        # the launch-level aggregate of
                                        # the per-wave padded_steps
        "pad_overhead": (padded / scanned) if scanned else 0.0,
        "per_wave": per_wave,
    }
    # megakernel waves additionally carry per-wave fusion stats —
    # aggregate them launch-wide so profiles expose how much of the
    # schedule ran fused vs through the serialized global port
    fus = [w["fusion"] for w in per_wave if "fusion" in w]
    if fus:
        out["fusion"] = {
            "segments": sum(f["segments"] for f in fus),
            "fused_rows": sum(f["fused_rows"] for f in fus),
            "folded_rows": sum(f["folded_rows"] for f in fus),
            "gmem_rows": sum(f["gmem_rows"] for f in fus),
            "max_fused_run": max(f["max_fused_run"] for f in fus),
        }
    return out


@functools.lru_cache(maxsize=256)
def _merge_cached(keys: tuple, cfgs: tuple) -> MergedTraceSchedule:
    parts = tuple(_compile_cached(k, c) for k, c in zip(keys, cfgs))
    n_steps = max(p.n_steps for p in parts)
    xs = {f: jnp.stack([jnp.pad(p.xs[f], (0, n_steps - p.n_steps))
                        for p in parts], axis=1)
          for f in _FIELDS}
    bounds = sorted({p.n_steps for p in parts} | {0})
    segments = tuple(
        (a, b, tuple(k for k, p in enumerate(parts) if p.n_steps >= b))
        for a, b in zip(bounds[:-1], bounds[1:]))
    return MergedTraceSchedule(cfgs=cfgs, parts=parts, xs=xs,
                               segments=segments)


def compile_merged(programs, cfgs) -> MergedTraceSchedule:
    """Merge the schedules of ``programs`` (Programs or word arrays, one
    per ``SMConfig`` in ``cfgs``) into one padded heterogeneous-wave
    schedule. Cached on the multiset of ``(program words, SMConfig)``
    pairs (in slot order); the per-program lowerings are shared with
    ``compile_program``'s cache."""
    keys = []
    for p in programs:
        words = p.words if hasattr(p, "words") else p
        keys.append(tuple(int(w) for w in words))
    return _merge_cached(tuple(keys), tuple(cfgs))


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3))
def _run_merged(cfgs: tuple, backend_name: str, segments: tuple,
                counts: tuple, xs, block_idx, prog_idx, regs, shmem,
                gmem, oob):
    """Execute one merged heterogeneous wave: one fixed-length scan per
    segment, each step dispatching the LIVE program slots' pre-decoded
    instructions, in slot order — the single global port drains one
    program's writers before the next program's, mirroring the per-cycle
    (sm, thread) drain discipline. Wave members arrive ordered slot-major
    (``counts[k]`` SMs per slot), so each dispatch runs the shared
    execute stage on its program's own contiguous sub-batch — no masked
    work on other programs' SMs. Segment boundaries sit at each program's
    schedule end, so the padded rows of finished programs cost nothing."""
    backend = get_execute_backend(backend_name)
    tid = jnp.arange(MAX_THREADS, dtype=_I32)
    lane = tid % N_SP
    wave = tid // N_SP
    offs = np.concatenate([[0], np.cumsum(counts)])
    carry = (regs, shmem, gmem, oob)

    for a, b, live in segments:
        def step(carry, x, live=live):
            regs, shmem, gmem, oob = carry
            for k in live:
                cfg = cfgs[k]
                lo, hi = int(offs[k]), int(offs[k + 1])
                d = {f: x[f][k] for f in _FIELDS}
                active = (lane < d["act_wthreads"]) \
                    & (wave < d["act_waves"]) & (tid < cfg.n_threads)
                handlers = make_data_handlers(
                    cfg, backend, d, active, block_idx[lo:hi],
                    prog_idx[lo:hi], shmem_depth=cfg.shmem_depth)
                sub = (regs[lo:hi], shmem[lo:hi], gmem, oob[lo:hi])
                r_k, s_k, gmem, o_k = jax.lax.switch(d["sel"], handlers,
                                                     sub)
                regs = jax.lax.dynamic_update_slice_in_dim(regs, r_k,
                                                           lo, 0)
                shmem = jax.lax.dynamic_update_slice_in_dim(shmem, s_k,
                                                            lo, 0)
                oob = jax.lax.dynamic_update_slice_in_dim(oob, o_k, lo, 0)
            return (regs, shmem, gmem, oob), None

        carry, _ = jax.lax.scan(step, carry,
                                {f: xs[f][a:b] for f in _FIELDS},
                                unroll=2)
    return carry


def run_wave_merged(backend: str, msched: MergedTraceSchedule,
                    counts: tuple, block_idx, prog_idx, regs, shmem,
                    gmem, oob):
    """Run one heterogeneous wave. Wave members MUST be ordered
    slot-major — ``counts[k]`` consecutive SMs run program slot ``k`` of
    the merged schedule (the device layer's merged dispatch orders them;
    cross-program global-store drains follow that device order).
    ``block_idx``/``prog_idx`` carry each SM's program-local ``BID`` and
    launch-wide ``PID``. ``shmem`` is the device-depth batch — programs
    with a shallower ``Kernel(shmem_depth=)`` override are bounds-checked
    at their own depth inside the execute stage. Returns
    (regs, shmem, gmem, oob)."""
    return _run_merged(msched.cfgs, backend, msched.segments,
                       tuple(int(c) for c in counts), msched.xs,
                       jnp.asarray(block_idx, _I32),
                       jnp.asarray(prog_idx, _I32), regs, shmem, gmem,
                       oob)


# ---------------------------------------------------------------------------
# segment megakernels: fused runs between global-port accesses
# ---------------------------------------------------------------------------
#
# The scanned trace engine still pays per-row dispatch: a 10-way
# ``lax.switch`` on the handler id plus traced decoded fields and a
# recomputed active mask, every scan step. But every field of every row
# is a HOST constant — so the megakernel engine unrolls each *segment*
# (the maximal run of SM-local rows between global-port accesses; GLD/GST
# rows serialize on the one device-wide port and so delimit segments)
# body-to-body with constant fields and constant masks, and hands the
# whole run to the ``ExecBackend.segment`` seam as ONE fused kernel. The
# switch, the mask arithmetic and the operand selects fold away at trace
# time; the Pallas implementation additionally keeps the SM batch's
# registers/shmem resident in VMEM across the fused steps
# (``kernels.simt_step.simt_segment``). Gmem rows between segments still
# dispatch through the same per-row handlers as the scan.
#
# Functionally the megakernel engine IS the trace engine — same rows,
# same handler graph (``executor.make_data_handlers``), same counters
# from the static trace — so it is bit-identical to both other engines
# by construction. Only compile strategy changes.

def _active_mask(cfg: SMConfig, act_waves: int, act_wthreads: int
                 ) -> np.ndarray:
    """The (512,) flexible-ISA thread mask of one row, as a host
    constant — exactly the scan body's per-step mask computation."""
    tid = np.arange(MAX_THREADS)
    lane = tid % N_SP
    wave = tid // N_SP
    return ((lane < act_wthreads) & (wave < act_waves)
            & (tid < cfg.n_threads))


def _fused_rows(sched: TraceSchedule) -> tuple:
    """Lower a schedule's rows to host-constant ``executor.FusedRow``s."""
    from .executor import FusedRow

    cols = {f: np.asarray(sched.xs[f]) for f in _FIELDS}
    rows = []
    for i in range(sched.n_steps):
        d = {f: np.int32(cols[f][i]) for f in
             ("opcode", "typ", "rd", "ra", "rb", "imm", "x", "ext_a",
              "ext_b", "pen", "preg", "pneg")}
        waves = int(cols["act_waves"][i])
        wthreads = int(cols["act_wthreads"][i])
        rows.append(FusedRow(
            sel=int(cols["sel"][i]), d=d,
            active=_active_mask(sched.cfg, waves, wthreads),
            act_waves=waves, act_wthreads=wthreads))
    return tuple(rows)


_GMEM_SELS = (8, 9)        # GLD/GST data-switch branches (the global port)


def _segment_items(rows, slot: int | None = None) -> tuple:
    """Split a row sequence at global-port rows: ``("fused", slot, rows)``
    runs as one fused kernel, ``("gmem", slot, row)`` dispatches the
    serialized port row by itself."""
    items, run = [], []
    for r in rows:
        if r.sel in _GMEM_SELS:
            if run:
                items.append(("fused", slot, tuple(run)))
                run = []
            items.append(("gmem", slot, r))
        else:
            run.append(r)
    if run:
        items.append(("fused", slot, tuple(run)))
    return tuple(items)


def _partial_eval_items(items, cfg_of, depth_of) -> tuple:
    """Run the plan-time partial evaluator over a segment item list.

    Threads per-slot register-column constant state (starting from the
    zero-init wave contract: ``device.init_device_state`` always zeroes
    registers) through the plan in execution order, wrapping every fused
    payload in an ``executor.FusedSegment``. A GLD row makes its
    destination runtime; GST reads only. ``cfg_of``/``depth_of`` map the
    slot tag of each item to its SMConfig / shared-memory depth."""
    from .executor import eval_segment_rows
    from .machine import N_REGS

    state: dict = {}
    out = []
    for kind, slot, payload in items:
        cols = state.setdefault(
            slot, [np.zeros(MAX_THREADS, np.uint32)] * N_REGS)
        if kind == "fused":
            seg, cols = eval_segment_rows(cfg_of(slot), payload, cols,
                                          depth_of(slot))
            state[slot] = cols
            out.append((kind, slot, seg))
        else:
            if payload.sel == 8:                    # GLD: rd now runtime
                cols = list(cols)
                cols[int(payload.d["rd"])] = None
                state[slot] = cols
            out.append((kind, slot, payload))
    return tuple(out)


def _fusion_stats(items) -> dict:
    segs = [it[2] for it in items if it[0] == "fused"]
    return {
        "segments": len(segs),
        "fused_rows": sum(len(s.rows) for s in segs),
        "folded_rows": sum(s.n_folded for s in segs),
        "gmem_rows": sum(1 for it in items if it[0] == "gmem"),
        "max_fused_run": max((len(s.rows) for s in segs), default=0),
    }


@dataclasses.dataclass(frozen=True)
class MegakernelPlan:
    """One program lowered to fused segments (megakernel engine unit).

    ``items`` is the ordered execution plan; ``sched`` keeps the
    underlying trace schedule for the timing model (cycle counters are
    engine-independent — the megakernel is a functional-path
    optimization only).
    """

    key: tuple                 # program words (the compile-cache key)
    cfg: SMConfig
    sched: TraceSchedule
    items: tuple

    @property
    def halted(self) -> bool:
        return self.sched.halted

    def stats(self) -> dict:
        return _fusion_stats(self.items)


@functools.lru_cache(maxsize=256)
def _megakernel_cached(words_key: tuple, cfg: SMConfig) -> MegakernelPlan:
    sched = _compile_cached(words_key, cfg)
    items = _partial_eval_items(
        _segment_items(_fused_rows(sched)),
        lambda _s: cfg, lambda _s: cfg.shmem_depth)
    return MegakernelPlan(key=words_key, cfg=cfg, sched=sched, items=items)


def compile_megakernel(program, cfg: SMConfig) -> MegakernelPlan:
    """Lower ``program`` to a fused-segment megakernel plan for ``cfg``.

    Cached like ``compile_program`` (and sharing its schedule cache); the
    jitted runner is cached separately per (program, config, backend)."""
    words = program.words if hasattr(program, "words") else program
    return _megakernel_cached(tuple(int(w) for w in words), cfg)


@functools.lru_cache(maxsize=256)
def _megakernel_runner(words_key: tuple, cfg: SMConfig, backend_name: str):
    """The jitted homogeneous-wave megakernel for one (program, config,
    backend). The plan is closed over, not passed: its rows hold
    unhashable host constants, and closing over it keys XLA's jit cache
    on exactly (plan identity, batch shapes)."""
    plan = _megakernel_cached(words_key, cfg)
    backend = get_execute_backend(backend_name)

    @jax.jit
    def run(block_idx, prog_idx, regs, shmem, gmem, oob):
        for kind, _, payload in plan.items:
            if kind == "fused":
                regs, shmem, oob = exec_segment(
                    backend, cfg, payload, block_idx, prog_idx, regs,
                    shmem, oob)
            else:
                handlers = make_data_handlers(cfg, backend, payload.d,
                                              jnp.asarray(payload.active),
                                              block_idx, prog_idx)
                regs, shmem, gmem, oob = handlers[payload.sel](
                    (regs, shmem, gmem, oob))
        return regs, shmem, gmem, oob

    return run


def run_wave_megakernel(backend: str, plan: MegakernelPlan, block_idx,
                        prog_idx, state):
    """Megakernel replacement for ``run_wave_trace``: same DeviceState
    in/out contract, same static-trace counters — only the functional
    path changes (fused segments instead of a scanned schedule)."""
    n = state.regs.shape[0]
    fn = _megakernel_runner(plan.key, plan.cfg, backend)
    regs, shmem, gmem, oob = fn(
        jnp.asarray(block_idx, _I32), jnp.asarray(prog_idx, _I32),
        state.regs, state.shmem, state.gmem, state.oob)
    tr = plan.sched.trace
    return state.replace(
        regs=regs, shmem=shmem, gmem=gmem, oob=oob,
        halted=state.halted | jnp.asarray(tr.halted),
        steps=state.steps + jnp.int32(tr.steps),
        cycles=state.cycles + jnp.int32(tr.static_cycles(n)),
        cycles_by_class=state.cycles_by_class
        + jnp.asarray(plan.sched.cycles_by_class(n), _I32),
    )


@dataclasses.dataclass(frozen=True)
class MergedMegakernelPlan:
    """A heterogeneous wave's fused-segment plan.

    Unlike ``MergedTraceSchedule`` there is NO padding: each slot's rows
    fuse independently, and only the global-port rows impose a global
    order — they drain in (scan step, program slot) lexicographic order,
    exactly the merged scan's dispatch order, so cross-program
    global-store drains stay bit-identical to the scan and the step
    machine.
    """

    keys: tuple                # per-slot program words
    cfgs: tuple[SMConfig, ...]
    parts: tuple[TraceSchedule, ...]
    items: tuple               # ("fused"|"gmem", slot, payload)

    @property
    def halted(self) -> bool:
        return all(p.halted for p in self.parts)

    @property
    def n_steps(self) -> int:
        """Longest participant's schedule (the merged scan's row count —
        kept for profile continuity; the megakernel executes no padded
        rows)."""
        return max((p.n_steps for p in self.parts), default=0)

    def stats(self) -> dict:
        return _fusion_stats(self.items)


@functools.lru_cache(maxsize=256)
def _merged_megakernel_cached(keys: tuple, cfgs: tuple
                              ) -> MergedMegakernelPlan:
    parts = tuple(_compile_cached(k, c) for k, c in zip(keys, cfgs))
    slot_rows = [_fused_rows(p) for p in parts]
    # global-port rows must drain in the merged scan's dispatch order:
    # (schedule step, slot order) — between them, different slots' rows
    # touch disjoint per-SM state and commute, so each slot's runs fuse
    # independently and flush only when one of its gmem rows comes due
    events = sorted((i, k) for k, rows in enumerate(slot_rows)
                    for i, r in enumerate(rows) if r.sel in _GMEM_SELS)
    cursor = [0] * len(parts)
    items = []
    for i, k in events:
        if cursor[k] < i:
            items.append(("fused", k, tuple(slot_rows[k][cursor[k]:i])))
        items.append(("gmem", k, slot_rows[k][i]))
        cursor[k] = i + 1
    for k, rows in enumerate(slot_rows):
        if cursor[k] < len(rows):
            items.append(("fused", k, tuple(rows[cursor[k]:])))
    items = _partial_eval_items(
        tuple(items), lambda s: cfgs[s], lambda s: cfgs[s].shmem_depth)
    return MergedMegakernelPlan(keys=keys, cfgs=cfgs, parts=parts,
                                items=items)


def compile_merged_megakernel(programs, cfgs) -> MergedMegakernelPlan:
    """Megakernel counterpart of ``compile_merged``: fuse each slot's
    segments, ordering only the global-port rows across slots."""
    keys = []
    for p in programs:
        words = p.words if hasattr(p, "words") else p
        keys.append(tuple(int(w) for w in words))
    return _merged_megakernel_cached(tuple(keys), tuple(cfgs))


@functools.lru_cache(maxsize=256)
def _merged_megakernel_runner(keys: tuple, cfgs: tuple,
                              backend_name: str):
    mplan = _merged_megakernel_cached(keys, cfgs)
    backend = get_execute_backend(backend_name)

    @functools.partial(jax.jit, static_argnums=(0,))
    def run(counts, block_idx, prog_idx, regs, shmem, gmem, oob):
        offs = np.concatenate([[0], np.cumsum(counts)])
        for kind, k, payload in mplan.items:
            cfg = cfgs[k]
            lo, hi = int(offs[k]), int(offs[k + 1])
            if kind == "fused":
                r_k, s_k, o_k = exec_segment(
                    backend, cfg, payload, block_idx[lo:hi],
                    prog_idx[lo:hi], regs[lo:hi], shmem[lo:hi],
                    oob[lo:hi], shmem_depth=cfg.shmem_depth)
            else:
                handlers = make_data_handlers(
                    cfg, backend, payload.d, jnp.asarray(payload.active),
                    block_idx[lo:hi], prog_idx[lo:hi],
                    shmem_depth=cfg.shmem_depth)
                sub = (regs[lo:hi], shmem[lo:hi], gmem, oob[lo:hi])
                r_k, s_k, gmem, o_k = handlers[payload.sel](sub)
            regs = regs.at[lo:hi].set(r_k)
            shmem = shmem.at[lo:hi].set(s_k)
            oob = oob.at[lo:hi].set(o_k)
        return regs, shmem, gmem, oob

    return run


def run_wave_merged_megakernel(backend: str, mplan: MergedMegakernelPlan,
                               counts: tuple, block_idx, prog_idx, regs,
                               shmem, gmem, oob):
    """Run one heterogeneous wave on the megakernel engine. Same
    slot-major member-ordering contract as ``run_wave_merged``; returns
    (regs, shmem, gmem, oob)."""
    fn = _merged_megakernel_runner(mplan.keys, mplan.cfgs, backend)
    return fn(tuple(int(c) for c in counts),
              jnp.asarray(block_idx, _I32), jnp.asarray(prog_idx, _I32),
              regs, shmem, gmem, oob)


def run_wave_trace(cfg: SMConfig, backend: str, sched: TraceSchedule,
                   block_idx, prog_idx, state):
    """Trace-engine replacement for ``device.run_wave``: same DeviceState
    in/out contract, counters synthesized from the static trace (identical
    to the stepping machine's — the lockstep wave rule charges each member
    for the whole wave's port drain, ``trace.static_cycles``)."""
    n = state.regs.shape[0]
    regs, shmem, gmem, oob = _run_schedule(
        cfg, backend, sched.xs, jnp.asarray(block_idx, _I32),
        jnp.asarray(prog_idx, _I32), state.regs, state.shmem, state.gmem,
        state.oob)
    tr = sched.trace
    return state.replace(
        regs=regs, shmem=shmem, gmem=gmem, oob=oob,
        halted=state.halted | jnp.asarray(tr.halted),
        steps=state.steps + jnp.int32(tr.steps),
        cycles=state.cycles + jnp.int32(tr.static_cycles(n)),
        cycles_by_class=state.cycles_by_class
        + jnp.asarray(sched.cycles_by_class(n), _I32),
    )
