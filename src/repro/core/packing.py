"""Schedule-aware wave packing: which blocks share a wave, and why.

The eGPU paper packs multiple SMs into one Agilex logic region and earns
its throughput by keeping every SP lane busy; the scalable follow-up
(arXiv 2401.04261) shows dispatch-order decisions dominate multi-SM
occupancy. Our merged-wave trace engine (``core.trace_engine``) executes
a heterogeneous wave as ONE scan padded to the wave's longest
participant, so wave *membership* is a first-class performance decision:
a long program padded next to a short one wastes a masked no-op scan row
per step of the difference, per member. Grid-order packing (the PR-4
rule) routinely shows >30% pad overhead on adversarial mixed grids.

``pack_waves`` decides that membership once, and every layer consumes
the same decision:

  * the **functional** merged-trace path groups blocks into exactly
    these waves (``device.launch``);
  * the **static timing** model chunks its lockstep waves identically
    (``scheduler.schedule_blocks(packing=)``), so golden cycle totals
    stay an exact statement about the waves that actually ran;
  * the **dynamic** queue pops blocks in the packed order (FIFO ties),
    which is what keeps the fuzzed ``dynamic <= static`` bound holding
    against the *packed* wave baseline — list dispatch in order X never
    loses to serial waves chunked from the same order X, but it can lose
    to waves chunked from a different one.

Policies (``DeviceConfig.packing`` / ``launch(packing=)``):

``"grid"``
    Waves are consecutive chunks of ``n_sms`` blocks in grid order
    within each barrier phase — byte-identical to the PR-4 behaviour,
    and the default: packing is opt-in, never a silent timing change.

``"length"``
    Within each phase, blocks are stably sorted by descending schedule
    length (ties keep grid order) and split into the same *number* of
    waves as grid packing, with wave boundaries chosen by a small DP
    that minimizes total padded scan steps (each wave may be narrower
    than ``n_sms`` — isolating one long straggler beats padding three
    short blocks to it). Sorting first is lossless: an exchange
    argument shows some contiguous-in-sorted-order split is optimal
    over ALL partitions into that many waves of width <= ``n_sms``, so
    length packing NEVER pads more than grid packing
    (``tests/test_packing.py`` property-tests this).

``"auto"``
    ``"length"`` when a phase mixes schedule lengths (a heterogeneous
    grid), ``"grid"`` otherwise — single-program grids resolve to grid,
    where the two policies coincide anyway.

Packing never changes observable state: functional results stay
canonical (the step machine's program-major order; merged waves under
the no-concurrent-gmem-races launch contract), it only changes which
blocks share a wave — and therefore the modeled timing and the merge
padding. A wave never crosses a ``Kernel(barrier=True)`` phase fence.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

PACKINGS = ("grid", "length", "auto")


@dataclasses.dataclass(frozen=True)
class WavePacking:
    """One launch's wave membership decision.

    ``waves[w]`` is the tuple of block indices sharing wave ``w``, in
    dispatch order (phase-major; within a phase, the policy's order).
    ``wave_phase[w]`` is the barrier phase every member of wave ``w``
    belongs to. ``lengths[b]`` is the per-block schedule length the
    policy packed on (the trace engine's data-step count).
    """

    policy: str                          # resolved: "grid" | "length"
    n_sms: int
    waves: tuple[tuple[int, ...], ...]
    wave_phase: tuple[int, ...]
    lengths: tuple[int, ...]

    @property
    def n_waves(self) -> int:
        return len(self.waves)

    @property
    def n_blocks(self) -> int:
        return len(self.lengths)

    @property
    def order(self) -> np.ndarray:
        """(n_blocks,) block dispatch order: the waves concatenated.

        This is the order the dynamic queue pops (FIFO ties) and the
        order whose consecutive chunks are the static waves — one order,
        consumed by every layer.
        """
        return np.asarray([b for wave in self.waves for b in wave],
                          np.int64)

    @property
    def wave_sizes(self) -> tuple[int, ...]:
        return tuple(len(w) for w in self.waves)

    @property
    def occupancy(self) -> float:
        """Mean wave fill fraction: members per wave over ``n_sms``,
        averaged across waves. 1.0 means every wave used every SM slot —
        the batch-occupancy figure the serving front door reports per
        dispatched batch (``serve.LaunchServer``)."""
        if not self.waves:
            return 0.0
        return sum(len(w) for w in self.waves) / (self.n_sms
                                                  * len(self.waves))

    def pad_steps(self) -> int:
        """Total padded scan steps: rows a member idles while its wave
        drains the longest participant, summed over waves — the metric
        the "length" policy minimizes."""
        return sum(sum(max(self.lengths[b] for b in wave)
                       - self.lengths[b] for b in wave)
                   for wave in self.waves)


def _grid_waves(idx: np.ndarray, n_sms: int) -> list[tuple[int, ...]]:
    return [tuple(int(b) for b in idx[w0:w0 + n_sms])
            for w0 in range(0, idx.size, n_sms)]


def _length_waves(idx: np.ndarray, lengths: np.ndarray,
                  n_sms: int) -> list[tuple[int, ...]]:
    """Pad-minimal waves for one phase: stable-desc sort, then a DP over
    contiguous wave boundaries.

    With blocks sorted by descending length, a wave's pad cost is
    ``first_member_length * size - sum(member lengths)``; the member-sum
    term is partition-invariant, so the DP minimizes
    ``sum(first * size)`` over exactly ``ceil(n / n_sms)`` contiguous
    groups of size 1..n_sms. Ties prefer wider waves, so all-equal
    lengths reproduce grid chunking exactly (single-program grids are
    packing-invariant by construction).
    """
    order = sorted((int(b) for b in idx),
                   key=lambda b: (-int(lengths[b]), b))
    n = len(order)
    m = n_sms
    n_waves = -(-n // m)
    inf = float("inf")
    # f[i][k]: min cost covering order[i:] with k waves; pick[i][k]: the
    # winning wave size at (i, k)
    f = [[inf] * (n_waves + 1) for _ in range(n + 1)]
    pick = [[0] * (n_waves + 1) for _ in range(n + 1)]
    f[n][0] = 0.0
    for i in range(n - 1, -1, -1):
        for k in range(1, n_waves + 1):
            rem = n - i
            if rem > k * m or rem < k:
                continue
            # widest-first: on equal pad cost keep the grid-shaped split
            for s in range(min(m, rem), 0, -1):
                c = int(lengths[order[i]]) * s + f[i + s][k - 1]
                if c < f[i][k]:
                    f[i][k] = c
                    pick[i][k] = s
    waves: list[tuple[int, ...]] = []
    i, k = 0, n_waves
    while i < n:
        s = pick[i][k]
        waves.append(tuple(order[i:i + s]))
        i, k = i + s, k - 1
    return waves


def pack_waves(lengths: Sequence[int], n_sms: int,
               policy: str = "grid",
               phase_of: Sequence[int] | None = None) -> WavePacking:
    """Group blocks into waves of at most ``n_sms``, per barrier phase.

    ``lengths[b]`` is block ``b``'s schedule length (for the merged
    trace engine: data-instruction scan steps — what the padding is
    measured in). ``phase_of[b]`` is its barrier phase; a wave never
    crosses a phase. Returns a :class:`WavePacking`; the waves cover
    every block exactly once, phases appear in ascending order, and both
    policies produce ``ceil(n_phase / n_sms)`` waves per phase.
    """
    if policy not in PACKINGS:
        raise ValueError(f"packing={policy!r} must be one of {PACKINGS}")
    if n_sms < 1:
        raise ValueError(f"n_sms={n_sms} must be >= 1")
    lens = np.asarray(list(lengths), np.int64)
    if lens.ndim != 1 or lens.shape[0] < 1:
        raise ValueError("lengths must be a non-empty 1-D sequence")
    if (lens < 0).any():
        raise ValueError("schedule lengths must be non-negative")
    n_blocks = int(lens.shape[0])
    if phase_of is None:
        phase = np.zeros(n_blocks, np.int64)
    else:
        phase = np.asarray(list(phase_of), np.int64)
        if phase.shape != (n_blocks,):
            raise ValueError(f"phase_of has shape {phase.shape}, want "
                             f"({n_blocks},)")
    parts = [(int(p), np.flatnonzero(phase == p))
             for p in np.unique(phase)]
    if policy == "auto":
        policy = "length" if any(np.unique(lens[idx]).size > 1
                                 for _, idx in parts) else "grid"
    waves: list[tuple[int, ...]] = []
    wave_phase: list[int] = []
    for p, idx in parts:
        ws = _grid_waves(idx, n_sms) if policy == "grid" \
            else _length_waves(idx, lens, n_sms)
        waves.extend(ws)
        wave_phase.extend([p] * len(ws))
    return WavePacking(policy=policy, n_sms=n_sms,
                       waves=tuple(waves), wave_phase=tuple(wave_phase),
                       lengths=tuple(int(x) for x in lens))
