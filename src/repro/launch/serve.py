"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Spins up the continuous-batching engine, feeds it a synthetic request
trace with staggered arrivals/lengths, and reports throughput + the
active-mask history (the flexible-wavefront telemetry)."""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, max_slots=args.slots,
                 capacity=args.capacity)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(4, 17))),
            max_new_tokens=int(rng.integers(4, args.max_new + 1))))
        eng.step()
    outs = eng.run_until_done()
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in outs.values())
    print(json.dumps({
        "arch": cfg.name, "requests": len(outs), "tokens": toks,
        "wall_s": round(dt, 2), "tok_per_s": round(toks / dt, 1),
        "decode_steps": eng.steps_run,
        "active_width_histogram": {
            str(w): eng.active_history.count(w)
            for w in sorted(set(eng.active_history))},
    }))


if __name__ == "__main__":
    main()
