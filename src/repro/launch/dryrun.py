import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, RunConfig, get_arch, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch import shardings as sh
from repro.models import build_model, cache_specs, input_specs
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_row

"""Multi-pod dry-run driver.

For every (architecture x input-shape x mesh) cell:
  * build the step function (train_step for train shapes, forward for
    prefill, serve_step = one-token decode for decode shapes),
  * jit with explicit in/out shardings from launch/shardings.py,
  * .lower(**ShapeDtypeStruct inputs)  -> .compile()  [no allocation],
  * record memory_analysis(), cost_analysis(), and the collective bytes
    parsed from the optimized HLO.

Results stream to a JSONL file (resumable: done cells are skipped), which
benchmarks/ and EXPERIMENTS.md consume.
"""

COMPUTE_DTYPE = jnp.bfloat16


def layer_variants(cfg):
    """Two reduced-depth clones (a, b) + the unit count n such that
    cost(full) = cost(a) + (n - units(a)) * (cost(b) - cost(a)) / (units(b)
    - units(a)). Needed because XLA's cost_analysis counts a while-loop
    (scan over layers) body ONCE — depth must be re-multiplied by
    differencing two compiled depths (EXPERIMENTS.md §Dry-run notes)."""
    import dataclasses as dc

    # depths (2, 4) rather than (1, 2): GSPMD may pick a different (worse)
    # partition for a 1-layer module than for deeper ones, which breaks the
    # linear extrapolation — observed on the optimized-policy train cells
    if cfg.family == "hybrid":
        g = len(cfg.block_pattern)
        n_groups, rem = divmod(cfg.n_layers, g)
        a = dc.replace(cfg, n_layers=2 * g + rem, scan_unroll=True)
        b = dc.replace(cfg, n_layers=4 * g + rem, scan_unroll=True)
        return a, 2, b, 4, n_groups
    if cfg.family == "audio":
        a = dc.replace(cfg, n_layers=2, encoder_layers=2, scan_unroll=True)
        b = dc.replace(cfg, n_layers=4, encoder_layers=4, scan_unroll=True)
        return a, 2, b, 4, cfg.n_layers          # enc/dec scale together
    extra = int(cfg.first_layer_dense)
    a = dc.replace(cfg, n_layers=2 + extra, scan_unroll=True)
    b = dc.replace(cfg, n_layers=4 + extra, scan_unroll=True)
    return a, 2, b, 4, cfg.n_layers - extra


OPTIMIZED_QPAD = {"qwen2.5-32b": 48}   # zero-padded q heads (numerics-exact)


def apply_policy(cfg, shape, policy: str):
    """'baseline' = paper-faithful naive rules; 'optimized' = the §Perf
    winners applied globally (head-aware TP, blocked attention, serving
    prefill last-token logits, SSM in_proj FSDP-only)."""
    import dataclasses as dc

    if policy != "optimized":
        return cfg, dict(naive_tp=True, last_only=False)
    # per-cell autotuning: cells where the global recipe measured WORSE
    # than baseline revert to baseline (EXPERIMENTS.md §Perf, iterations
    # 7-9). Train cells regress under blocked-attention + row-parallel
    # backward (0.40-0.97x with consistent measurement), so the optimized
    # recipe applies to INFERENCE kinds only.
    BASELINE_CELLS = {
        ("whisper-tiny", "prefill_32k"), ("whisper-tiny", "decode_32k"),
        ("recurrentgemma-2b", "long_500k"),
        ("mamba2-780m", "long_500k"),
    }
    if shape.kind == "train" or (cfg.name, shape.name) in BASELINE_CELLS:
        return cfg, dict(naive_tp=True, last_only=False)
    patch = {}
    if cfg.family != "ssm" and shape.seq_len >= 4096             and shape.kind in ("train", "prefill"):
        patch["attn_q_chunk"] = 2048
    if cfg.name in OPTIMIZED_QPAD:
        patch["n_heads"] = OPTIMIZED_QPAD[cfg.name]
    if patch:
        cfg = dc.replace(cfg, **patch)
    opts = dict(naive_tp=False, last_only=(shape.kind == "prefill"))
    if cfg.family == "ssm":
        opts["overrides"] = {"in_proj": "fsdp_in"}
    if cfg.name == "qwen1.5-32b" and shape.kind == "decode":
        # MHA (kv=40) 32k cache is 5.5 TB global: fp8 storage halves it
        # under 16 GiB/chip (scores/softmax stay f32 — reads upcast)
        opts["cache_dtype"] = jnp.float8_e4m3fn
    return cfg, opts


def build_cell(arch_name: str, shape_name: str, multi_pod: bool,
               *, cfg=None, mesh=None, policy: str = "baseline"):
    shape = SHAPES[shape_name]
    base = cfg or get_arch(arch_name)
    base, opts = apply_policy(base, shape, policy)
    cfg = base
    naive_tp = opts["naive_tp"]
    last_only = opts["last_only"]
    if opts.get("overrides"):
        sh.PARAM_OVERRIDES.update(opts["overrides"])
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    rc = RunConfig()

    if shape.kind == "train":
        from repro.train.step import TrainState, make_train_step
        from repro.optim.adamw import AdamWState

        step = make_train_step(model, rc)
        pspecs = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), COMPUTE_DTYPE))
        f32like = lambda t: jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
        state_like = TrainState(
            params=pspecs,
            opt=AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                           mu=f32like(pspecs), nu=f32like(pspecs)),
            step=jax.ShapeDtypeStruct((), jnp.int32), ef=None)
        batch_like = input_specs(cfg, shape, COMPUTE_DTYPE)
        state_sh = sh.state_shardings(mesh, state_like, cfg, naive_tp)
        batch_sh = sh.batch_shardings(mesh, batch_like)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None))
        args = (state_like, batch_like)
    elif shape.kind == "prefill":
        pspecs = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), COMPUTE_DTYPE))
        batch_like = input_specs(cfg, shape, COMPUTE_DTYPE)
        p_sh = sh.param_shardings(mesh, pspecs, cfg, naive_tp)
        b_sh = sh.batch_shardings(mesh, batch_like)
        fwd = lambda params, batch: model.forward(params, batch,
                                                  last_only=last_only)
        jitted = jax.jit(fwd, in_shardings=(p_sh, b_sh), out_shardings=None)
        args = (pspecs, batch_like)
    else:  # decode
        pspecs = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), COMPUTE_DTYPE))
        cspecs = cache_specs(cfg, shape,
                             opts.get("cache_dtype", COMPUTE_DTYPE))
        batch_like = input_specs(cfg, shape, COMPUTE_DTYPE)
        p_sh = sh.param_shardings(mesh, pspecs, cfg, naive_tp)
        c_sh = sh.cache_shardings(mesh, cspecs, shape.global_batch)
        b_sh = sh.batch_shardings(mesh, batch_like)

        def serve_step(params, caches, batch):
            return model.decode_step(params, caches, batch["tokens"])

        jitted = jax.jit(serve_step, in_shardings=(p_sh, c_sh, b_sh),
                         out_shardings=(None, c_sh))
        args = (pspecs, cspecs, batch_like)
    return cfg, shape, mesh, jitted, args


def _peak_bytes(mem) -> int:
    """Per-device peak memory from ``compiled.memory_analysis()``.

    Some jaxlibs expose ``peak_memory_in_bytes`` directly; others —
    including the 0.4.37 pinned here — only carry the component sizes on
    ``CompiledMemoryStats``, so fall back to the resident-set bound
    arguments + outputs + temporaries - aliased (aliased output bytes
    reuse argument storage).
    """
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak is None:
        peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return int(peak)


def _compile_costs(arch_name, shape_name, multi_pod, cfg=None, mesh=None,
                   hlo_dir=None, tag=None, policy="baseline"):
    t0 = time.perf_counter()
    cfg_, shape, mesh, jitted, args = build_cell(arch_name, shape_name,
                                                 multi_pod, cfg=cfg,
                                                 mesh=mesh, policy=policy)
    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # some jax versions (e.g. 0.4.37, the one pinned here) return a
    # one-element list of dicts per executable; others return the dict
    # directly — normalize both shapes
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    if hlo_dir and tag:
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(hlo_dir, tag + ".hlo"), "w") as f:
            f.write(hlo)
    return {
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": collective_bytes_from_hlo(hlo),
        "argument_bytes_per_device": int(mem.argument_size_in_bytes),
        "output_bytes_per_device": int(mem.output_size_in_bytes),
        "temp_bytes_total": int(mem.temp_size_in_bytes),
        "peak_bytes_per_device": int(_peak_bytes(mem)),
        "mesh_obj": mesh,
    }


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             hlo_dir: str | None = None, roofline: bool = True,
             policy: str = "baseline") -> dict:
    shape = SHAPES[shape_name]
    cfg, _ = apply_policy(get_arch(arch_name), shape, policy)
    tag = f"{arch_name}_{shape_name}_{'mp' if multi_pod else 'sp'}"
    full = _compile_costs(arch_name, shape_name, multi_pod,
                          hlo_dir=hlo_dir, tag=tag, policy=policy)
    mesh = full.pop("mesh_obj")
    n_chips = int(np.prod(list(mesh.shape.values())))
    row = {"arch": arch_name, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": shape.kind, "n_chips": n_chips, "status": "ok"}
    row.update(full)

    if roofline:
        # XLA cost_analysis counts scan (while-loop) bodies once; recover
        # true depth-scaled costs by differencing two compiled depths.
        cfg_a, ua, cfg_b, ub, n_units = layer_variants(cfg)
        ca = _compile_costs(arch_name, shape_name, multi_pod, cfg=cfg_a,
                            mesh=mesh, policy=policy)
        cb = _compile_costs(arch_name, shape_name, multi_pod, cfg=cfg_b,
                            mesh=mesh, policy=policy)
        for k in ("flops", "bytes_accessed", "collective_bytes"):
            per_unit = (cb[k] - ca[k]) / (ub - ua)
            fixed = ca[k] - ua * per_unit
            row[k + "_scaled"] = max(fixed + n_units * per_unit, row[k])
        scaled = {**row,
                  "flops": row["flops_scaled"],
                  "bytes_accessed": row["bytes_accessed_scaled"],
                  "collective_bytes": row["collective_bytes_scaled"]}
        row.update(roofline_row(cfg, shape, scaled))
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["sp", "mp", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun.jsonl")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--policy", default="baseline",
                    choices=["baseline", "optimized"])
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"sp": [False], "mp": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    with open(args.out, "a") as out:
        for arch in archs:
            for shape_name in shapes:
                cfg = get_arch(arch)
                ok, why = shape_applicable(cfg, SHAPES[shape_name])
                for mp in meshes:
                    mesh_name = "2x16x16" if mp else "16x16"
                    if (arch, shape_name, mesh_name) in done:
                        continue
                    if not ok:
                        row = {"arch": arch, "shape": shape_name,
                               "mesh": mesh_name, "status": "skipped",
                               "reason": why}
                        print(f"[skip] {arch} {shape_name} {mesh_name}: {why}",
                              flush=True)
                    else:
                        print(f"[cell] {arch} {shape_name} {mesh_name} ...",
                              flush=True)
                        try:
                            # roofline terms: single-pod only (per brief);
                            # the multi-pod compile proves pod-axis sharding
                            row = run_cell(arch, shape_name, mp,
                                           hlo_dir=args.hlo_dir,
                                           roofline=not mp,
                                           policy=args.policy)
                            row["policy"] = args.policy
                            print(f"   ok: compile={row['compile_s']}s "
                                  f"flops={row['flops']:.3g} "
                                  f"coll={row['collective_bytes']:.3g}B "
                                  f"peak={row['peak_bytes_per_device']/2**30:.2f}GiB",
                                  flush=True)
                        except Exception as e:
                            traceback.print_exc()
                            row = {"arch": arch, "shape": shape_name,
                                   "mesh": mesh_name, "status": "error",
                                   "error": f"{type(e).__name__}: {e}"[:500]}
                    out.write(json.dumps(row) + "\n")
                    out.flush()


if __name__ == "__main__":
    main()
