"""Sharding rules: param/batch/cache PartitionSpecs for any mesh.

Discipline (DESIGN.md §6):
  * batch dims -> ("pod", "data") (pure DP across pods);
  * 2-D weight matrices -> P(fsdp_axis, "model"): tensor parallel on the
    output features, FSDP (ZeRO-3) on the input features — XLA re-gathers
    per layer inside the depth scan, so peak memory is one layer's weights;
  * embeddings -> vocab on "model" (padded % 256), d_model on FSDP axis;
  * MoE experts -> expert dim on "model" (EP), features FSDP;
  * every rule checks divisibility against the actual mesh and falls back
    (drop the FSDP axis first, then TP) — the "resource-ratio-driven
    design" discipline of the paper's §III.E applied to mesh resources:
    never force a shard the substrate can't honor.

Works by walking the pytree with key paths; scan-stacked blocks carry a
leading layer axis that is never sharded.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import data_axes

# leaf-name classification
_EMBED = {"embedding"}
_UNEMBED = {"unembed"}
_SCALARISH = {"scale", "bias", "b_a", "b_i", "lam", "a_log", "d_skip",
              "dt_bias", "conv_b", "bq", "bk", "bv"}
_CONV = {"conv_w"}
_EXPERT_PARENT = "experts"
# attention projections: TP only when the HEAD COUNT divides the model
# axis — a flat-feature shard that cuts inside head_dim puts the scores
# einsum's contraction on a sharded dim and XLA all-reduces S^2 score
# tiles (hundreds of GiB/step at 32k). Head-boundary-aware rules are the
# beyond-paper default; ``naive_tp=True`` restores the naive baseline.
_ATTN_Q = {"wq"}
_ATTN_KV = {"wk", "wv"}
# second matmuls: row-parallel (contraction sharded, one activation psum)
# so their input sharding matches the first matmul's output sharding
_ROW_PARALLEL = {"wo", "w_down", "out_proj", "w_out"}


def _axis_ok(mesh: Mesh, axis: str, dim: int) -> bool:
    return axis in mesh.shape and dim % mesh.shape[axis] == 0


def _fsdp_axis(mesh: Mesh) -> str | None:
    return "data" if "data" in mesh.shape else None


def _matrix_spec(mesh: Mesh, shape, prefix_none: int, *, under_experts: bool):
    """2D (d_in, d_out) weight (possibly stacked): TP on d_out, FSDP d_in."""
    d_in, d_out = shape[-2], shape[-1]
    fsdp = _fsdp_axis(mesh)
    tp_out = _axis_ok(mesh, "model", d_out) and not under_experts
    fs_in = fsdp is not None and _axis_ok(mesh, fsdp, d_in)
    # avoid TP+FSDP on the same tiny matrix if either dim is small
    spec_in = fsdp if fs_in else None
    spec_out = "model" if tp_out else None
    if not tp_out and fsdp is not None and _axis_ok(mesh, fsdp, d_out):
        # TP impossible: at least FSDP the larger dim
        if not fs_in:
            spec_out = fsdp
    return P(*([None] * prefix_none + [spec_in, spec_out]))


# perf-experiment hooks: leaf-name -> policy ("replicate" | "fsdp_in")
PARAM_OVERRIDES: dict[str, str] = {}


def param_spec(mesh: Mesh, path: str, shape, cfg=None,
               naive_tp: bool = False) -> P:
    """PartitionSpec for one parameter leaf addressed by its tree path."""
    parts = path.split("/")
    name = parts[-1]
    ndim = len(shape)
    under_experts = _EXPERT_PARENT in parts
    if name in PARAM_OVERRIDES:
        policy = PARAM_OVERRIDES[name]
        if policy == "replicate":
            return P()
        if policy == "fsdp_in" and ndim >= 2:
            fsdp = _fsdp_axis(mesh)
            ok = fsdp is not None and _axis_ok(mesh, fsdp, shape[-2])
            return P(*([None] * (ndim - 2) + [fsdp if ok else None, None]))
    if not naive_tp and cfg is not None and ndim >= 2 \
            and not under_experts \
            and name in (_ATTN_Q | _ATTN_KV | _ROW_PARALLEL):
        fsdp = _fsdp_axis(mesh)
        m = mesh.shape.get("model", 1)
        heads_ok = {"wq": cfg.n_heads % m == 0,
                    "wk": cfg.n_kv_heads % m == 0,
                    "wv": cfg.n_kv_heads % m == 0,
                    "wo": cfg.n_heads % m == 0,
                    "w_down": shape[-2] % m == 0,
                    "out_proj": shape[-2] % m == 0,
                    "w_out": shape[-2] % m == 0}[name]
        fs_in = fsdp is not None and _axis_ok(mesh, fsdp, shape[-2])
        fs_out = fsdp is not None and _axis_ok(mesh, fsdp, shape[-1])
        prefix = [None] * (ndim - 2)
        if name in _ROW_PARALLEL:
            # contraction sharded; one activation psum per layer
            return P(*(prefix + ["model" if heads_ok else (fsdp if fs_in else None),
                                 fsdp if (heads_ok and fs_out) else None]))
        return P(*(prefix + [fsdp if fs_in else None,
                             "model" if heads_ok else None]))
    # how many leading stacking axes (scan layers, expert dim handled below)
    if name in _SCALARISH or ndim <= 1:
        return P()
    if name in _CONV:
        return P()  # (K, C) small depthwise filters: replicate
    if name in _EMBED:
        # (V, D) -> vocab on model, d FSDP
        fsdp = _fsdp_axis(mesh)
        v_ok = _axis_ok(mesh, "model", shape[0])
        d_ok = fsdp is not None and _axis_ok(mesh, fsdp, shape[1])
        return P("model" if v_ok else None, fsdp if d_ok else None)
    if name in _UNEMBED:
        prefix = ndim - 2
        fsdp = _fsdp_axis(mesh)
        d_ok = fsdp is not None and _axis_ok(mesh, fsdp, shape[-2])
        v_ok = _axis_ok(mesh, "model", shape[-1])
        return P(*([None] * prefix
                   + [fsdp if d_ok else None, "model" if v_ok else None]))
    if under_experts and ndim >= 3:
        # (L, E, d_in, d_out) or (E, d_in, d_out): experts on model (EP)
        e_axis = ndim - 3
        e_ok = _axis_ok(mesh, "model", shape[e_axis])
        fsdp = _fsdp_axis(mesh)
        fs_in = fsdp is not None and _axis_ok(mesh, fsdp, shape[-2])
        spec = [None] * ndim
        if e_ok:
            spec[e_axis] = "model"
        if fs_in:
            spec[-2] = fsdp
        return P(*spec)
    if ndim >= 2:
        return _matrix_spec(mesh, shape, ndim - 2,
                            under_experts=under_experts)
    return P()


def fleet_spec(ndim: int = 1) -> P:
    """PartitionSpec for fleet-stacked device state (``core.fleet``):
    the leading axis is one simulated eGPU per mesh device, everything
    under it (blocks, threads, registers, memory words) stays local."""
    if ndim < 1:
        raise ValueError(f"ndim={ndim} must be >= 1")
    return P(*(["fleet"] + [None] * (ndim - 1)))


def fleet_shardings(mesh: Mesh, state_like) -> Any:
    """NamedSharding tree putting every leaf's leading axis on "fleet".

    ``state_like`` is any pytree of arrays (or ShapeDtypeStructs) whose
    leaves all carry a leading ``(n_devices, ...)`` fleet axis — the
    stacked per-device regs/shmem/gmem/oob images the fleet launcher
    feeds ``shard_map``.
    """
    flat, treedef = _tree_paths(state_like)
    out = [NamedSharding(mesh, fleet_spec(max(1, leaf.ndim)))
           for _, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(f"[{p.idx}]")
            elif hasattr(p, "name"):
                keys.append(str(p.name))
            else:
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out, treedef


def param_shardings(mesh: Mesh, params_like, cfg=None,
                    naive_tp: bool = False) -> Any:
    """NamedSharding tree matching ``params_like`` (arrays or SDS)."""
    flat, treedef = _tree_paths(params_like)
    shardings = [NamedSharding(mesh, param_spec(mesh, path, leaf.shape,
                                                cfg=cfg, naive_tp=naive_tp))
                 for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, shardings)


def batch_spec(mesh: Mesh, batch_size: int) -> P:
    """Shard a leading batch dim over as many data axes as divide it."""
    axes = [a for a in data_axes(mesh)]
    use: list[str] = []
    div = 1
    for a in axes:
        if batch_size % (div * mesh.shape[a]) == 0:
            use.append(a)
            div *= mesh.shape[a]
    if not use:
        return P()
    return P(tuple(use) if len(use) > 1 else use[0])


def batch_shardings(mesh: Mesh, batch_like) -> Any:
    flat, treedef = _tree_paths(batch_like)
    out = []
    for _, leaf in flat:
        if leaf.ndim == 0:
            out.append(NamedSharding(mesh, P()))
        else:
            bs = batch_spec(mesh, leaf.shape[0])
            out.append(NamedSharding(
                mesh, P(*(list(bs) + [None] * (leaf.ndim - len(bs))))))
    return jax.tree_util.tree_unflatten(treedef, out)


def cache_spec(mesh: Mesh, shape, batch_size: int,
               features: bool = True) -> P:
    """Spec for one decode-cache leaf: batch axis (exact size match in the
    first two axes — layer-stacked entries are (L, B, ...), plain ones
    (B, ...)) shards over the data axes. KV/state caches additionally shard
    a feature axis on "model": a 32k-context KV cache is hundreds of GB and
    MUST split beyond batch (heads if divisible, else the capacity axis —
    decode attention over a length-sharded cache costs one small stats
    combine)."""
    ndim = len(shape)
    spec: list = [None] * ndim
    bs = batch_spec(mesh, batch_size)
    batch_ax = None
    if ndim and len(bs):
        for ax in range(min(2, ndim)):
            if shape[ax] == batch_size:
                spec[ax] = bs[0] if len(bs) == 1 else tuple(bs)
                batch_ax = ax
                break
    if features and ndim >= 3 and "model" in mesh.shape:
        m = mesh.shape["model"]
        # candidate feature axes, preferred order: heads (-2), then
        # capacity/state (-3), then trailing feature (-1)
        for ax in (ndim - 2, ndim - 3, ndim - 1):
            if ax <= (batch_ax if batch_ax is not None else 0):
                continue
            if spec[ax] is None and shape[ax] % m == 0 and shape[ax] >= m:
                spec[ax] = "model"
                break
    return P(*spec)


def cache_shardings(mesh: Mesh, cache_like, batch_size: int,
                    features: bool = True) -> Any:
    flat, treedef = _tree_paths(cache_like)
    out = [NamedSharding(mesh, cache_spec(mesh, leaf.shape, batch_size,
                                          features))
           for _, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def state_shardings(mesh: Mesh, state_like, cfg=None,
                    naive_tp: bool = False):
    """TrainState: params/mu/nu share param specs; counters replicated."""
    from ..train.step import TrainState

    p_sh = param_shardings(mesh, state_like.params, cfg, naive_tp)
    mu_sh = param_shardings(mesh, state_like.opt.mu, cfg, naive_tp)
    nu_sh = param_shardings(mesh, state_like.opt.nu, cfg, naive_tp)
    rep = NamedSharding(mesh, P())
    from ..optim.adamw import AdamWState

    ef = (None if state_like.ef is None
          else param_shardings(mesh, state_like.ef, cfg, naive_tp))
    return TrainState(params=p_sh,
                      opt=AdamWState(step=rep, mu=mu_sh, nu=nu_sh),
                      step=rep, ef=ef)
