"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

Single pod:  (16, 16)    axes ("data", "model")       = 256 chips
Multi pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips

The sharding discipline (launch/shardings.py):
  * batch over ("pod", "data") — pure DP across pods (cheapest inter-pod
    traffic: one gradient all-reduce per step);
  * weights 2D-sharded: "model" = tensor parallel (heads / d_ff / experts /
    vocab), "data" = FSDP (ZeRO-3 style parameter+optimizer sharding,
    re-gathered per layer inside the scan);
  * elastic: any (data, model) shape works — checkpoints are mesh-agnostic
    and restore reshards (checkpoint/ckpt.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic variant: any shape whose product <= len(jax.devices())."""
    return jax.make_mesh(shape, axes)


def make_fleet_mesh(n_devices: int):
    """1-D mesh for the simulated-eGPU fleet (``core.fleet``): axis
    ``"fleet"`` carries one simulated device per real JAX device. Run
    CPU-only hosts with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    to expose N devices."""
    if n_devices < 1:
        raise ValueError(f"n_devices={n_devices} must be >= 1")
    if n_devices > len(jax.devices()):
        raise ValueError(
            f"fleet mesh wants {n_devices} devices but jax exposes "
            f"{len(jax.devices())}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_devices} "
            f"(CPU) or use placement='host'")
    return jax.make_mesh((n_devices,), ("fleet",))


def data_axes(mesh) -> tuple[str, ...]:
    """The axes a batch dimension shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_divisor(mesh) -> int:
    d = 1
    for a in data_axes(mesh):
        d *= mesh.shape[a]
    return d
