"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it runs reduced configs end-to-end (data pipeline ->
sharded train step -> checkpoints -> metrics). On a TPU pod the same
driver runs the full config: the mesh/sharding layer is identical — only
``--devices`` changes.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, RunConfig, get_arch
from repro.data import PipelineSpec
from repro.launch import shardings as sh
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.train import make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="1x1",
                    help="dataxmodel, e.g. 16x16 on a pod")
    ap.add_argument("--log", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    rc = RunConfig(learning_rate=args.lr, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every, warmup_steps=10,
                   async_ckpt=True)
    d, m = (int(x) for x in args.mesh.split("x"))
    spec = PipelineSpec(vocab=cfg.vocab_size, seq_len=args.seq,
                        global_batch=args.batch, seed=rc.seed)

    if d * m > 1:
        mesh = make_mesh((d, m), ("data", "model"))
        with mesh:
            step = make_train_step(model, rc, args.steps)
            from repro.train.step import TrainState, init_state
            state0 = jax.eval_shape(
                lambda: init_state(model, jax.random.PRNGKey(rc.seed), rc))
            st_sh = sh.state_shardings(mesh, state0)
            step_fn = jax.jit(step, in_shardings=(st_sh, None),
                              out_shardings=(st_sh, None))
            res = train_loop(model, cfg, rc, spec, args.steps,
                             step_fn=step_fn, log_path=args.log)
    else:
        res = train_loop(model, cfg, rc, spec, args.steps, log_path=args.log)
    print(json.dumps({
        "arch": cfg.name, "steps": len(res.losses),
        "resumed_from": res.resumed_from,
        "first_loss": res.losses[0] if res.losses else None,
        "last_loss": res.losses[-1] if res.losses else None,
        "stragglers": res.straggler_steps,
    }))


if __name__ == "__main__":
    main()
