import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede jax init (same contract as dryrun.py)

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, RunConfig, get_arch
from repro.launch import shardings as sh
from repro.launch.dryrun import COMPUTE_DTYPE, layer_variants
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, cache_specs, input_specs
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_row

"""§Perf hillclimbing harness: run one (arch x shape) cell under a NAMED
VARIANT (config patch + build options + sharding overrides), record the
same depth-scaled roofline terms as the dry-run, append to perf.jsonl.

Variants are defined in VARIANTS below — each entry is one
hypothesis->change iteration documented in EXPERIMENTS.md §Perf.
"""


def build(arch, shape_name, *, cfg_patch=None, last_only=False,
          sharding_overrides=None, cfg_base=None, naive_tp=True,
          cache_batch_only=False):
    cfg = cfg_base or get_arch(arch)
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    model = build_model(cfg)

    if sharding_overrides:
        sh.PARAM_OVERRIDES.update(sharding_overrides)
    try:
        if shape.kind == "train":
            from repro.optim.adamw import AdamWState
            from repro.train.step import TrainState, make_train_step

            step = make_train_step(model, RunConfig())
            pspecs = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0), COMPUTE_DTYPE))
            f32like = lambda t: jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
            state_like = TrainState(
                params=pspecs,
                opt=AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                               mu=f32like(pspecs), nu=f32like(pspecs)),
                step=jax.ShapeDtypeStruct((), jnp.int32), ef=None)
            batch_like = input_specs(cfg, shape, COMPUTE_DTYPE)
            st_sh = sh.state_shardings(mesh, state_like, cfg, naive_tp)
            b_sh = sh.batch_shardings(mesh, batch_like)
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None))
            args = (state_like, batch_like)
        elif shape.kind == "prefill":
            pspecs = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0), COMPUTE_DTYPE))
            batch_like = input_specs(cfg, shape, COMPUTE_DTYPE)
            p_sh = sh.param_shardings(mesh, pspecs, cfg, naive_tp)
            b_sh = sh.batch_shardings(mesh, batch_like)
            fwd = lambda params, batch: model.forward(params, batch,
                                                      last_only=last_only)
            jitted = jax.jit(fwd, in_shardings=(p_sh, b_sh),
                             out_shardings=None)
            args = (pspecs, batch_like)
        else:
            pspecs = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0), COMPUTE_DTYPE))
            cspecs = cache_specs(cfg, shape, COMPUTE_DTYPE)
            batch_like = input_specs(cfg, shape, COMPUTE_DTYPE)
            p_sh = sh.param_shardings(mesh, pspecs, cfg, naive_tp)
            c_sh = sh.cache_shardings(mesh, cspecs, shape.global_batch,
                                      features=not cache_batch_only)
            b_sh = sh.batch_shardings(mesh, batch_like)

            def serve_step(params, caches, batch):
                return model.decode_step(params, caches, batch["tokens"])

            jitted = jax.jit(serve_step, in_shardings=(p_sh, c_sh, b_sh),
                             out_shardings=(None, c_sh))
            args = (pspecs, cspecs, batch_like)
    finally:
        pass
    return cfg, shape, mesh, jitted, args


def compile_costs(arch, shape_name, **kw):
    t0 = time.perf_counter()
    cfg, shape, mesh, jitted, args = build(arch, shape_name, **kw)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    return cfg, shape, mesh, {
        "compile_s": round(time.perf_counter() - t0, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": collective_bytes_from_hlo(hlo),
        "argument_bytes_per_device": int(mem.argument_size_in_bytes),
        "peak_bytes_per_device": int(mem.peak_memory_in_bytes),
    }, hlo


def run_variant(arch, shape_name, variant_name, hlo_dir=None, **kw):
    """Full depth-scaled roofline for one variant of one cell."""
    cfg, shape, mesh, full, hlo = compile_costs(arch, shape_name, **kw)
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(
                hlo_dir, f"{arch}_{shape_name}_{variant_name}.hlo"),
                "w") as f:
            f.write(hlo)
    cfg_a, ua, cfg_b, ub, n_units = layer_variants(cfg)
    patch_a = {f.name: getattr(cfg_a, f.name)
               for f in dataclasses.fields(cfg_a)}
    patch_b = {f.name: getattr(cfg_b, f.name)
               for f in dataclasses.fields(cfg_b)}
    kw_a = dict(kw, cfg_patch=None, cfg_base=cfg_a)
    kw_b = dict(kw, cfg_patch=None, cfg_base=cfg_b)
    _, _, _, ca, _ = compile_costs(arch, shape_name, **kw_a)
    _, _, _, cb, _ = compile_costs(arch, shape_name, **kw_b)
    row = {"arch": arch, "shape": shape_name, "variant": variant_name,
           "mesh": "16x16", "kind": shape.kind, "n_chips": 256,
           "status": "ok"}
    row.update(full)
    for k in ("flops", "bytes_accessed", "collective_bytes"):
        per_unit = (cb[k] - ca[k]) / (ub - ua)
        fixed = ca[k] - ua * per_unit
        row[k + "_scaled"] = max(fixed + n_units * per_unit, row[k])
    scaled = {**row, "flops": row["flops_scaled"],
              "bytes_accessed": row["bytes_accessed_scaled"],
              "collective_bytes": row["collective_bytes_scaled"]}
    row.update(roofline_row(cfg, shape, scaled))
    return row


# ---------------------------------------------------------------------------
# the named variants (EXPERIMENTS.md §Perf iterations)
# ---------------------------------------------------------------------------

VARIANTS = {
    # ---- cell C: qwen2.5-32b x prefill_32k --------------------------------
    ("qwen2.5-32b", "prefill_32k"): {
        "baseline": {},
        "last_only": dict(last_only=True),
        "blocked_attn": dict(last_only=True,
                             cfg_patch=dict(attn_q_chunk=2048)),
        "blocked_attn_4k": dict(last_only=True,
                                cfg_patch=dict(attn_q_chunk=4096)),
        "tp_headfix": dict(last_only=True,
                           cfg_patch=dict(attn_q_chunk=2048),
                           naive_tp=False),
        # zero-pad q heads 40->48 (numerics-exact: padded heads hit zero
        # wo rows) so wq/wo TP-shard on head boundaries again
        "qpad48": dict(last_only=True,
                       cfg_patch=dict(attn_q_chunk=2048, n_heads=48),
                       naive_tp=False),
        "bf16_pv": dict(last_only=True,
                        cfg_patch=dict(attn_q_chunk=2048, n_heads=48,
                                       attn_w_bf16=True),
                        naive_tp=False),
    },
    # ---- cell A: mamba2-780m x train_4k ------------------------------------
    ("mamba2-780m", "train_4k"): {
        "baseline": {},
        "chunk128": dict(cfg_patch=dict(ssm_chunk=128)),
        "chunk512": dict(cfg_patch=dict(ssm_chunk=512)),
        "inproj_fsdp_only": dict(sharding_overrides={
            "in_proj": "fsdp_in"}),
        "chunk128_inproj": dict(cfg_patch=dict(ssm_chunk=128),
                                sharding_overrides={"in_proj": "fsdp_in"}),
        "tp_headfix": dict(naive_tp=False),
        "headfix_inproj": dict(naive_tp=False,
                               sharding_overrides={"in_proj": "fsdp_in"}),
        "headfix_inproj_c128": dict(naive_tp=False,
                                    cfg_patch=dict(ssm_chunk=128),
                                    sharding_overrides={"in_proj": "fsdp_in"}),
        "inproj_bf16ssd": dict(
            cfg_patch=dict(ssd_bf16=True),
            sharding_overrides={"in_proj": "fsdp_in"}),
        "headfix_inproj_ssdheads": dict(
            naive_tp=False,
            cfg_patch=dict(ssd_shard_heads=True),
            sharding_overrides={"in_proj": "fsdp_in"}),
    },
    # ---- cell B: recurrentgemma-2b x decode_32k ----------------------------
    ("recurrentgemma-2b", "decode_32k"): {
        "baseline": {},
        "replicate_attn": dict(sharding_overrides={
            "wq": "replicate", "wk": "replicate", "wv": "replicate",
            "wo": "replicate"}),
        "lru_fsdp_only": dict(sharding_overrides={
            "w_a": "fsdp_in", "w_i": "fsdp_in"}),
        "tp_headfix": dict(naive_tp=False),
        "headfix_repl_attn": dict(naive_tp=False, sharding_overrides={
            "wq": "replicate", "wk": "replicate", "wv": "replicate",
            "wo": "replicate"}),
        "headfix_cache_batch": dict(naive_tp=False, cache_batch_only=True),
    },
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--out", default="benchmarks/results/perf.jsonl")
    ap.add_argument("--hlo-dir", default=None)
    args = ap.parse_args()
    spec = VARIANTS[(args.arch, args.shape)][args.variant]
    row = run_variant(args.arch, args.shape, args.variant,
                      hlo_dir=args.hlo_dir, **spec)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(row) + "\n")
    print(json.dumps({k: row[k] for k in
                      ("variant", "compute_s", "memory_s", "collective_s",
                       "dominant", "roofline_fraction",
                       "peak_bytes_per_device", "compile_s")}))


if __name__ == "__main__":
    main()
