"""Gradient compression for the data-parallel all-reduce.

int8 error-feedback (EF-SGD style): each step quantizes (grad + carried
error) to int8 with a per-tensor scale, all-reduces the int8 payload
(8/32 = 4x less DP traffic), dequantizes, and carries the quantization
residual into the next step. Unbiased-enough in practice because the error
feedback re-injects what was rounded away.

Two entry points:
  * ``compress``/``decompress`` — pure tensor-level transform + EF state,
    testable anywhere;
  * ``compressed_psum`` — the shard_map collective: quantize -> psum the
    int8 payload (as int32 accumulator to avoid overflow) -> dequantize.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: Any     # carried quantization residual, same tree as grads


def init_ef(grads_like) -> EFState:
    return EFState(error=jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _quant(x32):
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress(grads, ef: EFState):
    """-> (int8 tree, scales tree, new EF state)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quant(x)
        deq = q.astype(jnp.float32) * scale
        return q, scale, x - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qs = treedef.unflatten([o[0] for o in out])
    scales = treedef.unflatten([o[1] for o in out])
    errs = treedef.unflatten([o[2] for o in out])
    return qs, scales, EFState(error=errs)


def decompress(qs, scales, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda q, s: (q.astype(jnp.float32) * s).astype(dtype), qs, scales)


def compressed_psum(grads, ef: EFState, axis_name: str, n_devices: int):
    """EF-int8 all-reduce inside shard_map: returns (mean grads, EF')."""
    qs, scales, ef2 = compress(grads, ef)
    # accumulate in int32 (127 * n_devices fits easily), average scales
    summed = jax.tree_util.tree_map(
        lambda q: jax.lax.psum(q.astype(jnp.int32), axis_name), qs)
    s_mean = jax.tree_util.tree_map(
        lambda s: jax.lax.psum(s, axis_name) / n_devices, scales)
    # per-device scale varies; using the mean scale on the summed payload is
    # the standard approximation — the EF residual absorbs the mismatch
    mean = jax.tree_util.tree_map(
        lambda qsum, s: (qsum.astype(jnp.float32) * s) / n_devices,
        summed, s_mean)
    return mean, ef2
