"""AdamW in pure JAX (no optax in this environment) with:

  * f32 master accumulators regardless of param dtype (bf16-safe),
  * decoupled weight decay,
  * linear warmup + cosine decay schedule,
  * global-norm gradient clipping (clip.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import RunConfig


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any       # first moment (f32)
    nu: Any       # second moment (f32)


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def schedule(rc: RunConfig, step, total_steps: int = 10_000):
    warm = jnp.minimum(step / jnp.maximum(rc.warmup_steps, 1), 1.0)
    t = jnp.clip((step - rc.warmup_steps)
                 / jnp.maximum(total_steps - rc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return rc.learning_rate * warm * (0.1 + 0.9 * cos)


def apply(rc: RunConfig, params, grads, state: AdamWState,
          total_steps: int = 10_000):
    """Returns (new_params, new_state). Decay skips 1-D params (norms/bias)."""
    step = state.step + 1
    lr = schedule(rc, step, total_steps)
    b1, b2, eps = rc.beta1, rc.beta2, 1e-8

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * g32 * g32
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:
            delta = delta + rc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
