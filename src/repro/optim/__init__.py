"""Optimization: pure-JAX AdamW, clipping, gradient compression."""
from . import adamw, clip, compression

__all__ = ["adamw", "clip", "compression"]
