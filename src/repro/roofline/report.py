"""Render EXPERIMENTS.md tables from dryrun.jsonl."""
from __future__ import annotations

import json
import sys


def load(path: str):
    rows = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.3g}us"
    if x < 1:
        return f"{x*1e3:.3g}ms"
    return f"{x:.3g}s"


def roofline_table(rows) -> str:
    out = ["| arch | shape | kind | compute | memory | collective | dominant "
           "| useful (6ND/HLO) | roofline frac | peak GiB/dev | what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    hints = {
        ("prefill", "memory"): "blocked/flash attention: stop materializing S^2 score tiles",
        ("train", "memory"): "fused attention + remat: cut activation traffic",
        ("decode", "memory"): "KV-cache layout/quantization; batch-major sharding",
        ("train", "collective"): "overlap DP all-reduce with backward; int8-EF compression",
        ("decode", "collective"): "batch-major (DPxDP) layout: drop per-layer TP gathers",
        ("prefill", "collective"): "sequence sharding; gather K/V once per layer",
        ("train", "compute"): "already MXU-bound: increase batch/seq",
        ("decode", "compute"): "n/a (bandwidth-bound by construction)",
        ("prefill", "compute"): "already MXU-bound",
    }
    for (arch, shape, mesh) in sorted(rows):
        r = rows[(arch, shape, mesh)]
        if mesh != "16x16":
            continue
        if r.get("status") == "skipped":
            out.append(f"| {arch} | {shape} | — | — | — | — | — | — | — | — | "
                       f"skipped: full attention at 500k (DESIGN.md §5) |")
            continue
        if r.get("status") != "ok" or "compute_s" not in r:
            continue
        hint = hints.get((r["kind"], r["dominant"]), "")
        out.append(
            f"| {arch} | {shape} | {r['kind']} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2g} "
            f"| {r['peak_bytes_per_device']/2**30:.2f} | {hint} |")
    return "\n".join(out)


def dryrun_table(rows) -> str:
    out = ["| arch | shape | 16x16 compile | 2x16x16 compile | args GiB/dev "
           "| peak GiB/dev | collectives (bytes/dev/step) |",
           "|---|---|---|---|---|---|---|"]
    archs = sorted({a for (a, _, _) in rows})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for arch in archs:
        for shape in shapes:
            sp = rows.get((arch, shape, "16x16"))
            mp = rows.get((arch, shape, "2x16x16"))
            if sp is None:
                continue
            if sp.get("status") == "skipped":
                out.append(f"| {arch} | {shape} | skip | skip | — | — | — |")
                continue
            coll = sp.get("collective_bytes_scaled", sp.get("collective_bytes", 0))
            out.append(
                f"| {arch} | {shape} | {sp.get('compile_s', '?')}s "
                f"| {(mp or {}).get('compile_s', '?')}s "
                f"| {sp.get('argument_bytes_per_device', 0)/2**30:.2f} "
                f"| {sp.get('peak_bytes_per_device', 0)/2**30:.2f} "
                f"| {coll:.3g} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = load(sys.argv[1] if len(sys.argv) > 1
                else "benchmarks/results/dryrun.jsonl")
    print("## Dry-run matrix\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single-pod 16x16, 256 chips)\n")
    print(roofline_table(rows))
