"""Roofline analysis from compiled dry-run artifacts (TPU v5e model).

Three terms per (arch x shape x mesh) cell:

    compute_s    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory_s     = HLO_bytes / (chips * HBM_BW)
    collective_s = collective_bytes / (chips * LINK_BW * links)

cost_analysis() reports whole-program FLOPs/bytes (already per the SPMD
module = per device). collective_bytes comes from parsing the optimized
HLO text: the summed operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) measures how much of the
compiled compute is "useful" (catches remat/redundancy waste).
"""
from __future__ import annotations

import re

import numpy as np

# ---- TPU v5e hardware constants (per the brief) ------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
ICI_LINKS = 4                # links per chip usable concurrently (2D torus)
HBM_PER_CHIP = 16 * 2**30    # v5e: 16 GiB

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,4096,128]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?\b(" + "|".join(_COLLECTIVES)
    + r")(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    if not dims:
        return nb
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def collective_bytes_from_hlo(hlo_text: str) -> float:
    """Sum output-shape bytes over every collective op in the module.

    Tuple-shaped collectives (multi-operand all-reduce) appear as
    ``= (bf16[...], bf16[...]) all-reduce(...)`` — handled by scanning all
    shape literals between '=' and the op name. ``-start``(async) ops are
    counted once; their ``-done`` twins carry no shape payload in the same
    line format.
    """
    total = 0.0
    for line in hlo_text.splitlines():
        hit = None
        for c in _COLLECTIVES:
            if f" {c}(" in line or f" {c}-start(" in line:
                hit = c
                break
        if hit is None:
            continue
        lhs = line.split(f" {hit}")[0]
        for m in re.finditer(r"([a-z0-9]+)\[([\d,]*)\]", lhs):
            total += _shape_bytes(m.group(1), m.group(2))
    return total


# ---------------------------------------------------------------------------
# parameter counts for MODEL_FLOPS
# ---------------------------------------------------------------------------

def param_count(cfg) -> tuple[float, float]:
    """(total_params, active_params) — embedding excluded from the 6ND
    convention's N (we report both)."""
    d, L = cfg.d_model, cfg.n_layers
    V = cfg.padded_vocab
    emb = V * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        return d * cfg.n_heads * cfg.head_dim + \
            2 * d * cfg.n_kv_heads * cfg.head_dim + \
            cfg.n_heads * cfg.head_dim * d

    def mlp_params(ff):
        mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
        return mult * d * ff

    if cfg.family == "ssm":
        di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
        per = d * (2 * di + 2 * G * N + H) + di * d
        total = L * per + emb
        return total, total
    if cfg.family == "hybrid":
        pat = cfg.block_pattern
        n_rec = sum(1 for i in range(L) if pat[i % len(pat)] == "rec")
        n_att = L - n_rec
        w = cfg.lru_width
        rec = 2 * d * w + 2 * w * w + w * d
        per_mlp = mlp_params(cfg.d_ff)
        total = n_rec * (rec + per_mlp) + n_att * (attn_params() + per_mlp) + emb
        return total, total
    if cfg.family == "moe":
        shared = mlp_params(cfg.d_ff * cfg.n_shared_experts) \
            if cfg.n_shared_experts else 0
        expert = mlp_params(cfg.d_ff)
        n_moe = L - int(cfg.first_layer_dense)
        total = n_moe * (attn_params() + cfg.n_experts * expert + shared
                         + d * cfg.n_experts) + emb
        active = n_moe * (attn_params() + cfg.top_k * expert + shared
                          + d * cfg.n_experts) + emb
        if cfg.first_layer_dense:
            dense = attn_params() + mlp_params(cfg.dense_d_ff)
            total += dense
            active += dense
        return total, active
    if cfg.family == "audio":
        enc = cfg.encoder_layers * (attn_params() + mlp_params(cfg.d_ff))
        dec = L * (2 * attn_params() + mlp_params(cfg.d_ff))
        total = enc + dec + emb
        return total, total
    # dense / vlm
    total = L * (attn_params() + mlp_params(cfg.d_ff)) + emb
    return total, total


def model_flops(cfg, shape) -> float:
    """6*N_active*D convention (D = tokens processed by the step)."""
    _, active = param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens        # forward only
    tokens = shape.global_batch              # one new token per sequence
    return 2.0 * active * tokens


def roofline_row(cfg, shape, row: dict) -> dict:
    """Compute the three terms + bottleneck for one dry-run row.

    cost_analysis flops/bytes on the SPMD module are per-device."""
    chips = row["n_chips"]
    flops_dev = row["flops"]
    bytes_dev = row["bytes_accessed"]
    coll_dev = row["collective_bytes"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / (LINK_BW * ICI_LINKS)
    mf = model_flops(cfg, shape)
    useful = mf / (flops_dev * chips) if flops_dev else 0.0
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": useful,
        "roofline_fraction": (mf / chips / PEAK_FLOPS) / bound if bound else 0.0,
        "step_time_lower_bound_s": bound,
    }
