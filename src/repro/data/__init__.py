"""Data substrate: deterministic sharded synthetic pipeline."""
from .pipeline import PipelineSpec, make_batch, spec_for

__all__ = ["PipelineSpec", "make_batch", "spec_for"]
