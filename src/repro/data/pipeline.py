"""Deterministic sharded synthetic-token pipeline.

Design goals mirrored from production data stacks:
  * deterministic: batch content is a pure function of (seed, step) — a
    restart at step k reproduces exactly the batches a non-failing run saw
    (exactly-once sample accounting; the pipeline state in a checkpoint is
    just the step counter);
  * host-shardable: each data-parallel host materializes only its slice
    (``host_slice``), the global batch is never built on one host;
  * structured enough to learn: tokens follow a seeded Markov-ish pattern
    (next token = f(prev)) so training loss measurably drops in the
    end-to-end example — pure-noise pipelines can't show that.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    n_rules: int = 8

    def _rules(self):
        """A small per-seed pool of affine next-token rules — few enough
        that a ~100M model can learn all transition tables, instead of
        having to infer a fresh rule in-context per row."""
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, 991]))
        a = 1 + 2 * rng.integers(0, (self.vocab - 1) // 2, self.n_rules)
        b = rng.integers(0, self.vocab, self.n_rules)
        return a.astype(np.int64), b.astype(np.int64)

    def batch_at(self, step: int, lo: int = 0, hi: int | None = None):
        """Global batch rows [lo, hi) at `step` (numpy, host-side)."""
        hi = self.global_batch if hi is None else hi
        a_pool, b_pool = self._rules()
        rows = []
        for r in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, r]))
            # seeded affine next-token process with noise: learnable structure
            rule = int(rng.integers(0, self.n_rules))
            a, b = int(a_pool[rule]), int(b_pool[rule])
            x = np.empty(self.seq_len, np.int32)
            x[0] = rng.integers(0, self.vocab)
            noise = rng.random(self.seq_len) < 0.05
            rnd = rng.integers(0, self.vocab, self.seq_len)
            for t in range(1, self.seq_len):
                x[t] = rnd[t] if noise[t] else (a * x[t - 1] + b) % self.vocab
            rows.append(x)
        return np.stack(rows)

    def host_slice(self, step: int, host_id: int, n_hosts: int):
        per = self.global_batch // n_hosts
        return self.batch_at(step, host_id * per, (host_id + 1) * per)


def make_batch(cfg: ModelConfig, spec: PipelineSpec, step: int,
               dtype=jnp.float32) -> dict:
    """Full train batch for a model family (tokens/labels + stub frontends)."""
    toks = jnp.asarray(spec.batch_at(step))
    batch = {"tokens": toks, "labels": toks}
    key = jax.random.PRNGKey(hash((spec.seed, step)) & 0x7FFFFFFF)
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (spec.global_batch, cfg.encoder_seq, cfg.d_model), dtype)
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.1 * jax.random.normal(
            key, (spec.global_batch, cfg.num_image_tokens, cfg.d_model), dtype)
    return batch


def spec_for(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
             batch: int | None = None, seq: int | None = None) -> PipelineSpec:
    seq_len = seq or shape.seq_len
    if cfg.family == "vlm":
        seq_len = seq_len - cfg.num_image_tokens
    return PipelineSpec(vocab=cfg.vocab_size, seq_len=seq_len,
                        global_batch=batch or shape.global_batch, seed=seed)
