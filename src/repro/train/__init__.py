"""Training substrate: step, loop, pipeline parallelism."""
from .step import TrainState, init_state, make_compressed_dp_step, make_train_step
from .loop import LoopResult, Watchdog, train_loop

__all__ = ["TrainState", "init_state", "make_train_step",
           "make_compressed_dp_step", "LoopResult", "Watchdog", "train_loop"]
