"""Training loop: checkpoint/restart, straggler watchdog, metrics log.

Fault-tolerance contract:
  * the loop can be killed at ANY step and resumed with the same command —
    it restores the latest complete checkpoint (params, optimizer moments,
    step counter, data-pipeline position) and continues bit-identically to
    a run that never died (deterministic pipeline + step-indexed batches);
  * saves are atomic and (optionally) async;
  * the watchdog records per-step wall times and flags stragglers at
    k * MAD above the running median — on a real multi-host cluster this is
    the signal for preempt/redispatch; here it is measured, logged, and
    surfaced in metrics so the policy layer is testable.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable

import jax
import numpy as np

from ..checkpoint import ckpt
from ..configs.base import ModelConfig, RunConfig
from ..data.pipeline import PipelineSpec, make_batch
from .step import TrainState, init_state, make_train_step


class Watchdog:
    """Per-step wall-time tracker with MAD-based straggler detection."""

    def __init__(self, window: int = 50, k: float = 5.0):
        self.times: list[float] = []
        self.window = window
        self.k = k
        self.flagged: list[int] = []

    def record(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        hist = np.asarray(self.times[-self.window:])
        if len(hist) < 8:
            return False
        med = float(np.median(hist))
        mad = float(np.median(np.abs(hist - med))) + 1e-9
        is_straggler = dt > med + self.k * 1.4826 * mad and dt > 1.5 * med
        if is_straggler:
            self.flagged.append(step)
        return is_straggler


@dataclasses.dataclass
class LoopResult:
    state: TrainState
    losses: list
    straggler_steps: list
    resumed_from: int


def train_loop(model, cfg: ModelConfig, rc: RunConfig, spec: PipelineSpec,
               n_steps: int, *, state: TrainState | None = None,
               step_fn: Callable | None = None,
               log_path: str | None = None,
               fail_at_step: int | None = None) -> LoopResult:
    """Run (or resume) training for up to ``n_steps`` total steps.

    ``fail_at_step`` injects a crash (for the restart tests — the paper of
    record for "would it survive node failure" is a test, not a promise).
    """
    step_fn = step_fn or jax.jit(make_train_step(model, rc, n_steps))
    saver = ckpt.AsyncSaver() if rc.async_ckpt else None
    os.makedirs(rc.ckpt_dir, exist_ok=True)
    resumed_from = 0

    if state is None:
        state = init_state(model, jax.random.PRNGKey(rc.seed), rc)
        latest = ckpt.latest_step(rc.ckpt_dir)
        if latest is not None:
            state, extra = ckpt.restore(rc.ckpt_dir, state, step=latest)
            resumed_from = int(extra.get("step", latest))

    wd = Watchdog()
    losses = []
    logf = open(log_path, "a") if log_path else None
    start_step = int(state.step)
    for step in range(start_step, n_steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        t0 = time.perf_counter()
        batch = make_batch(cfg, spec, step)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        straggle = wd.record(step, dt)
        losses.append(loss)
        if logf:
            logf.write(json.dumps({"step": step, "loss": loss, "dt": dt,
                                   "straggler": straggle}) + "\n")
            if step % 10 == 0:
                logf.flush()
        if rc.ckpt_every and (step + 1) % rc.ckpt_every == 0:
            extra = {"step": step + 1, "pipeline_step": step + 1,
                     "seed": rc.seed}
            if saver:
                saver.save(rc.ckpt_dir, step + 1, state, extra)
            else:
                ckpt.save(rc.ckpt_dir, step + 1, state, extra)
    if saver:
        saver.wait()
    if logf:
        logf.close()
    return LoopResult(state=state, losses=losses,
                      straggler_steps=wd.flagged, resumed_from=resumed_from)
