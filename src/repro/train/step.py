"""Training step: loss -> grads -> clip -> (optional compressed psum) ->
AdamW, with gradient-accumulation microbatching and remat policies.

The step is mesh-agnostic: under pjit/GSPMD the same code runs on 1 CPU
device (smoke tests) or 512 TPU chips (dry-run) — parallelism comes from
in/out shardings, not from the step logic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from ..optim import adamw, clip, compression


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    step: jax.Array
    ef: Any = None            # error-feedback state (compression)


def init_state(model, key, rc: RunConfig, dtype=jnp.float32) -> TrainState:
    params = model.init(key, dtype)
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32))


def _split_microbatches(batch, n: int):
    return jax.tree_util.tree_map(
        lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)


def make_train_step(model, rc: RunConfig, total_steps: int = 10_000):
    """Returns step_fn(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if rc.microbatch and rc.microbatch > 1:
            mb = _split_microbatches(batch, rc.microbatch)

            def body(acc, micro):
                (l, m), g = grad_fn(params, micro)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), m

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), ms = jax.lax.scan(body, (zero, 0.0), mb)
            n = rc.microbatch
            grads = jax.tree_util.tree_map(lambda g: g / n, gsum)
            loss = lsum / n
            metrics = jax.tree_util.tree_map(lambda x: x[-1], ms)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def step_fn(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state.params, batch)
        grads, gnorm = clip.clip_by_global_norm(grads, rc.grad_clip)
        params, opt = adamw.apply(rc, state.params, grads, state.opt,
                                  total_steps)
        out = TrainState(params=params, opt=opt, step=state.step + 1,
                         ef=state.ef)
        m = {"loss": loss, "grad_norm": gnorm,
             "lr": adamw.schedule(rc, state.step + 1, total_steps)}
        m.update(metrics)
        return out, m

    return step_fn


def make_compressed_dp_step(model, rc: RunConfig, mesh, total_steps=10_000):
    """Explicit shard_map data-parallel step with int8 error-feedback
    gradient all-reduce (the distributed-optimization trick; DP traffic
    shrinks 4x). Batch is sharded over the 'data' axis; params replicated.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    n_data = mesh.shape["data"]

    def local_step(params, opt_state, ef, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p, b: model.loss(p, b), has_aux=True)(params, batch)
        mean_grads, ef2 = compression.compressed_psum(grads, ef, "data",
                                                      n_data)
        mean_grads, gnorm = clip.clip_by_global_norm(mean_grads, rc.grad_clip)
        params2, opt2 = adamw.apply(rc, params, mean_grads, opt_state,
                                    total_steps)
        loss = jax.lax.pmean(loss, "data")
        return params2, opt2, ef2, {"loss": loss, "grad_norm": gnorm}

    rep = P()  # replicated
    batch_spec = P("data")
    smapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(rep, rep, rep, batch_spec),
        out_specs=(rep, rep, rep, rep),
        check_rep=False)

    def step_fn(state: TrainState, batch):
        ef = state.ef if state.ef is not None \
            else compression.init_ef(state.params)
        p, o, ef2, m = smapped(state.params, state.opt, ef, batch)
        return TrainState(params=p, opt=o, step=state.step + 1, ef=ef2), m

    return step_fn
