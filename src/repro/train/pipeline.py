"""Pipeline parallelism: GPipe-style microbatch schedule over a "stage"
mesh axis via shard_map + ppermute.

The production dry-run mesh is (data, model) per the brief; PP is the
third parallelism feature for deeper-than-memory models and is exercised
by tests on a host-device mesh (and composes with DP by adding a "data"
axis to the mesh passed in).

Schedule: M microbatches through S stages takes M + S - 1 ticks. Each tick
every stage runs its layer block on the activation it received, then
``ppermute``s the result downstream. jax.grad differentiates straight
through (ppermute transposes to the reverse permute), giving GPipe-style
full-activation backward without bespoke adjoint plumbing.

The stage function is built from the SAME per-layer block functions as the
sequential model: ``build_stage_fn`` stacks n_layers/S layers per stage,
so PP output provably equals the sequential forward (tests assert exact
agreement).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def stack_stages(layer_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-major stacking."""
    def resh(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])
    return jax.tree_util.tree_map(resh, layer_params)


def pipeline_apply(mesh: Mesh, stage_fn: Callable, stage_params, x_mb,
                   axis: str = "stage"):
    """Run the pipeline. x_mb: (M, mb, ...) microbatched input.

    stage_fn(params_for_stage, x) -> y, applied by every stage each tick.
    Returns (M, mb, ...) outputs (as produced by the LAST stage).
    """
    n_stages = mesh.shape[axis]
    M = x_mb.shape[0]
    ticks = M + n_stages - 1

    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(params, xs):
        # params: this stage's slice (leading stage axis of size 1)
        params = jax.tree_util.tree_map(lambda p: p[0], params)
        s = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])      # activation arriving from upstream
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (while available); others use buf
            inj = jnp.where(t < M, xs[jnp.clip(t, 0, M - 1)], jnp.zeros_like(buf))
            x_in = jnp.where(s == 0, inj, buf)
            y = stage_fn(params, x_in)
            # last stage emits microbatch t - (S-1)
            emit_idx = t - (n_stages - 1)
            do_emit = (s == n_stages - 1) & (emit_idx >= 0)
            outs = jax.lax.cond(
                do_emit,
                lambda o: jax.lax.dynamic_update_slice(
                    o, y[None].astype(o.dtype), (jnp.maximum(emit_idx, 0),)
                    + (0,) * y.ndim),
                lambda o: o, outs)
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage's outs are real; broadcast them to all stages
        # (psum over one-hot keeps the pipeline SPMD-uniform)
        sel = (s == n_stages - 1).astype(outs.dtype)
        outs = jax.lax.psum(outs * sel, axis)
        return outs

    spec_params = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_params, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_mb)


def make_pp_loss(mesh: Mesh, stage_fn, embed_fn, head_fn, n_stages: int):
    """Compose embed -> pipelined stages -> head into a loss usable with
    jax.grad (GPipe backward falls out of autodiff)."""

    def loss_fn(params, batch, labels_fn):
        stage_params, other = params
        x = embed_fn(other, batch)
        y = pipeline_apply(mesh, stage_fn, stage_params, x)
        return head_fn(other, y, batch, labels_fn)

    return loss_fn
