"""Test-suite bootstrap.

Two jobs:

1. Make ``import repro`` work without an installed package (the repo uses a
   src/ layout; CI and the tier-1 command both set PYTHONPATH=src, but a bare
   ``pytest`` from the repo root should work too).

2. Degrade gracefully when ``hypothesis`` is not installed (it is a dev-only
   dependency, declared in requirements-dev.txt). Five test modules import
   ``hypothesis`` at module scope; without this shim the whole collection
   dies with ModuleNotFoundError. The shim registers a stand-in module whose
   ``@given`` marks the test as skipped, so the plain unit tests in those
   modules still run.
"""
from __future__ import annotations

import sys
import types
from pathlib import Path

import pytest

_SRC = Path(__file__).resolve().parent.parent / "src"
if _SRC.is_dir() and str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "scheduler: block-scheduler + golden cycle-model regression tests "
        "(CI runs them standalone via `pytest -m scheduler`)")
    config.addinivalue_line(
        "markers",
        "conformance: engine x schedule x backend x n_sms cross-engine "
        "conformance matrix (CI runs it standalone via "
        "`pytest -m conformance`)")
    config.addinivalue_line(
        "markers",
        "packing: wave-packing property suite — pad-minimality, "
        "packing-invariance, dynamic<=static under the packed wave rule "
        "(CI runs it standalone via `pytest -m packing`)")
    config.addinivalue_line(
        "markers",
        "serve: serving-layer suite — decode-engine budget/admission "
        "regressions and the LaunchServer continuous-batching front door "
        "(CI runs it standalone via `pytest -m serve`)")
    config.addinivalue_line(
        "markers",
        "divergence: SIMT predication suite — SETP/SELP semantics, "
        "masked-lane never-mutate properties, and predicated-program "
        "fuzz differentially vs the step oracle "
        "(CI runs it standalone via `pytest -m divergence`)")
    config.addinivalue_line(
        "markers",
        "fleet: multi-device fleet conformance — fleet(n) bit-identity "
        "to the single device, NUMA cycle charges, shard_map placement "
        "(CI runs it standalone under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4 via "
        "`pytest -m fleet`)")

try:
    import hypothesis  # noqa: F401
except ImportError:
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    def _strategy_stub(*_a, **_k):
        # self-returning so decorator-style uses (@st.composite) and chained
        # calls all collect cleanly
        return _strategy_stub

    def _st_getattr(_name):
        # every strategy constructor (integers, sampled_from, composite, ...)
        # returns an inert placeholder; the decorated test never runs.
        return _strategy_stub

    st.__getattr__ = _st_getattr  # type: ignore[attr-defined]  # PEP 562
    hyp.given = given  # type: ignore[attr-defined]
    hyp.settings = settings  # type: ignore[attr-defined]
    hyp.strategies = st  # type: ignore[attr-defined]
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
