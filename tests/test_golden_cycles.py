"""Golden cycle-count regression suite.

Every case records the exact modeled cycle/instruction counts of a
representative launch into ``tests/golden_cycles.json``. The eGPU ISA has
no data-dependent control flow, so these numbers are a pure function of
the cost model + scheduler — any change to either becomes a visible diff
here instead of silently shifting the paper-table reproductions.

Regenerate after an INTENTIONAL cost-model change with:

    PYTHONPATH=src python tests/test_golden_cycles.py --update
"""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import DeviceConfig, SMConfig

pytestmark = pytest.mark.scheduler

GOLDEN_PATH = Path(__file__).parent / "golden_cycles.json"


def _record(res):
    out = {"schedule": res.schedule, "cycles": int(res.cycles),
           "steps": int(res.steps),
           "static_cycles": int(res.static_cycles),
           "gmem": int(res.cycles_by_class[-1])}
    if res.n_waves:
        out["wave_cycles"] = [int(c) for c in res.wave_cycles]
    if getattr(res, "fleet", None) is not None:
        # the NUMA charge is part of the cost model — pin it explicitly
        # next to the totals it already flows into (cycles + gmem class)
        out["remote_gmem"] = int(res.fleet["remote_gmem_cycles"])
    return out


def _saxpy(n_sms):
    from repro.core.programs import launch_saxpy

    x = np.arange(256, dtype=np.float32)
    dev = DeviceConfig(n_sms=n_sms, global_mem_depth=1024,
                       sm=SMConfig(max_steps=10_000))
    _, res = launch_saxpy(2.0, x, np.ones_like(x), device=dev, block=64)
    return res


def _reduction_fused(n_sms):
    from repro.core.programs import launch_reduction

    x = np.ones(1024, np.float32)
    dev = DeviceConfig(n_sms=n_sms, global_mem_depth=2048,
                       sm=SMConfig(max_steps=50_000))
    _, res = launch_reduction(x, device=dev, block=256, fused=True)
    return res


def _fft_batch(n_sms):
    from repro.core.programs.fft import run_fft_batch

    xs = np.ones((5, 64), np.complex64)
    dev = DeviceConfig(n_sms=n_sms,
                       sm=SMConfig(shmem_depth=192, max_steps=200_000))
    _, res = run_fft_batch(xs, device=dev)
    return res


def _qrd_batch(n_sms):
    from repro.core.programs.qrd import run_qrd_batch

    As = np.stack([np.eye(16, dtype=np.float32) + 0.1 * i
                   for i in range(5)])
    dev = DeviceConfig(n_sms=n_sms,
                       sm=SMConfig(shmem_depth=1024, imem_depth=1024,
                                   max_steps=200_000))
    _, _, res = run_qrd_batch(As, device=dev)
    return res


def _cholesky_batch(n_sms):
    from repro.core.programs.cholesky import cholesky_imem_depth, \
        run_cholesky_batch

    rng = np.random.default_rng(0)
    g = rng.standard_normal((16, 16)).astype(np.float32)
    As = np.stack([(g @ g.T + (16.0 + i) * np.eye(16)).astype(np.float32)
                   for i in range(5)])
    bs = np.stack([np.ones(16, np.float32) * (i + 1) for i in range(5)])
    dev = DeviceConfig(n_sms=n_sms,
                       sm=SMConfig(shmem_depth=1024,
                                   imem_depth=cholesky_imem_depth(True),
                                   max_steps=200_000))
    _, _, res = run_cholesky_batch(As, bs, device=dev)
    return res


def _masked_reduction(n_sms):
    from repro.core.programs import launch_masked_reduction

    x = np.linspace(-4.0, 4.0, 1024, dtype=np.float32)
    dev = DeviceConfig(n_sms=n_sms, global_mem_depth=2048,
                       sm=SMConfig(max_steps=50_000))
    _, _, res = launch_masked_reduction(x, 0.5, clip=(-2.0, 2.0),
                                        device=dev, block=256)
    return res


def _mixed(schedule, priorities=None, interleave=True, engine=None,
           n_sms=None, packing=None):
    from repro.core.programs import launch_fft_qrd
    from repro.core.programs.mixed import mixed_device

    xs = np.ones((6, 64), np.complex64)
    As = np.stack([np.eye(16, dtype=np.float32)] * 3)
    device = mixed_device(64, n_sms=n_sms) if n_sms is not None else None
    _, _, _, res = launch_fft_qrd(xs, As, device=device, schedule=schedule,
                                  priorities=priorities,
                                  interleave=interleave, engine=engine,
                                  packing=packing)
    return res


CASES = {}
for _n in (1, 2, 4):
    CASES[f"saxpy256_b64[{_n}sm]"] = (lambda n=_n: _saxpy(n))
    CASES[f"reduction1024_fused[{_n}sm]"] = (lambda n=_n: _reduction_fused(n))
    CASES[f"fft64_batch5[{_n}sm]"] = (lambda n=_n: _fft_batch(n))
    CASES[f"qrd16_batch5[{_n}sm]"] = (lambda n=_n: _qrd_batch(n))
    # predicated program library (PR 9): timing must stay a pure
    # function of the schedule — masks never move a cycle
    CASES[f"cholesky16_solve_batch5[{_n}sm]"] = \
        (lambda n=_n: _cholesky_batch(n))
    CASES[f"masked_reduction1024[{_n}sm]"] = \
        (lambda n=_n: _masked_reduction(n))
CASES["mixed_fft_qrd[4sm,dynamic]"] = lambda: _mixed("dynamic")
CASES["mixed_fft_qrd[4sm,static]"] = lambda: _mixed("static")
# priority discipline: all FFT blocks queue FIRST (interleave=False, the
# worst case for FIFO), and Kernel(priority=1) pulls the long QRD blocks
# ahead of them — the prioritized makespan must beat the FIFO one
CASES["mixed_fft_qrd[4sm,dynamic,fifo-backloaded]"] = \
    lambda: _mixed("dynamic", interleave=False)
CASES["mixed_fft_qrd[4sm,dynamic,qrd-first]"] = \
    lambda: _mixed("dynamic", priorities=(0, 1), interleave=False)
# heterogeneous launches pinned on EACH functional engine: timing comes
# from the static traces either way, so the trace engine's merged waves
# (and the megakernel's fused segments) must report exactly the step
# machine's totals — the megakernel is a functional-path optimization,
# never a timing change
CASES["mixed_fft_qrd[4sm,dynamic,trace-engine]"] = \
    lambda: _mixed("dynamic", engine="trace")
CASES["mixed_fft_qrd[4sm,static,trace-engine]"] = \
    lambda: _mixed("static", engine="trace")
CASES["mixed_fft_qrd[4sm,dynamic,megakernel-engine]"] = \
    lambda: _mixed("dynamic", engine="megakernel")
CASES["mixed_fft_qrd[4sm,static,megakernel-engine]"] = \
    lambda: _mixed("static", engine="megakernel")
# packed-mixed entries (wave packing is OPT-IN: every grid-order entry
# above must stay byte-identical — a default-packing launch never sees
# the packer). The backloaded grid is the pad-adversarial shape; pinning
# BOTH engines pins that timing stays engine-independent under packing.
for _n in (1, 2, 4):
    for _e in ("step", "trace", "megakernel"):
        CASES[f"mixed_fft_qrd[{_n}sm,dynamic,packed,{_e}-engine]"] = \
            (lambda n=_n, e=_e: _mixed("dynamic", engine=e, n_sms=n,
                                       interleave=False,
                                       packing="length"))
        CASES[f"mixed_fft_qrd[{_n}sm,static,packed,{_e}-engine]"] = \
            (lambda n=_n, e=_e: _mixed("static", engine=e, n_sms=n,
                                       interleave=False,
                                       packing="length"))


def _fleet_mixed(n_devices, route="block"):
    """2-device fleet on the golden mixed FFT+QRD workload: the fleet
    makespan (per-device schedules merged under the device-wide fence)
    is as much a cost-model output as any single-device number."""
    from repro.core import FleetConfig, launch_fleet
    from repro.core.programs.fft import fft_kernel, fft_shmem
    from repro.core.programs.mixed import mixed_device
    from repro.core.programs.qrd import qrd_kernel, qrd_shmem

    dcfg = mixed_device(64, n_sms=2)
    xs = np.ones((6, 64), np.complex64)
    As = np.stack([np.eye(16, dtype=np.float32)] * 3)
    sh_f = np.stack([fft_shmem(x, dcfg.sm.shmem_depth) for x in xs])
    sh_q = np.stack([qrd_shmem(A, dcfg.sm.shmem_depth) for A in As])
    fcfg = FleetConfig(n_devices=n_devices, device=dcfg, route=route)
    return launch_fleet(fcfg, programs=[fft_kernel(64), qrd_kernel()],
                        grid_map=[0, 1, 0, 1, 0, 1, 0, 0, 0],
                        shmem=[sh_f, sh_q])


def _fleet_saxpy(n_devices, lat):
    """The NUMA golden: FFT/QRD touch gmem only through shmem images,
    so the remote-gmem charge is pinned on the gmem-heavy saxpy grid —
    blocks routed off the home device pay ``lat`` per GLD/GST row,
    visible in ``cycles``, the gmem class, and ``remote_gmem``."""
    from repro.core import FleetConfig, launch_fleet
    from repro.core.programs.saxpy import saxpy_grid_program

    n, block = 256, 64
    buffers = {"x": np.arange(n, dtype=np.float32),
               "y": np.ones(n, np.float32),
               "z": np.zeros(n, np.float32),
               "alpha": np.asarray([2.0], np.float32)}
    dcfg = DeviceConfig(n_sms=2, global_mem_depth=1024,
                        sm=SMConfig(max_steps=10_000))
    fcfg = FleetConfig(n_devices=n_devices, device=dcfg,
                       remote_gmem_latency=lat)
    return launch_fleet(fcfg, saxpy_grid_program(n, block),
                        grid=(n // block,), block=block, buffers=buffers)


CASES["fleet_mixed_fft_qrd[2dev,2sm]"] = lambda: _fleet_mixed(2)
CASES["fleet_mixed_fft_qrd[2dev,2sm,kernel-route]"] = \
    lambda: _fleet_mixed(2, route="kernel")
CASES["fleet_saxpy256_b64[2dev,numa0]"] = lambda: _fleet_saxpy(2, 0)
CASES["fleet_saxpy256_b64[2dev,numa7]"] = lambda: _fleet_saxpy(2, 7)


@pytest.mark.parametrize("engine", ["trace", "megakernel"])
@pytest.mark.parametrize("packing", [None, "length"])
@pytest.mark.parametrize("schedule", ["static", "dynamic"])
def test_heterogeneous_trace_engine_reports_step_cycle_totals(schedule,
                                                              packing,
                                                              engine):
    tr = _mixed(schedule, engine=engine, packing=packing)
    st = _mixed(schedule, engine="step", packing=packing)
    assert tr.engine == engine and tr.trace_merge is not None
    assert st.engine == "step"
    assert _record(tr) == _record(st)


def test_packing_is_opt_in_stable():
    # an explicit packing="grid" is byte-identical to the default — the
    # packer's presence alone never moves a golden number
    assert _record(_mixed("static", packing="grid")) \
        == _record(_mixed("static"))
    assert _record(_mixed("dynamic", packing="grid")) \
        == _record(_mixed("dynamic"))


@pytest.fixture(scope="module")
def golden():
    if not GOLDEN_PATH.exists():
        pytest.fail(f"{GOLDEN_PATH} missing — regenerate with "
                    f"`python tests/test_golden_cycles.py --update`")
    return json.loads(GOLDEN_PATH.read_text())


@pytest.mark.parametrize("name", sorted(CASES))
def test_golden_cycles(name, golden):
    assert name in golden, (f"no golden entry for {name!r} — regenerate "
                            f"with --update")
    got = _record(CASES[name]())
    assert got == golden[name], (
        f"cycle model drift on {name}: {got} != {golden[name]} — if the "
        f"change is intentional, regenerate golden_cycles.json")


def _update():
    data = {name: _record(fn()) for name, fn in sorted(CASES.items())}
    GOLDEN_PATH.write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {len(data)} cases to {GOLDEN_PATH}")


if __name__ == "__main__":
    import sys

    if "--update" in sys.argv[1:]:
        _update()
    else:
        print(__doc__)
