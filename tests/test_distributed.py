"""Distributed-behaviour tests, each in a subprocess with 8 host devices
(the main pytest process must keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest

_CASES = ["gspmd_matches_single", "compressed_dp", "pipeline_parallel",
          "elastic_checkpoint", "decode_sharded"]
_SCRIPT = os.path.join(os.path.dirname(__file__), "multidevice_cases.py")


@pytest.mark.parametrize("case", _CASES)
def test_multidevice(case):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, _SCRIPT, case],
                       capture_output=True, text=True, timeout=560, env=env)
    assert r.returncode == 0, f"{case}\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    assert f"PASS {case}" in r.stdout
