"""Declarative conformance cases for the packing x engine x schedule x
backend x n_sms cube.

One table (``CASES``) names every golden program plus the heterogeneous
grids; ``tests/test_conformance.py`` sweeps each case over the full cube
and asserts bit-identity of the trace engine against the step machine —
the differential oracle — at the same (schedule, n_sms, packing) point,
and ARCHITECTURAL identity of every packed cell against the grid-order
oracle (wave packing may change timing, never observable state).
Workload sizes are deliberately tiny: the Pallas backend runs the whole
sweep through the kernel interpreter, so every case must stay CI-sized.

The table is data, not tests, so other suites (benchmarks, future
engines) can reuse the same launches.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core import DeviceConfig, Kernel, LaunchResult, SMConfig, launch
from repro.core.assembler import assemble, auto_nop


@dataclasses.dataclass(frozen=True)
class ConformanceCase:
    """One launch, parameterized over the conformance cube axes."""

    build: Callable[..., LaunchResult]  # (engine, schedule, backend,
                                        #  n_sms, packing)
    heterogeneous: bool = False         # mixed grid (merged trace waves)
    pallas_sms: tuple[int, ...] = (1, 2)  # n_sms swept under the (slow)
                                          # Pallas interpreter; inline
                                          # sweeps the full axis
    packings: tuple[str, ...] = ("grid",)  # packing policies swept; the
                                           # heterogeneous cases add
                                           # "length" (homogeneous grids
                                           # are packing-invariant by
                                           # construction — pinned in
                                           # tests/test_packing.py)


def _saxpy(engine, schedule, backend, n_sms, packing) -> LaunchResult:
    from repro.core.programs.saxpy import launch_saxpy

    x = np.arange(64, dtype=np.float32)
    dev = DeviceConfig(n_sms=n_sms, global_mem_depth=512, engine=engine,
                       backend=backend, packing=packing,
                       sm=SMConfig(max_steps=10_000))
    _, res = launch_saxpy(2.0, x, np.ones_like(x), device=dev, block=16,
                          schedule=schedule)
    return res


def _reduction_fused(engine, schedule, backend, n_sms,
                     packing) -> LaunchResult:
    # two programs + a barrier fence: stage 2 GLDs the partials stage 1
    # GSTs — the cross-block global-memory dataflow pattern merged waves
    # must keep behind the fence (and a packed wave must never cross)
    from repro.core.programs import launch_reduction

    dev = DeviceConfig(n_sms=n_sms, global_mem_depth=1024, engine=engine,
                       backend=backend, packing=packing,
                       sm=SMConfig(max_steps=50_000))
    _, res = launch_reduction(np.arange(256, dtype=np.float32), device=dev,
                              block=64, fused=True, schedule=schedule)
    return res


def _fft_batch(engine, schedule, backend, n_sms, packing) -> LaunchResult:
    from repro.core.programs.fft import run_fft_batch

    xs = (np.linspace(-1, 1, 3 * 32).reshape(3, 32)
          + 0.5j * np.ones((3, 32))).astype(np.complex64)
    dev = DeviceConfig(n_sms=n_sms, engine=engine, backend=backend,
                       packing=packing,
                       sm=SMConfig(shmem_depth=128, max_steps=100_000))
    _, res = run_fft_batch(xs, device=dev, schedule=schedule)
    return res


def _qrd_batch(engine, schedule, backend, n_sms, packing) -> LaunchResult:
    from repro.core.programs.qrd import run_qrd_batch

    As = np.stack([np.eye(16, dtype=np.float32) + 0.1,
                   np.eye(16, dtype=np.float32) * 2.0])
    dev = DeviceConfig(n_sms=n_sms, engine=engine, backend=backend,
                       packing=packing,
                       sm=SMConfig(shmem_depth=1024, imem_depth=1024,
                                   max_steps=200_000))
    _, _, res = run_qrd_batch(As, device=dev, schedule=schedule)
    return res


def _mixed_fft_qrd(engine, schedule, backend, n_sms, packing,
                   interleave=True, priorities=None) -> LaunchResult:
    from repro.core.programs.mixed import launch_fft_qrd, mixed_device

    dev = dataclasses.replace(mixed_device(32, n_sms=n_sms),
                              engine=engine, backend=backend)
    xs = (np.ones((3, 32)) + 0.25j * np.arange(32)).astype(np.complex64)
    As = np.stack([np.eye(16, dtype=np.float32) + 0.05])
    _, _, _, res = launch_fft_qrd(xs, As, device=dev, schedule=schedule,
                                  interleave=interleave,
                                  priorities=priorities, packing=packing)
    return res


_OVR_PROG = """
    TDX R1
    PID R2
    BID R4
    STO R1, (R1)+0
    ADD.INT32 R3, R1, R2
    ADD.INT32 R3, R3, R4
    GST R3, (R3)+64 {w4,d1}
    STOP
"""


def _mixed_overrides(engine, schedule, backend, n_sms,
                     packing) -> LaunchResult:
    # per-Kernel imem/shmem overrides INSIDE one heterogeneous grid: the
    # small kernel traps stores >= 24 and pads back to the device depth;
    # every GST writes value == address - 64, so colliding writers are
    # value-identical and the grid stays deterministic under any wave mix
    words = assemble(auto_nop(_OVR_PROG, 32)).words
    other = assemble("TDX R1\nLOD R2, (R1)+0\nADD.INT32 R2, R2, R1\n"
                     "NOP\nNOP\nSTO R2, (R1)+0\nSTOP").words
    kerns = [Kernel(words, block=32, name="small", shmem_depth=24,
                    imem_depth=64),
             Kernel(other, block=48, name="full")]
    dev = DeviceConfig(n_sms=n_sms, global_mem_depth=256, engine=engine,
                       backend=backend,
                       sm=SMConfig(shmem_depth=64, max_steps=5_000))
    return launch(dev, programs=kerns, grid_map=[0, 1, 1, 0, 1],
                  schedule=schedule, packing=packing)


# ---------------------------------------------------------------------------
# predicated (SIMT divergence) cases
# ---------------------------------------------------------------------------

# alternating-mask predication over every masked structure: guarded ALU,
# SELP, masked shared store, masked global store AND load. Two blocks per
# program write PID/BID-disjoint global ranges with address-determined
# values, so the grid is deterministic under any wave mix.
_PRED_A = """
    TDX R1
    BID R9
    LOD R7, #1
    LOD R8, #16
    MUL.INT32 R10, R9, R8
    AND R4, R1, R7                 // tid parity
    SETP.EQ.INT32 R5, R4, R7       // P = tid odd (alternating mask)
    ADD.INT32 R10, R10, R1         // gid = 16*BID + tid
    @R5 ADD.INT32 R6, R10, R8      // odd lanes only: R6 = gid + 16
    @R5 SELP R12, R10, R1          // ALL lanes: P ? gid : tid
    @R5 STO R6, (R1)+0             // masked shared store (odd lanes)
    @R5 GST R10, (R10)+32          // odd gids: gmem[32+gid] = gid
    @!R5 GST R10, (R10)+96         // even gids: gmem[96+gid] = gid
    @R5 GLD R11, (R10)+32          // masked global load-back (odd lanes)
    @R5 STO R11, (R1)+16
    STOP
"""

_PRED_B = """
    TDX R1
    BID R9
    LOD R8, #16
    MUL.INT32 R10, R9, R8
    ADD.INT32 R10, R10, R1         // gid
    ADD.INT32 R2, R10, R8
    STO R2, (R1)+0
    GST R2, (R10)+160              // legacy lane: gmem[160+gid] = gid+16
    STOP
"""


def _predicated_mix(engine, schedule, backend, n_sms,
                    packing) -> LaunchResult:
    a = assemble(auto_nop(_PRED_A, 16)).words
    b = assemble(auto_nop(_PRED_B, 16)).words
    kerns = [Kernel(a, block=16, name="pred"),
             Kernel(b, block=16, name="legacy")]
    dev = DeviceConfig(n_sms=n_sms, global_mem_depth=256, engine=engine,
                       backend=backend,
                       sm=SMConfig(shmem_depth=64, max_steps=5_000))
    return launch(dev, programs=kerns, grid_map=[0, 1, 0, 1],
                  schedule=schedule, packing=packing)


def _cholesky_batch(engine, schedule, backend, n_sms,
                    packing) -> LaunchResult:
    # one SPD matrix (every pivot taken) + one PSD matrix with an exactly
    # singular row/column (pivot 5 skipped) — both predicate branches of
    # the pivot guard live in the same wave
    from repro.core.programs.cholesky import run_cholesky_batch

    rng = np.random.default_rng(7)
    g = rng.standard_normal((16, 16)).astype(np.float32)
    spd = (g @ g.T + 16 * np.eye(16)).astype(np.float32)
    psd = spd.copy()
    psd[5, :] = 0.0
    psd[:, 5] = 0.0
    dev = DeviceConfig(n_sms=n_sms, engine=engine, backend=backend,
                       packing=packing,
                       sm=SMConfig(shmem_depth=1024, imem_depth=1024,
                                   max_steps=200_000))
    _, _, res = run_cholesky_batch(np.stack([spd, psd]), device=dev,
                                   schedule=schedule, solve=False)
    return res


def _masked_reduction(engine, schedule, backend, n_sms,
                      packing) -> LaunchResult:
    # clipped/masked grid reduction: stage 1 runs SETP/SELP clipping and
    # mask-guarded SUMs, stage 2 is the stock fold behind a barrier — a
    # heterogeneous grid whose predicated stage must merge-schedule
    from repro.core.programs.masked_reduction import launch_masked_reduction

    dev = DeviceConfig(n_sms=n_sms, global_mem_depth=512, engine=engine,
                       backend=backend, packing=packing,
                       sm=SMConfig(max_steps=50_000))
    _, _, res = launch_masked_reduction(
        np.linspace(-2.0, 2.0, 120, dtype=np.float32), 0.25,
        clip=(-1.0, 1.0), device=dev, block=64, schedule=schedule)
    return res


_HET_PACKINGS = ("grid", "length")

CASES: dict[str, ConformanceCase] = {
    "saxpy64_b16": ConformanceCase(_saxpy),
    "reduction256_fused": ConformanceCase(_reduction_fused,
                                          heterogeneous=True,
                                          packings=_HET_PACKINGS),
    "fft32_batch3": ConformanceCase(_fft_batch),
    "qrd16_batch2": ConformanceCase(_qrd_batch, pallas_sms=(2,)),
    "mixed_fft_qrd": ConformanceCase(_mixed_fft_qrd, heterogeneous=True,
                                     packings=_HET_PACKINGS),
    "mixed_backloaded_prio": ConformanceCase(
        lambda e, s, b, n, p: _mixed_fft_qrd(e, s, b, n, p,
                                             interleave=False,
                                             priorities=(0, 1)),
        heterogeneous=True, pallas_sms=(2,), packings=_HET_PACKINGS),
    "mixed_overrides": ConformanceCase(_mixed_overrides,
                                       heterogeneous=True,
                                       packings=_HET_PACKINGS),
    "predicated_mix": ConformanceCase(_predicated_mix, heterogeneous=True,
                                      packings=_HET_PACKINGS),
    "cholesky16_batch2": ConformanceCase(_cholesky_batch, pallas_sms=(2,)),
    "masked_reduction120": ConformanceCase(_masked_reduction,
                                           heterogeneous=True,
                                           pallas_sms=(2,),
                                           packings=_HET_PACKINGS),
}

ENGINES = ("step", "trace", "megakernel")
SCHEDULES = ("static", "dynamic")
BACKENDS = ("inline", "pallas")
N_SMS = (1, 2, 4)


def cube(backend: str):
    """The (case, schedule, n_sms, packing) cells swept for one backend.

    The Pallas interpreter is slow, so its packed ("length") cells run
    only at the case's widest ``pallas_sms`` point — the inline sweep
    covers the full axis, and packed Pallas cells add backend coverage,
    not packing coverage.
    """
    for name, case in CASES.items():
        for packing in case.packings:
            if backend == "inline":
                sms = N_SMS
            else:
                sms = case.pallas_sms if packing == "grid" \
                    else case.pallas_sms[-1:]
            for schedule in SCHEDULES:
                for n_sms in sms:
                    yield name, schedule, n_sms, packing


def assert_arch_identical(a: LaunchResult, b: LaunchResult) -> None:
    """Architectural (observable-state) equality of two launches: every
    register, shared-memory and global-memory word, the OOB flags, and
    halting. Cycle counters are deliberately NOT compared — wave packing
    legitimately changes modeled timing, never state."""
    np.testing.assert_array_equal(np.asarray(a.regs), np.asarray(b.regs))
    np.testing.assert_array_equal(np.asarray(a.shmem), np.asarray(b.shmem))
    np.testing.assert_array_equal(np.asarray(a.gmem), np.asarray(b.gmem))
    np.testing.assert_array_equal(np.asarray(a.oob), np.asarray(b.oob))
    assert a.halted == b.halted


def assert_bit_identical(a: LaunchResult, b: LaunchResult) -> None:
    """Full architectural + counter equality of two launches."""
    assert_arch_identical(a, b)
    assert a.cycles == b.cycles and a.steps == b.steps
    assert list(a.wave_cycles) == list(b.wave_cycles)
    assert list(np.asarray(a.cycles_by_class)) \
        == list(np.asarray(b.cycles_by_class))
    assert a.static_cycles == b.static_cycles
