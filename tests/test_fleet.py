"""Fleet conformance suite: ``launch_fleet`` vs the single device.

The fleet's first contract (``core/fleet.py``) is bit-identity: a fleet
launch computes exactly what ``device.launch`` computes on the same
grid, for every ``n_devices`` and both routers — the fleet only changes
where blocks run and what the cycle model charges. This suite pins that
contract over the golden-program shapes (gmem-heavy saxpy grid, the
fused two-stage reduction with its barrier fence, the interleaved
FFT64 + QRD16 mix with per-block shmem batches), plus the fleet-only
semantics on top: the device-wide barrier fence, the NUMA remote-gmem
charge, per-device accounting, and the shard_map placement ladder.

Run standalone with ``pytest -m fleet``; CI additionally runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the
shard_map placement cells execute on real (forced-host) JAX devices.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax

from repro.core import (
    DeviceConfig,
    FleetConfig,
    Kernel,
    SMConfig,
    assemble,
    buffer_layout,
    launch,
    launch_fleet,
)
from repro.core.programs.fft import fft_kernel, fft_shmem
from repro.core.programs.mixed import mixed_device
from repro.core.programs.qrd import qrd_kernel, qrd_shmem
from repro.core.programs.reduction import reduction_grid_asm
from repro.core.programs.saxpy import saxpy_grid_program

from engine_conformance import assert_arch_identical, assert_bit_identical

pytestmark = pytest.mark.fleet

_N_JAX = len(jax.devices())


# ---------------------------------------------------------------- cases

def _case_saxpy():
    """Gmem-heavy grid: 4 blocks, every block GLD/GSTs its slice — the
    shape the NUMA charge is pinned on."""
    n, block = 256, 64
    rng = np.random.default_rng(7)
    buffers = {
        "x": rng.standard_normal(n).astype(np.float32),
        "y": rng.standard_normal(n).astype(np.float32),
        "z": np.zeros(n, np.float32),
        "alpha": np.asarray([1.5], np.float32),
    }
    dcfg = DeviceConfig(n_sms=2, global_mem_depth=3 * n + 16,
                        sm=SMConfig(max_steps=10_000))
    return dcfg, dict(program=saxpy_grid_program(n, block),
                      grid=(n // block,), block=block, buffers=buffers)


def _case_reduction_fused():
    """Two programs + a barrier: stage 2 GLDs the partials stage 1 GSTs
    — the fence must stay device-WIDE under the fleet."""
    x = np.arange(256, dtype=np.float32)
    block, n_blocks, n2 = 64, 4, 16
    buffers = {"x": x, "partials": np.zeros(n2, np.float32),
               "result": np.zeros(16, np.float32)}
    layout = buffer_layout(buffers)
    src, par, res_off = (layout[k][0] for k in ("x", "partials", "result"))
    kernels = [Kernel(assemble(reduction_grid_asm(block, src, par, True)),
                      block=block, name="reduce.stage1"),
               Kernel(assemble(reduction_grid_asm(n2, par, res_off, False)),
                      block=n2, name="reduce.stage2", barrier=True)]
    dcfg = DeviceConfig(n_sms=2, global_mem_depth=512,
                        sm=SMConfig(max_steps=50_000))
    return dcfg, dict(programs=kernels, grid_map=[0] * n_blocks + [1],
                      buffers=buffers)


def _case_mixed_fft_qrd():
    """Interleaved FFT64 + QRD16 (6 + 3 blocks) with per-block shmem
    batches — the heterogeneous shape the ``kernel`` router exists for."""
    dcfg = mixed_device(64, n_sms=2)
    xs = (np.linspace(-1, 1, 6 * 64).reshape(6, 64)
          + 0.5j * np.ones((6, 64))).astype(np.complex64)
    As = np.stack([np.eye(16, dtype=np.float32) + 0.1 * b
                   for b in range(3)])
    sh_f = np.stack([fft_shmem(x, dcfg.sm.shmem_depth) for x in xs])
    sh_q = np.stack([qrd_shmem(A, dcfg.sm.shmem_depth) for A in As])
    gmap = [0, 1, 0, 1, 0, 1, 0, 0, 0]
    return dcfg, dict(programs=[fft_kernel(64), qrd_kernel()],
                      grid_map=gmap, shmem=[sh_f, sh_q])


CASES = {
    "saxpy256_g4": _case_saxpy,
    "reduction256_fused": _case_reduction_fused,
    "mixed_fft_qrd": _case_mixed_fft_qrd,
}


def _plain(name):
    dcfg, kw = CASES[name]()
    return launch(dcfg, **kw)


def _fleet(name, n_devices, **fleet_kw):
    dcfg, kw = CASES[name]()
    fcfg = FleetConfig(n_devices=n_devices, device=dcfg, **fleet_kw)
    return launch_fleet(fcfg, **kw)


# --------------------------------------------------- fleet(1) delegation

@pytest.mark.parametrize("name", sorted(CASES))
def test_fleet1_is_the_plain_launch(name):
    # delegation, not re-implementation: identical down to every counter,
    # plus the fleet view attached
    res = _fleet(name, 1)
    assert_bit_identical(res, _plain(name))
    fleet = res.profile()["fleet"]
    assert fleet["n_devices"] == 1
    assert fleet["remote_gmem_cycles"] == 0
    assert fleet["per_device"][0]["blocks"] == res.n_blocks
    assert fleet["per_device"][0]["makespan"] == res.cycles


# ------------------------------------------------ fleet(n) bit-identity

@pytest.mark.parametrize("route", ["block", "kernel"])
@pytest.mark.parametrize("n_devices", [2, 3, 4])
@pytest.mark.parametrize("name", sorted(CASES))
def test_fleet_n_is_functionally_identical(name, n_devices, route):
    # scaling out changes timing, never observable state
    plain = _plain(name)
    res = _fleet(name, n_devices, route=route)
    assert_arch_identical(res, plain)
    fleet = res.profile()["fleet"]
    assert fleet["n_devices"] == n_devices
    assert fleet["placement"] in ("host", "shard_map")
    assert sum(d["blocks"] for d in fleet["per_device"]) == res.n_blocks
    assert max(d["makespan"] for d in fleet["per_device"]) == res.cycles


def test_kernel_route_keeps_programs_device_local():
    res = _fleet("mixed_fft_qrd", 2, route="kernel")
    per = res.profile()["fleet"]["per_device"]
    # program k -> device k % 2: 6 FFT blocks home, 3 QRD blocks remote
    assert [d["blocks"] for d in per] == [6, 3]
    assert_arch_identical(res, _plain("mixed_fft_qrd"))


# ------------------------------------------------------- barrier fence

@pytest.mark.parametrize("n_devices", [2, 3])
def test_barrier_fences_the_whole_fleet(n_devices):
    # stage 2 (block 4) must not issue anywhere before EVERY stage-1
    # block has retired on EVERY device
    res = _fleet("reduction256_fused", n_devices)
    t = res.timing
    assert int(t.block_start[4]) >= int(t.block_finish[:4].max())
    total = float(np.asarray(res.buffer("result"))[0])
    assert total == float(np.arange(256, dtype=np.float32).sum())


# ------------------------------------------------------------ NUMA tier

def test_remote_gmem_latency_charges_off_home_blocks():
    base = _fleet("saxpy256_g4", 2, remote_gmem_latency=0)
    numa = _fleet("saxpy256_g4", 2, remote_gmem_latency=7)
    # the charge is cycles, not semantics
    assert_arch_identical(numa, base)
    f0 = base.profile()["fleet"]
    f7 = numa.profile()["fleet"]
    assert f0["remote_gmem_cycles"] == 0
    assert f7["remote_gmem_cycles"] > 0
    assert f7["remote_gmem_cycles"] % 7 == 0
    assert numa.cycles > base.cycles
    # only the off-home device pays: its makespan moves, home's doesn't
    assert f7["per_device"][0]["makespan"] == f0["per_device"][0]["makespan"]
    assert f7["per_device"][1]["makespan"] > f0["per_device"][1]["makespan"]
    # by_class grew by exactly the charge
    assert int(np.asarray(numa.cycles_by_class).sum()) \
        == int(np.asarray(base.cycles_by_class).sum()) \
        + f7["remote_gmem_cycles"]


def test_home_device_moves_the_charge():
    a = _fleet("saxpy256_g4", 2, remote_gmem_latency=5, home_device=0)
    b = _fleet("saxpy256_g4", 2, remote_gmem_latency=5, home_device=1)
    assert_arch_identical(a, b)
    fa, fb = a.profile()["fleet"], b.profile()["fleet"]
    assert fa["remote_gmem_cycles"] == fb["remote_gmem_cycles"] > 0
    assert [d["home"] for d in fa["per_device"]] == [True, False]
    assert [d["home"] for d in fb["per_device"]] == [False, True]


# ----------------------------------------------------- timing / scaling

def test_fleet_makespan_improves_on_wide_grids():
    # 4 gmem-heavy blocks on 2-SM devices: doubling devices must not
    # slow the modeled launch down, and 4 devices must beat 1
    c = {n: _fleet("saxpy256_g4", n).cycles for n in (1, 2, 4)}
    assert c[2] <= c[1] and c[4] <= c[2]
    assert c[4] < c[1]


# ------------------------------------------------------------ placement

def test_forced_shard_map_raises_on_mixed_grid():
    with pytest.raises(ValueError, match="shard_map"):
        _fleet("mixed_fft_qrd", 2, placement="shard_map")


def test_auto_placement_records_why_not():
    res = _fleet("mixed_fft_qrd", 2)          # mixed grid: host, always
    fleet = res.profile()["fleet"]
    assert fleet["placement"] == "host"
    assert "mixed-program grid" in fleet["placement_reason"]
    res = _fleet("saxpy256_g4", 3)            # 4 blocks % 3 devices != 0
    fleet = res.profile()["fleet"]
    assert fleet["placement"] == "host"
    assert "not divisible" in fleet["placement_reason"]


def test_forced_host_always_works():
    res = _fleet("saxpy256_g4", 2, placement="host")
    assert res.profile()["fleet"]["placement"] == "host"
    assert res.profile()["fleet"]["placement_reason"] == "requested"
    assert_arch_identical(res, _plain("saxpy256_g4"))


@pytest.mark.skipif(_N_JAX < 2, reason=f"jax exposes {_N_JAX} device(s); "
                    "run under XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4")
@pytest.mark.parametrize("n_devices", [2, 4])
def test_shard_map_placement_is_bit_identical(n_devices):
    if _N_JAX < n_devices:
        pytest.skip(f"jax exposes {_N_JAX} device(s) < {n_devices}")
    res = _fleet("saxpy256_g4", n_devices, placement="shard_map")
    fleet = res.profile()["fleet"]
    assert fleet["placement"] == "shard_map"
    assert_arch_identical(res, _plain("saxpy256_g4"))
    # auto must pick the same path on this uniform grid
    auto = _fleet("saxpy256_g4", n_devices)
    assert auto.profile()["fleet"]["placement"] == "shard_map"
    assert_arch_identical(auto, res)


# -------------------------------------------------------------- config

def test_fleet_config_validation():
    with pytest.raises(ValueError, match="n_devices"):
        FleetConfig(n_devices=0)
    with pytest.raises(ValueError, match="remote_gmem_latency"):
        FleetConfig(remote_gmem_latency=-1)
    with pytest.raises(ValueError, match="home_device"):
        FleetConfig(n_devices=2, home_device=2)
    with pytest.raises(ValueError, match="route"):
        FleetConfig(route="hash")
    with pytest.raises(ValueError, match="placement"):
        FleetConfig(placement="tpu")
    assert FleetConfig(n_devices=3).n_sms \
        == 3 * FleetConfig().device.n_sms
