"""Trace-engine differential suite: the decode-once scan pipeline must be
bit-identical to the stepping machine on every golden program, at every SM
count, on both execute backends — plus the engine plumbing (auto
selection, compile cache), the per-Kernel imem/shmem overrides, and the
priority dispatch discipline that ride along in this layer.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    DeviceConfig,
    Kernel,
    SMConfig,
    assemble,
    compile_program,
    launch,
    program_trace,
    schedule_blocks,
)
from repro.core.assembler import auto_nop
from repro.core.isa import Depth, Instr, Op, Typ, Width

RNG = np.random.default_rng(23)


def _dcfg(n_sms=4, gdepth=256, engine="auto", backend="inline", **sm_kw):
    sm_kw.setdefault("max_steps", 5000)
    return DeviceConfig(n_sms=n_sms, global_mem_depth=gdepth,
                        engine=engine, backend=backend, sm=SMConfig(**sm_kw))


def _assert_launches_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.regs), np.asarray(b.regs))
    np.testing.assert_array_equal(np.asarray(a.shmem), np.asarray(b.shmem))
    np.testing.assert_array_equal(np.asarray(a.gmem), np.asarray(b.gmem))
    np.testing.assert_array_equal(np.asarray(a.oob), np.asarray(b.oob))
    assert a.halted == b.halted
    assert a.cycles == b.cycles and a.steps == b.steps
    assert list(a.wave_cycles) == list(b.wave_cycles)
    assert list(np.asarray(a.cycles_by_class)) \
        == list(np.asarray(b.cycles_by_class))
    assert a.static_cycles == b.static_cycles


# ---------------------------------------------------------------------------
# golden programs: step vs trace across SM counts and backends
# ---------------------------------------------------------------------------

def _golden_launches(n_sms, backend, engine):
    """One launch per golden program on an ``n_sms`` device; returns
    {name: LaunchResult}. Sizes kept small enough for the Pallas
    interpreter to sweep the whole set."""
    from repro.core.programs import launch_fft_qrd, launch_reduction
    from repro.core.programs.fft import run_fft_batch
    from repro.core.programs.qrd import run_qrd_batch
    from repro.core.programs.saxpy import launch_saxpy

    out = {}
    x = np.arange(64, dtype=np.float32)
    dev = DeviceConfig(n_sms=n_sms, global_mem_depth=1024, engine=engine,
                       backend=backend, sm=SMConfig(max_steps=10_000))
    _, out["saxpy"] = launch_saxpy(2.0, x, np.ones_like(x), device=dev,
                                   block=16)
    dev = DeviceConfig(n_sms=n_sms, global_mem_depth=2048, engine=engine,
                       backend=backend, sm=SMConfig(max_steps=50_000))
    _, out["reduction"] = launch_reduction(np.ones(512, np.float32),
                                           device=dev, block=128,
                                           fused=True)
    dev = DeviceConfig(n_sms=n_sms, engine=engine, backend=backend,
                       sm=SMConfig(shmem_depth=192, max_steps=200_000))
    _, out["fft"] = run_fft_batch(np.ones((3, 64), np.complex64),
                                  device=dev)
    dev = DeviceConfig(n_sms=n_sms, engine=engine, backend=backend,
                       sm=SMConfig(shmem_depth=1024, imem_depth=1024,
                                   max_steps=200_000))
    As = np.stack([np.eye(16, dtype=np.float32) + 0.1 * i
                   for i in range(2)])
    _, _, out["qrd"] = run_qrd_batch(As, device=dev)
    from repro.core.programs.mixed import mixed_device

    dev = dataclasses.replace(mixed_device(64, n_sms=n_sms), engine=engine,
                              backend=backend)
    _, _, _, out["mixed"] = launch_fft_qrd(
        np.ones((3, 64), np.complex64),
        np.stack([np.eye(16, dtype=np.float32)] * 2), device=dev)
    return out


@pytest.mark.parametrize("n_sms", [1, 2, 4])
def test_trace_engine_bit_identical_golden_inline(n_sms):
    step = _golden_launches(n_sms, "inline", "step")
    trace = _golden_launches(n_sms, "inline", "trace")
    for name in step:
        assert step[name].engine == "step"
        assert trace[name].engine == "trace"
        _assert_launches_identical(step[name], trace[name])


@pytest.mark.parametrize("n_sms", [1, 2])
def test_trace_engine_bit_identical_golden_pallas(n_sms):
    step = _golden_launches(n_sms, "pallas", "step")
    trace = _golden_launches(n_sms, "pallas", "trace")
    for name in step:
        _assert_launches_identical(step[name], trace[name])


def test_trace_engine_bit_identical_golden_pallas_4sm():
    # keep the 4-SM Pallas sweep to the two kernel-heavy programs so the
    # interpreter sweep stays CI-sized; 1/2-SM cover the full set above
    step = _golden_launches(4, "pallas", "step")
    trace = _golden_launches(4, "pallas", "trace")
    for name in ("fft", "qrd"):
        _assert_launches_identical(step[name], trace[name])


# ---------------------------------------------------------------------------
# fuzz: random legal programs (loops, subroutines, every data op)
# ---------------------------------------------------------------------------

_DATA_OPS = [Op.ADD, Op.SUB, Op.MUL, Op.AND, Op.OR, Op.XOR, Op.LSL,
             Op.LSR, Op.LODI, Op.TDX, Op.TDY, Op.BID, Op.PID, Op.LOD,
             Op.STO, Op.GLD, Op.GST, Op.DOT, Op.SUM, Op.INVSQR, Op.NOP]


def _data_instr(draw):
    op = draw(st.sampled_from(_DATA_OPS))
    return Instr(op=op, typ=draw(st.sampled_from(list(Typ))),
                 rd=draw(st.integers(0, 15)), ra=draw(st.integers(0, 15)),
                 rb=draw(st.integers(0, 15)),
                 imm=draw(st.integers(0, 31)),
                 width=draw(st.sampled_from(list(Width))),
                 depth=draw(st.sampled_from(list(Depth))))


@st.composite
def _random_program(draw):
    """pre | INIT t; body; LOOP | JSR sub | STOP | sub: ...; RTS —
    terminating by construction, exercising the pre-resolved control."""
    pre = [_data_instr(draw) for _ in range(draw(st.integers(0, 4)))]
    body = [_data_instr(draw) for _ in range(draw(st.integers(1, 4)))]
    trip = draw(st.integers(1, 4))
    sub = [_data_instr(draw) for _ in range(draw(st.integers(0, 2)))]
    use_jsr = draw(st.booleans())
    prog = list(pre)
    prog.append(Instr(op=Op.INIT, imm=trip))
    body_start = len(prog)
    prog.extend(body)
    prog.append(Instr(op=Op.LOOP, imm=body_start))
    stop_at = len(prog) + (1 if use_jsr else 0)
    if use_jsr:
        prog.append(Instr(op=Op.JSR, imm=stop_at + 1))
    prog.append(Instr(op=Op.STOP))
    if use_jsr:
        prog.extend(sub)
        prog.append(Instr(op=Op.RTS))
    return np.array([i.encode() for i in prog], np.int64)


@settings(max_examples=40, deadline=None)
@given(words=_random_program(), seed=st.integers(0, 2**31 - 1),
       n_sms=st.integers(1, 3), n_blocks=st.integers(1, 5))
def test_fuzz_trace_engine_matches_step_machine(words, seed, n_sms,
                                                n_blocks):
    rng = np.random.default_rng(seed)
    gmem = rng.standard_normal(64).astype(np.float32)
    shmem = rng.standard_normal((n_blocks, 64)).astype(np.float32)
    outs = {}
    for engine in ("step", "trace"):
        dcfg = _dcfg(n_sms=n_sms, gdepth=64, engine=engine,
                     shmem_depth=64, max_steps=500)
        outs[engine] = launch(dcfg, words, grid=(n_blocks,), block=32,
                              gmem=gmem, shmem=shmem)
    _assert_launches_identical(outs["step"], outs["trace"])


# ---------------------------------------------------------------------------
# engine plumbing: auto selection, cache, runaway programs
# ---------------------------------------------------------------------------

def test_auto_engine_picks_megakernel_for_halting_programs():
    # enough fusible (non-gmem) work to clear MEGAKERNEL_MIN_FUSED_ROWS
    prog = assemble("INIT 12\ntop:\nTDX R1\nADD.INT32 R2, R1, R1\n"
                    "LOOP top\nSTO R2, (R1)+0\nSTOP")
    res = launch(_dcfg(max_steps=100), prog, grid=(2,), block=16)
    assert res.engine == "megakernel" and res.halted
    assert res.engine_fallback is None


def test_auto_engine_never_picks_megakernel_for_short_programs():
    # the BENCH_engine.json regression: on saxpy256_b64 the megakernel
    # measured 0.811x vs step, because a 7-residual-row program is all
    # dispatch glue. auto must fall back to step and say why; an
    # explicit engine choice is still honored.
    from repro.core import trace_engine
    from repro.core.programs.saxpy import saxpy_kernel

    kern = saxpy_kernel(256, block=64)
    words = kern.program.words
    dcfg = _dcfg(n_sms=2, gdepth=1024, max_steps=10_000)
    res = launch(dcfg, words, grid=(4,), block=64,
                 gmem=np.zeros(1024, np.float32))
    assert res.engine == "step"
    assert res.profile()["engine_fallback"] == "megakernel-too-small"
    # an explicit engine choice is never second-guessed — and all three
    # engines stay bit-identical on the shape
    for eng in ("megakernel", "trace"):
        forced = launch(_dcfg(n_sms=2, gdepth=1024, max_steps=10_000,
                              engine=eng), words, grid=(4,), block=64,
                        gmem=np.zeros(1024, np.float32))
        assert forced.engine == eng and forced.engine_fallback is None
        _assert_launches_identical(res, forced)


def test_auto_engine_degrades_to_trace_past_unroll_cap():
    # a schedule longer than the megakernel unroll cap would compile an
    # unboundedly large fused body — auto degrades to the scanned trace
    # engine and says why
    from repro.core import trace_engine

    trip = trace_engine.MEGAKERNEL_UNROLL_CAP // 2 + 1
    prog = assemble(f"INIT {trip}\ntop:\nTDX R1\nADD.INT32 R2, R1, R1\n"
                    f"LOOP top\nSTOP")
    res = launch(_dcfg(max_steps=3 * trip + 8), prog, grid=(1,), block=16)
    assert res.engine == "trace"
    assert res.profile()["engine_fallback"] == "megakernel-unroll-cap"


def test_auto_engine_falls_back_to_step_for_runaway_programs():
    runaway = assemble("top:\nTDX R1\nJMP top")
    res = launch(_dcfg(max_steps=50), runaway, grid=(1,), block=16)
    assert res.engine == "step"
    assert not res.halted and res.steps == 50


def test_forced_trace_engine_matches_step_on_fuel_limited_program():
    # fuel-limited (non-halting) traces still replay exactly
    runaway = assemble("top:\nTDX R1\nADD.INT32 R2, R1, R1\nSTO R2, (R1)+0\nJMP top")
    outs = {e: launch(_dcfg(max_steps=47, engine=e), runaway, grid=(3,),
                      block=16) for e in ("step", "trace")}
    _assert_launches_identical(outs["step"], outs["trace"])
    assert not outs["trace"].halted


def test_compile_cache_is_keyed_and_hit():
    prog = assemble("TDX R1\nSTO R1, (R1)+0\nSTOP")
    cfg = SMConfig(n_threads=16, dim_x=16, shmem_depth=64, max_steps=100)
    s1 = compile_program(prog, cfg)
    s2 = compile_program(prog.words, cfg)
    assert s1 is s2                       # same (program, SMConfig) key
    cfg2 = dataclasses.replace(cfg, n_threads=32, dim_x=32)
    assert compile_program(prog, cfg2) is not s1
    # NOP/control compiled out: only TDX + STO remain
    assert s1.n_steps == 2 and s1.halted


@pytest.fixture
def persistent_cache(tmp_path, monkeypatch):
    """An isolated on-disk compile cache, torn down after the test (the
    cache is opt-in: other tests must never see it)."""
    from repro.core import compile_cache
    from repro.core.cycles import _trace_cached

    monkeypatch.setenv("EGPU_JAX_CACHE", "0")   # keep jax's cache out
    cc = compile_cache.configure(str(tmp_path / "cache"))
    _trace_cached.cache_clear()                 # force disk consultation
    yield cc
    compile_cache.configure(None)
    _trace_cached.cache_clear()


def test_persistent_cache_miss_then_hit(persistent_cache):
    from repro.core.cycles import _trace_cached

    prog = assemble("TDX R1\nSTO R1, (R1)+0\nSTOP")
    tr1 = program_trace(prog, 16)
    st = persistent_cache.stats
    assert st.misses >= 1 and st.stores >= 1 and st.hits == 0
    # a fresh process is simulated by clearing the in-memory LRU: the
    # walk must now be SERVED from disk, not recomputed
    _trace_cached.cache_clear()
    tr2 = program_trace(prog, 16)
    assert persistent_cache.stats.hits >= 1
    assert tr2 == tr1                  # served artifact is the same walk
    # a different config is a different key — miss, not a stale hit
    _trace_cached.cache_clear()
    program_trace(prog, 32)
    assert persistent_cache.stats.misses >= 2


def test_persistent_cache_corrupt_entry_is_miss_and_quarantined(
        persistent_cache, tmp_path):
    import os
    import pickle

    from repro.core import compile_cache
    from repro.core.cycles import _trace_cached

    prog = assemble("TDX R1\nSTO R1, (R1)+0\nSTOP")
    program_trace(prog, 16)
    entries = [os.path.join(r, f)
               for r, _, fs in os.walk(persistent_cache.path)
               for f in fs if f.endswith(".pkl")]
    assert len(entries) == 1
    # truncated garbage: load must be a counted error->miss, the entry
    # unlinked, and the launch path never sees an exception
    with open(entries[0], "wb") as fh:
        fh.write(b"\x80\x04 truncated garbage")
    _trace_cached.cache_clear()
    tr = program_trace(prog, 16)
    assert tr.halted and tr.steps == 3
    st = persistent_cache.stats
    assert st.errors >= 1
    assert not os.path.exists(entries[0]) or \
        compile_cache.load(compile_cache.key_for(
            "trace", prog.words, (16, 512, 100_000))) is not None
    # wrong-key (foreign) entries are rejected the same way
    key = compile_cache.key_for("trace", prog.words, (16, 512, 100_000))
    f = persistent_cache._file(key)
    with open(f, "wb") as fh:
        pickle.dump({"magic": "egpu-compile-cache", "format": 1,
                     "key": "someone-else", "value": 42}, fh)
    _trace_cached.cache_clear()
    assert program_trace(prog, 16) == tr
    assert persistent_cache.stats.errors >= 2


def test_persistent_cache_disabled_without_configuration(tmp_path,
                                                         monkeypatch):
    from repro.core import compile_cache

    monkeypatch.delenv("EGPU_CACHE_DIR", raising=False)
    compile_cache.configure(None)
    assert compile_cache.active() is None
    assert compile_cache.load("deadbeef") is None
    compile_cache.store("deadbeef", 1)          # silent no-op
    assert compile_cache.stats() is None


def test_bogus_engine_rejected():
    with pytest.raises(ValueError, match="engine"):
        DeviceConfig(engine="warp")
    prog = assemble("STOP")
    with pytest.raises(ValueError, match="engine"):
        launch(_dcfg(), prog, grid=(1,), block=16, engine="warp")


# ---------------------------------------------------------------------------
# per-Kernel imem/shmem overrides
# ---------------------------------------------------------------------------

def test_kernel_override_exceeding_device_ceiling_rejected():
    prog = assemble("STOP").words
    for field in ("imem_depth", "shmem_depth"):
        kern = Kernel(prog, block=16, **{field: 1 << 20})
        with pytest.raises(ValueError, match="exceeds the device ceiling"):
            launch(_dcfg(), programs=[kern], grid_map=[0])
        with pytest.raises(ValueError, match="must be >= 1"):
            launch(_dcfg(), programs=[Kernel(prog, block=16, **{field: 0})],
                   grid_map=[0])


def test_kernel_imem_override_bounds_program_length():
    long_prog = assemble("\n".join(["NOP"] * 40 + ["STOP"])).words
    with pytest.raises(ValueError, match="exceeds I-MEM depth"):
        launch(_dcfg(), programs=[Kernel(long_prog, block=16,
                                         imem_depth=32)], grid_map=[0])
    # fits the override: runs normally
    res = launch(_dcfg(), programs=[Kernel(long_prog, block=16,
                                           imem_depth=64)], grid_map=[0])
    assert res.halted


@pytest.mark.parametrize("engine", ["step", "trace"])
def test_kernel_overrides_per_program_in_mixed_grid(engine):
    # BOTH programs of one heterogeneous launch carry their own override:
    # each block is bounds-checked at ITS program's depth even when the
    # merged trace path stacks them into one device-depth wave batch
    prog = assemble("TDX R1\nSTO R1, (R1)+0\nSTOP").words
    kerns = [Kernel(prog, block=64, name="a", shmem_depth=16),
             Kernel(prog, block=64, name="b", shmem_depth=48,
                    imem_depth=32)]
    res = launch(_dcfg(n_sms=2, engine=engine, shmem_depth=64),
                 programs=kerns, grid_map=[0, 1, 1, 0])
    if engine == "trace":
        assert res.trace_merge is not None     # the merged path ran
    oob = np.asarray(res.oob)
    assert oob.all()                 # 64 threads overflow both overrides
    sh = np.asarray(res.shmem)
    assert sh.shape[1] == 64         # padded back to the device depth
    for b, depth in zip(range(4), (16, 48, 48, 16)):
        np.testing.assert_array_equal(sh[b, :depth], np.arange(depth))
        np.testing.assert_array_equal(sh[b, depth:], 0)


def test_kernel_override_ceiling_rejected_in_mixed_grid():
    # the ceiling check runs per program of a heterogeneous launch too
    prog = assemble("STOP").words
    kerns = [Kernel(prog, block=16),
             Kernel(prog, block=16, shmem_depth=1 << 20)]
    with pytest.raises(ValueError, match="program 1 exceeds the device "
                                         "ceiling"):
        launch(_dcfg(), programs=kerns, grid_map=[0, 1])


@pytest.mark.parametrize("engine", ["step", "trace"])
def test_kernel_shmem_override_tightens_oob_and_pads_result(engine):
    # thread t stores to address t: legal at the device depth (64), but
    # threads >= 32 are out of range under a shmem_depth=32 override
    prog = assemble("TDX R1\nSTO R1, (R1)+0\nSTOP").words
    kerns = [Kernel(prog, block=64, name="small", shmem_depth=32),
             Kernel(prog, block=64, name="full")]
    res = launch(_dcfg(engine=engine, shmem_depth=64),
                 programs=kerns, grid_map=[0, 1])
    assert bool(np.asarray(res.oob)[0]) and not bool(np.asarray(res.oob)[1])
    sh = np.asarray(res.shmem)
    assert sh.shape[1] == 64              # padded back to the device depth
    np.testing.assert_array_equal(sh[0, :32], np.arange(32))
    np.testing.assert_array_equal(sh[0, 32:], 0)   # dropped + padding
    np.testing.assert_array_equal(sh[1], np.arange(64))


# ---------------------------------------------------------------------------
# priority dispatch
# ---------------------------------------------------------------------------

def _prio_traces():
    long_p = assemble("INIT 60\ntop:\nSTO R1, (R0)+0\nLOOP top\nSTOP").words
    short_p = assemble("STO R1, (R0)+0\nSTOP").words
    return (program_trace(long_p, 256), program_trace(short_p, 64))


def test_priority_zero_is_bit_identical_to_fifo():
    long_t, short_t = _prio_traces()
    traces = [short_t] * 5 + [long_t] + [short_t] * 3
    base = schedule_blocks(traces, 2, "dynamic")
    prio = schedule_blocks(traces, 2, "dynamic",
                           priority_of=[0] * len(traces))
    for f in ("block_sm", "block_start", "block_finish", "block_wait"):
        np.testing.assert_array_equal(getattr(base, f), getattr(prio, f))
    assert base.makespan == prio.makespan


def test_priority_pulls_high_priority_blocks_first():
    long_t, short_t = _prio_traces()
    # back-loaded queue: the long block sits LAST in grid order
    traces = [short_t] * 6 + [long_t]
    prio = [0] * 6 + [5]
    fifo = schedule_blocks(traces, 2, "dynamic")
    sched = schedule_blocks(traces, 2, "dynamic", priority_of=prio)
    assert int(sched.block_start[6]) == 0     # pulled immediately
    assert sched.makespan < fifo.makespan
    # every block still runs exactly once
    assert int(sched.sm_blocks.sum()) == len(traces)


@pytest.mark.parametrize("engine", ["step", "trace"])
def test_priority_is_timing_only(engine):
    # functional state must be invariant to the priority discipline
    prog = assemble(auto_nop("""
        PID R1
        BID R2
        LOD R3, #16
        MUL.INT32 R4, R1, R3
        ADD.INT32 R5, R4, R2
        GST R5, (R5)+0 {w1,d1}
        STOP
    """, 16)).words
    gmap = [0, 0, 1, 0, 1]
    outs = {}
    for pri in (0, 7):
        kerns = [Kernel(prog, block=16, name="a"),
                 Kernel(prog, block=16, name="b", priority=pri)]
        outs[pri] = launch(_dcfg(n_sms=2, engine=engine), programs=kerns,
                           grid_map=gmap, schedule="dynamic")
    np.testing.assert_array_equal(np.asarray(outs[0].gmem),
                                  np.asarray(outs[7].gmem))
    np.testing.assert_array_equal(np.asarray(outs[0].regs),
                                  np.asarray(outs[7].regs))


def test_prioritized_mixed_launch_beats_backloaded_fifo():
    from repro.core.programs import launch_fft_qrd

    xs = np.ones((6, 64), np.complex64)
    As = np.stack([np.eye(16, dtype=np.float32)] * 3)
    _, _, _, fifo = launch_fft_qrd(xs, As, schedule="dynamic",
                                   interleave=False)
    _, _, _, prio = launch_fft_qrd(xs, As, schedule="dynamic",
                                   interleave=False, priorities=(0, 1))
    assert prio.cycles < fifo.cycles
    np.testing.assert_array_equal(np.asarray(fifo.shmem),
                                  np.asarray(prio.shmem))
