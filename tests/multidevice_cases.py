"""Multi-device test payloads, run in a subprocess with 8 host devices.

Invoked by test_distributed.py as:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 python multidevice_cases.py <case>
Prints "PASS <case>" on success; any exception exits nonzero.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402
import tempfile  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import RunConfig, get_arch  # noqa: E402
from repro.data import PipelineSpec, make_batch  # noqa: E402
from repro.launch import shardings as sh  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train import init_state, make_compressed_dp_step, make_train_step  # noqa: E402

KEY = jax.random.PRNGKey(0)


def _setup():
    cfg = get_arch("granite-3-2b", smoke=True)
    model = build_model(cfg)
    rc = RunConfig(learning_rate=1e-3, warmup_steps=0, weight_decay=0.0)
    spec = PipelineSpec(vocab=cfg.vocab_size, seq_len=32, global_batch=8,
                        seed=0)
    batch = make_batch(cfg, spec, 0)
    return cfg, model, rc, batch


def case_gspmd_matches_single():
    """A (2 data x 4 model) sharded train step == unsharded step."""
    cfg, model, rc, batch = _setup()
    state = init_state(model, KEY, rc)
    step = make_train_step(model, rc, 100)
    s1, m1 = jax.jit(step)(state, batch)

    mesh = make_mesh((2, 4), ("data", "model"))
    with mesh:
        st_sh = sh.state_shardings(mesh, state)
        b_sh = sh.batch_shardings(mesh, batch)
        state_d = jax.device_put(state, st_sh)
        batch_d = jax.device_put(batch, b_sh)
        s2, m2 = jax.jit(step, in_shardings=(st_sh, b_sh),
                         out_shardings=(st_sh, None))(state_d, batch_d)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5, \
        (float(m1["loss"]), float(m2["loss"]))
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        # fp32 reduction order differs across shardings: 1e-4 absorbs it
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    print("PASS gspmd_matches_single")


def case_compressed_dp():
    """int8-EF compressed DP step: loss matches uncompressed within the
    quantization tolerance and keeps improving."""
    cfg, model, rc, batch = _setup()
    state = init_state(model, KEY, rc)
    mesh = make_mesh((8,), ("data",))
    with mesh:
        comp_step = make_compressed_dp_step(model, rc, mesh, 100)
        plain_step = make_train_step(model, rc, 100)
        s_ref, m_ref = jax.jit(plain_step)(state, batch)
        s_c, m_c = comp_step(state, batch)
        assert abs(float(m_ref["loss"]) - float(m_c["loss"])) < 1e-4
        # params close to the uncompressed update (int8 grid tolerance)
        ref = np.concatenate([np.asarray(x).ravel() for x in
                              jax.tree_util.tree_leaves(s_ref.params)])
        got = np.concatenate([np.asarray(x).ravel() for x in
                              jax.tree_util.tree_leaves(s_c.params)])
        assert np.abs(ref - got).max() < 5e-3, np.abs(ref - got).max()
        # and repeated compressed steps on a FIXED batch keep decreasing
        # loss (error feedback does not stall optimization)
        s = s_c
        losses = [float(m_c["loss"])]
        for _ in range(5):
            s, m = comp_step(s, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.01, losses
    print("PASS compressed_dp")


def case_pipeline_parallel():
    """8-stage pipeline == sequential forward; grads flow (GPipe autodiff)."""
    from repro.train.pipeline import pipeline_apply, stack_stages

    mesh = make_mesh((8,), ("stage",))
    D, L, M, B = 16, 8, 4, 2
    keys = jax.random.split(KEY, L)
    layer_params = {
        "w": jnp.stack([jax.random.normal(k, (D, D)) / np.sqrt(D)
                        for k in keys]),
        "b": jnp.zeros((L, D)),
    }

    def one_layer(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    def stage_fn(p, x):  # L/S = 1 layer per stage
        def body(h, lp):
            return one_layer(lp, h), None
        h, _ = jax.lax.scan(body, x, p)
        return h

    x = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))

    # sequential reference
    def seq(params, x):
        def body(h, lp):
            return one_layer(lp, h), None
        h, _ = jax.lax.scan(body, x.reshape(M * B, D), params)
        return h.reshape(M, B, D)

    ref = seq(layer_params, x)
    staged = stack_stages(layer_params, 8)
    with mesh:
        got = pipeline_apply(mesh, stage_fn, staged, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)

    # gradients through the pipeline == sequential gradients
    def loss_pp(sp):
        with mesh:
            return jnp.sum(pipeline_apply(mesh, stage_fn, sp, x) ** 2)

    def loss_seq(lp):
        return jnp.sum(seq(lp, x) ** 2)

    g_pp = jax.grad(loss_pp)(staged)
    g_seq = jax.grad(loss_seq)(layer_params)
    np.testing.assert_allclose(
        np.asarray(g_pp["w"]).reshape(L, D, D), np.asarray(g_seq["w"]),
        atol=1e-4)
    print("PASS pipeline_parallel")


def case_elastic_checkpoint():
    """Save while sharded on (4,2); restore onto (2,4) and (1,1) meshes.

    The batch must be sharded along the data axis on every mesh (as in
    ``case_gspmd_matches_single``): jitting with an UNSHARDED batch leaves
    GSPMD free to pick a degenerate partitioning for the loss reductions
    (the "involuntary full rematerialization" path), which perturbs the
    fp32 accumulation order by ~1e-2 — that, not the restore, was this
    case's historical failure; restored leaves are bit-identical.
    """
    from repro.checkpoint import ckpt

    cfg, model, rc, batch = _setup()
    state = init_state(model, KEY, rc)
    step = make_train_step(model, rc, 100)
    mesh_a = make_mesh((4, 2), ("data", "model"))
    with mesh_a:
        st_sh = sh.state_shardings(mesh_a, state)
        b_sh = sh.batch_shardings(mesh_a, batch)
        state_a = jax.device_put(state, st_sh)
        batch_a = jax.device_put(batch, b_sh)
        state_a, _ = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None))(state_a, batch_a)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, state_a, {"step": 1})
        # restore onto a DIFFERENT mesh shape (elastic rescale)
        mesh_b = make_mesh((2, 4), ("data", "model"))
        with mesh_b:
            st_sh_b = sh.state_shardings(mesh_b, state)
            b_sh_b = sh.batch_shardings(mesh_b, batch)
            restored, _ = ckpt.restore(d, state, shardings=st_sh_b)
            batch_b = jax.device_put(batch, b_sh_b)
            _, m_b = jax.jit(step, in_shardings=(st_sh_b, b_sh_b),
                             out_shardings=(st_sh_b, None))(restored, batch_b)
        # and onto a single device
        restored_1, _ = ckpt.restore(d, state)
        _, m_1 = jax.jit(step)(restored_1, batch)
    assert abs(float(m_b["loss"]) - float(m_1["loss"])) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(state_a.params),
                    jax.tree_util.tree_leaves(restored_1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("PASS elastic_checkpoint")


def case_decode_sharded():
    """Sharded serve_step equals single-device decode."""
    cfg, model, rc, _ = _setup()
    params = model.init(KEY)
    B = 8
    caches = model.init_decode_caches(B, 64)
    tok = jnp.arange(B, dtype=jnp.int32).reshape(B, 1) % cfg.vocab_size

    ref_logits, _ = jax.jit(model.decode_step)(params, caches, tok,
                                               jnp.int32(0))
    mesh = make_mesh((2, 4), ("data", "model"))
    with mesh:
        p_sh = sh.param_shardings(mesh, params)
        c_sh = sh.cache_shardings(mesh, caches, B)
        params_d = jax.device_put(params, p_sh)
        caches_d = jax.device_put(caches, c_sh)
        got, _ = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, jnp.int32(0)),
            in_shardings=(p_sh, c_sh, None),
            out_shardings=(None, c_sh))(params_d, caches_d, tok)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits),
                               atol=3e-5)
    print("PASS decode_sharded")


CASES = {f[5:]: globals()[f] for f in list(globals())
         if f.startswith("case_")}

if __name__ == "__main__":
    CASES[sys.argv[1]]()
