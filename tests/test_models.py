"""Per-architecture smoke tests (reduced configs, one fwd/train step on CPU)
+ model-math unit tests (SSD recurrence, RG-LRU, MoE router, attention)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _smoke_batch(cfg, B=2, S=32):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.1 * jax.random.normal(
            KEY, (B, cfg.num_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(name):
    """One forward + one SGD step on the reduced config: shapes + no NaNs."""
    cfg = get_arch(name, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _smoke_batch(cfg)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), name
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)

    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), name
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), name
    # gradient step reduces loss on the same batch
    params2 = jax.tree_util.tree_map(lambda p, g: p - 0.5 * g, params, grads)
    loss2, _ = jax.jit(model.loss)(params2, batch)
    assert float(loss2) < float(loss), (name, float(loss), float(loss2))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_decode_step(name):
    cfg = get_arch(name, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    B = 2
    caches = model.init_decode_caches(B, 64)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, caches2 = jax.jit(model.decode_step)(params, caches, tok,
                                                 jnp.int32(0))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    # a second step with the updated cache
    logits2, _ = jax.jit(model.decode_step)(params, caches2, tok, jnp.int32(1))
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("name", ["yi-6b", "deepseek-moe-16b", "mamba2-780m",
                                  "recurrentgemma-2b"])
def test_decode_matches_full_forward(name):
    cfg = get_arch(name, smoke=True)
    if cfg.n_experts:
        # dropless capacity: capacity-overflow drops are batch-size dependent
        # (a real MoE semantic, not a bug), so disable them for this check
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    full = jax.jit(model.forward)(params, batch)
    caches = model.init_decode_caches(B, S)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, caches = step(params, caches, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=5e-5)


def test_full_configs_have_exact_published_dims():
    a = ARCHS
    assert (a["mamba2-780m"].n_layers, a["mamba2-780m"].d_model,
            a["mamba2-780m"].ssm_state, a["mamba2-780m"].vocab_size) \
        == (48, 1536, 128, 50280)
    assert (a["internvl2-76b"].n_layers, a["internvl2-76b"].d_model,
            a["internvl2-76b"].n_heads, a["internvl2-76b"].n_kv_heads,
            a["internvl2-76b"].d_ff, a["internvl2-76b"].vocab_size) \
        == (80, 8192, 64, 8, 28672, 128256)
    assert (a["yi-6b"].n_layers, a["yi-6b"].d_model, a["yi-6b"].n_kv_heads,
            a["yi-6b"].d_ff, a["yi-6b"].vocab_size) \
        == (32, 4096, 4, 11008, 64000)
    assert (a["qwen1.5-32b"].n_layers, a["qwen1.5-32b"].d_model,
            a["qwen1.5-32b"].n_kv_heads, a["qwen1.5-32b"].d_ff,
            a["qwen1.5-32b"].qkv_bias) == (64, 5120, 40, 27392, True)
    assert (a["granite-3-2b"].n_layers, a["granite-3-2b"].d_model,
            a["granite-3-2b"].n_kv_heads, a["granite-3-2b"].vocab_size) \
        == (40, 2048, 8, 49155)
    assert (a["qwen2.5-32b"].n_layers, a["qwen2.5-32b"].d_ff,
            a["qwen2.5-32b"].n_kv_heads) == (64, 27648, 8)
    assert (a["phi3.5-moe-42b-a6.6b"].n_experts,
            a["phi3.5-moe-42b-a6.6b"].top_k,
            a["phi3.5-moe-42b-a6.6b"].d_ff) == (16, 2, 6400)
    assert (a["deepseek-moe-16b"].n_experts, a["deepseek-moe-16b"].top_k,
            a["deepseek-moe-16b"].n_shared_experts,
            a["deepseek-moe-16b"].d_ff) == (64, 6, 2, 1408)
    assert (a["recurrentgemma-2b"].block_pattern,
            a["recurrentgemma-2b"].window,
            a["recurrentgemma-2b"].vocab_size) \
        == (("rec", "rec", "attn"), 2048, 256000)
    assert (a["whisper-tiny"].encoder_layers, a["whisper-tiny"].d_model,
            a["whisper-tiny"].vocab_size) == (4, 384, 51865)


def test_vocab_padding_divisible_by_tp16():
    for cfg in ARCHS.values():
        assert cfg.padded_vocab % 16 == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_long_context_skip_rules():
    shape = SHAPES["long_500k"]
    runnable = {n for n, c in ARCHS.items()
                if shape_applicable(c, shape)[0]}
    assert runnable == {"mamba2-780m", "recurrentgemma-2b"}
    for n, c in ARCHS.items():
        ok, why = shape_applicable(c, SHAPES["train_4k"])
        assert ok, (n, why)


# ---------------------------------------------------------------------------
# layer math
# ---------------------------------------------------------------------------

def test_ssd_matches_naive_recurrence():
    from repro.models.ssm import ssd_scan

    cfg = dataclasses.replace(get_arch("mamba2-780m", smoke=True), ssm_chunk=16)
    rng = np.random.default_rng(0)
    b, L, H, P = 2, 64, cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    x = jnp.asarray(rng.standard_normal((b, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, L, H)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, L, G, N)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, L, G, N)) * 0.3, jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 1, (H,)), jnp.float32)
    y, hT = ssd_scan(cfg, x, dt, B, C, a_log)

    A = -np.exp(np.asarray(a_log))
    rep = H // G
    h = np.zeros((b, H, P, N))
    Br = np.repeat(np.asarray(B), rep, axis=2)
    Cr = np.repeat(np.asarray(C), rep, axis=2)
    xn, dtn = np.asarray(x, np.float64), np.asarray(dt, np.float64)
    for t in range(L):
        dA = np.exp(dtn[:, t] * A[None])
        h = h * dA[..., None, None] \
            + (dtn[:, t][..., None] * xn[:, t])[..., None] * Br[:, t][:, :, None, :]
        np.testing.assert_allclose(np.asarray(y)[:, t],
                                   np.einsum("bhpn,bhn->bhp", h, Cr[:, t]),
                                   atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), h, atol=1e-4)


def test_ssd_chunk_size_invariance():
    from repro.models.ssm import ssd_scan

    cfg = get_arch("mamba2-780m", smoke=True)
    rng = np.random.default_rng(1)
    b, L, H, P = 1, 64, cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    args = (jnp.asarray(rng.standard_normal((b, L, H, P)), jnp.float32),
            jnp.asarray(rng.uniform(0.01, 0.2, (b, L, H)), jnp.float32),
            jnp.asarray(rng.standard_normal((b, L, G, N)) * .3, jnp.float32),
            jnp.asarray(rng.standard_normal((b, L, G, N)) * .3, jnp.float32),
            jnp.asarray(rng.uniform(-1, 1, (H,)), jnp.float32))
    y16, _ = ssd_scan(dataclasses.replace(cfg, ssm_chunk=16), *args)
    y64, _ = ssd_scan(dataclasses.replace(cfg, ssm_chunk=64), *args)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), atol=1e-5)


def test_rglru_scan_matches_step():
    from repro.models.rglru import rglru, rglru_params, rglru_step

    cfg = get_arch("recurrentgemma-2b", smoke=True)
    p = rglru_params(KEY, cfg, jnp.float32)
    x = 0.5 * jax.random.normal(KEY, (2, 16, cfg.lru_width))
    y_scan, h_last = rglru(p, x)
    h = jnp.zeros((2, cfg.lru_width))
    ys = []
    for t in range(16):
        yt, h = rglru_step(p, x[:, t:t + 1], h)
        ys.append(yt[:, 0])
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_seq), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h), atol=1e-5)


def test_rglru_stability():
    # |a| < 1 by construction: long inputs cannot blow up
    from repro.models.rglru import rglru, rglru_params

    cfg = get_arch("recurrentgemma-2b", smoke=True)
    p = rglru_params(KEY, cfg, jnp.float32)
    x = jnp.ones((1, 2048, cfg.lru_width))
    y, h = rglru(p, x)
    assert bool(jnp.isfinite(y).all()) and float(jnp.abs(h).max()) < 1e3


def test_moe_router_invariants():
    from repro.models.moe import route_topk

    rng = np.random.default_rng(2)
    T, E, k, C = 128, 8, 2, 48
    logits = jnp.asarray(rng.standard_normal((T, E)), jnp.float32)
    plan = route_topk(logits, k, C)
    st = np.asarray(plan["slot_token"])
    keep = np.asarray(plan["keep"])
    expert = np.asarray(plan["expert"])
    slot = np.asarray(plan["slot"])
    gate = np.asarray(plan["gate"])
    # slot table is consistent: every kept (token, choice) appears at its
    # (expert, slot) and nowhere else
    for t in range(T):
        for j in range(k):
            if keep[t, j]:
                assert st[expert[t, j], slot[t, j]] == t
    # occupied slots are unique tokens; empty slots are -1
    occ = st[st >= 0]
    assert len(occ) == keep.sum()
    assert (slot[keep] < C).all()
    # gates renormalized over the k picks
    np.testing.assert_allclose(gate.sum(-1), 1.0, atol=1e-5)
    assert float(plan["aux"]) > 0
    # with ample capacity every token is fully routed
    plan2 = route_topk(logits, k, T * k)
    assert bool(np.asarray(plan2["keep"]).all())


def test_moe_capacity_drops_overflow():
    from repro.models.moe import route_topk

    # all tokens want expert 0 -> only `capacity` of them get slots
    logits = jnp.tile(jnp.asarray([[10.0, 0, 0, 0]]), (64, 1))
    C = 8
    plan = route_topk(logits, 1, C)
    assert int(np.asarray(plan["keep"]).sum()) == C
    # priority order: the first C tokens win their slots
    assert bool(np.asarray(plan["keep"])[:C].all())


def test_attention_causality():
    from repro.models.attention import attention, attn_params

    cfg = get_arch("yi-6b", smoke=True)
    p = attn_params(KEY, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.head_dim, jnp.float32)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model))
    pos = jnp.arange(16)[None]
    y1, _ = attention(p, x, pos, cfg)
    x2 = x.at[:, 10:].set(0.0)  # future perturbation
    y2, _ = attention(p, x2, pos, cfg)
    np.testing.assert_allclose(np.asarray(y1[:, :10]), np.asarray(y2[:, :10]),
                               atol=1e-5)


def test_local_window_attention_band():
    from repro.models.attention import attention, attn_params

    cfg = get_arch("recurrentgemma-2b", smoke=True)
    p = attn_params(KEY, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.head_dim, jnp.float32)
    x = jax.random.normal(KEY, (1, 128, cfg.d_model))
    pos = jnp.arange(128)[None]
    y1, _ = attention(p, x, pos, cfg, window=cfg.window)
    # perturbing a token outside the window of position 127 changes nothing
    x2 = x.at[:, 0].set(0.0)
    y2, _ = attention(p, x2, pos, cfg, window=cfg.window)
    np.testing.assert_allclose(np.asarray(y1[:, -1]), np.asarray(y2[:, -1]),
                               atol=1e-5)
