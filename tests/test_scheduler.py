"""Dynamic block scheduler tests: trace fidelity, per-SM sequencers, the
work-queue vs lockstep-wave disciplines, and the scheduler invariants.

Marked ``scheduler`` (with the golden cycle tests) so CI can run the
cycle-model regression set on its own: ``pytest -m scheduler``.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    DeviceConfig,
    Kernel,
    SMConfig,
    assemble,
    launch,
    program_trace,
    schedule_blocks,
)
from repro.core.assembler import auto_nop
from repro.core.isa import Depth, Instr, Op, Typ, Width

pytestmark = pytest.mark.scheduler

RNG = np.random.default_rng(11)


def _dcfg(n_sms=4, gdepth=256, **sm_kw):
    sm_kw.setdefault("max_steps", 5000)
    return DeviceConfig(n_sms=n_sms, global_mem_depth=gdepth,
                        sm=SMConfig(**sm_kw))


# ---------------------------------------------------------------------------
# trace fidelity: the host-side sequencer walk == the traced device machine
# ---------------------------------------------------------------------------

def _programs_under_test():
    from repro.core.programs.fft import fft_program
    from repro.core.programs.qrd import qrd_asm_loop
    from repro.core.programs.reduction import reduction_grid_asm
    from repro.core.programs.saxpy import saxpy_grid_program

    return [
        ("saxpy", saxpy_grid_program(64, 16), 16, 16),
        ("fft64-loop", fft_program(64), 32, 32),
        ("fft32-unrolled", fft_program(32, unroll=True), 16, 16),
        ("qrd-loop", assemble(qrd_asm_loop()), 256, 16),
        ("reduction", assemble(reduction_grid_asm(64, 0, 64, True)), 64, 64),
    ]


_CASES = _programs_under_test()


@pytest.mark.parametrize("name,prog,block,dim_x", _CASES,
                         ids=[c[0] for c in _CASES])
def test_trace_cycles_match_lockstep_machine(name, prog, block, dim_x):
    # one block: trace.cycles == the device machine's cycles; steps too
    dcfg = _dcfg(n_sms=1, gdepth=512, shmem_depth=1024, max_steps=50_000)
    res = launch(dcfg, prog, grid=(1,), block=block, dim_x=dim_x)
    tr = program_trace(prog, block, imem_depth=dcfg.sm.imem_depth,
                       max_steps=dcfg.sm.max_steps)
    assert tr.halted and res.halted
    assert tr.cycles == res.cycles, name
    assert tr.steps == res.steps, name
    # n-block lockstep wave: static_cycles(n) == the wave machine's cycles
    for n_sms in (2, 3):
        dcfg_n = _dcfg(n_sms=n_sms, gdepth=512, shmem_depth=1024,
                       max_steps=50_000)
        res_n = launch(dcfg_n, prog, grid=(n_sms,), block=block, dim_x=dim_x)
        assert tr.static_cycles(n_sms) == res_n.cycles, (name, n_sms)


def test_trace_by_class_matches_machine():
    prog = assemble(auto_nop("""
        BID R1
        GLD R2, (R1)+0
        ADD.FP32 R3, R2, R2
        GST R3, (R1)+16
        STO R3, (R1)+0
        STOP
    """, 16))
    tr = program_trace(prog, 16)
    res = launch(_dcfg(n_sms=3), prog, grid=(3,), block=16)
    # the lockstep wave charges GMEM at wave_n x; the trace knows that view
    assert tr.cycles_by_class(wave_n=3) == \
        [int(c) for c in res.cycles_by_class]


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------

def _word_strategy():
    ops = st.sampled_from([Op.ADD, Op.MUL, Op.LODI, Op.TDX, Op.NOP,
                           Op.LOD, Op.STO, Op.GLD, Op.GST, Op.DOT])
    return st.builds(
        lambda op, typ, w, d: Instr(
            op=op, typ=typ, rd=1, ra=2, rb=3, width=w, depth=d),
        ops, st.sampled_from(list(Typ)), st.sampled_from(list(Width)),
        st.sampled_from(list(Depth)))


@st.composite
def _trace_set(draw):
    n_programs = draw(st.integers(1, 3))
    progs = []
    for _ in range(n_programs):
        instrs = draw(st.lists(_word_strategy(), min_size=1, max_size=12))
        instrs.append(Instr(op=Op.STOP))
        n_threads = draw(st.sampled_from([16, 64, 256]))
        words = np.array([i.encode() for i in instrs], np.int64)
        progs.append(program_trace(words, n_threads))
    gmap = draw(st.lists(st.integers(0, n_programs - 1),
                         min_size=1, max_size=12))
    n_sms = draw(st.integers(1, 5))
    return [progs[k] for k in gmap], n_sms


@settings(max_examples=150, deadline=None)
@given(ts=_trace_set())
def test_every_block_scheduled_exactly_once_and_dynamic_never_slower(ts):
    traces, n_sms = ts
    stat = schedule_blocks(traces, n_sms, "static")
    dyn = schedule_blocks(traces, n_sms, "dynamic")
    for s in (stat, dyn):
        # every block assigned to exactly one SM, executed exactly once
        assert s.block_sm.shape == (len(traces),)
        assert (s.block_sm >= 0).all() and (s.block_sm < n_sms).all()
        assert int(s.sm_blocks.sum()) == len(traces)
        # timeline sanity: finish = start + busy + wait, inside the makespan
        np.testing.assert_array_equal(
            s.block_finish, s.block_start + s.block_busy + s.block_wait)
        assert (s.block_finish <= s.makespan).all()
        assert (s.sm_idle >= 0).all()
        # busy is schedule-independent (it is the trace's own cost)
        np.testing.assert_array_equal(
            s.block_busy, [t.cycles for t in traces])
    # the acceptance property: work-queue dispatch never loses to waves
    assert dyn.makespan <= stat.makespan


@settings(max_examples=60, deadline=None)
@given(ts=_trace_set(), seed=st.integers(0, 2**31 - 1))
def test_schedule_invariant_to_dispatch_permutation_within_program(ts, seed):
    """Permuting same-trace blocks in the queue never changes the makespan
    multiset story: total busy is conserved and every block still runs."""
    traces, n_sms = ts
    perm = np.random.default_rng(seed).permutation(len(traces))
    base = schedule_blocks(traces, n_sms, "dynamic")
    shuf = schedule_blocks([traces[i] for i in perm], n_sms, "dynamic")
    assert int(base.sm_busy.sum()) == int(shuf.sm_busy.sum())
    assert int(base.sm_blocks.sum()) == int(shuf.sm_blocks.sum())


# ---------------------------------------------------------------------------
# launch-level: fast path vs dynamic, functional invariance
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_blocks=st.integers(1, 6),
       n_sms=st.integers(1, 4))
def test_homogeneous_fast_path_vs_dynamic_bit_identical_property(
        seed, n_blocks, n_sms):
    """Any homogeneous launch: the lockstep fast path and the dynamic
    scheduler produce bit-identical architectural state."""
    rng = np.random.default_rng(seed)
    ops = [Op.ADD, Op.MUL, Op.LODI, Op.TDX, Op.BID, Op.LOD, Op.STO,
           Op.GLD, Op.GST]
    instrs = [Instr(op=ops[int(rng.integers(0, len(ops)))],
                    typ=Typ(int(rng.integers(0, 3))),
                    rd=int(rng.integers(0, 16)), ra=0,
                    rb=int(rng.integers(0, 16)),
                    imm=int(rng.integers(0, 16)),
                    width=Width(int(rng.integers(0, 4))),
                    depth=Depth(int(rng.integers(0, 4))))
              for _ in range(int(rng.integers(1, 10)))]
    instrs.append(Instr(op=Op.STOP))
    words = np.array([i.encode() for i in instrs], np.int64)
    gmem = rng.standard_normal(64).astype(np.float32)
    dcfg = _dcfg(n_sms=n_sms, gdepth=64, shmem_depth=64, max_steps=200)
    res_s = launch(dcfg, words, grid=(n_blocks,), block=16, gmem=gmem,
                   schedule="static")
    res_d = launch(dcfg, words, grid=(n_blocks,), block=16, gmem=gmem,
                   schedule="dynamic")
    np.testing.assert_array_equal(np.asarray(res_s.regs),
                                  np.asarray(res_d.regs))
    np.testing.assert_array_equal(np.asarray(res_s.shmem),
                                  np.asarray(res_d.shmem))
    np.testing.assert_array_equal(np.asarray(res_s.gmem),
                                  np.asarray(res_d.gmem))
    np.testing.assert_array_equal(np.asarray(res_s.oob),
                                  np.asarray(res_d.oob))
    assert res_d.cycles <= res_s.cycles == res_d.static_cycles


def test_homogeneous_dynamic_bit_identical_to_lockstep_fast_path():
    prog = assemble(auto_nop("""
        BID R7
        TDX R1
        LOD R8, #16
        MUL.INT32 R9, R7, R8
        ADD.INT32 R1, R9, R1
        GLD R2, (R1)+0
        ADD.FP32 R3, R2, R2
        GST R3, (R1)+96
        STO R3, (R1)+0
        STOP
    """, 16))
    gmem = RNG.standard_normal(256).astype(np.float32)
    dcfg = _dcfg(n_sms=4, shmem_depth=256)
    res_s = launch(dcfg, prog, grid=(6,), block=16, gmem=gmem,
                   schedule="static")
    res_d = launch(dcfg, prog, grid=(6,), block=16, gmem=gmem,
                   schedule="dynamic")
    assert res_s.schedule == "static" and res_d.schedule == "dynamic"
    # architectural state is invariant to the dispatch discipline
    np.testing.assert_array_equal(np.asarray(res_s.regs),
                                  np.asarray(res_d.regs))
    np.testing.assert_array_equal(np.asarray(res_s.shmem),
                                  np.asarray(res_d.shmem))
    np.testing.assert_array_equal(np.asarray(res_s.gmem),
                                  np.asarray(res_d.gmem))
    # and dynamic cycles never exceed the wave schedule's
    assert res_d.cycles <= res_s.cycles == res_d.static_cycles


def test_heterogeneous_results_invariant_to_grid_map_permutation():
    # two programs writing disjoint gmem slots keyed by PID and BID
    prog = assemble(auto_nop("""
        BID R1
        PID R2
        LOD R3, #32
        MUL.INT32 R4, R2, R3
        ADD.INT32 R5, R4, R1
        LOD R6, #100
        ADD.INT32 R7, R6, R1
        GST R7, (R5)+0 {w1,d1}
        STOP
    """, 16)).words
    kernels = [Kernel(prog, block=16, name="a"),
               Kernel(prog, block=16, name="b")]
    gmap = [0, 1, 0, 0, 1, 1, 0]
    base = launch(_dcfg(), programs=kernels, grid_map=gmap)
    want = np.asarray(base.gmem)
    rng = np.random.default_rng(3)
    for _ in range(4):
        perm = list(rng.permutation(gmap))
        res = launch(_dcfg(), programs=kernels, grid_map=perm)
        np.testing.assert_array_equal(np.asarray(res.gmem), want)


def test_barrier_kernel_waits_for_all_prior_blocks():
    slow = assemble("INIT 50\ntop:\nSTO R1, (R0)+0\nLOOP top\nSTOP").words
    fast = assemble("GST R1, (R0)+1 {w1,d1}\nSTOP").words
    res = launch(_dcfg(n_sms=2),
                 programs=[Kernel(slow, block=64, name="slow"),
                           Kernel(fast, block=16, name="fast",
                                  barrier=True)],
                 grid_map=[0, 0, 0, 1])
    t = res.timing
    fence = max(int(c) for c in t.block_finish[:3])
    assert int(t.block_start[3]) >= fence


def test_dynamic_backfills_imbalanced_grid():
    # 1 long block + 6 short ones on 2 SMs: waves idle an SM while the
    # long block runs; the queue keeps it busy
    long_p = assemble("INIT 100\ntop:\nSTO R1, (R0)+0\nLOOP top\nSTOP").words
    short_p = assemble("STO R1, (R0)+0\nSTOP").words
    kernels = [Kernel(long_p, block=256, name="long"),
               Kernel(short_p, block=256, name="short")]
    gmap = [0] + [1] * 6
    res_d = launch(_dcfg(n_sms=2), programs=kernels, grid_map=gmap,
                   schedule="dynamic")
    res_s = launch(_dcfg(n_sms=2), programs=kernels, grid_map=gmap,
                   schedule="static")
    assert res_d.cycles < res_s.cycles
    assert res_d.static_cycles == res_s.cycles  # same wave baseline


def test_fused_reduction_matches_two_launch_and_numpy():
    from repro.core.programs import launch_reduction

    x = RNG.standard_normal(4096).astype(np.float32)
    tot_fused, res = launch_reduction(x, block=512, fused=True)
    tot_two, _ = launch_reduction(x, block=512, fused=False)
    assert tot_fused == tot_two                      # bit-identical folds
    np.testing.assert_allclose(tot_fused, float(x.sum()), rtol=1e-4)
    assert res.schedule == "dynamic"
    names = list(res.profile()["per_program"])
    assert names == ["reduce.stage1", "reduce.stage2"]
