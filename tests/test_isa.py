"""ISA encoding/decoding + assembler unit & property tests."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import assemble, check_hazards, disassemble
from repro.core.assembler import AsmError, assemble_line
from repro.core.isa import (
    CONTROL_IMM_OPS,
    NUM_CLASSES,
    Depth,
    Instr,
    Op,
    Typ,
    Width,
    instr_class,
)


def test_encode_decode_roundtrip_basic():
    ins = Instr(op=Op.ADD, typ=Typ.FP32, rd=1, ra=2, rb=3,
                width=Width.HALF, depth=Depth.SINGLE)
    assert Instr.decode(ins.encode()) == ins


def test_word_is_40_bits():
    ins = Instr(op=Op.STOP, width=Width.SINGLE, depth=Depth.SINGLE,
                typ=Typ.FP32, rd=15, ra=15, rb=15, imm=-1)
    w = ins.encode()
    assert 0 <= w < (1 << 40)


@settings(max_examples=300, deadline=None)
@given(
    op=st.sampled_from(list(Op)),
    typ=st.sampled_from(list(Typ)),
    rd=st.integers(0, 15), ra=st.integers(0, 15), rb=st.integers(0, 15),
    imm=st.integers(-(1 << 14), (1 << 14) - 1),
    width=st.sampled_from(list(Width)),
    depth=st.sampled_from(list(Depth)),
)
def test_encode_decode_roundtrip_property(op, typ, rd, ra, rb, imm, width, depth):
    if op in (Op.JMP, Op.JSR, Op.LOOP, Op.INIT):
        imm = abs(imm)  # control addresses are unsigned
    ins = Instr(op=op, typ=typ, rd=rd, ra=ra, rb=rb, imm=imm,
                width=width, depth=depth)
    dec = Instr.decode(ins.encode())
    assert dec == ins


@settings(max_examples=200, deadline=None)
@given(rd=st.integers(0, 15), ra=st.integers(0, 15), rb=st.integers(0, 15),
       ea=st.integers(0, 31), eb=st.integers(0, 31))
def test_snoop_roundtrip_property(rd, ra, rb, ea, eb):
    ins = Instr(op=Op.ADD, typ=Typ.FP32, rd=rd, ra=ra, rb=rb, x=1,
                ext_a=ea, ext_b=eb)
    assert Instr.decode(ins.encode()) == ins


def test_snoop_excludes_immediate():
    with pytest.raises(ValueError):
        Instr(op=Op.ADD, x=1, ext_a=1, imm=5).encode()


def test_imm_range_checked():
    with pytest.raises(ValueError):
        Instr(op=Op.LODI, imm=1 << 15).encode()


def test_signed_imm_rejects_sign_extension_range():
    # regression: encode used to accept [2^14, 2^15) for signed-immediate
    # ops, but decode sign-extends bit 14, so those values round-tripped
    # negative. The encode-time check now matches decode.
    for op in (Op.LODI, Op.LOD, Op.STO, Op.GLD, Op.GST, Op.ADD):
        with pytest.raises(ValueError):
            Instr(op=op, imm=1 << 14).encode()
        with pytest.raises(ValueError):
            Instr(op=op, imm=(1 << 15) - 1).encode()
        # the boundary values round-trip exactly
        for imm in (-(1 << 14), (1 << 14) - 1, -1, 0):
            assert Instr.decode(Instr(op=op, imm=imm).encode()).imm == imm


def test_control_imm_full_unsigned_range():
    for op in CONTROL_IMM_OPS:
        assert Instr.decode(Instr(op=op, imm=(1 << 15) - 1).encode()).imm \
            == (1 << 15) - 1
        with pytest.raises(ValueError):
            Instr(op=op, imm=1 << 15).encode()
        with pytest.raises(ValueError):
            Instr(op=op, imm=-1).encode()


def test_new_device_ops_roundtrip():
    for op in (Op.GLD, Op.GST):
        ins = Instr(op=op, rd=3, ra=5, imm=-17, width=Width.SINGLE,
                    depth=Depth.SINGLE)
        assert Instr.decode(ins.encode()) == ins
    ins = Instr(op=Op.BID, rd=9)
    assert Instr.decode(ins.encode()) == ins


def test_assemble_basic_program():
    prog = assemble("""
        TDX R1
        LOD R2, (R1)+0
        ADD.FP32 R3, R2, R2 {w8,dhalf}
        STO R3, (R1)+16
        STOP
    """)
    assert len(prog) == 5
    assert prog.instrs[2].width == Width.HALF
    assert prog.instrs[2].depth == Depth.HALF


def test_assemble_labels_and_loops():
    prog = assemble("""
        INIT 4
    top:
        NOP
        LOOP top
        JMP end
        NOP
    end:
        STOP
    """)
    assert prog.labels["top"] == 1
    assert prog.instrs[2].imm == 1
    assert prog.instrs[3].imm == 5


def test_assemble_snoop_syntax():
    prog = assemble("ADD.FP32 R1, R2@3, R4@7 {d1}")
    ins = prog.instrs[0]
    assert ins.x == 1 and ins.ext_a == 3 and ins.ext_b == 7


def test_assembler_errors():
    for bad in ["FROB R1, R2, R3", "ADD.FP32 R1, R2", "LOD R99, #1",
                "STO R1, #5", "JMP nowhere", "ADD.FP32 R1, R2@99, R3"]:
        with pytest.raises(AsmError):
            assemble(bad)


def test_disassemble_smoke():
    src = ["ADD.FP32 R1, R2, R3", "LOD R2, (R1)+5", "STO R2, (R3)+0",
           "LOD R4, #-7", "DOT.FP32 R1, R2, R3", "STOP"]
    for s in src:
        prog = assemble(s)
        d = disassemble(int(prog.words[0]))
        prog2 = assemble(d)
        assert prog2.words[0] == prog.words[0], (s, d)


@settings(max_examples=100, deadline=None)
@given(op=st.sampled_from(list(Op)), typ=st.sampled_from(list(Typ)))
def test_instr_class_total(op, typ):
    assert 0 <= instr_class(op, typ) < NUM_CLASSES


def test_hazard_checker_flags_raw():
    prog = assemble("""
        TDX R1
        ADD.INT32 R2, R1, R1
        STOP
    """)
    warns = check_hazards(prog, n_threads=16)  # 1 wavefront: gap 1 < 9
    assert warns
    prog2 = assemble("TDX R1\n" + "NOP\n" * 8 + "ADD.INT32 R2, R1, R1\nSTOP")
    assert not check_hazards(prog2, n_threads=16)


def test_auto_nop_converges_and_clean():
    from repro.core.assembler import auto_nop

    text = """
        TDX R1
        ADD.INT32 R2, R1, R1
        MUL.FP32 R3, R2, R2
        STO R3, (R1)+0
        LOD R4, (R1)+0
        STOP
    """
    padded = auto_nop(text, n_threads=16)
    assert not check_hazards(assemble(padded), n_threads=16)
