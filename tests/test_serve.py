"""Serving-layer suite (``pytest -m serve``).

Covers both serving levels:

* the slot-based decode ``Engine`` — the two PR 7 bugfix regressions
  (budget off-by-one that emitted ``max_new_tokens + 1`` tokens; queued
  requests silently dropped from results) plus the full
  eos/budget/capacity termination story, slot reuse, and FIFO queued
  admission;
* the device-level ``LaunchServer`` — continuous-batching correctness
  against numpy references, deterministic virtual-time accounting (same
  trace => same per-request cycle counts), priority-aware admission,
  backpressure under both admission policies, solo dispatch of
  buffer-carrying requests, the threaded batcher, and the host
  dispatch-latency cycle model surfaced through ``profile()``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import DeviceConfig, SMConfig, launch
from repro.core.programs.fft import bitrev_indices, fft_kernel, fft_shmem
from repro.core.programs.qrd import Q_BASE, R_BASE, qrd_kernel, qrd_shmem
from repro.models import build_model
from repro.serve import Engine, LaunchRequest, LaunchServer, QueueFull, Request

pytestmark = pytest.mark.serve

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# decode engine: termination + admission
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm():
    cfg = get_arch("granite-3-2b", smoke=True)
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def _prompt(cfg, rng, n):
    return rng.integers(0, cfg.vocab_size, n)


def test_budget_counts_all_emitted_tokens(lm):
    """PR 7 regression: max_new_tokens bounds ALL emitted tokens. The
    pre-fix engine budgeted the decode loop separately from the
    prefill-sampled first token and emitted max_new_tokens + 1."""
    cfg, model, params = lm
    eng = Engine(model, params, max_slots=2, capacity=64)
    rng = np.random.default_rng(0)
    for rid, budget in enumerate((3, 1, 0)):
        eng.submit(Request(rid=rid, prompt=_prompt(cfg, rng, 4 + rid),
                           max_new_tokens=budget))
    outs = eng.run_until_done()
    assert len(outs[0]) == 3            # pre-fix: 4
    assert len(outs[1]) == 1            # prefill token alone spends it all
    assert len(outs[2]) == 0            # zero budget emits nothing
    assert all(r.finish_reason == "budget" for r in eng.requests.values())


def test_unadmitted_requests_are_reported(lm):
    """PR 7 regression: a queued request that never reaches a slot must
    appear in the results with finish_reason='unadmitted'. The pre-fix
    engine only registered requests on slot admission, so run_until_done
    silently dropped it."""
    cfg, model, params = lm
    eng = Engine(model, params, max_slots=1, capacity=64)
    rng = np.random.default_rng(1)
    eng.submit(Request(rid=0, prompt=_prompt(cfg, rng, 4),
                       max_new_tokens=50))
    eng.submit(Request(rid=1, prompt=_prompt(cfg, rng, 5),
                       max_new_tokens=2))
    outs = eng.run_until_done(max_steps=3)   # rid 0 hogs the only slot
    assert sorted(outs) == [0, 1]            # pre-fix: rid 1 absent
    assert not eng.requests[0].done          # mid-decode, not finished
    assert eng.requests[1].finish_reason == "unadmitted"
    assert outs[1] == []


def test_eos_termination(lm):
    """Replaying a decoded token as eos_id stops the request early with
    finish_reason='eos' — and the emitted prefix is unchanged (greedy
    decode is deterministic)."""
    cfg, model, params = lm
    rng = np.random.default_rng(2)
    prompt = _prompt(cfg, rng, 6)

    ref = Engine(model, params, max_slots=1, capacity=64)
    ref.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    toks = ref.run_until_done()[0]
    assert ref.requests[0].finish_reason == "budget" and len(toks) == 6

    eos = toks[1]
    eng = Engine(model, params, max_slots=1, capacity=64)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6, eos_id=eos))
    out = eng.run_until_done()[0]
    assert eng.requests[0].finish_reason == "eos"
    assert out[-1] == eos
    assert out == toks[:len(out)]            # same greedy prefix
    assert len(out) == toks.index(eos) + 1   # stops at FIRST occurrence


def test_capacity_termination(lm):
    """Cache-row exhaustion truncates the request with
    finish_reason='capacity' instead of decoding past the KV rows."""
    cfg, model, params = lm
    rng = np.random.default_rng(3)
    eng = Engine(model, params, max_slots=1, capacity=16)
    eng.submit(Request(rid=0, prompt=_prompt(cfg, rng, 8),
                       max_new_tokens=50))
    out = eng.run_until_done()[0]
    assert eng.requests[0].finish_reason == "capacity"
    # prefill token + decode up to position capacity-1: 8 tokens, not 50
    assert len(out) == 8


def test_slot_reuse_after_completion(lm):
    """More requests than slots all complete: freed slots are reused."""
    cfg, model, params = lm
    eng = Engine(model, params, max_slots=2, capacity=64)
    rng = np.random.default_rng(4)
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=_prompt(cfg, rng, 3 + rid),
                           max_new_tokens=3))
    outs = eng.run_until_done()
    assert sorted(outs) == list(range(5))
    assert all(len(v) == 3 for v in outs.values())
    assert all(r.finish_reason == "budget" for r in eng.requests.values())
    assert max(eng.active_history) <= 2      # never more than the slots
    assert not eng.active.any() and not eng.slot_of and not eng.pending


def test_queued_admission_is_fifo(lm):
    """Queued requests take the freed slot in submission order."""
    cfg, model, params = lm
    eng = Engine(model, params, max_slots=1, capacity=64)
    rng = np.random.default_rng(5)
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=_prompt(cfg, rng, 4),
                           max_new_tokens=3))
    order = [next(iter(eng.slot_of))]
    for _ in range(20):
        if not eng.active.any() and not eng.pending:
            break
        eng.step()
        for rid in eng.slot_of:
            if rid != order[-1]:
                order.append(rid)
    assert order == [0, 1, 2]
    assert all(r.done for r in eng.requests.values())


# ---------------------------------------------------------------------------
# LaunchServer: continuous batching of device launches
# ---------------------------------------------------------------------------

def _small_dcfg(**kw):
    """Tiny device for FFT-16 traffic (block of 8 threads)."""
    sm = SMConfig(shmem_depth=64, max_steps=200_000)
    return DeviceConfig(n_sms=2, global_mem_depth=128, sm=sm, **kw)


def _fft16_req(rng, **kw):
    x = (rng.standard_normal(16)
         + 1j * rng.standard_normal(16)).astype(np.complex64)
    return x, LaunchRequest(kernel=fft_kernel(16),
                            shmem=fft_shmem(x, 64), **kw)


def _fft_out(r, n):
    mem = np.asarray(r.shmem_f32())[0]
    out = np.empty(n, np.complex64)
    out[bitrev_indices(n)] = mem[0:2 * n:2] + 1j * mem[1:2 * n:2]
    return out


def test_launch_server_merges_heterogeneous_batch():
    """FFT-64 and QRD-16 tenants coalesce into ONE merged launch and
    every request gets its own correct result slice back."""
    dcfg = DeviceConfig(
        n_sms=4, global_mem_depth=64,
        sm=SMConfig(shmem_depth=1024, imem_depth=1024, max_steps=200_000))
    server = LaunchServer(dcfg, max_batch=8)
    rng = np.random.default_rng(0)
    xs = [(rng.standard_normal(64)
           + 1j * rng.standard_normal(64)).astype(np.complex64)
          for _ in range(3)]
    As = [rng.standard_normal((16, 16)).astype(np.float32) for _ in range(2)]
    futs = [server.submit(LaunchRequest(kernel=fft_kernel(64),
                                        shmem=fft_shmem(x, 1024)))
            for x in xs]
    futs += [server.submit(LaunchRequest(kernel=qrd_kernel(),
                                         shmem=qrd_shmem(a, 1024)))
             for a in As]
    assert server.drain() == 5
    results = [f.result() for f in futs]
    assert all(r.batch_size == 5 and r.batch_id == 0 for r in results)
    for x, r in zip(xs, results[:3]):
        np.testing.assert_allclose(_fft_out(r, 64), np.fft.fft(x),
                                   atol=1e-4)
    for a, r in zip(As, results[3:]):
        mem = np.asarray(r.shmem_f32())[0]
        q = mem[Q_BASE:Q_BASE + 256].reshape(16, 16).T
        rr = mem[R_BASE:R_BASE + 256].reshape(16, 16)
        np.testing.assert_allclose(q @ rr, a, atol=1e-4)
    # the cycle story is consistent and the profile rode along
    for r in results:
        assert r.latency_cycles == r.wait_cycles + r.cycles
        assert r.finish_cycle == r.dispatch_cycle + r.cycles
        assert r.profile["schedule"] in ("static", "dynamic")
    s = server.stats()
    assert s["batches"] == 1 and s["completed"] == 5 and s["pending"] == 0


def _serve_trace(server):
    """Submit a fixed 6-request FFT-16 trace with arrivals + priorities;
    returns the ServeResults in submission order."""
    rng = np.random.default_rng(7)
    futs = []
    for arrival, prio in ((0, 0), (100, 0), (5000, 2), (5100, 0),
                          (5200, 0), (20000, 1)):
        kern = fft_kernel(16)
        if prio:
            kern = dataclasses.replace(kern, priority=prio)
        x = (rng.standard_normal(16)
             + 1j * rng.standard_normal(16)).astype(np.complex64)
        futs.append(server.submit(LaunchRequest(
            kernel=kern, shmem=fft_shmem(x, 64), arrival_cycle=arrival)))
    server.drain()
    return [f.result() for f in futs]


def test_launch_server_determinism():
    """Same request trace => same per-request cycle counts, batch by
    batch — the virtual clock is wall-clock independent."""
    a = _serve_trace(LaunchServer(_small_dcfg(), max_batch=4,
                                  schedule="dynamic"))
    b = _serve_trace(LaunchServer(_small_dcfg(), max_batch=4,
                                  schedule="dynamic"))
    for ra, rb in zip(a, b):
        assert (ra.cycles, ra.wait_cycles, ra.latency_cycles,
                ra.dispatch_cycle, ra.finish_cycle, ra.batch_id,
                ra.batch_size) == \
               (rb.cycles, rb.wait_cycles, rb.latency_cycles,
                rb.dispatch_cycle, rb.finish_cycle, rb.batch_id,
                rb.batch_size)
    # arrivals are honored: nobody dispatches before arriving
    assert all(r.dispatch_cycle >= r.arrival_cycle for r in a)


def test_priority_enters_earlier_batch():
    """A high-priority tenant submitted LAST still rides the FIRST batch
    (admission ordering), ahead of earlier normal requests."""
    server = LaunchServer(_small_dcfg(), max_batch=2, schedule="dynamic")
    rng = np.random.default_rng(8)
    futs = [server.submit(_fft16_req(rng, arrival_cycle=0)[1])
            for _ in range(3)]
    kern = dataclasses.replace(fft_kernel(16), priority=5)
    x = (rng.standard_normal(16)
         + 1j * rng.standard_normal(16)).astype(np.complex64)
    prio_fut = server.submit(LaunchRequest(kernel=kern,
                                           shmem=fft_shmem(x, 64),
                                           arrival_cycle=0))
    server.drain()
    prio = prio_fut.result()
    normals = [f.result() for f in futs]
    assert prio.batch_id == 0                       # jumped the line
    assert sorted(r.batch_id for r in normals) == [0, 1, 1]
    # in-launch the same field reaches the dynamic dispatch heap
    assert prio.profile["priority_respected"] is True
    np.testing.assert_allclose(_fft_out(prio, 16), np.fft.fft(x), atol=1e-4)


def test_backpressure_reject():
    server = LaunchServer(_small_dcfg(), max_queue=2, admission="reject")
    rng = np.random.default_rng(9)
    server.submit(_fft16_req(rng)[1])
    server.submit(_fft16_req(rng)[1])
    with pytest.raises(QueueFull):
        server.submit(_fft16_req(rng)[1])
    assert server.stats()["rejected"] == 1
    assert server.drain() == 2


def test_backpressure_block_dispatches_inline():
    """Under admission='block' with no batcher thread, an over-full
    submit makes its own progress by dispatching a batch inline."""
    server = LaunchServer(_small_dcfg(), max_queue=2, admission="block",
                          max_batch=2)
    rng = np.random.default_rng(10)
    futs = [server.submit(_fft16_req(rng)[1]) for _ in range(3)]
    # the third submit had to dispatch the first batch to find room
    assert futs[0].done() and futs[1].done()
    assert server.queue_depth == 1
    server.drain()
    assert all(f.result().oob.any() == False for f in futs)  # noqa: E712
    assert server.stats()["rejected"] == 0


def test_buffer_requests_dispatch_solo():
    """A request carrying a private gmem image never merges with other
    tenants — it heads its own batch of 1 and gets gmem back."""
    server = LaunchServer(_small_dcfg(), max_batch=8)
    rng = np.random.default_rng(11)
    f_a = server.submit(_fft16_req(rng)[1])
    f_b = server.submit(_fft16_req(rng)[1])
    x, req = _fft16_req(rng)
    scratch = np.arange(16, dtype=np.uint32)
    f_solo = server.submit(dataclasses.replace(
        req, buffers={"scratch": scratch}))
    f_d = server.submit(_fft16_req(rng)[1])
    server.drain()
    solo = f_solo.result()
    assert solo.batch_size == 1
    assert solo.gmem is not None and solo.buffer_offsets is not None
    off, n = solo.buffer_offsets["scratch"]
    np.testing.assert_array_equal(np.asarray(solo.gmem)[off:off + n],
                                  scratch)
    np.testing.assert_allclose(_fft_out(solo, 16), np.fft.fft(x), atol=1e-4)
    # the normals before the solo merged; the one after ran separately
    assert f_a.result().batch_size == 2 and f_b.result().batch_size == 2
    assert f_d.result().batch_size == 1
    assert f_a.result().gmem is None


def test_threaded_server_round_trip():
    """The background batcher serves submissions from the client thread."""
    server = LaunchServer(_small_dcfg(), max_batch=4)
    server.start()
    try:
        rng = np.random.default_rng(12)
        xs, futs = [], []
        for _ in range(4):
            x, req = _fft16_req(rng)
            xs.append(x)
            futs.append(server.submit(req))
        results = [f.result(timeout=120) for f in futs]
    finally:
        server.stop()
    for x, r in zip(xs, results):
        np.testing.assert_allclose(_fft_out(r, 16), np.fft.fft(x),
                                   atol=1e-4)
    assert server.stats()["completed"] == 4 and server.queue_depth == 0
    assert all(r.finish_reason == "ok" for r in results)


def test_stop_without_drain_resolves_queued_futures_terminally():
    """PR 9 satellite: stop(drain=False) with requests still queued must
    resolve every pending Future to a terminal state — the pre-fix
    server raised QueueFull into them, and a submitter racing stop()
    could enqueue into the dead server and hang its client forever."""
    server = LaunchServer(_small_dcfg(), max_batch=4)
    server.start()
    rng = np.random.default_rng(21)
    # pile on more than one batch so something is still queued when the
    # batcher is told to stop
    futs = [server.submit(_fft16_req(rng)[1]) for _ in range(6)]
    server.stop(drain=False)
    for f in futs:
        r = f.result(timeout=60)            # terminal, never a hang
        assert r.finish_reason in ("ok", "unadmitted")
    st = server.stats()
    assert st["completed"] + st["unadmitted"] == 6
    assert server.queue_depth == 0
    # a submit AFTER stop (no restart) is unadmitted, already resolved
    late = server.submit(_fft16_req(rng)[1])
    assert late.done()
    assert late.result(timeout=1).finish_reason == "unadmitted"


def test_stop_with_drain_serves_every_queued_request():
    """stop() (drain=True) finishes the queue: every future resolves to
    a real result, none unadmitted."""
    server = LaunchServer(_small_dcfg(), max_batch=2)
    server.start()
    rng = np.random.default_rng(22)
    xs, futs = [], []
    for _ in range(5):
        x, req = _fft16_req(rng)
        xs.append(x)
        futs.append(server.submit(req))
    server.stop()
    results = [f.result(timeout=60) for f in futs]
    assert all(r.finish_reason == "ok" for r in results)
    for x, r in zip(xs, results):
        np.testing.assert_allclose(_fft_out(r, 16), np.fft.fft(x),
                                   atol=1e-4)
    assert server.stats()["completed"] == 5


def test_submitter_blocked_on_full_queue_survives_stop():
    """The hang scenario itself: a client thread blocked in submit()'s
    full-queue wait while stop() runs must come back with a terminal
    unadmitted result within a bounded join, not deadlock."""
    server = LaunchServer(_small_dcfg(), max_queue=1, admission="block",
                          max_batch=1)
    rng = np.random.default_rng(23)
    outcome: dict[str, object] = {}

    def blocked_submit():
        fut = server.submit(_fft16_req(rng)[1])
        outcome["result"] = fut.result(timeout=60)

    with server._lock:                  # hold the batcher off
        server.start()
        server.submit(_fft16_req(rng)[1])       # fills max_queue=1
        t = threading.Thread(target=blocked_submit, daemon=True)
        t.start()
        # wait until the submitter is parked in the full-queue wait
        deadline = time.time() + 30
        while time.time() < deadline:
            server._lock.release()
            time.sleep(0.01)
            server._lock.acquire()
            if len(server._queue) >= server.max_queue and t.is_alive():
                break
    server.stop(drain=False)
    t.join(timeout=60)
    assert not t.is_alive()             # the pre-fix code hangs here
    # depending on whether the batcher won the race for the lock, the
    # parked submitter is either served or turned away — but its future
    # is ALWAYS terminal
    res = outcome["result"]
    assert res.finish_reason in ("ok", "unadmitted")


# ---------------------------------------------------------------------------
# host dispatch-latency cycle model + static-priority visibility
# ---------------------------------------------------------------------------

def _one_fft16_launch(dcfg, *, queue_depth=0, schedule=None, priority=0):
    rng = np.random.default_rng(13)
    x = (rng.standard_normal(16)
         + 1j * rng.standard_normal(16)).astype(np.complex64)
    kern = fft_kernel(16)
    if priority:
        kern = dataclasses.replace(kern, priority=priority)
    return launch(dcfg, programs=[kern], grid_map=[0, 0],
                  shmem=[np.stack([fft_shmem(x, 64)] * 2)],
                  queue_depth=queue_depth, schedule=schedule)


def test_host_dispatch_latency_in_cycle_model():
    """dispatch_latency + queue_latency * depth is charged before the
    first block issues and surfaced in profile(); zero latencies stay
    bit-identical to the pre-serving device (no profile key)."""
    base = _one_fft16_launch(_small_dcfg())
    assert "host_dispatch" not in base.profile()

    dcfg = _small_dcfg(dispatch_latency=100, queue_latency=10)
    res = _one_fft16_launch(dcfg, queue_depth=3)
    hd = res.profile()["host_dispatch"]
    assert hd == {"queue_depth": 3, "dispatch_cycles": 100,
                  "queue_cycles": 30, "latency_cycles": 130}
    assert int(res.cycles) == int(base.cycles) + 130
    np.testing.assert_array_equal(np.asarray(res.timing.block_start),
                                  np.asarray(base.timing.block_start) + 130)
    # the charge scales with the queue depth the dispatch saw
    deeper = _one_fft16_launch(dcfg, queue_depth=10)
    assert int(deeper.cycles) == int(base.cycles) + 200
    # identical machine state either way: latency is schedule-only
    np.testing.assert_array_equal(np.asarray(res.shmem),
                                  np.asarray(base.shmem))


def test_static_schedule_surfaces_priority_loss():
    """PR 7 satellite: schedule='static' ignoring Kernel(priority=) is no
    longer silent — one UserWarning per process plus a per-launch
    profile()['priority_respected'] flag."""
    from repro.core import device as device_mod

    device_mod._STATIC_PRIORITY_WARNED = False
    with pytest.warns(UserWarning, match="priority"):
        res = _one_fft16_launch(_small_dcfg(), schedule="static",
                                priority=3)
    assert res.profile()["priority_respected"] is False
    # warn-once: the second prioritized static launch stays quiet
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        res2 = _one_fft16_launch(_small_dcfg(), schedule="static",
                                 priority=3)
    assert res2.profile()["priority_respected"] is False
    # dynamic dispatch honors the field; unprioritized static is fine too
    assert _one_fft16_launch(
        _small_dcfg(), schedule="dynamic",
        priority=3).profile()["priority_respected"] is True
    assert _one_fft16_launch(
        _small_dcfg(), schedule="static").profile()["priority_respected"] \
        is True
