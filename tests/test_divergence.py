"""SIMT divergence suite: predicated execution, proven three ways.

1. ISA surface: SETP/SELP encode/decode round-trips, the predication
   extension byte (bits 40-45) stays zero on legacy words, control ops
   reject guards at both the ``Instr`` and assembler layers.
2. Semantics vs a numpy oracle: every SETP condition x type, SELP's
   guard-as-selector rule, and the core masking contract — a
   predicated-off lane never mutates registers, shared memory, global
   memory, or the OOB flag (masked global lanes generate no port
   traffic, so even an out-of-range address on a masked lane is
   invisible).
3. Differential fuzz: random predicated programs (all-off / all-on /
   alternating / data-dependent masks) run through step, trace and
   megakernel engines and compared bit-identically against the
   inline-step oracle; plus the property fuzz that an all-off guard is
   architecturally a NOP and an all-on guard is bit-identical (cycles
   included — predication never changes timing) to the unguarded
   program.

Run standalone with ``pytest -m divergence``.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import DeviceConfig, SMConfig, launch
from repro.core.assembler import AsmError, assemble, auto_nop, disassemble
from repro.core.isa import Cond, Depth, Instr, Op, Typ, Width

from engine_conformance import assert_arch_identical, assert_bit_identical

pytestmark = pytest.mark.divergence


# ---------------------------------------------------------------------------
# ISA surface
# ---------------------------------------------------------------------------

def test_predicated_encode_decode_roundtrip():
    for op in (Op.ADD, Op.LOD, Op.STO, Op.GLD, Op.GST, Op.SELP, Op.SETP,
               Op.DOT, Op.INVSQR, Op.LODI, Op.TDX):
        ins = Instr(op=op, typ=Typ.INT32, rd=3, ra=1, rb=2,
                    imm=int(Cond.LT) if op == Op.SETP else 5,
                    pen=1, preg=9, pneg=1)
        back = Instr.decode(ins.encode())
        assert (back.pen, back.preg, back.pneg) == (1, 9, 1), op
        assert back.op == op


def test_legacy_words_carry_no_predication():
    # every pre-predication program encodes below bit 40; decode must see
    # pen=0 (predication is opt-in per instruction)
    from repro.core.programs.qrd import qrd_program

    for w in qrd_program().words:
        assert int(w) < (1 << 40)
        ins = Instr.decode(int(w))
        assert ins.pen == 0 and ins.preg == 0 and ins.pneg == 0


def test_control_ops_reject_predication():
    for op in (Op.JMP, Op.JSR, Op.LOOP, Op.INIT):
        with pytest.raises(ValueError):
            Instr(op=op, imm=1, pen=1, preg=2).encode()
    for op in (Op.RTS, Op.STOP, Op.NOP):
        with pytest.raises(ValueError):
            Instr(op=op, pen=1, preg=2).encode()
    with pytest.raises(AsmError):
        assemble("@R3 STOP")
    with pytest.raises(AsmError):
        assemble("top:\n@!R2 JMP top")


def test_predicated_disassembly_roundtrip():
    src = ("TDX R1\n"
           "SETP.LT.INT32 R3, R1, R2\n"
           "@R3 ADD.INT32 R4, R1, R1\n"
           "@!R3 SELP R5, R1, R2\n"
           "@R3 GST R4, (R1)+8")
    prog = assemble(src)
    texts = [disassemble(int(w)) for w in prog.words]
    assert texts[1] == "SETP.LT.INT32 R3, R1, R2"
    assert texts[2].startswith("@R3 ")
    assert texts[3].startswith("@!R3 SELP")
    # disassembled text re-assembles to the same words
    again = assemble("\n".join(texts))
    np.testing.assert_array_equal(prog.words, again.words)


# ---------------------------------------------------------------------------
# semantics vs numpy
# ---------------------------------------------------------------------------

def _run_block(src: str, *, block=16, gmem=None, depth=64, n_sms=1,
               grid=1, engine=None, backend=None):
    dev = DeviceConfig(n_sms=n_sms, global_mem_depth=depth,
                       sm=SMConfig(shmem_depth=64, max_steps=5_000),
                       engine=engine or "auto", backend=backend or "inline")
    return launch(dev, assemble(auto_nop(src, block)), grid=grid,
                  block=block, gmem=gmem)


_CONDS = {
    Cond.EQ: lambda a, b: a == b, Cond.NE: lambda a, b: a != b,
    Cond.LT: lambda a, b: a < b, Cond.LE: lambda a, b: a <= b,
    Cond.GT: lambda a, b: a > b, Cond.GE: lambda a, b: a >= b,
}


@pytest.mark.parametrize("cond", list(Cond))
@pytest.mark.parametrize("typ", [Typ.INT32, Typ.UINT32, Typ.FP32])
def test_setp_conditions_match_numpy(cond, typ):
    rng = np.random.default_rng(int(cond) * 8 + int(typ))
    if typ == Typ.FP32:
        vals = rng.standard_normal(16).astype(np.float32)
        a = np.float32(0.1)
        gmem = np.concatenate([vals, np.full(16, a, np.float32)])
        av, bv = np.full(16, a), vals
    else:
        bits = rng.integers(0, 1 << 32, 16, dtype=np.uint64).astype(np.uint32)
        bits[0] = 0x80000001          # sign-significant either way
        a = np.uint32(0x80000001)
        gmem = np.concatenate([bits, np.full(16, a, np.uint32)])
        if typ == Typ.INT32:
            av, bv = np.full(16, a).astype(np.int32), bits.view(np.int32)
        else:
            av, bv = np.full(16, a), bits
    src = (f"    TDX R1\n"
           f"    GLD R2, (R1)+16\n"
           f"    GLD R3, (R1)+0\n"
           f"    SETP.{cond.name}.{typ.name} R4, R2, R3\n"
           f"    STOP")
    res = _run_block(src, gmem=gmem)
    got = np.asarray(res.regs)[0, :16, 4]
    np.testing.assert_array_equal(got, _CONDS[cond](av, bv).astype(np.uint32))


def test_selp_guard_is_selector_not_write_mask():
    # SELP writes on EVERY active lane; the @-guard picks the arm. With
    # no guard (pen=0) it selects Ra.
    src = ("    TDX R1\n"
           "    LOD R2, #100\n"
           "    LOD R7, #1\n"
           "    AND R3, R1, R7\n"            # P = tid odd
           "    @R3 SELP R4, R2, R1\n"       # odd -> 100, even -> tid
           "    @!R3 SELP R5, R2, R1\n"      # odd -> tid, even -> 100
           "    SELP R6, R2, R1\n"           # pen=0 -> Ra everywhere
           "    STOP")
    regs = np.asarray(_run_block(src).regs)[0, :16]
    tid = np.arange(16, dtype=np.uint32)
    np.testing.assert_array_equal(regs[:, 4], np.where(tid % 2, 100, tid))
    np.testing.assert_array_equal(regs[:, 5], np.where(tid % 2, tid, 100))
    np.testing.assert_array_equal(regs[:, 6], np.full(16, 100, np.uint32))


def test_masked_lanes_mutate_nothing():
    # every masked structure at once: guarded ALU / LOD / STO / GLD / GST
    # on an alternating mask. Off lanes must keep registers, shared and
    # global words bit-exact.
    sentinel = np.arange(100, 164, dtype=np.uint32)
    src = ("    TDX R1\n"
           "    LOD R7, #1\n"
           "    AND R3, R1, R7\n"            # P = tid odd
           "    LOD R4, #7\n"                # R4 = 7 on all lanes first
           "    @R3 ADD.INT32 R4, R1, R1\n"  # odd lanes overwrite with 2*tid
           "    @R3 LOD R5, (R1)+0\n"        # shared load (shmem zeros)
           "    @R3 GLD R6, (R1)+16\n"       # global load of sentinel
           "    @R3 STO R4, (R1)+32\n"
           "    @R3 GST R4, (R1)+32\n"
           "    STOP")
    res = _run_block(src, gmem=sentinel)
    tid = np.arange(16, dtype=np.uint32)
    odd = (tid % 2).astype(bool)
    regs = np.asarray(res.regs)[0, :16]
    np.testing.assert_array_equal(regs[:, 4], np.where(odd, 2 * tid, 7))
    np.testing.assert_array_equal(regs[:, 6],
                                  np.where(odd, sentinel[16:32], 0))
    shmem = np.asarray(res.shmem)[0, 32:48]
    np.testing.assert_array_equal(shmem, np.where(odd, 2 * tid, 0))
    gmem = np.asarray(res.gmem)
    np.testing.assert_array_equal(gmem[32:48],
                                  np.where(odd, 2 * tid, sentinel[32:48]))
    # untouched global words keep their sentinel bits
    np.testing.assert_array_equal(gmem[48:], sentinel[48:])


def test_masked_global_lanes_generate_no_port_traffic():
    # off lanes with OUT-OF-RANGE global addresses: no write, no OOB —
    # a masked lane never reaches the port
    src = ("    TDX R1\n"
           "    LOD R7, #1\n"
           "    AND R3, R1, R7\n"
           "    LOD R2, #4000\n"             # far out of range (depth 64)
           "    @!R3 SELP R4, R2, R1\n"      # odd lanes: tid (valid addr)
           "    @R3 GST R1, (R4)+0\n"        # odd lanes store tid -> gmem[tid]
           "    STOP")
    res = _run_block(src)
    assert not bool(np.asarray(res.oob).any())
    tid = np.arange(16, dtype=np.uint32)
    np.testing.assert_array_equal(np.asarray(res.gmem)[:16],
                                  np.where(tid % 2, tid, 0))
    # flip the guard: now unmasked lanes DO address out of range -> OOB
    bad = src.replace("@R3 GST", "@!R3 GST")
    assert bool(np.asarray(_run_block(bad).oob).any())


def test_predicated_reduction_empty_wavefront_keeps_partial():
    # a wavefront whose lanes are all masked off leaves its lane-0
    # partial untouched (the masked_reduction kernel leans on this)
    src = ("    TDX R1\n"
           "    LOD R2, #5\n"
           "    LOD R3, #0\n"
           "    SETP.LT.INT32 R4, R1, R3\n"  # all-off mask (tid < 0)
           "    @R4 SUM.FP32 R5, R2, R0\n"
           "    STOP")
    regs = np.asarray(_run_block(src, block=32).regs)
    assert (regs[0, :32, 5] == 0).all()


def test_timing_is_mask_independent():
    # all-off, all-on and alternating guards on the same program must
    # report IDENTICAL cycle totals: predicated-off lanes still occupy
    # their issue/drain slots (cycles.py's predication rule)
    def prog(k):
        return ("    TDX R1\n"
                f"    LOD R7, #{k}\n"
                "    SETP.LT.INT32 R3, R1, R7\n"  # P = tid < k
                "    @R3 ADD.INT32 R4, R1, R1\n"
                "    @R3 STO R4, (R1)+0\n"
                "    @R3 GST R4, (R1)+16\n"
                "    @!R3 GST R1, (R1)+32\n"
                "    STOP")
    # k=0: all off; k=16: all on; k=8: divergent half-wavefront
    runs = [_run_block(prog(k), n_sms=2, grid=2) for k in (0, 16, 8)]
    assert len({r.cycles for r in runs}) == 1
    assert len({r.steps for r in runs}) == 1
    for r in runs[1:]:
        assert list(np.asarray(r.cycles_by_class)) \
            == list(np.asarray(runs[0].cycles_by_class))


def test_predicated_programs_launch_through_fleet():
    # the new program library must ride the fleet front door unchanged:
    # same blocks, two devices, bit-identical architectural state
    from repro.core.fleet import FleetConfig, launch_fleet
    from repro.core.programs.masked_reduction import launch_masked_reduction

    x = np.linspace(-2.0, 2.0, 96, dtype=np.float32)
    dev = DeviceConfig(n_sms=2, global_mem_depth=512,
                       sm=SMConfig(max_steps=50_000))
    s_dev, c_dev, res_dev = launch_masked_reduction(x, 0.5, clip=(-1.5, 1.5),
                                                    device=dev, block=32)
    fcfg = FleetConfig(n_devices=2, device=DeviceConfig(
        n_sms=1, global_mem_depth=512, sm=SMConfig(max_steps=50_000)))
    from repro.core.programs import masked_reduction as mr

    # rebuild the same two-stage grid against the fleet front door
    x_pad = np.zeros(96, np.float32)
    x_pad[:96] = x
    buffers = {"x": x_pad,
               "params": np.array([0.5, -1.5, 1.5], np.float32),
               "meta": np.array([96], np.int32),
               "partials": np.zeros(32, np.float32),
               "result": np.zeros(16, np.float32)}
    from repro.core import Kernel
    from repro.core.device import buffer_layout
    from repro.core.programs.reduction import reduction_grid_asm

    layout = buffer_layout(buffers)
    src, prm, meta, par, res_off = (
        layout[k][0] for k in ("x", "params", "meta", "partials", "result"))
    stage1 = mr.masked_reduction_program(32, src, par, prm, meta, 16)
    stage2 = assemble(reduction_grid_asm(16, par, res_off, True))
    res_fleet = launch_fleet(
        fcfg, programs=[Kernel(stage1, block=32, name="masked.stage1"),
                        Kernel(stage2, block=16, name="masked.stage2",
                               barrier=True)],
        grid_map=[0, 0, 0] + [1, 1], buffers=buffers)
    out = np.asarray(res_fleet.buffer("result"))
    assert float(out[0]) == pytest.approx(s_dev)
    assert int(round(float(out[1]))) == c_dev
    assert res_fleet.fleet["n_devices"] == 2


# ---------------------------------------------------------------------------
# differential fuzz vs the inline-step oracle
# ---------------------------------------------------------------------------

# predicable data ops (no GST: fuzz grids run 2 concurrent blocks that
# would race; the deterministic tests above cover predicated GST)
_PRED_OPS = [Op.ADD, Op.SUB, Op.MUL, Op.AND, Op.OR, Op.XOR, Op.LSL,
             Op.LSR, Op.LODI, Op.TDX, Op.TDY, Op.BID, Op.LOD, Op.STO,
             Op.GLD, Op.DOT, Op.SUM, Op.INVSQR, Op.SELP, Op.SETP]

# R14 carries the fuzz mask, R15 stays all-zero (never a destination)
_MASK_PROLOGUES = {
    "all_off": [Instr(op=Op.LODI, rd=14, imm=0)],
    "all_on": [Instr(op=Op.LODI, rd=14, imm=1)],
    "alternating": [Instr(op=Op.TDX, rd=14)],       # LSB of tid
    "data": [Instr(op=Op.TDX, rd=14),
             Instr(op=Op.LOD, rd=14, ra=14, imm=0)],  # LSB of shmem[tid]
}


def _pred_instr(draw, pen):
    op = draw(st.sampled_from(_PRED_OPS))
    imm = draw(st.integers(0, 5)) if op == Op.SETP \
        else draw(st.integers(0, 31))
    return Instr(op=op, typ=draw(st.sampled_from(list(Typ))),
                 rd=draw(st.integers(0, 13)), ra=draw(st.integers(0, 14)),
                 rb=draw(st.integers(0, 14)), imm=imm,
                 width=draw(st.sampled_from(list(Width))),
                 depth=draw(st.sampled_from(list(Depth))),
                 pen=pen,
                 preg=draw(st.integers(0, 14)) if pen else 0,
                 pneg=draw(st.integers(0, 1)) if pen else 0)


@st.composite
def _random_predicated_program(draw):
    """mask prologue | pre | INIT t; body; LOOP | STOP."""
    mask = draw(st.sampled_from(sorted(_MASK_PROLOGUES)))
    prog = list(_MASK_PROLOGUES[mask])
    prog += [_pred_instr(draw, draw(st.integers(0, 1)))
             for _ in range(draw(st.integers(0, 3)))]
    body = [_pred_instr(draw, draw(st.integers(0, 1)))
            for _ in range(draw(st.integers(1, 4)))]
    prog.append(Instr(op=Op.INIT, imm=draw(st.integers(1, 4))))
    body_start = len(prog)
    prog.extend(body)
    prog.append(Instr(op=Op.LOOP, imm=body_start))
    prog.append(Instr(op=Op.STOP))
    return np.array([i.encode() for i in prog], np.int64)


@settings(max_examples=30, deadline=None)
@given(prog=_random_predicated_program(), seed=st.integers(0, 2**31 - 1),
       n_sms=st.integers(1, 2),
       schedule=st.sampled_from(["static", "dynamic"]),
       block=st.sampled_from([16, 32]))
def test_fuzz_predicated_programs_conform(prog, seed, n_sms, schedule,
                                          block):
    rng = np.random.default_rng(seed)
    gmem = rng.standard_normal(64).astype(np.float32)
    shmem = rng.standard_normal((2, 64)).astype(np.float32)
    outs = {}
    for engine in ("step", "trace", "megakernel"):
        dcfg = DeviceConfig(n_sms=n_sms, global_mem_depth=64, engine=engine,
                            sm=SMConfig(shmem_depth=64, max_steps=500))
        outs[engine] = launch(dcfg, prog, grid=2, block=block, gmem=gmem,
                              shmem=shmem, schedule=schedule)
    assert_bit_identical(outs["step"], outs["trace"])
    assert_bit_identical(outs["step"], outs["megakernel"])


@st.composite
def _guarded_program(draw):
    """Every body instr guarded by R15 (all-zero): (guarded, nop_swapped,
    unguarded) word arrays with IDENTICAL instruction counts. SELP is
    excluded — its guard selects an arm instead of gating the write, so
    it is never architecturally a no-op."""
    from dataclasses import replace as dc_replace
    body = []
    for _ in range(draw(st.integers(1, 5))):
        i = _pred_instr(draw, 1)
        while i.op == Op.SELP:
            i = _pred_instr(draw, 1)
        body.append(dc_replace(i, preg=15))

    guarded = body + [Instr(op=Op.STOP)]
    nops = [Instr(op=Op.NOP) for _ in body] + [Instr(op=Op.STOP)]
    bare = [dc_replace(i, pen=0, preg=0, pneg=0) for i in body] \
        + [Instr(op=Op.STOP)]
    enc = lambda p: np.array([i.encode() for i in p], np.int64)  # noqa: E731
    pneg_any = any(i.pneg for i in body)
    return enc(guarded), enc(nops), enc(bare), pneg_any


@settings(max_examples=30, deadline=None)
@given(progs=_guarded_program(), seed=st.integers(0, 2**31 - 1))
def test_fuzz_all_off_guard_is_architectural_nop(progs, seed):
    guarded, nops, bare, pneg_any = progs
    rng = np.random.default_rng(seed)
    gmem = rng.standard_normal(64).astype(np.float32)
    shmem = rng.standard_normal((1, 64)).astype(np.float32)

    def go(words):
        dcfg = DeviceConfig(n_sms=1, global_mem_depth=64,
                            sm=SMConfig(shmem_depth=64, max_steps=200))
        return launch(dcfg, words, grid=1, block=32, gmem=gmem, shmem=shmem)

    res = go(guarded)
    if pneg_any:
        # mixed-polarity guards: at least each @R15 (all-off) instr is
        # dead, but the @!R15 ones are live -> only compare vs bare when
        # ALL polarities are negated
        if all(Instr.decode(int(w)).pneg for w in guarded[:-1]):
            assert_bit_identical(res, go(bare))   # all-ON: cycles too
    else:
        # masked lanes never mutate registers, shmem, or gmem
        assert_arch_identical(res, go(nops))


def test_all_on_guard_is_bit_identical_to_unguarded():
    # deterministic witness of the fuzz property's all-on arm, cycles
    # included: predication is free when every lane passes
    body = [Instr(op=Op.TDX, rd=1),
            Instr(op=Op.ADD, typ=Typ.INT32, rd=2, ra=1, rb=1),
            Instr(op=Op.STO, rd=2, ra=1, imm=0),
            Instr(op=Op.GST, rd=2, ra=1, imm=16)]
    guarded = [Instr(**{**i.__dict__, "pen": 1, "preg": 15, "pneg": 1})
               for i in body] + [Instr(op=Op.STOP)]
    bare = body + [Instr(op=Op.STOP)]
    enc = lambda p: np.array([i.encode() for i in p], np.int64)  # noqa: E731

    def go(words):
        dcfg = DeviceConfig(n_sms=2, global_mem_depth=64,
                            sm=SMConfig(shmem_depth=64, max_steps=200))
        return launch(dcfg, words, grid=2, block=16)

    assert_bit_identical(go(enc(guarded)), go(enc(bare)))


# ---------------------------------------------------------------------------
# the new program library, numerically
# ---------------------------------------------------------------------------

def test_cholesky_factors_spd_and_solves():
    rng = np.random.default_rng(0)
    g = rng.standard_normal((16, 16)).astype(np.float32)
    a = (g @ g.T + 16 * np.eye(16)).astype(np.float32)
    b = rng.standard_normal(16).astype(np.float32)
    from repro.core.programs.cholesky import run_cholesky

    el, y, _ = run_cholesky(a, b)
    ref = np.linalg.cholesky(a.astype(np.float64))
    assert np.abs(el - ref).max() < 1e-4
    assert np.all(el[np.triu_indices(16, 1)] == 0.0)  # masked stores: exact
    assert np.abs(el @ y - b).max() < 1e-4


def test_cholesky_skips_singular_pivot():
    rng = np.random.default_rng(1)
    g = rng.standard_normal((16, 16)).astype(np.float32)
    a = (g @ g.T + 16 * np.eye(16)).astype(np.float32)
    a[5, :] = 0.0
    a[:, 5] = 0.0                      # exactly singular pivot 5
    from repro.core.programs.cholesky import run_cholesky

    el, _, _ = run_cholesky(a)
    assert np.all(el[:, 5] == 0.0)     # the guarded column folded to zero
    keep = np.ones(16, bool)
    keep[5] = False
    r = (el @ el.T - a)[np.ix_(keep, keep)]
    assert np.abs(r).max() < 1e-4      # the rest factored normally


def test_masked_reduction_matches_numpy():
    rng = np.random.default_rng(2)
    x = (rng.standard_normal(300) * 3).astype(np.float32)
    from repro.core.programs.masked_reduction import launch_masked_reduction

    for t, clip in [(0.0, (-np.inf, np.inf)), (1.0, (-2.0, 2.0)),
                    (99.0, (-2.0, 2.0)), (-99.0, (-1.0, 1.0))]:
        s, c, _ = launch_masked_reduction(x, t, clip=clip, block=64)
        y = np.clip(x, clip[0], clip[1])
        m = y > t
        assert c == int(m.sum()), (t, clip)
        ref = float(np.sum(y[m], dtype=np.float64))
        assert s == pytest.approx(ref, abs=2e-3 * max(1.0, abs(ref)))
