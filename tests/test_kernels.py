"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _nan_aware_equal_u32(got, want):
    """Bitwise equality, except NaN float payloads compare as equal."""
    got_f = np.asarray(got).view(np.float32)
    want_f = np.asarray(want).view(np.float32)
    same_bits = np.asarray(got) == np.asarray(want)
    both_nan = np.isnan(got_f) & np.isnan(want_f)
    return bool(np.all(same_bits | both_nan))


# ---------------------------------------------------------------------------
# simt_alu
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", range(1, 10))
@pytest.mark.parametrize("typ", range(3))
def test_simt_alu_matches_ref(op, typ):
    a = jnp.asarray(RNG.integers(0, 2**32, (8, 512), dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**32, (8, 512), dtype=np.uint32))
    mask = jnp.asarray(RNG.integers(0, 2, (8, 512), dtype=np.uint32))
    old = jnp.asarray(RNG.integers(0, 2**32, (8, 512), dtype=np.uint32))
    got = ops.alu(op, typ, a, b, mask, old)
    want = jnp.where(mask != 0,
                     ref.alu_ref(jnp.int32(op), jnp.int32(typ), a, b), old)
    assert _nan_aware_equal_u32(got, want), (op, typ)


@pytest.mark.parametrize("n_sm,block", [(8, 8), (16, 8), (32, 16)])
def test_simt_alu_blocking_sweep(n_sm, block):
    a = jnp.asarray(RNG.integers(0, 2**10, (n_sm, 512), dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**10, (n_sm, 512), dtype=np.uint32))
    mask = jnp.ones((n_sm, 512), jnp.uint32)
    old = jnp.zeros((n_sm, 512), jnp.uint32)
    got = ops.alu(1, 0, a, b, mask, old, block_sm=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(a + b))


def test_simt_alu_fp_exactness():
    # FP32 results must be bit-exact IEEE754 ops
    af = RNG.standard_normal((8, 512)).astype(np.float32)
    bf = RNG.standard_normal((8, 512)).astype(np.float32)
    a = jnp.asarray(af.view(np.uint32))
    b = jnp.asarray(bf.view(np.uint32))
    ones = jnp.ones((8, 512), jnp.uint32)
    zeros = jnp.zeros((8, 512), jnp.uint32)
    got = np.asarray(ops.alu(3, 2, a, b, ones, zeros)).view(np.float32)
    np.testing.assert_array_equal(got, af * bf)


# ---------------------------------------------------------------------------
# wavefront_dot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", [0, 1])
@pytest.mark.parametrize("n_sm", [8, 24])
def test_wavefront_dot_sweep(mode, n_sm):
    a = jnp.asarray(RNG.standard_normal((n_sm, 512)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((n_sm, 512)), jnp.float32)
    m = jnp.asarray(RNG.integers(0, 2, (n_sm, 512)), jnp.float32)
    got = ops.dot(a, b, m, mode=mode)
    if mode == 0:
        want = ref.wavefront_dot_ref(a, b, m != 0)
    else:
        want = jnp.sum(jnp.where((m != 0).reshape(n_sm, 32, 16),
                                 (a + b).reshape(n_sm, 32, 16), 0.0), -1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


def test_wavefront_dot_flexible_width_mask():
    # quarter-width wavefronts: only lanes 0..3 contribute
    a = jnp.ones((8, 512), jnp.float32)
    b = jnp.ones((8, 512), jnp.float32)
    lane = np.tile(np.arange(16), 32 * 8).reshape(8, 512)
    m = jnp.asarray((lane < 4).astype(np.float32))
    got = ops.dot(a, b, m, mode=0)
    np.testing.assert_array_equal(np.asarray(got), np.full((8, 32), 4.0))


# ---------------------------------------------------------------------------
# mgs_qrd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch,n", [(32, 16), (64, 16), (32, 8), (32, 32)])
def test_mgs_qrd_sweep(batch, n):
    # hermetic per-param seed: the shared module RNG made these cases
    # order-dependent (seed-era failures [32-16]/[32-8] were whichever
    # draw hit an ill-conditioned matrix first)
    rng = np.random.default_rng(1000 * batch + n)
    a = jnp.asarray(rng.standard_normal((batch, n, n)), jnp.float32)
    q, r = ops.qrd(a, block_b=32)
    qr, rr = ref.mgs_qrd_ref(a)
    np.testing.assert_allclose(np.asarray(q), np.asarray(qr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(r), np.asarray(rr), atol=2e-5)


def test_mgs_qrd_factorization_properties():
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.standard_normal((32, 16, 16)), jnp.float32)
    q, r = ops.qrd(a)
    q, r = np.asarray(q), np.asarray(r)
    recon = np.einsum("bij,bjk->bik", q, r)
    np.testing.assert_allclose(recon, np.asarray(a), atol=5e-5)
    eye = np.eye(16)
    for i in range(32):
        np.testing.assert_allclose(q[i].T @ q[i], eye, atol=5e-5)
        assert np.abs(np.tril(r[i], -1)).max() < 1e-5


def test_mgs_qrd_agrees_with_iss():
    """Cross-layer: the Pallas kernel vs the eGPU ISS running the paper's
    assembly — two totally different implementations of §IV.B."""
    from repro.core.programs.qrd import run_qrd

    a = np.random.default_rng(7).standard_normal((16, 16)).astype(np.float32)
    q_iss, r_iss, _ = run_qrd(a)
    q_k, r_k = ops.qrd(jnp.asarray(a)[None].repeat(32, 0), block_b=32)
    np.testing.assert_allclose(np.asarray(q_k)[0], q_iss, atol=2e-4)
    np.testing.assert_allclose(np.asarray(r_k)[0], r_iss, atol=2e-4)


# ---------------------------------------------------------------------------
# fft_r2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [32, 64, 256, 1024])
def test_fft_r2_sweep(n):
    re = jnp.asarray(RNG.standard_normal((8, n)), jnp.float32)
    im = jnp.asarray(RNG.standard_normal((8, n)), jnp.float32)
    orr, oi = ops.fft(re, im)
    wr, wi = ref.fft_r2_ref(re, im)
    scale = np.abs(np.asarray(wr)).max()
    np.testing.assert_allclose(np.asarray(orr), np.asarray(wr), atol=3e-5 * scale)
    np.testing.assert_allclose(np.asarray(oi), np.asarray(wi), atol=3e-5 * scale)


def test_fft_r2_bitreversed_mode():
    re = jnp.asarray(RNG.standard_normal((8, 64)), jnp.float32)
    im = jnp.zeros((8, 64), jnp.float32)
    orr, oi = ops.fft(re, im, natural=False)
    wr, wi = ref.fft_r2_ref_br(re, im)
    np.testing.assert_allclose(np.asarray(orr), np.asarray(wr), atol=1e-4)


def test_fft_r2_agrees_with_iss():
    """Cross-layer: Pallas kernel vs eGPU ISS assembly FFT."""
    from repro.core.programs.fft import run_fft

    x = (RNG.standard_normal(256) + 1j * RNG.standard_normal(256)).astype(np.complex64)
    x_iss, _ = run_fft(x)
    orr, oi = ops.fft(jnp.asarray(np.real(x))[None], jnp.asarray(np.imag(x))[None])
    got = np.asarray(orr)[0] + 1j * np.asarray(oi)[0]
    np.testing.assert_allclose(got, x_iss, atol=1e-4 * np.abs(x_iss).max())


@settings(max_examples=20, deadline=None)
@given(logn=st.integers(4, 9), seed=st.integers(0, 2**31 - 1))
def test_fft_r2_linearity_property(logn, seed):
    # FFT(a x + b y) == a FFT(x) + b FFT(y)
    n = 1 << logn
    r = np.random.default_rng(seed)
    x = r.standard_normal((8, n)).astype(np.float32)
    y = r.standard_normal((8, n)).astype(np.float32)
    z = jnp.zeros((8, n), jnp.float32)
    fx = ops.fft(jnp.asarray(x), z)[0]
    fy = ops.fft(jnp.asarray(y), z)[0]
    fxy = ops.fft(jnp.asarray(2 * x + 3 * y), z)[0]
    np.testing.assert_allclose(np.asarray(fxy), 2 * np.asarray(fx) + 3 * np.asarray(fy),
                               atol=1e-3 * np.abs(np.asarray(fxy)).max())


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,d,blk", [(256, 64, 64), (512, 128, 128),
                                     (256, 64, 32)])
def test_flash_attention_sweep(s, d, blk):
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_ref)

    q = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.float32)
    got = flash_attention(q, k, v, blk_q=blk, blk_k=blk)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_noncausal():
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_ref)

    q = jnp.asarray(RNG.standard_normal((4, 128, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((4, 128, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((4, 128, 64)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, blk_q=64, blk_k=64)
    want = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_matches_model_attention():
    """Cross-layer: the Pallas kernel vs the model's blocked jnp attention
    (GQA folded to MHA) — the §Perf cell-C deployment path."""
    import dataclasses

    from repro.configs import get_arch
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import attention, attn_params

    cfg = dataclasses.replace(get_arch("yi-6b", smoke=True),
                              n_kv_heads=4)  # MHA for direct folding
    p = attn_params(jax.random.PRNGKey(0), cfg.d_model, cfg.n_heads,
                    cfg.n_kv_heads, cfg.head_dim, jnp.float32)
    B, S = 2, 128
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ref_out, (kk, vv) = attention(p, x, pos, cfg)

    from repro.models.layers import apply_rope
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    q = apply_rope(q, pos, cfg.rope_theta)
    qf = q.transpose(0, 2, 1, 3).reshape(B * cfg.n_heads, S, cfg.head_dim)
    kf = kk.transpose(0, 2, 1, 3).reshape(B * cfg.n_heads, S, cfg.head_dim)
    vf = vv.transpose(0, 2, 1, 3).reshape(B * cfg.n_heads, S, cfg.head_dim)
    o = flash_attention(qf, kf, vf, blk_q=32, blk_k=32)
    o = o.reshape(B, cfg.n_heads, S, cfg.head_dim).transpose(0, 2, 1, 3)
    got = o.reshape(B, S, -1) @ p["wo"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref_out),
                               atol=3e-5)
