"""Multi-SM device layer tests: wave scheduling, global memory, the
pluggable execute backends, and the run_many backward-compat shim."""
import numpy as np
import pytest

import jax

from repro.core import (
    DeviceConfig,
    SMConfig,
    assemble,
    execute_backends,
    launch,
    run,
    run_many,
)
from repro.core.assembler import auto_nop
from repro.core.isa import Depth, Instr, Op, Typ, Width

RNG = np.random.default_rng(7)


def _dcfg(n_sms=4, gdepth=256, **sm_kw):
    sm_kw.setdefault("max_steps", 2000)
    return DeviceConfig(n_sms=n_sms, global_mem_depth=gdepth,
                        sm=SMConfig(**sm_kw))


# ---------------------------------------------------------------------------
# block scheduling
# ---------------------------------------------------------------------------

def test_backends_registered():
    assert set(execute_backends()) >= {"inline", "pallas"}


def test_grid_schedules_in_waves():
    # 8 blocks on 4 SMs -> two rounds; 9 blocks -> three (last one partial)
    prog = assemble("BID R1\nSTO R1, (R0)+0 {w1,d1}\nSTOP")
    res = launch(_dcfg(), prog, grid=(8,), block=16)
    assert res.n_waves == 2 and res.n_blocks == 8
    assert res.cycles == int(res.wave_cycles.sum())
    res9 = launch(_dcfg(), prog, grid=(9,), block=16)
    assert res9.n_waves == 3
    # every block saw its own grid index through BID
    np.testing.assert_array_equal(np.asarray(res9.shmem[:, 0]), np.arange(9))


def test_block_private_shared_memory():
    # per-block shmem images stay private: each block doubles its own data
    prog = assemble(auto_nop("""
        TDX R1
        LOD R2, (R1)+0
        ADD.FP32 R3, R2, R2
        STO R3, (R1)+16
        STOP
    """, 16))
    images = RNG.standard_normal((6, 64)).astype(np.float32)
    res = launch(_dcfg(n_sms=4), prog, grid=(6,), block=16, shmem=images)
    out = np.asarray(res.shmem_f32())
    np.testing.assert_array_equal(out[:, 16:32], 2 * images[:, :16])
    assert res.halted and not bool(np.asarray(res.oob).any())


# ---------------------------------------------------------------------------
# global memory
# ---------------------------------------------------------------------------

def test_gmem_visible_across_sms_and_waves():
    # each block writes (bid+1)*7 to gmem[bid]; then reads gmem[0] — written
    # by a DIFFERENT SM (same wave, blocks 1-3) or a PREVIOUS wave (4-7) —
    # and echoes it to gmem[16+bid].
    prog = assemble(auto_nop("""
        BID R7
        LOD R2, #7
        LOD R5, #1
        ADD.INT32 R8, R7, R5
        MUL.INT32 R3, R8, R2      // (bid+1)*7
        GST R3, (R7)+0 {w1,d1}    // gmem[bid]
        GLD R4, (R0)+0 {w1,d1}    // gmem[0] = 7, written by block 0
        GST R4, (R7)+16 {w1,d1}
        STOP
    """, 16))
    res = launch(_dcfg(), prog, grid=(8,), block=16)
    gmem = np.asarray(res.gmem).astype(np.int64)
    np.testing.assert_array_equal(gmem[:8], 7 * (np.arange(8) + 1))
    np.testing.assert_array_equal(gmem[16:24], np.full(8, 7))


def test_gst_collision_last_sm_wins():
    # every block stores bid+1 to gmem[5]: the single device-wide port
    # drains in (sm, thread) order, so the wave's LAST block wins; across
    # waves the later wave overwrites.
    prog = assemble(auto_nop("""
        BID R1
        LOD R2, #1
        ADD.INT32 R3, R1, R2
        GST R3, (R0)+5 {w1,d1}
        STOP
    """, 16))
    res = launch(_dcfg(n_sms=4), prog, grid=(6,), block=16)
    assert int(np.asarray(res.gmem)[5]) == 6  # block 5 (wave 2's last)


def test_gmem_oob_flagged_per_block():
    prog = assemble("LOD R1, #4095\nGST R1, (R1)+0\nSTOP")
    res = launch(_dcfg(gdepth=64), prog, grid=(3,), block=16)
    assert bool(np.asarray(res.oob).all())


def test_device_step_matches_host_cycle_model():
    # the traced cost model in device._device_step must agree with the
    # host-side statement in cycles.instr_cycles for every class, incl.
    # the n_sms-contended GMEM row
    from repro.core.cycles import instr_cycles
    from repro.core.isa import CLASS_NAMES, instr_class

    n_sms, block = 3, 64
    cases = [
        Instr(op=Op.ADD, typ=Typ.FP32, rd=1, ra=2, rb=3),
        Instr(op=Op.LOD, rd=1, ra=0, imm=0),
        Instr(op=Op.STO, rd=1, ra=0, imm=0),
        Instr(op=Op.GLD, rd=1, ra=0, imm=0),
        Instr(op=Op.GST, rd=1, ra=0, imm=0, width=Width.SINGLE,
              depth=Depth.SINGLE),
        Instr(op=Op.LODI, rd=1, imm=5),
        Instr(op=Op.DOT, typ=Typ.FP32, rd=1, ra=2, rb=3),
        Instr(op=Op.INVSQR, typ=Typ.FP32, rd=1, ra=2),
        Instr(op=Op.NOP),
    ]
    for ins in cases:
        words = np.array([ins.encode(), Instr(op=Op.STOP).encode()], np.int64)
        res = launch(_dcfg(n_sms=n_sms, shmem_depth=64, gdepth=64), words,
                     grid=(n_sms,), block=block)
        klass = CLASS_NAMES[instr_class(ins.op, ins.typ)]
        assert res.profile()["by_class"][klass] \
            == instr_cycles(ins, block, n_sms), ins.op.name


def test_gmem_single_port_contention_cycles():
    # GLD on a 4-SM wave serializes: class GMEM pays n_sms * threads
    prog = assemble("GLD R1, (R0)+0\nSTOP")
    res = launch(_dcfg(n_sms=4), prog, grid=(4,), block=16)
    assert res.profile()["by_class"]["GMEM"] == 4 * 16
    # a single-block wave pays just its own threads
    res1 = launch(_dcfg(n_sms=4), prog, grid=(1,), block=16)
    assert res1.profile()["by_class"]["GMEM"] == 16


def test_buffers_layout_and_readback():
    x = np.arange(32, dtype=np.float32)
    prog = assemble(auto_nop("""
        TDX R1
        GLD R2, (R1)+0
        ADD.FP32 R3, R2, R2
        GST R3, (R1)+32
        STOP
    """, 32))
    res = launch(_dcfg(n_sms=2, gdepth=128), prog, grid=(1,), block=32,
                 buffers={"x": x, "y": np.zeros(32, np.float32)})
    assert res.buffer_offsets == {"x": (0, 32), "y": (32, 32)}
    np.testing.assert_array_equal(np.asarray(res.buffer("y")), 2 * x)


# ---------------------------------------------------------------------------
# execute backends: Pallas vs inline bit-exactness
# ---------------------------------------------------------------------------

_ALU_OPS = [Op.ADD, Op.SUB, Op.MUL, Op.AND, Op.OR, Op.XOR, Op.NOT,
            Op.LSL, Op.LSR]


def _random_program(rng, n_instr=10):
    """Random straightline mix of ALU/LODI/LOD/STO/TDX/BID instructions.

    The ISS executes architecturally (no interlocks to trip), so hazard
    padding is unnecessary for backend-equivalence checking.
    """
    instrs = []
    for _ in range(n_instr):
        kind = rng.integers(0, 4)
        if kind == 0:
            op = _ALU_OPS[rng.integers(0, len(_ALU_OPS))]
            instrs.append(Instr(
                op=op, typ=Typ(int(rng.integers(0, 3))),
                rd=int(rng.integers(0, 16)), ra=int(rng.integers(0, 16)),
                rb=int(rng.integers(0, 16)),
                width=Width(int(rng.integers(0, 4))),
                depth=Depth(int(rng.integers(0, 4)))))
        elif kind == 1:
            instrs.append(Instr(op=Op.LODI, typ=Typ(int(rng.integers(0, 3))),
                                rd=int(rng.integers(0, 16)),
                                imm=int(rng.integers(-100, 100))))
        elif kind == 2:
            instrs.append(Instr(op=Op.LOD, rd=int(rng.integers(0, 16)),
                                ra=0, imm=int(rng.integers(0, 32))))
        else:
            instrs.append(Instr(op=rng.choice([Op.TDX, Op.BID]),
                                rd=int(rng.integers(0, 16))))
    instrs.append(Instr(op=Op.STO, rd=1, ra=2, imm=0))
    instrs.append(Instr(op=Op.STOP))
    return np.array([i.encode() for i in instrs], np.int64)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pallas_inline_bit_exact_random_corpus(seed):
    rng = np.random.default_rng(seed)
    words = _random_program(rng)
    images = rng.standard_normal((3, 64)).astype(np.float32)
    dcfg = _dcfg(n_sms=2, shmem_depth=64)
    outs = {}
    for backend in ("inline", "pallas"):
        outs[backend] = launch(dcfg, words, grid=(3,), block=32,
                               shmem=images, backend=backend)
    a, b = outs["inline"], outs["pallas"]
    np.testing.assert_array_equal(np.asarray(a.regs), np.asarray(b.regs))
    np.testing.assert_array_equal(np.asarray(a.shmem), np.asarray(b.shmem))
    np.testing.assert_array_equal(np.asarray(a.gmem), np.asarray(b.gmem))
    assert a.cycles == b.cycles and a.steps == b.steps


def test_acceptance_two_waves_bit_identical():
    # the PR acceptance case: grid=(8,) block=512 on a 4-SM device
    prog = assemble(auto_nop("""
        BID R7
        TDX R1
        LOD R2, (R1)+0
        MUL.FP32 R3, R2, R2
        ADD.INT32 R4, R1, R7
        STO R3, (R1)+512
        STOP
    """, 512))
    images = RNG.standard_normal((8, 1024)).astype(np.float32)
    dcfg = _dcfg(n_sms=4, shmem_depth=1024)
    res_i = launch(dcfg, prog, grid=(8,), block=512, shmem=images,
                   backend="inline")
    res_p = launch(dcfg, prog, grid=(8,), block=512, shmem=images,
                   backend="pallas")
    assert res_i.n_waves == 2 and res_i.regs.shape[0] == 8
    assert res_i.halted and res_p.halted
    np.testing.assert_array_equal(np.asarray(res_i.regs),
                                  np.asarray(res_p.regs))
    np.testing.assert_array_equal(np.asarray(res_i.shmem),
                                  np.asarray(res_p.shmem))
    p = res_i.profile()
    assert p["total_cycles"] == res_i.cycles == res_p.cycles
    assert len(p["wave_cycles"]) == 2


# ---------------------------------------------------------------------------
# multi-program launches + the dynamic scheduler acceptance case
# ---------------------------------------------------------------------------

def test_pid_op_reports_program_index():
    from repro.core import Kernel

    prog = assemble("PID R1\nBID R2\nSTO R1, (R0)+0 {w1,d1}\n"
                    "STO R2, (R0)+1 {w1,d1}\nSTOP").words
    res = launch(_dcfg(n_sms=2),
                 programs=[Kernel(prog, block=16, name="a"),
                           Kernel(prog, block=16, name="b")],
                 grid_map=[0, 1, 1, 0, 1])
    sh = np.asarray(res.shmem)[:, :2]
    np.testing.assert_array_equal(sh[:, 0], [0, 1, 1, 0, 1])   # PID
    np.testing.assert_array_equal(sh[:, 1], [0, 0, 1, 1, 2])   # local BID


def test_acceptance_mixed_fft_qrd_4sm():
    """The PR acceptance case: a mixed FFT+QRD launch on a 4-SM device —
    correct numerics, non-zero per-SM occupancy for both programs, and
    dynamic dispatch never slower than the static wave schedule."""
    from repro.core.programs import launch_fft_qrd

    rng = np.random.default_rng(0)
    xs = (rng.standard_normal((12, 64))
          + 1j * rng.standard_normal((12, 64))).astype(np.complex64)
    As = rng.standard_normal((6, 16, 16)).astype(np.float32)
    X, Q, R, res = launch_fft_qrd(xs, As)

    assert res.schedule == "dynamic" and res.halted
    np.testing.assert_allclose(X, np.fft.fft(xs, axis=1), atol=1e-4)
    np.testing.assert_allclose(np.einsum("bij,bjk->bik", Q, R), As,
                               atol=1e-4)
    for i in range(6):
        np.testing.assert_allclose(Q[i].T @ Q[i], np.eye(16), atol=1e-4)

    p = res.profile()
    assert set(p["per_program"]) == {"fft64", "qrd16"}
    for name, d in p["per_program"].items():
        assert d["blocks"] > 0
        assert all(o > 0 for o in d["sm_occupancy"]), \
            f"{name} idle on some SM: {d['sm_occupancy']}"
    # the imbalanced grid: work-queue dispatch beats lockstep waves
    assert res.cycles <= res.static_cycles
    assert p["static_cycles"] == res.static_cycles
    # total busy is conserved across SMs and programs
    assert sum(d["busy_cycles"] for d in p["per_program"].values()) \
        == sum(d["busy"] for d in p["per_sm"])


def test_mixed_launch_static_vs_dynamic_same_results():
    from repro.core.programs import launch_fft_qrd, mixed_device

    rng = np.random.default_rng(1)
    xs = (rng.standard_normal((5, 32))
          + 1j * rng.standard_normal((5, 32))).astype(np.complex64)
    As = rng.standard_normal((3, 16, 16)).astype(np.float32)
    outs = {}
    for schedule in ("static", "dynamic"):
        X, Q, R, res = launch_fft_qrd(xs, As, schedule=schedule)
        outs[schedule] = (X, Q, R, res)
    Xs, Qs, Rs, rs = outs["static"]
    Xd, Qd, Rd, rd = outs["dynamic"]
    np.testing.assert_array_equal(Xs, Xd)
    np.testing.assert_array_equal(Qs, Qd)
    np.testing.assert_array_equal(Rs, Rd)
    assert rd.cycles <= rs.cycles == rd.static_cycles
    assert rs.n_waves == len(rs.wave_cycles) > 0 and rd.n_waves == 0


# ---------------------------------------------------------------------------
# backward compatibility
# ---------------------------------------------------------------------------

def test_run_many_shim_matches_per_instance_run():
    cfg = SMConfig(n_threads=16, dim_x=16, shmem_depth=64, max_steps=100)
    prog = assemble(auto_nop("""
        TDX R1
        LOD R2, (R1)+0
        ADD.FP32 R3, R2, R2
        STO R3, (R1)+16
        STOP
    """, 16))
    shmems = RNG.standard_normal((4, 64)).astype(np.float32)
    states = run_many(cfg, prog, shmems)
    # historical vmapped layout: leading batch axis on every field
    assert states.regs.shape[0] == states.shmem.shape[0] == 4
    assert states.halted.shape == (4,) and bool(states.halted.all())
    for b in range(4):
        st = run(cfg, prog, shmems[b])
        np.testing.assert_array_equal(np.asarray(states.regs[b]),
                                      np.asarray(st.regs))
        np.testing.assert_array_equal(np.asarray(states.shmem[b]),
                                      np.asarray(st.shmem))
        assert int(states.cycles[b]) == int(st.cycles)


def test_run_accepts_initial_state():
    from repro.core import init_state

    cfg = SMConfig(n_threads=16, dim_x=16, shmem_depth=64, max_steps=100)
    sh = np.arange(64, dtype=np.float32)
    state0 = init_state(cfg, sh)
    st = run(cfg, assemble("TDX R1\nSTO R1, (R1)+32\nSTOP"), state=state0)
    out = np.asarray(jax.lax.bitcast_convert_type(st.shmem, np.int32))
    np.testing.assert_array_equal(out[32:48], np.arange(16))
