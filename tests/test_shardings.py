"""Sharding-rule unit tests + blocked-attention equivalence (the §Perf
beyond-paper changes must preserve semantics exactly)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_arch, ARCHS
from repro.launch import shardings as sh
from repro.launch.mesh import make_mesh

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def mesh():
    # host has 1 device; build a (1,1) mesh with the production axis names
    # (rules only read axis SIZES, so checking specs needs a fake)
    return FakeMesh({"data": 16, "model": 16})


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_matrix_rule_fsdp_plus_tp(mesh):
    spec = sh.param_spec(mesh, "blocks/mlp/w_up", (32, 4096, 11008))
    assert spec == P(None, "data", "model")


def test_attention_head_rules(mesh):
    yi = get_arch("yi-6b")          # 32 heads, kv=4
    # q: 32 % 16 == 0 -> TP; kv: 4 % 16 != 0 -> FSDP only
    q = sh.param_spec(mesh, "blocks/attn/wq", (32, 4096, 4096), cfg=yi)
    k = sh.param_spec(mesh, "blocks/attn/wk", (32, 4096, 512), cfg=yi)
    o = sh.param_spec(mesh, "blocks/attn/wo", (32, 4096, 4096), cfg=yi)
    assert q == P(None, "data", "model")
    assert k == P(None, "data", None)
    assert o == P(None, "model", "data")    # row-parallel
    # naive mode reproduces the baseline flat-feature sharding
    k_naive = sh.param_spec(mesh, "blocks/attn/wk", (32, 4096, 512),
                            cfg=yi, naive_tp=True)
    assert k_naive == P(None, "data", "model")


def test_qwen_heads_not_divisible_fall_back(mesh):
    qw = get_arch("qwen2.5-32b")    # 40 heads
    q = sh.param_spec(mesh, "blocks/attn/wq", (64, 5120, 5120), cfg=qw)
    assert q == P(None, "data", None)
    qw48 = dataclasses.replace(qw, n_heads=48)
    q48 = sh.param_spec(mesh, "blocks/attn/wq", (64, 5120, 6144), cfg=qw48)
    assert q48 == P(None, "data", "model")


def test_embedding_and_expert_rules(mesh):
    e = sh.param_spec(mesh, "embed/embedding", (152064, 5120))
    assert e == P("model", "data")
    x = sh.param_spec(mesh, "blocks/moe/experts/w_up", (28, 64, 2048, 1408))
    assert x == P(None, "model", "data", None)


def test_scalars_replicated(mesh):
    assert sh.param_spec(mesh, "blocks/ln/scale", (32, 4096)) == P()
    assert sh.param_spec(mesh, "blocks/ssm/a_log", (48,)) == P()


def test_batch_spec_divisibility(mesh_=None):
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert sh.batch_spec(m, 256) == P(("pod", "data"))
    assert sh.batch_spec(m, 16) == P("pod")  # 16 % 2 == 0, then 8 % 16 != 0
    assert sh.batch_spec(m, 1) == P()


def test_cache_spec_finds_batch_axis():
    m = FakeMesh({"data": 16, "model": 16})
    spec = sh.cache_spec(m, (32, 128, 2048, 8, 128), 128)
    assert spec[1] == "data"                # batch axis found at position 1
    assert "model" in spec                  # and a feature axis sharded
    assert sh.cache_spec(m, (), 128) == P()
    # batch of 1 (long_500k): everything but a divisible feature replicated
    spec1 = sh.cache_spec(m, (48, 1, 48, 64, 128), 1)
    assert spec1[0] is None and spec1[1] is None
    assert "model" in spec1


# ---------------------------------------------------------------------------
# blocked attention == unblocked attention (semantics preserved)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 64])
def test_blocked_attention_equivalence(window):
    from repro.models.attention import attention, attn_params

    cfg = get_arch("yi-6b", smoke=True)
    cfg = dataclasses.replace(cfg, attn_q_chunk=32,
                              window=window)
    p = attn_params(KEY, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.head_dim, jnp.float32)
    x = jax.random.normal(KEY, (2, 128, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    blocked, _ = attention(p, x, pos, cfg, window=window)
    cfg0 = dataclasses.replace(cfg, attn_q_chunk=0)
    full, _ = attention(p, x, pos, cfg0, window=window)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(full),
                               atol=2e-5)


def test_forward_last_only_matches_full():
    from repro.models import build_model

    cfg = get_arch("granite-3-2b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    full = model.forward(params, {"tokens": toks})
    last = model.forward(params, {"tokens": toks}, last_only=True)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-5)


def test_qpad_is_numerics_exact():
    """Zero-padded q heads produce identical outputs (the §Perf qpad48
    change): fake heads go through zero wo rows."""
    from repro.models.attention import attention, attn_params

    cfg = get_arch("yi-6b", smoke=True)   # 4 heads, kv 2
    p = attn_params(KEY, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.head_dim, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    base, _ = attention(p, x, pos, cfg)
    # pad 4 -> 6 q heads (R 2 -> 3) with zero wq columns / wo rows
    cfg6 = dataclasses.replace(cfg, n_heads=6)
    d, hd, kv = cfg.d_model, cfg.head_dim, cfg.n_kv_heads
    wq = p["wq"].reshape(d, kv, 2, hd)
    wq6 = jnp.concatenate([wq, jnp.zeros((d, kv, 1, hd))], axis=2)
    wo = p["wo"].reshape(kv, 2, hd, d)
    wo6 = jnp.concatenate([wo, jnp.zeros((kv, 1, hd, d))], axis=1)
    p6 = dict(p, wq=wq6.reshape(d, 6 * hd), wo=wo6.reshape(6 * hd, d))
    padded, _ = attention(p6, x, pos, cfg6)
    np.testing.assert_allclose(np.asarray(padded), np.asarray(base),
                               atol=1e-5)
