"""Benchmark-program tests: numerics vs numpy + the paper's claims."""
import numpy as np
import pytest

from repro.core import check_hazards, profile
from repro.core.programs.fft import (
    bitrev_indices,
    fft_program,
    run_fft,
)
from repro.core.programs.qrd import qrd_program, run_qrd
from repro.core.programs.reduction import run_reduction
from repro.core.programs.saxpy import run_saxpy

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# FFT (paper §IV.A, Table III)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [32, 64, 128, 256])
def test_fft_matches_numpy(n):
    x = (RNG.standard_normal(n) + 1j * RNG.standard_normal(n)).astype(np.complex64)
    got, st = run_fft(x)
    ref = np.fft.fft(x)
    assert bool(st.halted) and not bool(st.oob)
    np.testing.assert_allclose(got, ref, rtol=0, atol=2e-5 * np.abs(ref).max())


def test_fft_unrolled_matches_numpy():
    x = (RNG.standard_normal(256) + 1j * RNG.standard_normal(256)).astype(np.complex64)
    got, _ = run_fft(x, unroll=True)
    np.testing.assert_allclose(got, np.fft.fft(x),
                               atol=2e-5 * np.abs(np.fft.fft(x)).max())


def test_fft_programs_hazard_free():
    for n in (32, 256):
        for unroll in (False, True):
            prog = fft_program(n, unroll)
            assert not check_hazards(prog, n_threads=n // 2)


def test_fft256_instruction_count_near_paper():
    # paper: "the 256 point radix-2 FFT ... require 135 ... instructions"
    prog = fft_program(256, unroll=True)
    assert 100 <= len(prog) <= 170, len(prog)
    # and the loop variant is far smaller (flexible I-MEM sizing argument)
    assert len(fft_program(256)) < 80


def test_fft256_profile_shared_memory_dominates():
    # paper Table III: address 12%, butterflies 13%, shared memory 75%
    x = (RNG.standard_normal(256) + 1j * RNG.standard_normal(256)).astype(np.complex64)
    _, st = run_fft(x)
    p = profile(st)
    b, tot = p["by_class"], p["total_cycles"]
    shared = (b["LOD_IDX"] + b["STO_IDX"]) / tot
    addr = (b["LOGIC"] + b["INT"] + b["LOD_IMM"]) / tot
    fp = (b["FP_ADDSUB"] + b["FP_MUL"]) / tot
    assert 0.65 <= shared <= 0.85          # paper: 0.75
    assert 0.05 <= addr <= 0.20            # paper: 0.12
    assert 0.05 <= fp <= 0.20              # paper: 0.13
    # memory access dominance is the paper's conclusion for R2 FFT
    assert shared > addr + fp


def test_bitrev_involution():
    for n in (32, 256):
        idx = bitrev_indices(n)
        np.testing.assert_array_equal(idx[idx], np.arange(n))


# ---------------------------------------------------------------------------
# QRD (paper §IV.B, Table IV)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loop", [False, True])
def test_qrd_factorizes(loop):
    a = RNG.standard_normal((16, 16)).astype(np.float32)
    q, r, st = run_qrd(a, loop=loop)
    assert bool(st.halted) and not bool(st.oob)
    np.testing.assert_allclose(q @ r, a, atol=5e-5)
    np.testing.assert_allclose(q.T @ q, np.eye(16), atol=5e-5)
    assert np.abs(np.tril(r, -1)).max() < 5e-6


def test_qrd_matches_numpy_up_to_sign():
    a = RNG.standard_normal((16, 16)).astype(np.float32)
    q, r, _ = run_qrd(a)
    qn, rn = np.linalg.qr(a)
    s = np.sign(np.diag(rn))
    np.testing.assert_allclose(q, qn * s, atol=1e-4)
    np.testing.assert_allclose(r, rn * s[:, None], atol=1e-4)


def test_qrd_programs_hazard_free():
    assert not check_hazards(qrd_program(), n_threads=256)
    assert not check_hazards(qrd_program(loop=True), n_threads=256)


def test_qrd_loop_program_size_near_paper():
    # paper: "the 16x16 QRD require ... 40 instructions" (I-MEM sizing)
    assert len(qrd_program(loop=True)) <= 80


def test_qrd_profile_matches_table_iv():
    """The strongest reproduction claim: per-iteration cycle profile."""
    a = RNG.standard_normal((16, 16)).astype(np.float32)
    _, _, st = run_qrd(a)
    p = profile(st)
    per = {k: v / 16 for k, v in p["by_class"].items()}
    # paper Table IV rows (per outer iteration): exact matches
    assert per["STO_IDX"] == 33          # 16 (Q col) + 16 (R row) + 1 (norm)
    assert per["FP_DOT"] == 17           # 1 (norm, {d1}) + 16 (R row, full)
    assert per["FP_SFU"] == 1            # one INVSQR per column
    # close matches (paper: LOD 132, ADD/SUB 16, NOP 44)
    assert 125 <= per["LOD_IDX"] <= 140
    assert 16 <= per["FP_ADDSUB"] <= 18
    assert 35 <= per["NOP"] <= 55
    # broadcast through shared memory dominates (the paper's observation)
    tot = p["total_cycles"] / 16
    assert per["LOD_IDX"] / tot > 0.40


def test_qrd_zero_column_no_nan_guard():
    # rank-deficient input: the rsqrt(0)=inf path mirrors hardware; the
    # factorization of the non-degenerate leading block must still be fine
    a = RNG.standard_normal((16, 16)).astype(np.float32)
    q, r, _ = run_qrd(a.copy())
    assert np.isfinite(q).all() and np.isfinite(r).all()


# ---------------------------------------------------------------------------
# reduction + saxpy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [32, 128, 512])
def test_reduction(n):
    x = RNG.standard_normal(n).astype(np.float32)
    tot, st = run_reduction(x)
    assert abs(tot - x.sum()) < 1e-3 * max(1.0, abs(x.sum()))
    assert bool(st.halted)


def test_reduction_never_touches_shared_for_partials():
    # snooping replaces shared-memory traffic: only the initial load and
    # the single result store hit memory
    x = RNG.standard_normal(512).astype(np.float32)
    _, st = run_reduction(x)
    p = profile(st)["by_class"]
    assert p["STO_IDX"] == 1
    assert p["LOD_IDX"] == 128  # 512 threads / 4 ports


def test_saxpy():
    x = RNG.standard_normal(128).astype(np.float32)
    y = RNG.standard_normal(128).astype(np.float32)
    z, _ = run_saxpy(-1.5, x, y)
    np.testing.assert_allclose(z, -1.5 * x + y, rtol=1e-6)


# ---------------------------------------------------------------------------
# launch-API (multi-SM device) variants
# ---------------------------------------------------------------------------

def _small_device(n_sms=2, gdepth=4096, **sm_kw):
    from repro.core import DeviceConfig, SMConfig

    sm_kw.setdefault("max_steps", 50_000)
    return DeviceConfig(n_sms=n_sms, global_mem_depth=gdepth,
                        sm=SMConfig(**sm_kw))


def test_launch_saxpy_grid():
    from repro.core.programs.saxpy import launch_saxpy

    x = RNG.standard_normal(192).astype(np.float32)
    y = RNG.standard_normal(192).astype(np.float32)
    z, res = launch_saxpy(0.75, x, y, device=_small_device(), block=64)
    np.testing.assert_allclose(z, 0.75 * x + y, rtol=1e-6)
    assert res.n_waves == 2  # 3 blocks on 2 SMs
    with pytest.raises(ValueError):
        launch_saxpy(1.0, np.zeros(8192, np.float32),
                     np.zeros(8192, np.float32))  # immediate range


@pytest.mark.parametrize("n", [16, 100, 512, 1600])
def test_launch_reduction_grid(n):
    from repro.core.programs.reduction import launch_reduction

    x = RNG.standard_normal(n).astype(np.float32)
    tot, res = launch_reduction(x, device=_small_device(), block=128)
    assert abs(tot - x.sum()) < 1e-3 * max(1.0, abs(float(x.sum())))
    assert res.halted


def test_launch_reduction_rejects_immediate_overflow():
    from repro.core.programs.reduction import launch_reduction

    with pytest.raises(ValueError):
        launch_reduction(np.ones(20_000, np.float32))


def test_fft_batch_matches_numpy():
    from repro.core.programs.fft import run_fft_batch

    xs = (RNG.standard_normal((3, 64))
          + 1j * RNG.standard_normal((3, 64))).astype(np.complex64)
    X, res = run_fft_batch(xs, device=_small_device(shmem_depth=192,
                                                    max_steps=200_000))
    ref = np.fft.fft(xs, axis=1)
    assert res.n_waves == 2 and res.halted
    np.testing.assert_allclose(X, ref, rtol=0, atol=2e-5 * np.abs(ref).max())


def test_qrd_batch_factorizes():
    from repro.core.programs.qrd import run_qrd_batch

    As = RNG.standard_normal((3, 16, 16)).astype(np.float32)
    Q, R, res = run_qrd_batch(As, device=_small_device(
        shmem_depth=1024, imem_depth=1024, max_steps=200_000))
    assert res.n_waves == 2 and res.halted
    for b in range(3):
        np.testing.assert_allclose(Q[b] @ R[b], As[b], atol=5e-5)
        np.testing.assert_allclose(Q[b].T @ Q[b], np.eye(16), atol=5e-5)
