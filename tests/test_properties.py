"""System-invariant property tests (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import SMConfig, assemble, run
from repro.core.assembler import Program
from repro.core.cycles import instr_cycles
from repro.core.isa import Depth, Instr, Op, Typ, Width

KEY = jax.random.PRNGKey(0)

_SAFE_OPS = [Op.ADD, Op.SUB, Op.MUL, Op.AND, Op.OR, Op.XOR, Op.NOT,
             Op.LSL, Op.LSR, Op.LODI, Op.TDX, Op.TDY, Op.DOT, Op.SUM,
             Op.NOP, Op.LOD, Op.STO]


@st.composite
def straightline_program(draw):
    n = draw(st.integers(1, 12))
    instrs = []
    for _ in range(n):
        op = draw(st.sampled_from(_SAFE_OPS))
        ins = Instr(
            op=op,
            typ=draw(st.sampled_from(list(Typ))),
            rd=draw(st.integers(0, 15)),
            ra=draw(st.integers(0, 15)),
            rb=draw(st.integers(0, 15)),
            imm=draw(st.integers(0, 31)) if op in (Op.LOD, Op.STO, Op.LODI)
            else 0,
            width=draw(st.sampled_from(list(Width))),
            depth=draw(st.sampled_from(list(Depth))),
        )
        instrs.append(ins)
    instrs.append(Instr(op=Op.STOP))
    return instrs


@settings(max_examples=25, deadline=None)
@given(instrs=straightline_program(), n_threads=st.sampled_from([16, 64, 256]))
def test_iss_cycles_match_cost_model(instrs, n_threads):
    """The executed cycle count equals the static cost model, always."""
    words = np.array([i.encode() for i in instrs], dtype=np.int64)
    cfg = SMConfig(n_threads=n_threads, dim_x=n_threads, shmem_depth=64,
                   max_steps=100)
    state = run(cfg, words)
    want = sum(instr_cycles(i, n_threads) for i in instrs)
    assert int(state.cycles) == want
    assert bool(state.halted)
    assert int(state.steps) == len(instrs)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), width=st.sampled_from(list(Width)),
       depth=st.sampled_from(list(Depth)))
def test_flexible_mask_never_touches_inactive_threads(seed, width, depth):
    """Any op at any width/depth leaves inactive threads' registers as-is."""
    n_threads = 128
    ins = Instr(op=Op.LODI, rd=3, imm=7, width=width, depth=depth)
    words = np.array([ins.encode(), Instr(op=Op.STOP).encode()], np.int64)
    cfg = SMConfig(n_threads=n_threads, dim_x=n_threads, shmem_depth=64,
                   max_steps=10)
    state = run(cfg, words)
    regs = np.asarray(state.regs)[:, 3]
    wt = {Width.FULL: 16, Width.HALF: 8, Width.QUARTER: 4, Width.SINGLE: 1}[width]
    n_waves = n_threads // 16
    dw = {Depth.FULL: n_waves, Depth.HALF: max(1, n_waves // 2),
          Depth.QUARTER: max(1, n_waves // 4), Depth.SINGLE: 1}[depth]
    for t in range(512):
        active = (t % 16 < wt) and (t // 16 < dw) and t < n_threads
        assert regs[t] == (7 if active else 0), (t, width, depth)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_checkpoint_roundtrip_property(seed, tmp_path_factory):
    from repro.checkpoint import ckpt

    rng = np.random.default_rng(seed)
    tree = {
        "a": jnp.asarray(rng.standard_normal((rng.integers(1, 8),
                                              rng.integers(1, 8)))),
        "b": [jnp.asarray(rng.integers(0, 100, 5), jnp.int32)],
        "c": {"d": jnp.asarray(rng.standard_normal(3), jnp.bfloat16)},
    }
    d = tmp_path_factory.mktemp("ck")
    ckpt.save(str(d), 1, tree)
    got, _ = ckpt.restore(str(d), tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                      np.asarray(b).view(np.uint8))


def test_engine_serves_ssm_arch():
    """The serving engine works for state-space (cache-free attention)
    models too — recurrent state splicing."""
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import Engine, Request

    cfg = get_arch("mamba2-780m", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    eng = Engine(model, params, max_slots=2, capacity=64)
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 8),
                       max_new_tokens=5))
    eng.submit(Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 4),
                       max_new_tokens=3))
    outs = eng.run_until_done()
    assert len(outs[0]) == 5 and len(outs[1]) == 3   # == max_new_tokens
    assert all(0 <= t for v in outs.values() for t in v)


def test_data_pipeline_seed_isolation():
    from repro.data import PipelineSpec

    a = PipelineSpec(vocab=64, seq_len=16, global_batch=4, seed=1)
    b = PipelineSpec(vocab=64, seq_len=16, global_batch=4, seed=2)
    assert not np.array_equal(a.batch_at(0), b.batch_at(0))
