"""Dry-run driver integration test: one real 512-device cell, end to end,
in a subprocess (the main pytest process keeps 1 device)."""
import json
import os
import subprocess
import sys

import pytest

from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.launch.dryrun import apply_policy, layer_variants


def test_dryrun_cell_subprocess(tmp_path):
    out = tmp_path / "dryrun.jsonl"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-tiny", "--shape", "decode_32k",
         "--mesh", "both", "--out", str(out)],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-2000:]
    rows = [json.loads(l) for l in open(out)]
    assert len(rows) == 2
    by_mesh = {row["mesh"]: row for row in rows}
    assert by_mesh["16x16"]["status"] == "ok"
    assert by_mesh["2x16x16"]["status"] == "ok"
    sp = by_mesh["16x16"]
    # roofline fields present and sane (single-pod only)
    assert sp["dominant"] in ("compute", "memory", "collective")
    assert sp["flops_scaled"] >= sp["flops"] > 0
    assert sp["peak_bytes_per_device"] < 16 * 2**30
    assert 0 < sp["roofline_fraction"] < 1
    # multi-pod row is the compile proof (no roofline terms)
    assert "compute_s" not in by_mesh["2x16x16"]


def test_layer_variants_cover_all_archs():
    for name, cfg in ARCHS.items():
        a, ua, b, ub, n = layer_variants(cfg)
        assert ub > ua >= 1 and n >= 1, name
        assert a.scan_unroll and b.scan_unroll
        assert a.n_layers < b.n_layers <= cfg.n_layers


def test_apply_policy_baseline_is_identity():
    for name, cfg in ARCHS.items():
        for shape in SHAPES.values():
            c2, opts = apply_policy(cfg, shape, "baseline")
            assert c2 is cfg
            assert opts["naive_tp"] and not opts["last_only"]


def test_apply_policy_optimized_rules():
    qw, opts = apply_policy(get_arch("qwen2.5-32b"), SHAPES["prefill_32k"],
                            "optimized")
    assert qw.n_heads == 48 and qw.attn_q_chunk == 2048
    assert not opts["naive_tp"] and opts["last_only"]
    # train cells revert to baseline per the autotune (iterations 7-9)
    mb, opts = apply_policy(get_arch("mamba2-780m"), SHAPES["train_4k"],
                            "optimized")
    assert opts["naive_tp"]
    mb, opts = apply_policy(get_arch("mamba2-780m"), SHAPES["decode_32k"],
                            "optimized")
    assert opts.get("overrides") == {"in_proj": "fsdp_in"}
    q15, opts = apply_policy(get_arch("qwen1.5-32b"), SHAPES["decode_32k"],
                             "optimized")
    assert "cache_dtype" in opts   # fp8 KV cache


def test_skip_matrix_is_exactly_eight_cells():
    skipped = [(a, s.name) for a, cfg in ARCHS.items()
               for s in SHAPES.values()
               if not shape_applicable(cfg, s)[0]]
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
