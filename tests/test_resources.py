"""Analytical resource/Fmax model vs the paper's published numbers."""
from repro.core import resources as R
from repro.core.machine import SMConfig


def test_table_v_verbatim():
    t = R.table_v()
    assert (t["SM"].alms, t["SM"].registers, t["SM"].dsps, t["SM"].m20ks) \
        == (5372, 14996, 24, 48)
    assert (t["SP"].alms, t["SP"].dsps, t["SP"].m20ks) == (267, 1.5, 2)
    assert (t["INT ALU"].alms, t["INT ALU"].dsps) == (114, 0.5)
    assert (t["Instruction"].alms, t["Instruction"].m20ks) == (235, 2)


def test_table_i_comparison():
    t = R.table_i()
    # eGPU is ~an order of magnitude smaller than FlexGrip and ~8x faster
    assert t["eGPU"]["alm"] < t["FlexGrip"]["alm"] / 10
    assert t["eGPU"]["fmax_mhz"] > 7 * t["FlexGrip"]["fmax_mhz"]
    assert t["eGPU"]["fmax_mhz"] > 3 * t["FGPU"]["fmax_mhz"]
    assert t["eGPU"]["dsp"] == 24
    assert t["eGPU"]["fmax_mhz"] == 771


def test_fmax_model():
    assert R.fmax_mhz(1) == 771.0
    assert R.fmax_mhz(1, use_dsp_fp32=False) == 831.0
    assert abs(R.fmax_mhz(4) - 738.0) < 1.0      # quad packing ~5% derate


def test_sector_packing_matches_paper():
    """§III.E arithmetic: 4 SMs/sector, 27 shared M20Ks, 16 dot DSPs,
    4100 ALM budget, 3K-word (12KB) shared memory."""
    p = R.pack_sector(4)
    assert p.regfile_m20ks == 128
    assert p.dsps_for_sms == 96
    assert p.m20ks_left == 109
    assert p.shared_copies_per_egpu == 27
    assert p.shared_depth_words == 3072
    assert p.shared_bytes == 12 * 1024
    assert p.dsps_left == 68
    assert p.dot_dsps_per_egpu == 16  # paper: 17 remain, dot core uses 16
    assert p.alm_budget_per_egpu == 4100


def test_sm_report_scales_with_config():
    base = R.sm_report(SMConfig())
    small = R.sm_report(SMConfig(shmem_depth=512, with_dot=False))
    assert small.m20ks < base.m20ks
    assert small.dsps == base.dsps - R.DOT_UNIT_DSP


def test_quad_read_port_costs_four_copies():
    # paper §III.A: 4 read ports => 4 identical copies of the array
    assert R.shared_memory_m20ks(512) == 4
    assert R.shared_memory_m20ks(3072) == 24


def test_peak_gflops():
    # 16 SPs * 2 flops + 31-flop dot unit at 771 MHz
    g = R.peak_gflops(1)
    assert abs(g - (32 + 31) * 0.771) < 1e-6
    assert R.peak_gflops(4) > 3.5 * R.peak_gflops(1) * R.QUAD_PACK_DERATE / 1.01
