"""Wave-packing property suite (``pytest -m packing``, own CI job).

The packing-invariance contract, property-tested:

  (a) the "length" policy NEVER yields more total padded scan steps than
      grid packing (it is DP-optimal over all partitions into the same
      number of waves of width <= n_sms);
  (b) every block appears in exactly one wave, and a wave never crosses
      a ``Kernel(barrier=True)`` phase fence;
  (c) single-program grids are packing-invariant in cycles too — the
      stable length sort of an all-equal phase reproduces grid chunking
      exactly, so the launch is BIT-identical, counters included;

plus the scheduler-level acceptance bound: ``dynamic <= static`` keeps
holding when both disciplines consume the same packing (the dynamic
queue pops the packed order; the static waves chunk it), fuzzed over
random trace sets, lengths, phases and policies.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    DeviceConfig,
    Kernel,
    SMConfig,
    assemble,
    launch,
    pack_waves,
    program_trace,
    schedule_blocks,
)
from repro.core.assembler import auto_nop
from repro.core.isa import Depth, Instr, Op, Typ, Width
from repro.core.packing import PACKINGS

from engine_conformance import assert_arch_identical, assert_bit_identical

pytestmark = pytest.mark.packing


# ---------------------------------------------------------------------------
# pack_waves unit tests: policies and bin-packing edge cases
# ---------------------------------------------------------------------------

def test_grid_policy_chunks_grid_order():
    p = pack_waves([7, 1, 9, 2, 5], 2, "grid")
    assert p.policy == "grid"
    assert p.waves == ((0, 1), (2, 3), (4,))
    assert list(p.order) == [0, 1, 2, 3, 4]
    assert p.wave_phase == (0, 0, 0)


def test_length_all_equal_matches_grid():
    # the all-equal edge case: the stable sort is the identity, the DP's
    # widest-first tiebreak keeps grid-shaped chunks — "length" and
    # "grid" coincide exactly (this is what makes single-program grids
    # packing-invariant by construction)
    for n, m in [(1, 1), (5, 2), (7, 3), (8, 4), (3, 8)]:
        g = pack_waves([6] * n, m, "grid")
        p = pack_waves([6] * n, m, "length")
        assert p.waves == g.waves, (n, m)


def test_length_isolates_straggler():
    # one long straggler: grid pads two short blocks to it; the DP gives
    # it a wave of its own (narrower than n_sms) and zeroes the padding
    g = pack_waves([10, 1, 1], 2, "grid")
    p = pack_waves([10, 1, 1], 2, "length")
    assert g.pad_steps() == 9
    assert p.waves == ((0,), (1, 2)) and p.pad_steps() == 0
    assert p.n_waves == g.n_waves          # same wave count, better waves
    assert min(p.wave_sizes) < p.n_sms     # a mid-sequence narrow wave


def test_length_keeps_wide_waves_when_that_pads_less():
    # the mirror-image case: isolating the tail would PAD MORE — the DP
    # must keep the grid-shaped split (boundary choice is data-dependent)
    p = pack_waves([3, 3, 2], 2, "length")
    assert p.waves == ((0, 1), (2,)) and p.pad_steps() == 0


def test_phase_narrower_than_n_sms_is_one_wave():
    p = pack_waves([4, 2], 8, "length")
    assert p.waves == ((0, 1),) and p.wave_sizes == (2,)


def test_length_sort_is_stable_within_equal_lengths():
    # equal lengths keep grid order (program-local BID order within a
    # slot is part of the merged-wave contract)
    p = pack_waves([5, 9, 5, 9, 5], 2, "length")
    assert list(p.order) == [1, 3, 0, 2, 4]


def test_waves_never_cross_phases():
    # pairing the two 9s would zero the padding, but they sit on opposite
    # sides of a fence — the packer must not reach across it
    p = pack_waves([9, 1, 1, 9], 2, "length", phase_of=[0, 0, 1, 1])
    assert p.wave_phase == (0, 1)
    assert p.waves == ((0, 1), (3, 2))
    assert p.pad_steps() == 16
    assert pack_waves([9, 1, 1, 9], 2, "length").pad_steps() == 0


def test_validation_errors():
    with pytest.raises(ValueError, match="packing"):
        pack_waves([1, 2], 2, "shortest-job-first")
    with pytest.raises(ValueError, match="n_sms"):
        pack_waves([1, 2], 0, "grid")
    with pytest.raises(ValueError, match="non-empty"):
        pack_waves([], 2, "grid")
    with pytest.raises(ValueError, match="phase_of"):
        pack_waves([1, 2], 2, "grid", phase_of=[0])
    with pytest.raises(ValueError, match="packing"):
        DeviceConfig(packing="by-vibes")


def test_scheduler_rejects_inconsistent_packing():
    words = np.array([Instr(op=Op.STOP).encode()], np.int64)
    traces = [program_trace(words, 16)] * 4
    for mode in ("static", "dynamic"):
        # wrong block count
        with pytest.raises(ValueError, match="covers"):
            schedule_blocks(traces, 2, mode,
                            packing=pack_waves([1, 1], 2, "grid"))
        # wrong SM count
        with pytest.raises(ValueError, match="SMs"):
            schedule_blocks(traces, 2, mode,
                            packing=pack_waves([1] * 4, 4, "grid"))
        # a packing built without the schedule's fences: its waves span
        # (or reorder) the declared phases
        with pytest.raises(ValueError, match="spans barrier"):
            schedule_blocks(traces, 2, mode, phase_of=[0, 1, 1, 1],
                            packing=pack_waves([1] * 4, 2, "grid"))


def test_auto_resolves_length_only_for_mixed_lengths():
    assert pack_waves([5, 5, 5], 2, "auto").policy == "grid"
    assert pack_waves([5, 1, 5], 2, "auto").policy == "length"
    # mixing across phases but uniform within each stays grid: there is
    # nothing for the packer to win inside any phase
    assert pack_waves([5, 5, 1, 1], 2, "auto",
                      phase_of=[0, 0, 1, 1]).policy == "grid"


# ---------------------------------------------------------------------------
# the hypothesis properties
# ---------------------------------------------------------------------------

@st.composite
def _packing_problem(draw):
    n = draw(st.integers(1, 16))
    lengths = [draw(st.integers(0, 40)) for _ in range(n)]
    n_sms = draw(st.integers(1, 6))
    n_phases = draw(st.integers(1, 3))
    # deliberately UNSORTED: launch() derives block_phase from grid_map,
    # and a grid interleaving a barrier kernel's blocks with earlier
    # kernels' produces out-of-order phase vectors
    phase = [draw(st.integers(0, n_phases - 1)) for _ in range(n)]
    return lengths, n_sms, phase


@settings(max_examples=300, deadline=None)
@given(prob=_packing_problem(), policy=st.sampled_from(PACKINGS))
def test_packing_partition_and_pad_properties(prob, policy):
    lengths, n_sms, phase = prob
    p = pack_waves(lengths, n_sms, policy, phase_of=phase)
    g = pack_waves(lengths, n_sms, "grid", phase_of=phase)
    # (b) exact partition: every block in exactly one wave
    flat = [b for wave in p.waves for b in wave]
    assert sorted(flat) == list(range(len(lengths)))
    assert all(len(w) <= n_sms and len(w) >= 1 for w in p.waves)
    # (b) waves never cross a phase fence, and phases stay in order
    for wave, ph in zip(p.waves, p.wave_phase):
        assert all(phase[b] == ph for b in wave)
    assert list(p.wave_phase) == sorted(p.wave_phase)
    # same wave count as grid packing (per phase, hence in total)
    assert p.n_waves == g.n_waves
    # (a) length packing never pads more than grid packing
    assert pack_waves(lengths, n_sms, "length",
                      phase_of=phase).pad_steps() <= g.pad_steps()
    # the dispatch order is a permutation consistent with the waves
    assert sorted(p.order) == list(range(len(lengths)))


def _random_traces(draw):
    ops = st.sampled_from([Op.ADD, Op.MUL, Op.LODI, Op.TDX, Op.NOP,
                           Op.LOD, Op.STO, Op.GLD, Op.GST, Op.DOT])
    word = st.builds(
        lambda op, typ, w, d: Instr(
            op=op, typ=typ, rd=1, ra=2, rb=3, width=w, depth=d),
        ops, st.sampled_from(list(Typ)), st.sampled_from(list(Width)),
        st.sampled_from(list(Depth)))
    n_programs = draw(st.integers(1, 3))
    progs = []
    for _ in range(n_programs):
        instrs = draw(st.lists(word, min_size=1, max_size=12))
        instrs.append(Instr(op=Op.STOP))
        n_threads = draw(st.sampled_from([16, 64, 256]))
        progs.append(program_trace(
            np.array([i.encode() for i in instrs], np.int64), n_threads))
    gmap = draw(st.lists(st.integers(0, n_programs - 1),
                         min_size=1, max_size=12))
    return [progs[k] for k in gmap]


@st.composite
def _schedule_problem(draw):
    traces = _random_traces(draw)
    n = len(traces)
    n_sms = draw(st.integers(1, 5))
    lengths = [draw(st.integers(0, 30)) for _ in range(n)]
    # unsorted on purpose — see _packing_problem
    phase = [draw(st.integers(0, 1)) for _ in range(n)]
    policy = draw(st.sampled_from(PACKINGS))
    return traces, n_sms, lengths, phase, policy


@settings(max_examples=200, deadline=None)
@given(prob=_schedule_problem())
def test_dynamic_never_slower_than_static_under_same_packing(prob):
    """The PR-2 acceptance bound survives packing: list dispatch in the
    packed order never loses to serial waves chunked from that same
    order (the packed wave rule charges every member the whole wave's
    port drain). The packing here is adversarial — the lengths fed to
    the packer are arbitrary, not the traces' own — because the bound
    must hold for ANY phase-respecting membership, not just pad-optimal
    ones."""
    traces, n_sms, lengths, phase, policy = prob
    wp = pack_waves(lengths, n_sms, policy, phase_of=phase)
    stat = schedule_blocks(traces, n_sms, "static", phase_of=phase,
                           packing=wp)
    dyn = schedule_blocks(traces, n_sms, "dynamic", phase_of=phase,
                          packing=wp)
    for s in (stat, dyn):
        assert s.block_sm.shape == (len(traces),)
        assert int(s.sm_blocks.sum()) == len(traces)
        np.testing.assert_array_equal(
            s.block_finish, s.block_start + s.block_busy + s.block_wait)
        assert (s.block_finish <= s.makespan).all()
        assert (s.sm_idle >= 0).all()
    assert len(stat.wave_cycles) == wp.n_waves
    assert dyn.makespan <= stat.makespan


@settings(max_examples=150, deadline=None)
@given(prob=_schedule_problem())
def test_grid_packing_is_bit_identical_to_no_packing(prob):
    """packing=None and an explicit grid WavePacking are the same
    scheduler — packing is opt-in, never a silent timing change."""
    traces, n_sms, _, phase, _ = prob
    wp = pack_waves([t.steps for t in traces], n_sms, "grid",
                    phase_of=phase)
    for mode in ("static", "dynamic"):
        a = schedule_blocks(traces, n_sms, mode, phase_of=phase)
        b = schedule_blocks(traces, n_sms, mode, phase_of=phase,
                            packing=wp)
        assert a.makespan == b.makespan
        for f in ("block_sm", "block_start", "block_finish", "block_busy",
                  "block_wait", "block_gmem", "wave_cycles"):
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f))


# ---------------------------------------------------------------------------
# launch-level invariance
# ---------------------------------------------------------------------------

def _dcfg(n_sms, packing, **sm_kw):
    sm_kw.setdefault("max_steps", 5000)
    sm_kw.setdefault("shmem_depth", 64)
    return DeviceConfig(n_sms=n_sms, global_mem_depth=128,
                        packing=packing, sm=SMConfig(**sm_kw))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_blocks=st.integers(1, 7),
       n_sms=st.integers(1, 4),
       schedule=st.sampled_from(["static", "dynamic"]))
def test_single_program_grids_are_packing_invariant_in_cycles(
        seed, n_blocks, n_sms, schedule):
    """Property (c): one program means all-equal schedule lengths, so
    every policy reproduces the grid waves — the whole LaunchResult,
    cycle counters included, is bit-identical."""
    rng = np.random.default_rng(seed)
    ops = [Op.ADD, Op.MUL, Op.LODI, Op.TDX, Op.BID, Op.LOD, Op.STO,
           Op.GLD, Op.GST]
    instrs = [Instr(op=ops[int(rng.integers(0, len(ops)))],
                    typ=Typ(int(rng.integers(0, 3))),
                    rd=int(rng.integers(0, 16)), ra=0,
                    rb=int(rng.integers(0, 16)),
                    imm=int(rng.integers(0, 16)),
                    width=Width(int(rng.integers(0, 4))),
                    depth=Depth(int(rng.integers(0, 4))))
              for _ in range(int(rng.integers(1, 10)))]
    instrs.append(Instr(op=Op.STOP))
    words = np.array([i.encode() for i in instrs], np.int64)
    gmem = rng.standard_normal(128).astype(np.float32)
    outs = {}
    for packing in ("grid", "length", "auto"):
        outs[packing] = launch(_dcfg(n_sms, packing, max_steps=200),
                               words, grid=(n_blocks,), block=16,
                               gmem=gmem, schedule=schedule)
    # "auto" must resolve to grid on a single-program grid
    assert outs["auto"].packing == "grid"
    assert_bit_identical(outs["grid"], outs["length"])
    assert_bit_identical(outs["grid"], outs["auto"])


_LONG = """
    BID R1
    LOD R2, #3
    INIT 12
top:
    ADD.INT32 R2, R2, R2
    STO R2, (R1)+0
    LOOP top
    STOP
"""
_SHORT = """
    BID R1
    PID R2
    ADD.INT32 R3, R1, R2
    STO R3, (R1)+1
    STOP
"""


def _mixed_launch(packing, schedule="dynamic", n_sms=2, engine=None,
                  barrier=False):
    long_p = assemble(auto_nop(_LONG, 16)).words
    short_p = assemble(auto_nop(_SHORT, 16)).words
    kerns = [Kernel(long_p, block=16, name="long"),
             Kernel(short_p, block=16, name="short",
                    barrier=barrier)]
    # backloaded-with-remainder grid: grid order pads short blocks
    # against the long ones in the straddling wave
    gmap = [0, 0, 0, 1, 1, 1, 1]
    return launch(_dcfg(n_sms, packing), programs=kerns, grid_map=gmap,
                  schedule=schedule, engine=engine)


def test_packed_launch_is_arch_identical_and_pads_less():
    grid = _mixed_launch("grid", engine="trace")
    packed = _mixed_launch("length", engine="trace")
    assert_arch_identical(grid, packed)
    g, p = grid.trace_merge, packed.trace_merge
    assert p["pad_overhead_total"] < g["pad_overhead_total"]
    assert p["policy"] == "length"
    # dynamic <= static holds against the PACKED static baseline
    assert packed.cycles <= packed.static_cycles
    assert grid.cycles <= grid.static_cycles


def test_packed_waves_respect_barrier_at_launch_level():
    res = _mixed_launch("length", barrier=True)
    wp = res.wave_packing
    phase = np.asarray([0, 0, 0, 1, 1, 1, 1])
    for wave, ph in zip(wp.waves, wp.wave_phase):
        assert all(phase[b] == ph for b in wave)
    # the timing layer honors the fence under packing: every barrier-side
    # block starts after every pre-fence block retired
    t = res.timing
    fence = max(int(c) for c in t.block_finish[:3])
    assert all(int(t.block_start[b]) >= fence for b in range(3, 7))


def test_packed_step_and_trace_engines_report_identical_records():
    # timing is engine-independent under packing too
    a = _mixed_launch("length", engine="step", schedule="static")
    b = _mixed_launch("length", engine="trace", schedule="static")
    assert a.engine == "step" and b.engine == "trace"
    assert_bit_identical(a, b)
