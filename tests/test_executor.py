"""eGPU ISS behaviour tests: semantics, flexible ISA, snooping, cycles."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    SMConfig,
    assemble,
    profile,
    regs_f32,
    regs_i32,
    run,
    run_many,
    shmem_f32,
    shmem_i32,
)
from repro.core.assembler import auto_nop


def _run(asm, n_threads=16, shmem=None, dim_x=None, depth=64, **kw):
    cfg = SMConfig(n_threads=n_threads, dim_x=dim_x or n_threads,
                   shmem_depth=depth, max_steps=10_000, **kw)
    return cfg, run(cfg, assemble(asm), shmem)


# ---------------------------------------------------------------------------
# arithmetic semantics
# ---------------------------------------------------------------------------

def test_fp32_arithmetic_exact():
    sh = np.zeros(64, np.float32)
    sh[0:16] = np.linspace(-3, 3, 16).astype(np.float32)
    sh[16:32] = np.linspace(0.1, 7, 16).astype(np.float32)
    _, st = _run("""
        TDX R1
        LOD R2, (R1)+0
        LOD R3, (R1)+16
        ADD.FP32 R4, R2, R3
        SUB.FP32 R5, R2, R3
        MUL.FP32 R6, R2, R3
        STOP
    """, shmem=sh)
    regs = np.asarray(regs_f32(st))[:16]
    x, y = sh[0:16], sh[16:32]
    np.testing.assert_array_equal(regs[:, 4], x + y)
    np.testing.assert_array_equal(regs[:, 5], x - y)
    np.testing.assert_array_equal(regs[:, 6], x * y)


def test_int_mul_is_16x16():
    # paper: "The multiply is 16x16 with a 32-bit output"
    _, st = _run("""
        LOD R1, #16383
        LOD R2, #3
        MUL.INT32 R3, R1, R2
        LOD R4, #-5
        MUL.INT32 R5, R4, R2
        MUL.UINT32 R6, R4, R2
        STOP
    """)
    regs = np.asarray(regs_i32(st))
    assert regs[0, 3] == 16383 * 3
    assert regs[0, 5] == -15                      # sign-extended 16-bit
    assert regs[0, 6] == (np.int64(0xFFFB) * 3)   # low-16 unsigned


def test_logic_and_shifts():
    _, st = _run("""
        LOD R1, #12345
        LOD R2, #774
        AND R3, R1, R2
        OR  R4, R1, R2
        XOR R5, R1, R2
        NOT R6, R1
        LOD R7, #3
        LSL R8, R1, R7
        LSR R9, R1, R7
        STOP
    """)
    r = np.asarray(st.regs)[0]
    assert r[3] == 12345 & 774
    assert r[4] == 12345 | 774
    assert r[5] == 12345 ^ 774
    assert r[6] == (~np.uint32(12345))
    assert r[8] == 12345 << 3
    assert r[9] == 12345 >> 3


def test_int_wraparound():
    _, st = _run("""
        LOD R1, #16383
        LOD R2, #16383
        ADD.INT32 R3, R1, R2
        LOD R4, #-16384
        SUB.INT32 R5, R4, R1
        STOP
    """)
    r = np.asarray(regs_i32(st))[0]
    assert r[3] == 32766
    assert r[5] == -32767


@settings(max_examples=50, deadline=None)
@given(a=st.integers(0, 2**32 - 1), b=st.integers(0, 2**32 - 1),
       op=st.sampled_from(["AND", "OR", "XOR"]))
def test_logic_property(a, b, op):
    # feed arbitrary bit patterns through shared memory
    sh = np.zeros(64, np.uint32)
    sh[0], sh[1] = a, b
    cfg = SMConfig(n_threads=16, dim_x=16, shmem_depth=64, max_steps=100)
    state = run(cfg, assemble(f"""
        LOD R1, (R0)+0
        LOD R2, (R0)+1
        {op} R3, R1, R2
        STOP
    """), sh)
    got = int(np.asarray(state.regs)[0, 3])
    want = {"AND": a & b, "OR": a | b, "XOR": a ^ b}[op]
    assert got == want


# ---------------------------------------------------------------------------
# memory system
# ---------------------------------------------------------------------------

def test_store_collision_last_thread_wins():
    # single write port, sequential writeback in thread order
    _, st = _run("""
        TDX R1
        STO R1, (R0)+5
        STOP
    """)
    assert int(np.asarray(shmem_i32(st))[5]) == 15  # highest active thread


def test_oob_flagged_and_dropped():
    _, st = _run("""
        LOD R1, #4095
        STO R1, (R1)+0
        LOD R2, (R1)+0
        STOP
    """, depth=64)
    assert bool(st.oob)


def test_lod_sto_roundtrip():
    sh = np.arange(64, dtype=np.float32)
    _, st = _run("""
        TDX R1
        LOD R2, (R1)+16
        STO R2, (R1)+32
        STOP
    """, shmem=sh)
    out = np.asarray(shmem_f32(st))
    np.testing.assert_array_equal(out[32:48], sh[16:32])


# ---------------------------------------------------------------------------
# flexible ISA (the paper's novel contribution)
# ---------------------------------------------------------------------------

def test_flexible_width_masks_lanes():
    _, st = _run("""
        LOD R1, #1 {w4}
        STOP
    """, n_threads=32)
    r = np.asarray(st.regs)[:32, 1].reshape(2, 16)
    assert (r[:, :4] == 1).all() and (r[:, 4:] == 0).all()


def test_flexible_depth_masks_waves():
    _, st = _run("""
        LOD R1, #1 {dhalf}
        LOD R2, #1 {d1}
        STOP
    """, n_threads=64)
    r1 = np.asarray(st.regs)[:64, 1].reshape(4, 16)
    assert (r1[:2] == 1).all() and (r1[2:] == 0).all()
    r2 = np.asarray(st.regs)[:64, 2].reshape(4, 16)
    assert (r2[0] == 1).all() and (r2[1:] == 0).all()


def test_flexible_store_single_cycle():
    # the paper's hero stat: {w1,d1} store = 1 cycle vs 512
    cfg = SMConfig(n_threads=512, dim_x=512, shmem_depth=1024, max_steps=100)
    st_full = run(cfg, assemble("TDX R1\nSTO R1, (R1)+0\nSTOP"))
    st_one = run(cfg, assemble("TDX R1\nSTO R1, (R1)+0 {w1,d1}\nSTOP"))
    full = int(st_full.cycles_by_class[9])
    one = int(st_one.cycles_by_class[9])
    assert full == 512 and one == 1


def test_cycle_model_matches_paper_rules():
    # op = waves, load = threads/4, store = threads (paper §III.A/C)
    cfg = SMConfig(n_threads=512, dim_x=512, shmem_depth=1024, max_steps=100)
    st = run(cfg, assemble("""
        TDX R1
        ADD.INT32 R2, R1, R1
        LOD R3, (R1)+0
        STO R3, (R1)+0
        STOP
    """))
    by = np.asarray(st.cycles_by_class)
    assert by[3] == 32 + 32      # TDX + ADD: 32 waves each
    assert by[4] == 128          # 512/4
    assert by[9] == 512


# ---------------------------------------------------------------------------
# extension units + snooping
# ---------------------------------------------------------------------------

def test_dot_writes_lane0_per_wavefront():
    sh = np.zeros(128, np.float32)
    sh[:64] = np.arange(64)
    _, st = _run("""
        TDX R1
        LOD R2, (R1)+0
        DOT.FP32 R3, R2, R2
        STOP
    """, n_threads=64, shmem=sh, depth=128)
    x = sh[:64].reshape(4, 16)
    want = (x * x).sum(axis=1)
    regs = np.asarray(regs_f32(st))
    got = regs[np.arange(4) * 16, 3]
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # non-lane0 threads untouched
    assert (regs[1:16, 3] == 0).all()


def test_sum_reduction():
    sh = np.zeros(64, np.float32)
    sh[:16] = np.linspace(1, 2, 16)
    _, st = _run("""
        TDX R1
        LOD R2, (R1)+0
        SUM.FP32 R3, R2, R2
        STOP
    """, shmem=sh)
    got = float(np.asarray(regs_f32(st))[0, 3])
    np.testing.assert_allclose(got, 2 * sh[:16].sum(), rtol=1e-6)


def test_invsqr_sfu():
    sh = np.zeros(64, np.float32)
    sh[0] = 16.0
    _, st = _run("""
        LOD R1, (R0)+0 {w1,d1}
        INVSQR.FP32 R2, R1 {w1,d1}
        STOP
    """, shmem=sh)
    assert abs(float(np.asarray(regs_f32(st))[0, 2]) - 0.25) < 1e-7


def test_thread_snooping_reads_other_wavefront():
    _, st = _run("""
        TDX R1
        ADD.INT32 R2, R1@3, R1@3 {d1}
        STOP
    """, n_threads=64)
    # wave-0 threads read R1 of wave 3 (threads 48..63), which hold TDX=tid
    got = np.asarray(regs_i32(st))[:16, 2]
    want = 2 * (np.arange(16) + 48)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# control flow
# ---------------------------------------------------------------------------

def test_nested_loops():
    _, st = _run("""
        LOD R1, #0
        LOD R2, #1
        INIT 3
    outer:
        INIT 4
    inner:
        ADD.INT32 R1, R1, R2
        LOOP inner
        LOOP outer
        STOP
    """)
    assert int(np.asarray(regs_i32(st))[0, 1]) == 12


def test_jsr_rts():
    _, st = _run("""
        LOD R1, #5
        JSR sub
        ADD.INT32 R1, R1, R1
        STOP
    sub:
        ADD.INT32 R1, R1, R1
        RTS
    """)
    assert int(np.asarray(regs_i32(st))[0, 1]) == 20


def test_stop_halts_and_fuel_limits():
    cfg = SMConfig(n_threads=16, dim_x=16, shmem_depth=64, max_steps=50)
    st = run(cfg, assemble("top:\nJMP top"))
    assert not bool(st.halted) and int(st.steps) == 50


def test_runaway_pc_halts_on_stop_padding():
    _, st = _run("NOP")  # falls through into STOP-padded I-MEM
    assert bool(st.halted)


# ---------------------------------------------------------------------------
# heterogeneous launches: inline vs pallas differential sweep
# ---------------------------------------------------------------------------

_HET_ALU = ["ADD", "SUB", "MUL", "AND", "OR", "XOR", "LSL", "LSR"]


def _random_het_program(rng, gdepth=64):
    """Random straightline program touching every multi-program feature:
    PID/BID addressing, shared + global memory, random-typed ALU traffic."""
    lines = ["    PID R1", "    BID R2", "    TDX R3"]
    for _ in range(int(rng.integers(4, 10))):
        kind = int(rng.integers(0, 4))
        if kind == 0:
            op = _HET_ALU[int(rng.integers(0, len(_HET_ALU)))]
            typ = ["", ".INT32", ".UINT32", ".FP32"][int(rng.integers(0, 4))]
            rd, ra, rb = (int(rng.integers(1, 16)) for _ in range(3))
            lines.append(f"    {op}{typ} R{rd}, R{ra}, R{rb}")
        elif kind == 1:
            lines.append(f"    LOD R{int(rng.integers(1, 16))}, "
                         f"#{int(rng.integers(-50, 50))}")
        elif kind == 2:
            lines.append(f"    GLD R{int(rng.integers(1, 16))}, "
                         f"(R0)+{int(rng.integers(0, gdepth))}")
        else:
            lines.append(f"    LOD R{int(rng.integers(1, 16))}, "
                         f"(R3)+{int(rng.integers(0, 16))}")
    lines.append(f"    STO R{int(rng.integers(1, 16))}, (R3)+16")
    lines.append(f"    GST R{int(rng.integers(1, 16))}, (R2)+32 {{w1,d1}}")
    lines.append("    STOP")
    return assemble("\n".join(lines))


@pytest.mark.parametrize("schedule", ["static", "dynamic"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_heterogeneous_launch_inline_pallas_bit_exact(seed, schedule):
    """The backend seam must stay bit-exact on the multi-program paths:
    per-program lockstep batches with mixed block sizes, PID plumbing,
    carried global memory."""
    from repro.core import DeviceConfig, Kernel, launch

    rng = np.random.default_rng(seed)
    kernels = [Kernel(_random_het_program(rng), block=16, name="a"),
               Kernel(_random_het_program(rng), block=32, name="b"),
               Kernel(_random_het_program(rng), block=16, name="c",
                      barrier=bool(seed % 2))]
    gmap = [int(g) for g in rng.integers(0, 3, 8)]
    gmem = rng.standard_normal(64).astype(np.float32)
    dcfg = DeviceConfig(n_sms=2, global_mem_depth=64,
                        sm=SMConfig(shmem_depth=64, max_steps=500))
    outs = {}
    for backend in ("inline", "pallas"):
        outs[backend] = launch(dcfg, programs=kernels, grid_map=gmap,
                               gmem=gmem, backend=backend,
                               schedule=schedule)
    a, b = outs["inline"], outs["pallas"]
    np.testing.assert_array_equal(np.asarray(a.regs), np.asarray(b.regs))
    np.testing.assert_array_equal(np.asarray(a.shmem), np.asarray(b.shmem))
    np.testing.assert_array_equal(np.asarray(a.gmem), np.asarray(b.gmem))
    assert a.cycles == b.cycles and a.steps == b.steps
    assert a.schedule == b.schedule == schedule
    assert a.static_cycles == b.static_cycles


def test_heterogeneous_two_stage_pipeline_through_gmem():
    """Program-major functional order: a consumer program in the same
    launch (barrier) reads what the producer wrote to global memory, on
    both backends."""
    from repro.core import DeviceConfig, Kernel, launch

    producer = assemble(auto_nop("""
        BID R1
        TDX R2
        ADD.INT32 R3, R1, R2
        GST R3, (R2)+0
        STOP
    """, 16))
    consumer = assemble(auto_nop("""
        TDX R2
        GLD R4, (R2)+0
        ADD.INT32 R5, R4, R4
        GST R5, (R2)+16
        STOP
    """, 16))
    dcfg = DeviceConfig(n_sms=2, global_mem_depth=64,
                        sm=SMConfig(shmem_depth=64, max_steps=500))
    for backend in ("inline", "pallas"):
        res = launch(dcfg,
                     programs=[Kernel(producer, block=16, name="produce"),
                               Kernel(consumer, block=16, name="consume",
                                      barrier=True)],
                     grid_map=[0, 0, 1], backend=backend)
        g = np.asarray(res.gmem).astype(np.int64)
        # last producer block (bid=1) wins the write: gmem[t] = 1 + t
        np.testing.assert_array_equal(g[:16], 1 + np.arange(16))
        np.testing.assert_array_equal(g[16:32], 2 * (1 + np.arange(16)))
        # the consumer's block never starts before both producers retire
        assert int(res.timing.block_start[2]) \
            >= int(res.timing.block_finish[:2].max())


# ---------------------------------------------------------------------------
# multi-SM (quad-packed sector, §III.E)
# ---------------------------------------------------------------------------

def test_run_many_vmapped_sms():
    n_sm = 4
    shmems = np.zeros((n_sm, 64), np.float32)
    shmems[:, :16] = np.arange(16) + np.arange(n_sm)[:, None]
    cfg = SMConfig(n_threads=16, dim_x=16, shmem_depth=64, max_steps=100)
    prog = assemble("""
        TDX R1
        LOD R2, (R1)+0
        ADD.FP32 R3, R2, R2
        STO R3, (R1)+16
        STOP
    """)
    states = run_many(cfg, prog, shmems)
    out = np.asarray(shmem_f32(states.__class__(**{
        k: getattr(states, k) for k in states.__dataclass_fields__})))
    # shmem_f32 works per-instance via bitcast on the batch too
    import jax
    out = np.asarray(jax.lax.bitcast_convert_type(states.shmem, np.float32))
    np.testing.assert_array_equal(out[:, 16:32], 2 * shmems[:, :16])
    assert bool(states.halted.all())
