"""Substrate tests: optimizer, data pipeline, checkpointing (incl. crash/
restart + async), gradient compression, watchdog, serve engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import ckpt
from repro.configs import RunConfig, get_arch
from repro.data import PipelineSpec, make_batch, spec_for
from repro.models import build_model
from repro.optim import adamw, clip, compression
from repro.serve import Engine, Request
from repro.train import Watchdog, init_state, make_train_step, train_loop

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    rc = RunConfig(learning_rate=0.1, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw.apply(rc, params, grads, state, 1000)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_weight_decay_only_on_matrices():
    rc = RunConfig(learning_rate=0.01, warmup_steps=0, weight_decay=0.5)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = adamw.init(params)
    zero = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    p2, _ = adamw.apply(rc, params, zero, state, 1000)
    assert float(p2["w"].max()) < 1.0         # decayed
    assert float(p2["b"].min()) == 1.0        # bias untouched


def test_warmup_cosine_schedule():
    rc = RunConfig(learning_rate=1e-3, warmup_steps=10)
    lrs = [float(adamw.schedule(rc, s, 100)) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[100] < lrs[10]


def test_global_norm_clip():
    g = {"a": jnp.full((10,), 3.0)}
    clipped, norm = clip.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 3.0 * np.sqrt(10)) < 1e-4
    assert abs(float(clip.global_norm(clipped)) - 1.0) < 1e-4
    g2, _ = clip.clip_by_global_norm({"a": jnp.ones((2,)) * 0.1}, 1.0)
    np.testing.assert_allclose(np.asarray(g2["a"]), 0.1)  # below: untouched


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_ef_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    ef = compression.init_ef(g)
    q, s, ef2 = compression.compress(g, ef)
    deq = compression.decompress(q, s)
    err = float(jnp.abs(deq["w"] - g["w"]).max())
    assert err <= float(s["w"]) * 0.5 + 1e-6   # half-ulp of int8 grid
    assert q["w"].dtype == jnp.int8


def test_error_feedback_accumulates_truncation():
    # feeding the same gradient repeatedly: EF must push the *average*
    # dequantized gradient toward the true value
    g = {"w": jnp.full((8,), 0.004, jnp.float32)}
    # scale = 0.004/127 -> fine grid; make coarse by adding one big element
    g = {"w": jnp.asarray([1.0] + [0.004] * 7, jnp.float32)}
    ef = compression.init_ef(g)
    total = np.zeros(8)
    for _ in range(64):
        q, s, ef = compression.compress(g, ef)
        total += np.asarray(compression.decompress(q, s)["w"])
    mean = total / 64
    np.testing.assert_allclose(mean, np.asarray(g["w"]), rtol=0.05)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_compression_property_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(128) * rng.uniform(0.1, 100),
                    jnp.float32)
    ef = compression.init_ef({"x": x})
    q, s, ef2 = compression.compress({"x": x}, ef)
    deq = compression.decompress(q, s)["x"]
    # max error is half a quantization step; EF carries exactly the residual
    assert float(jnp.abs(deq + ef2.error["x"] - x).max()) < 1e-5


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_sharded():
    spec = PipelineSpec(vocab=100, seq_len=32, global_batch=8, seed=3)
    b1 = spec.batch_at(5)
    b2 = spec.batch_at(5)
    np.testing.assert_array_equal(b1, b2)
    assert not np.array_equal(b1, spec.batch_at(6))
    # host slices tile the global batch exactly
    slices = [spec.host_slice(5, h, 4) for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(slices), b1)
    assert b1.min() >= 0 and b1.max() < 100


def test_pipeline_has_learnable_structure():
    spec = PipelineSpec(vocab=97, seq_len=128, global_batch=4, seed=0)
    b = spec.batch_at(0)
    # every row follows one of the seed's n_rules affine maps (mod noise):
    # some rule must explain >=80% of the transitions
    a_pool, b_pool = spec._rules()
    row = b[0].astype(np.int64)
    best = 0
    for a, c in zip(a_pool, b_pool):
        hits = sum(1 for t in range(1, 128)
                   if row[t] == (a * row[t - 1] + c) % 97)
        best = max(best, hits)
    assert best >= 0.8 * 127, best


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "nested": {"b": jnp.ones((3, 4), jnp.bfloat16)},
            "list": [jnp.zeros(2), jnp.ones(3)]}
    ckpt.save(str(tmp_path), 7, tree, {"step": 7})
    like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), tree)
    got, extra = ckpt.restore(str(tmp_path), like)
    assert extra["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_ckpt_latest_pointer_atomic(tmp_path):
    tree = {"x": jnp.ones(4)}
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    assert ckpt.latest_step(str(tmp_path)) == 2
    # a partial dir without manifest is ignored
    os.makedirs(tmp_path / "step_00000003")
    with open(tmp_path / "LATEST", "w") as f:
        f.write("step_00000003")
    assert ckpt.latest_step(str(tmp_path)) is None


def test_ckpt_async_saver(tmp_path):
    tree = {"x": jnp.arange(1000, dtype=jnp.float32)}
    s = ckpt.AsyncSaver()
    s.save(str(tmp_path), 5, tree)
    s.wait()
    got, _ = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(got["x"]), np.arange(1000))


def test_ckpt_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.ones(4)})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"x": jnp.ones(5)})


# ---------------------------------------------------------------------------
# training loop: loss goes down; crash + restart is bit-identical
# ---------------------------------------------------------------------------

def _tiny_setup(tmp_path, ckpt_every=4):
    cfg = get_arch("granite-3-2b", smoke=True)
    model = build_model(cfg)
    rc = RunConfig(learning_rate=3e-3, warmup_steps=2, ckpt_dir=str(tmp_path),
                   ckpt_every=ckpt_every, async_ckpt=False, seed=1)
    spec = PipelineSpec(vocab=cfg.vocab_size, seq_len=32, global_batch=4,
                        seed=1)
    return cfg, model, rc, spec


def test_train_loss_decreases(tmp_path):
    cfg, model, rc, spec = _tiny_setup(tmp_path, ckpt_every=0)
    rc = RunConfig(learning_rate=5e-3, warmup_steps=5,
                   ckpt_dir=rc.ckpt_dir, ckpt_every=0, async_ckpt=False,
                   seed=1, weight_decay=0.0)
    res = train_loop(model, cfg, rc, spec, n_steps=30)
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5]) - 0.05


def test_crash_restart_bit_identical(tmp_path):
    cfg, model, rc, spec = _tiny_setup(tmp_path / "a")
    # uninterrupted reference run
    ref = train_loop(model, cfg, rc, spec, n_steps=10)
    # crashed run: dies at step 7, restarts from the step-4 checkpoint
    cfg2, model2, rc2, spec2 = _tiny_setup(tmp_path / "b")
    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(model2, cfg2, rc2, spec2, n_steps=10, fail_at_step=7)
    res = train_loop(model2, cfg2, rc2, spec2, n_steps=10)
    assert res.resumed_from == 4
    # the resumed tail must equal the uninterrupted run exactly
    np.testing.assert_array_equal(np.asarray(ref.losses[4:]),
                                  np.asarray(res.losses))
    for a, b in zip(jax.tree_util.tree_leaves(ref.state.params),
                    jax.tree_util.tree_leaves(res.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_watchdog_flags_stragglers():
    wd = Watchdog(window=20, k=3.0)
    for i in range(20):
        wd.record(i, 0.10 + 0.001 * (i % 3))
    assert wd.record(20, 0.5)       # 5x median: straggler
    assert not wd.record(21, 0.101)
    assert wd.flagged == [20]


def test_microbatch_grad_accum_matches_full(tmp_path):
    cfg = get_arch("granite-3-2b", smoke=True)
    model = build_model(cfg)
    rc_full = RunConfig(microbatch=0, weight_decay=0.0)
    rc_micro = RunConfig(microbatch=4, weight_decay=0.0)
    state = init_state(model, KEY, rc_full)
    spec = PipelineSpec(vocab=cfg.vocab_size, seq_len=16, global_batch=8)
    batch = make_batch(cfg, spec, 0)
    s_full, m_full = jax.jit(make_train_step(model, rc_full))(state, batch)
    s_micro, m_micro = jax.jit(make_train_step(model, rc_micro))(state, batch)
    assert abs(float(m_full["loss"]) - float(m_micro["loss"])) < 1e-4
    for a, b in zip(jax.tree_util.tree_leaves(s_full.params),
                    jax.tree_util.tree_leaves(s_micro.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# serve engine
# ---------------------------------------------------------------------------

def test_engine_flexible_batching():
    cfg = get_arch("granite-3-2b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    eng = Engine(model, params, max_slots=4, capacity=64)
    rng = np.random.default_rng(0)
    for rid in range(6):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, 4 + rid),
                           max_new_tokens=3 + rid))
    outs = eng.run_until_done()
    assert sorted(outs) == list(range(6))            # queued ones admitted
    for rid, toks in outs.items():
        # the budget covers ALL emitted tokens (prefill-sampled first
        # token included) — exactly max_new_tokens, not one more
        assert len(toks) == 3 + rid
        assert eng.requests[rid].finish_reason == "budget"
        assert all(0 <= t < cfg.padded_vocab for t in toks)
    # the active width varied (the flexible-ISA analogue)
    assert len(set(eng.active_history)) > 1


def test_engine_matches_unbatched_decode():
    """A request decoded alongside others must produce the same tokens as
    the same request decoded alone (masking = correctness, like the eGPU's
    inactive lanes)."""
    cfg = get_arch("granite-3-2b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 6)

    eng1 = Engine(model, params, max_slots=1, capacity=64)
    eng1.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    alone = eng1.run_until_done()[0]

    eng2 = Engine(model, params, max_slots=4, capacity=64)
    eng2.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    eng2.submit(Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 9),
                        max_new_tokens=4))
    together = eng2.run_until_done()[0]
    assert alone == together
