"""Cross-engine conformance matrix: every case of
``tests/engine_conformance.py`` swept over the packing x engine x
schedule x backend x n_sms cube, asserted bit-identical against the
inline step machine — the differential oracle every engine (step,
trace, megakernel) and both backends must match at the same
(schedule, n_sms, packing) point.
Comparing every cell against ONE oracle makes the matrix transitive:
inline-trace, pallas-step and pallas-trace all collapse onto the same
architectural state, so any engine/backend drift anywhere in the cube
fails here. Packed ("length") cells additionally assert ARCHITECTURAL
identity against the grid-order oracle: wave packing may change which
blocks share a wave (and with it the modeled timing), never observable
state.

A hypothesis fuzz extends the table with random legal heterogeneous
grids (random program mix, grid_map, block sizes, priorities). The fuzz
programs draw every data op EXCEPT global stores: blocks that may run
concurrently must not race through global memory (the launch contract —
see ``device.launch``), and random programs cannot guarantee disjoint
GST targets across programs; the single-program fuzz in
``tests/test_trace_engine.py`` covers GST, and the declarative cases
cover fenced (``Kernel(barrier=True)``) and PID-disjoint global stores.

Run standalone with ``pytest -m conformance``.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import DeviceConfig, Kernel, SMConfig, assemble, launch
from repro.core.isa import Depth, Instr, Op, Typ, Width

from engine_conformance import (
    BACKENDS,
    CASES,
    assert_arch_identical,
    assert_bit_identical,
    cube,
)

pytestmark = pytest.mark.conformance

_ORACLE_CACHE: dict = {}


def _oracle(name, schedule, n_sms, packing="grid"):
    """The inline step machine's result for one cell (cached per module:
    every cube cell of a case shares its oracle). Packed cells get a
    packing-matched oracle — the step machine's timing consumes the same
    wave packing — and additionally compare architectural state against
    the grid-order oracle."""
    key = (name, schedule, n_sms, packing)
    if key not in _ORACLE_CACHE:
        _ORACLE_CACHE[key] = CASES[name].build("step", schedule, "inline",
                                               n_sms, packing)
    return _ORACLE_CACHE[key]


def _cells():
    for backend in BACKENDS:
        for name, schedule, n_sms, packing in cube(backend):
            if backend == "inline":
                engines = ("trace", "megakernel")
            else:
                # megakernel-Pallas traces one fused kernel per segment —
                # slow under the interpreter, so cover it at each case's
                # widest Pallas point instead of the full sub-cube
                engines = ("step", "trace")
                if (packing == "grid" and schedule == "static"
                        and n_sms == CASES[name].pallas_sms[-1]):
                    engines += ("megakernel",)
            for engine in engines:
                yield name, schedule, backend, n_sms, engine, packing


@pytest.mark.parametrize("name,schedule,backend,n_sms,engine,packing",
                         list(_cells()))
def test_conformance_cube(name, schedule, backend, n_sms, engine, packing):
    case = CASES[name]
    res = case.build(engine, schedule, backend, n_sms, packing)
    assert res.engine == engine and res.schedule == schedule
    assert res.packing == packing
    if engine == "trace" and case.heterogeneous:
        # the merged heterogeneous path must actually be the one running
        merge = res.profile().get("trace_merge")
        assert merge and merge["n_waves"] >= 1
        assert merge["pad_overhead"] >= 0.0
        assert merge["policy"] == packing
        # the launch-level aggregate really aggregates the per-wave stats
        assert merge["pad_overhead_total"] == \
            sum(w["padded_steps"] for w in merge["per_wave"])
    if engine == "megakernel" and case.heterogeneous:
        # merged megakernel waves execute NO padded rows — short members
        # just stop fusing earlier; the only cross-slot coupling is the
        # globally-ordered gmem drains, surfaced as fusion stats
        merge = res.profile().get("trace_merge")
        assert merge and merge["n_waves"] >= 1
        assert merge["pad_overhead"] == 0.0
        fus = merge["fusion"]
        assert fus["segments"] >= 1 and fus["fused_rows"] > 0
        assert 0 <= fus["folded_rows"] <= fus["fused_rows"]
        assert fus["max_fused_run"] <= fus["fused_rows"]
    # full bit-identity (state + counters) against the packing-matched
    # step-inline oracle: all engines and backends agree on the waves
    # that actually ran
    assert_bit_identical(res, _oracle(name, schedule, n_sms, packing))
    if packing != "grid":
        # the packing-invariance contract: packed cells are
        # architecturally identical to the GRID-ORDER oracle — packing
        # changes which blocks share a wave, never observable state
        assert_arch_identical(res, _oracle(name, schedule, n_sms))


# ---------------------------------------------------------------------------
# engine plumbing the matrix relies on
# ---------------------------------------------------------------------------

def test_trace_on_mixed_grid_runs_merged_not_fallback():
    # the PR-3 engine ran mixed grids as per-program homogeneous waves;
    # engine="trace" must now take the merged heterogeneous path and say so
    res = CASES["mixed_fft_qrd"].build("trace", "dynamic", "inline", 2,
                                       "grid")
    assert res.engine == "trace" and res.engine_fallback is None
    merge = res.profile()["trace_merge"]
    assert merge["n_waves"] >= 1 and merge["scan_steps"] > 0
    # interleaved FFT+QRD waves really are heterogeneous
    assert any(len(w["programs"]) > 1 for w in merge["per_wave"])
    # padding accounting: no-op rows never exceed scheduled rows
    assert 0.0 <= merge["pad_overhead"] < 1.0
    # per-wave pad stats + the launch-level aggregate agree
    assert merge["pad_overhead_total"] == \
        sum(w["padded_steps"] for w in merge["per_wave"])
    for w in merge["per_wave"]:
        assert 0.0 <= w["pad_overhead"] < 1.0


def test_length_packing_reduces_interleaved_merge_padding():
    # the interleaved FFT+QRD grid is the pad-adversarial shape: grid
    # order pairs every short FFT schedule with the long QRD one, so
    # every wave pads the FFT members; length packing segregates them
    grid = CASES["mixed_fft_qrd"].build("trace", "dynamic", "inline", 2,
                                        "grid")
    packed = CASES["mixed_fft_qrd"].build("trace", "dynamic", "inline", 2,
                                          "length")
    g = grid.profile()["trace_merge"]
    p = packed.profile()["trace_merge"]
    assert p["policy"] == "length" and g["policy"] == "grid"
    assert p["pad_overhead_total"] <= g["pad_overhead_total"]
    assert p["pad_overhead"] <= g["pad_overhead"]
    assert_arch_identical(packed, grid)


def test_auto_engine_fallback_is_profile_visible():
    runaway = assemble("top:\nTDX R1\nJMP top")
    dcfg = DeviceConfig(n_sms=2, global_mem_depth=64,
                        sm=SMConfig(max_steps=50))
    res = launch(dcfg, runaway, grid=(1,), block=16)
    assert res.engine == "step"
    assert res.profile()["engine_fallback"] == "fuel-limited-trace"
    # an explicit engine choice is never a fallback
    res = launch(dcfg, runaway, grid=(1,), block=16, engine="step")
    assert res.profile()["engine_fallback"] is None


def test_auto_engine_merges_mixed_grids():
    # auto's first choice is the megakernel — mixed grids take its merged
    # heterogeneous path (fused slots + globally-ordered gmem drains)
    res = CASES["mixed_fft_qrd"].build("auto", "auto", "inline", 2, "grid")
    assert res.engine == "megakernel" and res.engine_fallback is None
    assert res.trace_merge is not None
    assert res.trace_merge["fusion"]["fused_rows"] > 0


def test_auto_packing_resolves_length_on_mixed_grids():
    res = CASES["mixed_fft_qrd"].build("trace", "dynamic", "inline", 2,
                                       "auto")
    assert res.packing == "length"
    assert res.profile()["trace_merge"]["policy"] == "length"
    # homogeneous grids resolve to grid — packing stays a no-op there
    res = CASES["saxpy64_b16"].build("trace", "static", "inline", 2, "auto")
    assert res.packing == "grid"


def test_forced_trace_merges_fuel_limited_mixed_grid():
    # a merged wave pads every member to the LONGEST participant — a
    # fuel-limited trace must still replay exactly alongside a halting one
    runaway = assemble("top:\nTDX R1\nADD.INT32 R2, R1, R1\n"
                       "STO R2, (R1)+0\nJMP top").words
    short = assemble("TDX R3\nSTO R3, (R3)+32\nSTOP").words
    kerns = [Kernel(runaway, block=16, name="runaway"),
             Kernel(short, block=16, name="short")]
    outs = {}
    for eng in ("step", "trace"):
        dcfg = DeviceConfig(n_sms=2, global_mem_depth=64, engine=eng,
                            sm=SMConfig(shmem_depth=64, max_steps=37))
        outs[eng] = launch(dcfg, programs=kerns, grid_map=[0, 1])
    assert outs["trace"].trace_merge is not None
    assert not outs["trace"].halted
    assert_bit_identical(outs["step"], outs["trace"])


# ---------------------------------------------------------------------------
# fuzz: random legal heterogeneous grids
# ---------------------------------------------------------------------------

_DATA_OPS = [Op.ADD, Op.SUB, Op.MUL, Op.AND, Op.OR, Op.XOR, Op.LSL,
             Op.LSR, Op.LODI, Op.TDX, Op.TDY, Op.BID, Op.PID, Op.LOD,
             Op.STO, Op.GLD, Op.DOT, Op.SUM, Op.INVSQR, Op.NOP]


def _data_instr(draw):
    op = draw(st.sampled_from(_DATA_OPS))
    return Instr(op=op, typ=draw(st.sampled_from(list(Typ))),
                 rd=draw(st.integers(0, 15)), ra=draw(st.integers(0, 15)),
                 rb=draw(st.integers(0, 15)),
                 imm=draw(st.integers(0, 31)),
                 width=draw(st.sampled_from(list(Width))),
                 depth=draw(st.sampled_from(list(Depth))))


@st.composite
def _random_program(draw):
    """pre | INIT t; body; LOOP | STOP — terminating by construction."""
    pre = [_data_instr(draw) for _ in range(draw(st.integers(0, 3)))]
    body = [_data_instr(draw) for _ in range(draw(st.integers(1, 4)))]
    trip = draw(st.integers(1, 4))
    prog = list(pre)
    prog.append(Instr(op=Op.INIT, imm=trip))
    body_start = len(prog)
    prog.extend(body)
    prog.append(Instr(op=Op.LOOP, imm=body_start))
    prog.append(Instr(op=Op.STOP))
    return np.array([i.encode() for i in prog], np.int64)


@st.composite
def _random_grid(draw):
    n_progs = draw(st.integers(2, 3))
    progs = [draw(_random_program()) for _ in range(n_progs)]
    blocks = [draw(st.sampled_from([16, 32, 48])) for _ in range(n_progs)]
    prios = [draw(st.integers(0, 3)) for _ in range(n_progs)]
    gmap = draw(st.lists(st.integers(0, n_progs - 1), min_size=2,
                         max_size=7))
    return progs, blocks, prios, gmap


@settings(max_examples=25, deadline=None)
@given(grid=_random_grid(), seed=st.integers(0, 2**31 - 1),
       n_sms=st.integers(1, 3),
       schedule=st.sampled_from(["static", "dynamic"]),
       packing=st.sampled_from(["grid", "length", "auto"]))
def test_fuzz_heterogeneous_grid_conformance(grid, seed, n_sms, schedule,
                                             packing):
    progs, blocks, prios, gmap = grid
    rng = np.random.default_rng(seed)
    gmem = rng.standard_normal(64).astype(np.float32)
    shmems = [rng.standard_normal(
        (int(np.sum(np.asarray(gmap) == k)) or 1, 64)).astype(np.float32)
        for k in range(len(progs))]
    kerns = [Kernel(p, block=b, priority=pr)
             for p, b, pr in zip(progs, blocks, prios)]
    outs = {}
    for engine in ("step", "trace", "megakernel"):
        dcfg = DeviceConfig(n_sms=n_sms, global_mem_depth=64,
                            engine=engine,
                            sm=SMConfig(shmem_depth=64, max_steps=500))
        outs[engine] = launch(
            dcfg, programs=kerns, grid_map=gmap, gmem=gmem,
            shmem=[shmems[k] if (np.asarray(gmap) == k).any() else None
                   for k in range(len(progs))],
            schedule=schedule, packing=packing)
    if len(set(gmap)) > 1:
        assert outs["trace"].trace_merge is not None
    assert_bit_identical(outs["step"], outs["trace"])
    assert_bit_identical(outs["step"], outs["megakernel"])
