"""Roofline table from the dry-run results (EXPERIMENTS.md §Roofline).

Reads benchmarks/results/dryrun.jsonl (produced by repro.launch.dryrun)
and emits one line per (arch x shape) single-pod cell: the three terms,
the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs.
"""
from __future__ import annotations

import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")
RESULTS_OPT = os.path.join(os.path.dirname(__file__), "results",
                           "dryrun_optimized.jsonl")


def load_rows(path: str = RESULTS, mesh: str = "16x16"):
    if not os.path.exists(path):
        return []
    rows = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("mesh") == mesh:
                rows[(r["arch"], r["shape"])] = r
    return [rows[k] for k in sorted(rows)]


def run():
    rows = load_rows()
    if not rows:
        emit("roofline", 0.0, "no dryrun.jsonl yet — run "
             "`python -m repro.launch.dryrun` first")
        return
    _emit_rows(rows, "roofline")
    opt = load_rows(RESULTS_OPT)
    if opt:
        _emit_rows(opt, "roofline_optimized")


def _emit_rows(rows, prefix):
    for r in rows:
        name = f"{prefix}.{r['arch']}.{r['shape']}"
        if r.get("status") == "skipped":
            emit(name, 0.0, "SKIPPED full-attention 500k (DESIGN.md)")
            continue
        if r.get("status") != "ok":
            emit(name, 0.0, f"ERROR {r.get('error', '?')[:80]}")
            continue
        if "compute_s" not in r:
            emit(name, 0.0, "compiled ok (multi-pod proof cell)")
            continue
        emit(name, r.get("compile_s", 0) * 1e6,
             f"compute={r['compute_s']:.3g}s memory={r['memory_s']:.3g}s "
             f"collective={r['collective_s']:.3g}s dominant={r['dominant']} "
             f"useful={r['useful_flops_ratio']:.2f} "
             f"roofline_frac={r['roofline_fraction']:.3g} "
             f"peak_mem={r['peak_bytes_per_device'] / 2**30:.2f}GiB")


if __name__ == "__main__":
    run()
