"""Paper Table IV: 16x16 MGS QRD cycle profile on the eGPU ISS.

Our unrolled (paper-faithful) program reproduces the table's rows —
STO=33, DOT=17, SFU=1 exactly; LOD/ADDSUB/NOP within ~5% — and the derived
column reports the paper's efficiency argument: the dot-product unit does
31 flops per instruction, so "true" flops/cycle is far above 1-op/cycle
accounting (paper §IV.B).
"""
from __future__ import annotations

import numpy as np

from repro.core import profile, resources
from repro.core.programs.qrd import qrd_program, run_qrd

from .common import emit, time_fn


def run():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((16, 16)).astype(np.float32)
    t = time_fn(lambda: run_qrd(a), warmup=1, iters=1)
    q, r, st = run_qrd(a)
    qr_err = float(np.max(np.abs(q @ r - a)))
    p = profile(st)
    per = {k: v / 16 for k, v in p["by_class"].items()}
    paper = {"NOP": 44, "INT": 16, "LOD_IDX": 132, "FP_ADDSUB": 16,
             "FP_MUL": 32, "FP_DOT": 17, "FP_SFU": 1, "STO_IDX": 33}
    derived = " ".join(f"{k}={per.get(k, 0):.0f}(paper {v})"
                       for k, v in paper.items())
    emit("table4_qrd_profile", t, f"qr_err={qr_err:.1e} " + derived)

    # the efficiency argument: MGS flops vs cycles
    flops = 16 * (2 * 16 + 31 + 4 + 16 + 2 * 16 * 16)  # dots+scale+proj
    tot = p["total_cycles"]
    fmax = resources.fmax_mhz(1) * 1e6
    emit("table4_qrd_efficiency", 0.0,
         f"cycles_total={tot} cycles_per_iter={tot / 16:.0f} (paper 291) "
         f"gflops@771MHz={flops / (tot / fmax) / 1e9:.2f} "
         f"words_loop={len(qrd_program(loop=True))} (paper 40) "
         f"words_unrolled={len(qrd_program())}")


if __name__ == "__main__":
    run()
