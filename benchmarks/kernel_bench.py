"""Pallas kernel micro-bench (interpret mode on CPU).

The us_per_call numbers are CPU-interpreter wall times — NOT TPU
performance (this container has no TPU). The derived column carries the
structural facts that do transfer: VMEM tile bytes per grid step and
arithmetic intensity, which determine the TPU roofline position.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import emit, time_fn

RNG = np.random.default_rng(0)


def run_device_launch():
    """Device-layer launch throughput: a grid SAXPY through both execute
    backends. The Pallas backend runs each multi-SM step as ONE simt_alu
    grid over the wave's SM batch (interpreted here; compiled on TPU)."""
    from repro.core import DeviceConfig, SMConfig
    from repro.core.programs.saxpy import launch_saxpy

    n, block = 2048, 512
    x = RNG.standard_normal(n).astype(np.float32)
    y = RNG.standard_normal(n).astype(np.float32)
    dcfg = DeviceConfig(n_sms=4, global_mem_depth=3 * n + 16,
                        sm=SMConfig(max_steps=10_000))
    for backend in ("inline", "pallas"):
        z, res = launch_saxpy(2.0, x, y, device=dcfg, block=block,
                              backend=backend)
        t = time_fn(lambda b=backend: launch_saxpy(2.0, x, y, device=dcfg,
                                                   block=block, backend=b),
                    warmup=1, iters=1)
        emit(f"device_launch_saxpy_{backend}", t,
             f"grid={n // block} block={block} n_sms=4 waves={res.n_waves} "
             f"cycles={res.cycles} exact={np.allclose(z, 2 * x + y)}")


def run():
    # simt_alu: 16 SMs x 512 threads
    a = jnp.asarray(RNG.integers(0, 2**31, (16, 512), dtype=np.uint32))
    ones = jnp.ones((16, 512), jnp.uint32)
    t = time_fn(lambda: ops.alu(1, 2, a, a, ones, a).block_until_ready())
    emit("kernel_simt_alu", t,
         "tile=(8,512)u32x5=80KiB_VMEM elems=8192 fp32_exact=yes")

    af = jnp.asarray(RNG.standard_normal((16, 512)), jnp.float32)
    t = time_fn(lambda: ops.dot(af, af).block_until_ready())
    emit("kernel_wavefront_dot", t,
         "tile=(8,512)f32x3 reduce=16lanes flops_per_instr=31")

    A = jnp.asarray(RNG.standard_normal((64, 16, 16)), jnp.float32)
    t = time_fn(lambda: ops.qrd(A)[0].block_until_ready())
    flops = 64 * (4 * 16 ** 3)  # ~4n^3 for MGS
    emit("kernel_mgs_qrd", t,
         f"batch=64x16x16 tile=(32,16,16)=32KiB flops~{flops} "
         f"vmem_resident_factorization=yes")

    re = jnp.asarray(RNG.standard_normal((16, 256)), jnp.float32)
    im = jnp.zeros((16, 256), jnp.float32)
    t = time_fn(lambda: ops.fft(re, im)[0].block_until_ready())
    emit("kernel_fft_r2", t,
         "batch=16x256 passes=8_in_VMEM hbm_traffic_between_passes=0B")

    q = jnp.asarray(RNG.standard_normal((4, 256, 64)), jnp.float32)
    t = time_fn(lambda: ops.flash(q, q, q, blk_q=64, blk_k=64)
                .block_until_ready())
    emit("kernel_flash_attention", t,
         "bh=4 s=256 d=64 online_softmax s2_tiles_in_VMEM_only=yes "
         "(deploys the SPerf cell-C blocking win)")

    run_device_launch()


if __name__ == "__main__":
    run()
