"""Paper Table V: per-block resource report + §III.E sector packing.

Emits the block inventory (verbatim reproduction target) and the derived
sector-packing arithmetic: 4 SMs/sector, 27 shared-memory M20Ks per eGPU
(3K words / 12 KiB quad-ported), 16 dot-product DSPs, 4100 ALM budget.
"""
from __future__ import annotations

from repro.core import resources as R

from .common import emit, time_fn


def run():
    t = time_fn(R.table_v)
    for name, row in R.table_v().items():
        emit(f"table5.{name.replace(' ', '_')}", 0.0,
             f"alm={row.alms:.0f} regs={row.registers:.0f} "
             f"dsp={row.dsps} m20k={row.m20ks:.0f}")
    p = R.pack_sector(4)
    emit("table5_sector_packing", t,
         f"sms=4 regfile_m20k={p.regfile_m20ks} sm_dsp={p.dsps_for_sms} "
         f"shared_m20k_per_egpu={p.shared_copies_per_egpu} "
         f"shared_words={p.shared_depth_words} shared_kb={p.shared_bytes // 1024} "
         f"dot_dsp={p.dot_dsps_per_egpu} alm_budget={p.alm_budget_per_egpu}")
    emit("table5_fmax_model", 0.0,
         f"single={R.fmax_mhz(1):.0f}MHz soft_logic={R.fmax_mhz(1, use_dsp_fp32=False):.0f}MHz "
         f"quad={R.fmax_mhz(4):.0f}MHz (paper: 771/831/738)")
    emit("table5_peak_gflops", 0.0,
         f"one_sm={R.peak_gflops(1):.1f} quad_sector={R.peak_gflops(4):.1f}")


if __name__ == "__main__":
    run()
