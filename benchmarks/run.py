"""Benchmark harness: one function per paper table + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import (kernel_bench, roofline_bench, table1_resources,
                   table3_fft, table4_qrd, table5_resources)

    print("name,us_per_call,derived")
    table1_resources.run()
    table3_fft.run()
    table4_qrd.run()
    table5_resources.run()
    kernel_bench.run()
    roofline_bench.run()


if __name__ == "__main__":
    main()
