"""Benchmark harness: one function per paper table + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV lines.

``--smoke`` runs a fast CI-friendly probe: every benchmark module is
imported (so entry points can't silently rot), the cheap analytic tables
run in full, and the expensive ISS/kernel benches run one minimal case.
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import (engine_bench, fleet_bench, kernel_bench,
                   roofline_bench, serve_bench, table1_resources,
                   table3_fft, table4_qrd, table5_resources)

    print("name,us_per_call,derived")
    table1_resources.run()
    table3_fft.run()
    table4_qrd.run()
    table5_resources.run()
    kernel_bench.run()
    engine_bench.run()
    serve_bench.run()
    fleet_bench.run()
    roofline_bench.run()


def smoke() -> None:
    # importing every module is the point: a bitrotted benchmark fails here
    from . import (engine_bench, fleet_bench, kernel_bench,  # noqa: F401
                   roofline_bench, serve_bench, table1_resources,
                   table3_fft, table4_qrd, table5_resources)
    import numpy as np

    print("name,us_per_call,derived")
    table1_resources.run()
    table5_resources.run()
    # one minimal ISS case: FFT-32 profile
    derived, cycles = table3_fft._profile_line(32)
    print(f"smoke_fft32,0.0,{derived}")
    assert cycles > 0
    # one minimal device-layer launch through both execute backends
    from repro.core import DeviceConfig, SMConfig
    from repro.core.programs.saxpy import launch_saxpy

    x = np.arange(32, dtype=np.float32)
    y = np.ones(32, np.float32)
    dcfg = DeviceConfig(n_sms=2, global_mem_depth=128,
                        sm=SMConfig(max_steps=1000))
    for backend in ("inline", "pallas"):
        z, res = launch_saxpy(3.0, x, y, device=dcfg, block=16,
                              backend=backend)
        assert np.allclose(z, 3.0 * x + y), backend
        print(f"smoke_launch_{backend},0.0,waves={res.n_waves} "
              f"cycles={res.cycles}")
    # one heterogeneous launch through the dynamic block scheduler
    from repro.core.programs import launch_fft_qrd

    rng = np.random.default_rng(0)
    xs = (rng.standard_normal((2, 32))
          + 1j * rng.standard_normal((2, 32))).astype(np.complex64)
    As = rng.standard_normal((1, 16, 16)).astype(np.float32)
    X, Q, R, mres = launch_fft_qrd(xs, As)
    assert np.allclose(X, np.fft.fft(xs, axis=1), atol=1e-4)
    assert np.allclose(np.einsum("bij,bjk->bik", Q, R), As, atol=1e-4)
    assert mres.schedule == "dynamic" and mres.cycles <= mres.static_cycles
    # auto must take the merged heterogeneous MEGAKERNEL path (and say
    # so): fused segments per slot, zero padded rows, fusion stats
    assert mres.engine == "megakernel", \
        mres.profile()["engine_fallback"]
    merge = mres.profile()["trace_merge"]
    assert merge["n_waves"] >= 1
    assert merge["pad_overhead"] == 0.0
    assert merge["fusion"]["fused_rows"] > 0
    assert merge["fusion"]["folded_rows"] >= 0
    print(f"smoke_mixed_launch,0.0,dynamic={mres.cycles} "
          f"static={mres.static_cycles} "
          f"fused={merge['fusion']['fused_rows']} "
          f"folded={merge['fusion']['folded_rows']}")
    # wave packing: on the backloaded mixed grid (grid-order waves
    # straddle the FFT/QRD boundary) length packing must cut the
    # launch-level pad aggregate by >= 25% — a deterministic gate on the
    # packer itself, independent of wall-clock jitter. Bit-identity of
    # packed results is the conformance suite's job.
    from repro.core.programs.mixed import launch_fft_qrd as _lfq
    from repro.core.programs.mixed import mixed_device

    xs6 = (rng.standard_normal((6, 32))
           + 1j * rng.standard_normal((6, 32))).astype(np.complex64)
    As3 = rng.standard_normal((3, 16, 16)).astype(np.float32)
    pads = {}
    for pol in ("grid", "length"):
        _, _, _, pres = _lfq(xs6, As3, device=mixed_device(32, n_sms=4),
                             engine="trace", interleave=False, packing=pol)
        tm = pres.profile()["trace_merge"]
        assert tm["policy"] == pol
        pads[pol] = tm["pad_overhead_total"]
    assert pads["grid"] > 0, "backloaded mixed grid lost its pad overhead"
    assert pads["length"] <= 0.75 * pads["grid"], (
        f"length packing cut pad_overhead_total by < 25%: {pads}")
    print(f"smoke_packed_launch,0.0,pad_total {pads['grid']}->"
          f"{pads['length']}")
    # step/trace/megakernel engine wall clock; writes BENCH_engine.json
    # and gates CI on the trace engine not losing on the FFT/QRD lines,
    # beating 1.2x on the merged heterogeneous mixed line, the
    # megakernel beating the trace scan >= 1.5x on FFT64/QRD16 (and
    # never losing on the mixed line), and the auto ladder landing
    # within 0.95x of the best fixed engine on EVERY line; also times
    # the persistent compile cache's cold-vs-warm lowering
    engine_bench.run(smoke=True)
    # the serving front door under open-loop mixed FFT+QRD traffic;
    # writes BENCH_serve.json and gates CI on continuous batching
    # beating serial one-launch-at-a-time dispatch >= 1.2x in
    # requests/sec (plus the deterministic modeled-makespan bound)
    serve_bench.run(smoke=True)
    # the device fleet: writes BENCH_fleet.json and gates CI on
    # fleet(4) reaching >= 1.5x the single-device modeled throughput
    # on the mixed FFT64+QRD16 grid, with every point asserted
    # bit-identical to the single device before it counts
    fleet_bench.run(smoke=True)
    print("smoke_ok,0.0,all benchmark entry points importable")


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
