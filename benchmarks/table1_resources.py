"""Paper Table I: resource comparison vs FGPU / FlexGrip.

The eGPU row comes from our analytical model (core/resources.py); the
derived column checks the paper's headline claims: ~1/10 the ALMs of
FlexGrip at ~8x the Fmax, 3x FGPU's Fmax.
"""
from __future__ import annotations

from repro.core import resources as R

from .common import emit, time_fn


def run():
    t = time_fn(R.table_i)
    tab = R.table_i()
    e, fg, fx = tab["eGPU"], tab["FGPU"], tab["FlexGrip"]
    derived = (f"eGPU={e['alm']}ALM/{e['dsp']}DSP/{e['fmax_mhz']}MHz"
               f" alm_vs_flexgrip={fx['alm'] / e['alm']:.1f}x"
               f" fmax_vs_flexgrip={e['fmax_mhz'] / fx['fmax_mhz']:.2f}x"
               f" fmax_vs_fgpu={e['fmax_mhz'] / fg['fmax_mhz']:.2f}x")
    emit("table1_resource_comparison", t, derived)
    for name, row in tab.items():
        emit(f"table1.{name}", 0.0,
             f"config={row['config']} alm={row['alm']} dsp={row['dsp']} "
             f"fmax={row['fmax_mhz']}MHz")


if __name__ == "__main__":
    run()
