"""Device-fleet scale-out benchmark: N simulated eGPUs vs one.

Runs the golden mixed FFT64+QRD16 workload (8 FFT + 4 QRD blocks,
interleaved) through ``core.launch_fleet`` at ``n_devices`` = 1, 2, 4
and reports, per point:

* **modeled throughput** — blocks per kilocycle of the fleet makespan.
  This is the deterministic scaling number the smoke gate pins:
  ``fleet(4)`` must reach >= 1.5x the single-device throughput on this
  grid (same blocks, same programs, only more devices — the paper's
  tightly-packed multi-eGPU sector claim as a cycle-model statement).
  The host is usually a 1-2 core CI runner, so WALL clock does not
  scale — the model is the product here, exactly like the cycle goldens.
* **wall clock** — best-of-``repeats`` per fleet launch, for the
  archive (not gated).
* **bit-identity** — every point is asserted architecturally identical
  (regs/shmem/gmem/oob/halted) to the single-device launch before any
  number is reported. A fleet that scales by computing something else
  fails here, not in the throughput gate.

Two extra deterministic lines land in ``BENCH_fleet.json``:

* ``numa_saxpy256`` — the remote-gmem NUMA charge on the gmem-heavy
  saxpy grid (``FleetConfig(remote_gmem_latency=7)``): total charged
  cycles and the makespan delta vs latency 0.
* ``shard_map_saxpy512`` — when jax exposes >= 2 devices (CI forces 4
  via ``XLA_FLAGS=--xla_force_host_platform_device_count=4``), the
  uniform saxpy grid under ``placement="shard_map"``: the real-JAX-
  devices path, asserted bit-identical to the host path.
"""
from __future__ import annotations

import json
import time

import numpy as np

from .common import emit


def _time_launch(fn, repeats: int) -> float:
    """Best-of-``repeats`` wall clock of ``fn()`` after one warmup."""
    fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _assert_state_equal(a, b, what: str) -> None:
    """Architectural identity (state, not timing) of two launches."""
    for field in ("regs", "shmem", "gmem", "oob"):
        assert np.array_equal(np.asarray(getattr(a, field)),
                              np.asarray(getattr(b, field))), \
            f"{what}: {field} diverged from the single device"
    assert a.halted == b.halted, f"{what}: halted diverged"


def _mixed_case(n_fft: int = 8, n_qrd: int = 4, sms_per_dev: int = 1):
    """The scaling workload: interleaved FFT64 + QRD16 grid and the
    per-device config. One SM per device keeps the single-device
    baseline serial, so the scaling headroom is the device axis itself."""
    from repro.core.programs.fft import fft_kernel, fft_shmem
    from repro.core.programs.mixed import mixed_device
    from repro.core.programs.qrd import qrd_kernel, qrd_shmem

    dcfg = mixed_device(64, n_sms=sms_per_dev)
    rng = np.random.default_rng(42)
    xs = (rng.standard_normal((n_fft, 64))
          + 1j * rng.standard_normal((n_fft, 64))).astype(np.complex64)
    As = np.stack([np.eye(16, dtype=np.float32)
                   + 0.05 * rng.standard_normal((16, 16)).astype(np.float32)
                   for _ in range(n_qrd)])
    sh_f = np.stack([fft_shmem(x, dcfg.sm.shmem_depth) for x in xs])
    sh_q = np.stack([qrd_shmem(A, dcfg.sm.shmem_depth) for A in As])
    gmap: list[int] = []
    for i in range(max(n_fft, n_qrd)):
        if i < n_fft:
            gmap.append(0)
        if i < n_qrd:
            gmap.append(1)
    kw = dict(programs=[fft_kernel(64), qrd_kernel()], grid_map=gmap,
              shmem=[sh_f, sh_q])
    return dcfg, kw


def _saxpy_case(n: int = 512, block: int = 64):
    from repro.core import DeviceConfig, SMConfig
    from repro.core.programs.saxpy import saxpy_grid_program

    rng = np.random.default_rng(7)
    buffers = {
        "x": rng.standard_normal(n).astype(np.float32),
        "y": rng.standard_normal(n).astype(np.float32),
        "z": np.zeros(n, np.float32),
        "alpha": np.asarray([1.5], np.float32),
    }
    dcfg = DeviceConfig(n_sms=2, global_mem_depth=3 * n + 16,
                        sm=SMConfig(max_steps=10_000))
    kw = dict(program=saxpy_grid_program(n, block), grid=(n // block,),
              block=block, buffers=buffers)
    return dcfg, kw


def run(smoke: bool = False, out: str = "BENCH_fleet.json") -> dict:
    import jax

    from repro.core import FleetConfig, launch_fleet

    repeats = 2 if smoke else 4
    results: dict[str, dict] = {}

    dcfg, kw = _mixed_case()
    mixed_name = "mixed_fft8_qrd4"
    base = launch_fleet(FleetConfig(n_devices=1, device=dcfg), **kw)
    n_blocks = base.n_blocks
    thr: dict[int, float] = {}
    for n_dev in (1, 2, 4):
        fcfg = FleetConfig(n_devices=n_dev, device=dcfg)
        res = launch_fleet(fcfg, **kw)
        _assert_state_equal(res, base, f"fleet({n_dev}) {mixed_name}")
        wall_s = _time_launch(lambda: launch_fleet(fcfg, **kw), repeats)
        fleet = res.profile()["fleet"]
        thr[n_dev] = n_blocks / res.cycles * 1e3   # blocks per kilocycle
        results[f"fleet{n_dev}_{mixed_name}"] = {
            "n_devices": n_dev,
            "blocks": n_blocks,
            "cycles": int(res.cycles),
            "blocks_per_kcycle": round(thr[n_dev], 3),
            "wall_us": round(wall_s * 1e6, 1),
            "placement": fleet["placement"],
            "per_device_blocks": [d["blocks"]
                                  for d in fleet["per_device"]],
        }
        emit(f"fleet{n_dev}_{mixed_name}", wall_s * 1e6,
             f"cycles={res.cycles} "
             f"thr={thr[n_dev]:.2f}blk/kc "
             f"placement={fleet['placement']}")
    scaling = round(thr[4] / thr[1], 3)
    results["scaling"] = {
        "thr4_vs_thr1": scaling,
        "thr2_vs_thr1": round(thr[2] / thr[1], 3),
        "bit_identical": True,      # _assert_state_equal gates every point
    }
    emit("fleet_scaling", 0.0,
         f"thr4_vs_thr1={scaling:.2f}x thr2_vs_thr1="
         f"{thr[2] / thr[1]:.2f}x bit_identical=True")

    # NUMA: the deterministic remote-gmem charge on a gmem-heavy grid
    sdcfg, skw = _saxpy_case()
    flat = launch_fleet(FleetConfig(n_devices=2, device=sdcfg), **skw)
    numa = launch_fleet(FleetConfig(n_devices=2, device=sdcfg,
                                    remote_gmem_latency=7), **skw)
    _assert_state_equal(numa, flat, "numa saxpy512")
    results["numa_saxpy512"] = {
        "remote_gmem_latency": 7,
        "remote_gmem_cycles":
            numa.profile()["fleet"]["remote_gmem_cycles"],
        "cycles_flat": int(flat.cycles),
        "cycles_numa": int(numa.cycles),
    }
    emit("fleet_numa_saxpy512", 0.0,
         f"charge={results['numa_saxpy512']['remote_gmem_cycles']}cyc "
         f"makespan {flat.cycles}->{numa.cycles}")

    # shard_map: the real-JAX-devices path, when the host exposes them
    n_jax = len(jax.devices())
    if n_jax >= 2:
        n_dev = 4 if n_jax >= 4 else 2
        fcfg = FleetConfig(n_devices=n_dev, device=sdcfg,
                           placement="shard_map")
        res = launch_fleet(fcfg, **skw)
        _assert_state_equal(res, flat, f"shard_map({n_dev}) saxpy512")
        wall_s = _time_launch(lambda: launch_fleet(fcfg, **skw), repeats)
        results["shard_map_saxpy512"] = {
            "n_devices": n_dev,
            "jax_devices": n_jax,
            "cycles": int(res.cycles),
            "wall_us": round(wall_s * 1e6, 1),
            "placement": res.profile()["fleet"]["placement"],
        }
        emit(f"fleet_shard_map{n_dev}_saxpy512", wall_s * 1e6,
             f"cycles={res.cycles} jax_devices={n_jax}")
    else:
        results["shard_map_saxpy512"] = {
            "skipped": f"jax exposes {n_jax} device(s); run under "
                       "XLA_FLAGS=--xla_force_host_platform_device_count=4"
        }

    with open(out, "w") as f:
        json.dump({"smoke": smoke, "repeats": repeats,
                   "lines": results}, f, indent=2)
        f.write("\n")

    if smoke:
        # the scale-out gate: modeled throughput (deterministic — no
        # jitter retry needed) must reach 1.5x at 4 devices, with
        # bit-identity already asserted above on every point
        assert scaling >= 1.5, (
            f"fleet(4) modeled throughput below the 1.5x gate on "
            f"{mixed_name}: {results['scaling']}")
        assert results["numa_saxpy512"]["remote_gmem_cycles"] > 0, \
            "NUMA tier charged nothing on the gmem-heavy saxpy grid"
    return results
