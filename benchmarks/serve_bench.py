"""LaunchServer open-loop traffic benchmark: batched vs serial dispatch.

A synthetic open-loop generator emits the mixed FFT64+QRD16 request mix
(the golden heterogeneous workload, 2:1) with seeded exponential
inter-arrival times on the device's virtual cycle clock, plus a sprinkle
of high-priority tenants. The same request trace is served twice:

``serial``
    one-launch-at-a-time dispatch (``max_batch=1``) — every request pays
    the full host dispatch latency and runs its own single-block wave;

``batched``
    continuous batching (``max_batch=2*n_sms``) — pending compatible
    requests coalesce into merged heterogeneous waves (PR 4/5 machinery),
    amortizing host dispatch and filling SM slots.

Two views are reported per mode, and both land in ``BENCH_serve.json``:

* **wall clock** — requests/sec of draining the whole trace on this
  host (warm caches; best of ``repeats``). The smoke gate asserts
  batched >= 1.2x serial here: continuous batching must win in real
  time, not just in the model.
* **modeled cycles** — deterministic per-request latency percentiles
  (p50/p99 of arrival -> last-block-retire on the virtual clock,
  host dispatch + queueing included) and batch occupancy. Same trace,
  same numbers, every run — the regression-friendly view.
"""
from __future__ import annotations

import json
import time

import numpy as np

from .common import emit


def _mixed_trace(n_req: int, seed: int = 0):
    """The open-loop request trace: (kind, image, arrival, priority) per
    request, 2:1 FFT64:QRD16, Poisson arrivals, ~1 in 6 high-priority."""
    from repro.core.programs.fft import fft_shmem
    from repro.core.programs.qrd import qrd_shmem

    rng = np.random.default_rng(seed)
    # mean inter-arrival well under a lone launch's cycles: the offered
    # load exceeds serial capacity (open loop: arrivals don't wait for
    # completions), so the queue builds and batching has pending
    # requests to coalesce — the regime continuous batching exists for
    inter = rng.exponential(scale=600.0, size=n_req)
    arrivals = np.cumsum(inter).astype(np.int64)
    trace = []
    for i in range(n_req):
        prio = 2 if rng.random() < 1 / 6 else 0
        if i % 3 == 2:
            a = rng.standard_normal((16, 16)).astype(np.float32)
            trace.append(("qrd", qrd_shmem(a, 1024), int(arrivals[i]),
                          prio))
        else:
            x = (rng.standard_normal(64)
                 + 1j * rng.standard_normal(64)).astype(np.complex64)
            trace.append(("fft", fft_shmem(x, 1024), int(arrivals[i]),
                          prio))
    return trace


def _serve(trace, max_batch: int):
    """Serve one full trace; returns (wall_seconds, results)."""
    import dataclasses

    from repro.core import DeviceConfig, SMConfig
    from repro.core.programs.fft import fft_kernel
    from repro.core.programs.qrd import qrd_kernel
    from repro.serve import LaunchRequest, LaunchServer

    dcfg = DeviceConfig(
        n_sms=4, global_mem_depth=1024,
        sm=SMConfig(shmem_depth=1024, imem_depth=1024, max_steps=200_000),
        dispatch_latency=200, queue_latency=8)
    # dynamic dispatch end-to-end: Kernel(priority=) is honored both at
    # admission and in the in-launch dispatch heap (static would warn
    # and set profile()["priority_respected"]=False)
    server = LaunchServer(dcfg, max_queue=len(trace) + 1,
                          max_batch=max_batch, schedule="dynamic")
    kernels = {"fft": fft_kernel(64), "qrd": qrd_kernel()}
    t0 = time.perf_counter()
    futs = []
    for kind, img, arrival, prio in trace:
        kern = kernels[kind] if prio == 0 \
            else dataclasses.replace(kernels[kind], priority=prio)
        futs.append(server.submit(LaunchRequest(
            kernel=kern, shmem=img, arrival_cycle=arrival, tag=kind)))
    server.drain()
    results = [f.result() for f in futs]
    return time.perf_counter() - t0, results


def _measure(trace, max_batch: int, repeats: int) -> dict:
    wall, results = _serve(trace, max_batch)   # warmup: compile + caches
    for _ in range(repeats):
        w, results = _serve(trace, max_batch)
        wall = min(wall, w)
    lat = np.asarray(sorted(r.latency_cycles for r in results))
    occ = float(np.mean([r.batch_occupancy for r in results]))
    sizes = np.asarray([r.batch_size for r in results])
    return {
        "wall_s": round(wall, 4),
        "requests_per_sec": round(len(trace) / wall, 1),
        "p50_latency_cycles": int(np.percentile(lat, 50)),
        "p99_latency_cycles": int(np.percentile(lat, 99)),
        "mean_latency_cycles": int(lat.mean()),
        "makespan_cycles": int(max(r.finish_cycle for r in results)),
        "mean_batch_size": round(float(sizes.mean()), 2),
        "batch_occupancy": round(occ, 3),
    }


def run(smoke: bool = False, out: str = "BENCH_serve.json") -> dict:
    n_req = 24 if smoke else 96
    repeats = 2 if smoke else 4
    trace = _mixed_trace(n_req)
    serial = _measure(trace, max_batch=1, repeats=repeats)
    batched = _measure(trace, max_batch=8, repeats=repeats)

    def speedup():
        return round(batched["requests_per_sec"]
                     / serial["requests_per_sec"], 3)

    results = {"smoke": smoke, "n_requests": n_req, "repeats": repeats,
               "mix": "fft64:qrd16 2:1, poisson arrivals, 1/6 prio-2",
               "lines": {"serial": serial, "batched": batched},
               "throughput_speedup": speedup()}
    emit("serve_serial", serial["wall_s"] * 1e6,
         f"rps={serial['requests_per_sec']} "
         f"p50={serial['p50_latency_cycles']}cyc "
         f"p99={serial['p99_latency_cycles']}cyc")
    emit("serve_batched", batched["wall_s"] * 1e6,
         f"rps={batched['requests_per_sec']} "
         f"p50={batched['p50_latency_cycles']}cyc "
         f"p99={batched['p99_latency_cycles']}cyc "
         f"occ={batched['batch_occupancy']} "
         f"speedup={results['throughput_speedup']}x")
    if smoke:
        # deterministic gate first: on the virtual clock, continuous
        # batching must finish the same open-loop trace sooner than
        # serial dispatch (merged waves + amortized host dispatch)
        assert batched["makespan_cycles"] < serial["makespan_cycles"], (
            f"batched modeled makespan did not beat serial: "
            f"{batched['makespan_cycles']} vs {serial['makespan_cycles']}")
        # wall-clock gate: batched throughput >= 1.2x serial on the
        # mixed FFT+QRD request mix. One re-measure before failing
        # absorbs shared-runner scheduling jitter (engine_bench idiom).
        if speedup() < 1.2:
            redo_s = _measure(trace, max_batch=1, repeats=repeats)
            redo_b = _measure(trace, max_batch=8, repeats=repeats)
            if redo_b["requests_per_sec"] / redo_s["requests_per_sec"] \
                    > speedup():
                serial, batched = redo_s, redo_b
                results["lines"] = {"serial": serial, "batched": batched}
                results["throughput_speedup"] = speedup()
                emit("serve_batched_retry", batched["wall_s"] * 1e6,
                     f"speedup={results['throughput_speedup']}x")
        assert results["throughput_speedup"] >= 1.2, (
            f"continuous batching below the 1.2x-vs-serial throughput "
            f"gate on the mixed request mix: {results}")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    return results
