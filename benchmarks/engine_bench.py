"""Step-machine vs trace-engine vs megakernel wall-clock benchmark.

Runs the same launches through ``engine="step"`` (fetch/decode/dispatch
``lax.while_loop``), ``engine="trace"`` (decode-once ``lax.scan``,
``core.trace_engine``) and ``engine="megakernel"`` (fused segments with
plan-time partial evaluation) and reports wall-clock per launch, warm
(compile and trace-lowering excluded — best of ``repeats`` after one
warmup call). Functional bit-identity of the three engines is the test
suite's job (``tests/test_conformance.py``); this file measures the
speedups and emits ``BENCH_engine.json`` for CI to archive.

The smoke set doubles as the CI regression gate: the trace engine must
not be slower than the step machine on the FFT, QRD and predicated-
Cholesky batch lines (the last one pins that per-lane predication —
``@P``-guarded stores, SETP/SELP — costs the decode-once path nothing),
and must beat it by >= 1.2x on the heterogeneous FFT+QRD mixed launch — the
merged-wave path (``trace_engine.MergedTraceSchedule``) that removed the
last workload class excluded from the fast path. The megakernel engine
must beat the trace scan by >= 1.5x on the FFT64 and QRD16 batch lines
(the plan-time constant folding + fused-segment dividend) and must not
lose to it anywhere else. The ``"auto"`` ladder is timed as a fourth
column and gated at ``auto_vs_best >= 0.95`` on EVERY line: auto must
always land within jitter of the best fixed engine, so a ladder rung
that routes a shape to the wrong engine (megakernel on short saxpy
schedules was 0.81x vs step before
``trace_engine.MEGAKERNEL_MIN_FUSED_ROWS``) fails CI instead of
shipping as a silent default-path regression.

The cold-start line times the host-side lowering (trace walk + schedule
decode) against an empty vs a warmed persistent compile cache
(``core.compile_cache``), simulating a fresh process by clearing the
in-memory LRU tiers: the warm path must load artifacts instead of
re-tracing.

The packed line compares the trace engine against ITSELF under the two
wave-packing policies (``core.packing``) on the interleaved mixed
FFT+QRD grid — the pad-adversarial shape, where EVERY grid-order wave
mixes the two programs: each wave pads the shorter FFT schedule to the
QRD one AND dispatches two programs per scan row. Length packing
segregates them into pure waves (fewer scan rows, one dispatch per
row) and must not lose to grid order (>= 1.0x wall clock): removed
no-op rows are real work removed, not an accounting trick.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from .common import emit


def _time_launch(fn, repeats: int) -> float:
    """Best-of-``repeats`` wall clock of ``fn()`` after one warmup."""
    fn()                                   # compile + trace-lower + cache
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _lines(smoke: bool):
    from repro.core import DeviceConfig, SMConfig
    from repro.core.programs import launch_reduction
    from repro.core.programs.cholesky import (cholesky_imem_depth,
                                              run_cholesky_batch)
    from repro.core.programs.fft import run_fft_batch
    from repro.core.programs.qrd import run_qrd_batch
    from repro.core.programs.saxpy import launch_saxpy

    from repro.core.programs.mixed import launch_fft_qrd, mixed_device

    n_fft = 6 if smoke else 8
    n_qrd = 4 if smoke else 5
    n_chol = 3 if smoke else 5
    rng_c = np.random.default_rng(0)
    g_c = rng_c.standard_normal((16, 16)).astype(np.float32)
    Cs = np.stack([(g_c @ g_c.T + (16.0 + i) * np.eye(16))
                   .astype(np.float32) for i in range(n_chol)])
    bs = np.stack([np.ones(16, np.float32)] * n_chol)
    n_sms = 2 if smoke else 4
    xs = np.ones((n_fft, 64), np.complex64)
    As = np.stack([np.eye(16, dtype=np.float32) + 0.1 * i
                   for i in range(n_qrd)])
    x = np.arange(256, dtype=np.float32)

    def dev(engine, **sm_kw):
        return DeviceConfig(n_sms=n_sms, engine=engine,
                            global_mem_depth=1024, sm=SMConfig(**sm_kw))

    return {
        "saxpy256_b64": lambda engine: launch_saxpy(
            2.0, x, np.ones_like(x), block=64,
            device=dev(engine, max_steps=10_000)),
        "reduction2048_fused": lambda engine: launch_reduction(
            np.ones(2048, np.float32), block=512, fused=True,
            device=dataclasses.replace(dev(engine, max_steps=50_000),
                                       global_mem_depth=4096)),
        f"fft64_batch{n_fft}": lambda engine: run_fft_batch(
            xs, device=dev(engine, shmem_depth=192, max_steps=200_000)),
        f"qrd16_batch{n_qrd}": lambda engine: run_qrd_batch(
            As, device=dev(engine, shmem_depth=1024, imem_depth=1024,
                           max_steps=200_000)),
        # the predicated SIMT line: Cholesky + triangular solve, whose
        # inner loop runs @P-guarded stores and SETP/SELP selects — the
        # gate pins that predication costs the fast engines nothing
        # (trace must still not lose to step)
        f"cholesky16_pred_batch{n_chol}": lambda engine: run_cholesky_batch(
            Cs, bs, device=dev(engine, shmem_depth=1024,
                               imem_depth=cholesky_imem_depth(True),
                               max_steps=200_000)),
        # the heterogeneous launch (the golden mixed workload's 2:1
        # FFT:QRD ratio): FFT and QRD blocks interleaved in one grid —
        # the trace engine batches them as merged waves
        f"mixed_fft{n_fft}_qrd{n_fft // 2}": lambda engine: launch_fft_qrd(
            xs, As[:n_fft // 2], device=mixed_device(64, n_sms=n_sms),
            engine=engine),
    }


def _packed_line():
    """The packed-vs-grid mixed line: a 1:1 INTERLEAVED FFT+QRD grid at
    4 SMs, so every grid-order wave holds two FFT and two QRD blocks —
    each wave runs the QRD schedule length with the FFT members masked
    past their end, dispatching both programs on every scan row. Length
    packing re-groups the same blocks into pure FFT and pure QRD waves.
    Returns (name, fn) with ``fn(packing)`` running the trace engine.
    The shape is fixed across smoke and full runs: it is a policy
    comparison gated on its ratio, and the every-wave-mixed geometry
    (4 + 4 blocks alternating on 4 SMs) is the point, not the scale."""
    from repro.core.programs.mixed import launch_fft_qrd, mixed_device

    xs = np.ones((4, 32), np.complex64)
    As = np.stack([np.eye(16, dtype=np.float32) + 0.1 * i
                   for i in range(4)])

    def fn(packing):
        return launch_fft_qrd(xs, As, device=mixed_device(32, n_sms=4),
                              engine="trace", interleave=True,
                              packing=packing)

    return "mixed_interleaved_fft4_qrd4", fn


def _measure_line(fn, repeats: int) -> dict:
    """Time one launch line on all three engines plus the auto ladder.

    ``auto_vs_best`` is the ladder's report card: best fixed engine /
    auto. >= 1.0 means auto picked the winner; the smoke gate allows a
    5% jitter band but no more — a ladder that routes a shape to the
    wrong engine (the saxpy regression this gate was added for) shows
    up as a 15-30% loss, far outside the band."""
    step_s = _time_launch(lambda: fn("step"), repeats)
    trace_s = _time_launch(lambda: fn("trace"), repeats)
    mega_s = _time_launch(lambda: fn("megakernel"), repeats)
    auto_s = _time_launch(lambda: fn("auto"), repeats)
    best_s = min(step_s, trace_s, mega_s)
    return {
        "step_us": round(step_s * 1e6, 1),
        "trace_us": round(trace_s * 1e6, 1),
        "mega_us": round(mega_s * 1e6, 1),
        "auto_us": round(auto_s * 1e6, 1),
        "speedup": round(step_s / trace_s if trace_s > 0
                         else float("inf"), 3),
        "mega_vs_trace": round(trace_s / mega_s if mega_s > 0
                               else float("inf"), 3),
        "auto_vs_best": round(best_s / auto_s if auto_s > 0
                              else float("inf"), 3),
    }


def _cold_start_line(repeats: int) -> dict:
    """Host-side lowering time, cold vs warmed persistent compile cache.

    Simulates a process cold start by clearing the in-memory lowering
    LRUs between measurements; the on-disk cache (``core.compile_cache``)
    is the only state that survives, so the warm number is what a real
    second process pays before its first wave."""
    import tempfile

    from repro.core import SMConfig, compile_cache, trace_engine
    from repro.core.cycles import _trace_cached
    from repro.core.programs.fft import fft_program
    from repro.core.programs.qrd import qrd_program

    progs = [(fft_program(64), SMConfig(shmem_depth=192,
                                        max_steps=200_000)),
             (qrd_program(16), SMConfig(shmem_depth=1024, imem_depth=1024,
                                        max_steps=200_000))]

    def lower_all():
        t0 = time.perf_counter()
        for prog, cfg in progs:
            trace_engine.compile_program(prog, cfg)
        return time.perf_counter() - t0

    def fresh_process():
        _trace_cached.cache_clear()
        trace_engine.compile_cache_clear()

    with tempfile.TemporaryDirectory() as tmp:
        try:
            compile_cache.configure(tmp)
            fresh_process()
            cold_s = lower_all()          # misses: walks + stores
            warm_s = float("inf")
            for _ in range(max(repeats, 3)):
                fresh_process()
                warm_s = min(warm_s, lower_all())   # served from disk
            stats = compile_cache.stats()
        finally:
            compile_cache.configure(None)
            fresh_process()               # drop plans keyed to this run
    return {
        "cold_us": round(cold_s * 1e6, 1),
        "warm_us": round(warm_s * 1e6, 1),
        "speedup": round(cold_s / warm_s if warm_s > 0
                         else float("inf"), 3),
        "cache": stats,
    }


def _measure_packed(fn, repeats: int) -> dict:
    # the two policies differ by ~10-25% on this line, within reach of
    # shared-runner jitter for small repeat counts — the launches are
    # cheap, so always take at least best-of-6 per policy
    repeats = max(repeats, 6)
    grid_s = _time_launch(lambda: fn("grid"), repeats)
    packed_s = _time_launch(lambda: fn("length"), repeats)
    return {
        "grid_us": round(grid_s * 1e6, 1),
        "packed_us": round(packed_s * 1e6, 1),
        "speedup": round(grid_s / packed_s if packed_s > 0
                         else float("inf"), 3),
    }


def run(smoke: bool = False, out: str = "BENCH_engine.json") -> dict:
    repeats = 3 if smoke else 5
    results: dict[str, dict] = {}
    for name, fn in _lines(smoke).items():
        results[name] = _measure_line(fn, repeats)
        emit(f"engine_{name}", results[name]["mega_us"],
             f"trace={results[name]['trace_us']:.0f}us "
             f"step={results[name]['step_us']:.0f}us "
             f"mega_vs_trace={results[name]['mega_vs_trace']:.2f}x "
             f"auto_vs_best={results[name]['auto_vs_best']:.2f}x")
    results["cold_start_lowering"] = _cold_start_line(repeats)
    emit("engine_cold_start_lowering",
         results["cold_start_lowering"]["warm_us"],
         f"cold={results['cold_start_lowering']['cold_us']:.0f}us "
         f"speedup={results['cold_start_lowering']['speedup']:.2f}x")
    # packed-vs-grid: same engine (trace), different wave membership
    packed_name, packed_fn = _packed_line()
    packed_key = f"packed_{packed_name}"
    results[packed_key] = _measure_packed(packed_fn, repeats)
    emit(f"engine_{packed_key}", results[packed_key]["packed_us"],
         f"grid={results[packed_key]['grid_us']:.0f}us "
         f"speedup={results[packed_key]['speedup']:.2f}x")
    with open(out, "w") as f:
        json.dump({"smoke": smoke, "repeats": repeats,
                   "lines": results}, f, indent=2)
        f.write("\n")
    if smoke:
        # the CI gate: decode-once execution must not lose to per-step
        # decode on the compute-heavy lines (FFT + QRD), the merged
        # heterogeneous-wave path must beat the step machine by >= 1.2x
        # on the mixed FFT+QRD launch, and the megakernel's fused
        # segments + plan-time constant folding must beat the trace scan
        # by >= 1.5x on FFT64/QRD16 (and never lose to it on the mixed
        # line); and the AUTO ladder must land within 5% of the best
        # fixed engine on EVERY line — the gate that catches a ladder
        # rung routing a shape to the wrong engine (the
        # megakernel-on-saxpy regression, 0.81x vs step, fixed by
        # trace_engine.MEGAKERNEL_MIN_FUSED_ROWS). One re-measure before
        # failing absorbs shared-runner scheduling jitter without
        # weakening the bound.
        lines = _lines(smoke)
        auto_floor = 0.95
        floor = {n: (1.2 if n.startswith("mixed") else 1.0)
                 for n in results
                 if n.startswith(("fft", "qrd", "mixed", "cholesky"))}
        # the predicated cholesky line gates mega at "never lose": its
        # serial pivot chains leave fewer foldable rows than FFT/QRD
        mega_floor = {n: (1.0 if n.startswith(("mixed", "cholesky"))
                          else 1.5)
                      for n in floor}
        gated = sorted(floor)
        assert any(n.startswith("mixed") for n in gated), \
            "smoke set lost its heterogeneous mixed line"
        assert len(gated) >= 3, "smoke set lost its FFT/QRD lines"
        retried = False
        for n in lines:
            below = results[n]["auto_vs_best"] < auto_floor
            if n in floor:
                below = (below or results[n]["speedup"] < floor[n]
                         or results[n]["mega_vs_trace"] < mega_floor[n])
            if below:
                redo = _measure_line(lines[n], repeats)
                if (redo["speedup"] > results[n]["speedup"]
                        or redo["mega_vs_trace"]
                        > results[n]["mega_vs_trace"]
                        or redo["auto_vs_best"]
                        > results[n]["auto_vs_best"]):
                    results[n] = redo
                    emit(f"engine_{n}_retry", redo["mega_us"],
                         f"trace={redo['trace_us']:.0f}us "
                         f"speedup={redo['speedup']:.2f}x "
                         f"mega_vs_trace={redo['mega_vs_trace']:.2f}x "
                         f"auto_vs_best={redo['auto_vs_best']:.2f}x")
                retried = True
        # the packing gate: length packing must not lose to grid order
        # on the interleaved mixed trace line (same one-retry absorb)
        if results[packed_key]["speedup"] < 1.0:
            remeasure = _measure_packed(packed_fn, repeats)
            if remeasure["speedup"] > results[packed_key]["speedup"]:
                results[packed_key] = remeasure
                emit(f"engine_{packed_key}_retry", remeasure["packed_us"],
                     f"grid={remeasure['grid_us']:.0f}us "
                     f"speedup={remeasure['speedup']:.2f}x")
            retried = True
        if retried:
            with open(out, "w") as f:
                json.dump({"smoke": smoke, "repeats": repeats,
                           "lines": results}, f, indent=2)
                f.write("\n")
        for n in gated:
            assert results[n]["speedup"] >= floor[n], (
                f"trace engine speedup below the {floor[n]}x gate on "
                f"{n}: {results[n]}")
            assert results[n]["mega_vs_trace"] >= mega_floor[n], (
                f"megakernel below the {mega_floor[n]}x-vs-trace gate on "
                f"{n}: {results[n]}")
        for n in lines:
            assert results[n]["auto_vs_best"] >= auto_floor, (
                f"auto ladder below the {auto_floor}x-of-best-fixed-"
                f"engine gate on {n}: {results[n]}")
        assert results[packed_key]["speedup"] >= 1.0, (
            f"length packing lost to grid-order waves on the interleaved "
            f"mixed trace line: {results[packed_key]}")
    return results
