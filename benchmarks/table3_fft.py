"""Paper Table III: FFT-256 cycle profile on the eGPU ISS.

Reproduces the paper's instruction-class distribution (theirs: address 12%,
butterflies 13%, shared-memory access 75%) and the FFT-32 variant, plus
numerics validation vs numpy and the achieved-GFLOPS derivation from the
cycle count and modelled Fmax.
"""
from __future__ import annotations

import numpy as np

from repro.core import profile, resources
from repro.core.programs.fft import fft_program, run_fft

from .common import emit, time_fn


def _profile_line(n: int):
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(np.complex64)
    X, st = run_fft(x)
    err = float(np.max(np.abs(X - np.fft.fft(x))) / np.max(np.abs(np.fft.fft(x))))
    p = profile(st)
    b, tot = p["by_class"], p["total_cycles"]
    shared = (b["LOD_IDX"] + b["STO_IDX"]) / tot
    addr = (b["LOGIC"] + b["INT"] + b["LOD_IMM"]) / tot
    fp = (b["FP_ADDSUB"] + b["FP_MUL"]) / tot
    # flops: N/2 butterflies per pass * log2 N passes * 10 flops each
    log2n = n.bit_length() - 1
    flops = (n // 2) * log2n * 10
    fmax = resources.fmax_mhz(1) * 1e6
    gflops = flops / (tot / fmax) / 1e9
    return (f"cycles={tot} rel_err={err:.1e} shared={shared:.0%} "
            f"addr={addr:.0%} fp={fp:.0%} nop={b['NOP'] / tot:.0%} "
            f"gflops@771MHz={gflops:.2f} "
            f"paper(256pt)=75/12/13"), tot


def _multi_sm_line(batch: int = 8, n: int = 256, n_sms: int = 4):
    """Packed-sector deployment (§III.E): a batch of independent FFTs as a
    launch grid over a 4-SM device — the paper's quad-packed sector."""
    from repro.core import DeviceConfig, SMConfig
    from repro.core.programs.fft import run_fft_batch

    rng = np.random.default_rng(0)
    xs = (rng.standard_normal((batch, n))
          + 1j * rng.standard_normal((batch, n))).astype(np.complex64)
    dcfg = DeviceConfig(n_sms=n_sms,
                        sm=SMConfig(shmem_depth=3 * n, max_steps=200_000))
    X, res = run_fft_batch(xs, device=dcfg)
    ref = np.fft.fft(xs, axis=1)
    err = float(np.max(np.abs(X - ref)) / np.max(np.abs(ref)))
    # concurrent SMs: wall cycles = one wave's cycles * number of waves,
    # vs batch * single-SM cycles if run back to back on one SM
    single = _profile_line(n)[1]
    speedup = (batch * single) / res.cycles if res.cycles else 0.0
    fmax = resources.fmax_mhz(n_sms) * 1e6
    log2n = n.bit_length() - 1
    gflops = batch * (n // 2) * log2n * 10 / (res.cycles / fmax) / 1e9
    return (f"batch={batch} n_sms={n_sms} waves={res.n_waves} "
            f"cycles={res.cycles} rel_err={err:.1e} "
            f"speedup_vs_1sm={speedup:.2f}x gflops={gflops:.2f}")


def _mixed_sched_line(batch_f: int = 6, n: int = 256, batch_q: int = 3,
                      n_sms: int = 4):
    """Dynamic vs static block scheduling on an imbalanced mixed grid:
    FFT blocks backfill around the longer QRD blocks instead of idling a
    lockstep wave (the arXiv 2401.04261 dynamic-dispatch argument)."""
    from repro.core.programs import launch_fft_qrd, mixed_device

    rng = np.random.default_rng(0)
    xs = (rng.standard_normal((batch_f, n))
          + 1j * rng.standard_normal((batch_f, n))).astype(np.complex64)
    As = rng.standard_normal((batch_q, 16, 16)).astype(np.float32)
    dev = mixed_device(n, n_sms=n_sms)
    X, Q, R, res = launch_fft_qrd(xs, As, device=dev)
    ref = np.fft.fft(xs, axis=1)
    fft_err = float(np.max(np.abs(X - ref)) / np.max(np.abs(ref)))
    qr_err = float(np.max(np.abs(np.einsum("bij,bjk->bik", Q, R) - As)))
    p = res.profile()
    occ = {name: sum(1 for o in d["sm_occupancy"] if o > 0)
           for name, d in p["per_program"].items()}
    return (f"fft={batch_f} qrd={batch_q} n_sms={n_sms} "
            f"dynamic={res.cycles} static_wave={res.static_cycles} "
            f"speedup={res.static_cycles / res.cycles:.2f}x "
            f"fft_err={fft_err:.1e} qr_err={qr_err:.1e} "
            f"sms_used={occ}")


def run():
    for n in (32, 256):
        t = time_fn(lambda n=n: run_fft(
            np.ones(n, np.complex64)), warmup=1, iters=1)
        derived, _ = _profile_line(n)
        emit(f"table3_fft{n}_profile", t, derived)
    # program-size claims (paper: 135 instructions for FFT-256)
    emit("table3_fft256_words", 0.0,
         f"loop={len(fft_program(256))} "
         f"unrolled={len(fft_program(256, unroll=True))} paper=135")
    # multi-SM launch: the quad-packed sector running a batch of FFTs
    # (timed around the single evaluation — the launch is expensive)
    import time

    t0 = time.perf_counter()
    derived = _multi_sm_line()
    emit("table3_fft256_multi_sm", (time.perf_counter() - t0) * 1e6, derived)
    # dynamic block scheduling on a mixed FFT+QRD grid
    t0 = time.perf_counter()
    derived = _mixed_sched_line()
    emit("table3_mixed_sched", (time.perf_counter() - t0) * 1e6, derived)


if __name__ == "__main__":
    run()
