"""Paper Table III: FFT-256 cycle profile on the eGPU ISS.

Reproduces the paper's instruction-class distribution (theirs: address 12%,
butterflies 13%, shared-memory access 75%) and the FFT-32 variant, plus
numerics validation vs numpy and the achieved-GFLOPS derivation from the
cycle count and modelled Fmax.
"""
from __future__ import annotations

import numpy as np

from repro.core import profile, resources
from repro.core.programs.fft import fft_program, run_fft

from .common import emit, time_fn


def _profile_line(n: int):
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(np.complex64)
    X, st = run_fft(x)
    err = float(np.max(np.abs(X - np.fft.fft(x))) / np.max(np.abs(np.fft.fft(x))))
    p = profile(st)
    b, tot = p["by_class"], p["total_cycles"]
    shared = (b["LOD_IDX"] + b["STO_IDX"]) / tot
    addr = (b["LOGIC"] + b["INT"] + b["LOD_IMM"]) / tot
    fp = (b["FP_ADDSUB"] + b["FP_MUL"]) / tot
    # flops: N/2 butterflies per pass * log2 N passes * 10 flops each
    log2n = n.bit_length() - 1
    flops = (n // 2) * log2n * 10
    fmax = resources.fmax_mhz(1) * 1e6
    gflops = flops / (tot / fmax) / 1e9
    return (f"cycles={tot} rel_err={err:.1e} shared={shared:.0%} "
            f"addr={addr:.0%} fp={fp:.0%} nop={b['NOP'] / tot:.0%} "
            f"gflops@771MHz={gflops:.2f} "
            f"paper(256pt)=75/12/13"), tot


def run():
    for n in (32, 256):
        t = time_fn(lambda n=n: run_fft(
            np.ones(n, np.complex64)), warmup=1, iters=1)
        derived, _ = _profile_line(n)
        emit(f"table3_fft{n}_profile", t, derived)
    # program-size claims (paper: 135 instructions for FFT-256)
    emit("table3_fft256_words", 0.0,
         f"loop={len(fft_program(256))} "
         f"unrolled={len(fft_program(256, unroll=True))} paper=135")


if __name__ == "__main__":
    run()
