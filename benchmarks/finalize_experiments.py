"""Render the optimized-policy table + baseline/optimized comparison into
EXPERIMENTS.md (run after the optimized dry-run matrix completes)."""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.roofline.report import fmt_s, load  # noqa: E402

BASE = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")
OPT = os.path.join(os.path.dirname(__file__), "results",
                   "dryrun_optimized.jsonl")
EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def comparison_table(base_rows, opt_rows) -> str:
    out = ["| arch | shape | dominant term (base -> opt) | speedup | "
           "collective (base -> opt) | peak GiB (base -> opt) |",
           "|---|---|---|---|---|---|"]
    gains = []
    for key in sorted(base_rows):
        arch, shape, mesh = key
        if mesh != "16x16":
            continue
        b = base_rows[key]
        o = opt_rows.get(key)
        if not o or b.get("status") != "ok" or o.get("status") != "ok":
            continue
        if "memory_s" not in b or "memory_s" not in o:
            continue
        bd = max(b["compute_s"], b["memory_s"], b["collective_s"])
        od = max(o["compute_s"], o["memory_s"], o["collective_s"])
        gain = bd / od if od else float("nan")
        gains.append(gain)
        out.append(
            f"| {arch} | {shape} | {fmt_s(bd)} -> {fmt_s(od)} "
            f"| **{gain:.2f}x** | {fmt_s(b['collective_s'])} -> "
            f"{fmt_s(o['collective_s'])} "
            f"| {b['peak_bytes_per_device']/2**30:.2f} -> "
            f"{o['peak_bytes_per_device']/2**30:.2f} |")
    import numpy as np

    gm = float(np.exp(np.mean(np.log(gains)))) if gains else 0.0
    out.append("")
    out.append(f"Geometric-mean speedup on the dominant roofline term "
               f"across all {len(gains)} runnable single-pod cells: "
               f"**{gm:.2f}x**. Every cell still compiles on both meshes "
               f"under the optimized policy.")
    return "\n".join(out)


def main():
    base = load(BASE)
    opt = load(OPT)
    n_ok = sum(1 for r in opt.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in opt.values() if r.get("status") == "skipped")
    table = comparison_table(base, opt)
    block = f"""The optimized matrix compiles {n_ok} cells ({n_skip} brief-mandated
skips) across both meshes with zero errors — full data in
`benchmarks/results/dryrun_optimized.jsonl`.

{table}
"""
    s = open(EXP).read()
    marker = ("<!-- OPT-BEGIN -->", "<!-- OPT-END -->")
    i, j = s.find(marker[0]), s.find(marker[1])
    assert i != -1 and j != -1, "OPT markers missing"
    s = s[:i] + marker[0] + "\n" + block + "\n" + marker[1] \
        + s[j + len(marker[1]):]
    open(EXP, "w").write(s)
    print(f"wrote comparison ({n_ok} ok / {n_skip} skip)")


if __name__ == "__main__":
    main()
