"""Signal pipeline: windowed FFT spectral analysis on eGPU + Pallas kernel.

    PYTHONPATH=src python examples/fft_pipeline.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import profile, resources
from repro.core.programs.fft import run_fft
from repro.kernels import ops


def main():
    rng = np.random.default_rng(0)
    n = 256
    t = np.arange(n) / n
    # two tones + noise
    sig = (np.sin(2 * np.pi * 17 * t) + 0.5 * np.sin(2 * np.pi * 49 * t)
           + 0.05 * rng.standard_normal(n)).astype(np.float32)

    # eGPU ISS path
    X, st = run_fft(sig.astype(np.complex64))
    mag = np.abs(X[: n // 2])
    peaks = np.argsort(mag)[-2:]
    print("eGPU FFT peak bins:", sorted(peaks), "(expected [17, 49])")
    p = profile(st)
    us = p["total_cycles"] / resources.fmax_mhz(1)
    print(f"eGPU cycles={p['total_cycles']} = {us:.1f}us @771MHz; "
          f"shared-memory share = "
          f"{(p['by_class']['LOD_IDX'] + p['by_class']['STO_IDX']) / p['total_cycles']:.0%}"
          f" (paper: 75%)")

    # Pallas kernel path: batch of 16 windows in VMEM
    frames = np.stack([sig] * 16)
    fr, fi = ops.fft(jnp.asarray(frames), jnp.zeros_like(jnp.asarray(frames)))
    kmag = np.abs(np.asarray(fr)[0, : n // 2] + 1j * np.asarray(fi)[0, : n // 2])
    print("kernel/ISS spectra agree:",
          np.allclose(kmag, mag, atol=1e-3 * mag.max()))


if __name__ == "__main__":
    main()
