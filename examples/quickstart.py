"""Quickstart: write eGPU assembly, launch it on the multi-SM device, read
the aggregate profile.

    PYTHONPATH=src python examples/quickstart.py

Part 1 — a CUDA-style single-program launch: the grid's thread blocks are
scheduled onto the device's SMs in lockstep waves (blocks beyond ``n_sms``
queue for the next round). Each block owns a private shared memory; all
blocks share one global-memory segment through GLD/GST, and BID gives a
block its grid index.

Part 2 — a multi-program launch: FFT and QRD blocks mixed in ONE grid,
dispatched by the dynamic work-queue scheduler (each SM pulls the next
ready block when it retires its current one — ``PID`` tells a block which
program it is). ``profile()`` reports per-SM and per-program occupancy,
idle time, and global-port contention, plus the static-wave baseline the
dynamic schedule is measured against.
"""
import numpy as np

from repro.core import (
    DeviceConfig,
    SMConfig,
    assemble,
    check_hazards,
    launch,
)
from repro.core.assembler import auto_nop

N_BLOCKS = 4      # grid size: 4 thread blocks ...
N_SMS = 2         # ... on a 2-SM device => 2 scheduling waves
BLOCK = 32        # threads per block
N = N_BLOCKS * BLOCK

# z = 2x + y over global memory, one element per thread; each block also
# folds its chunk with the wavefront SUM unit + thread snooping and commits
# the partial with the paper's single-cycle {w1,d1} store.
ASM = f"""
    BID R7                    // block index
    TDX R1                    // thread index within the block
    LOD R8, #{BLOCK}
    MUL.INT32 R9, R7, R8
    ADD.INT32 R1, R9, R1      // gid = bid*block + tid
    GLD R2, (R1)+0            // x[gid]
    GLD R3, (R1)+{N}          // y[gid]
    LOD.FP32 R4, #2           // alpha = 2.0
    MUL.FP32 R5, R2, R4
    ADD.FP32 R6, R5, R3
    GST R6, (R1)+{2 * N}      // z[gid] back to global
    SUM.FP32 R10, R6, R0      // per-wavefront sums -> lane 0
    ADD.FP32 R11, R10@0, R10@1 {{w1,d1}}  // snoop: fold the 2 wavefronts
    GST R11, (R7)+{3 * N} {{w1,d1}}       // single-cycle partial store
    STOP
"""


def main():
    text = auto_nop(ASM, n_threads=BLOCK)  # pad the 9-cycle RAW windows
    prog = assemble(text)
    print(f"program: {len(prog)} words; hazards:",
          check_hazards(prog, BLOCK) or "none")

    rng = np.random.default_rng(0)
    x = rng.standard_normal(N).astype(np.float32)
    y = rng.standard_normal(N).astype(np.float32)

    dcfg = DeviceConfig(n_sms=N_SMS, global_mem_depth=4 * N,
                        sm=SMConfig(max_steps=1000))
    res = launch(dcfg, prog, grid=(N_BLOCKS,), block=BLOCK,
                 buffers={"x": x, "y": y,
                          "z": np.zeros(N, np.float32),
                          "partials": np.zeros(N_BLOCKS, np.float32)})

    z = np.asarray(res.buffer("z"))
    partials = np.asarray(res.buffer("partials"))
    print(f"grid {res.grid} x block {res.block} on {N_SMS} SMs "
          f"-> {res.n_waves} waves {list(res.wave_cycles)}")
    print("z == 2x+y:", np.allclose(z, 2 * x + y))
    print("block partials ok:",
          np.allclose(partials, z.reshape(N_BLOCKS, BLOCK).sum(axis=1),
                      rtol=1e-5))
    p = res.profile()
    print(f"aggregate cycles: {p['total_cycles']}  by class: "
          f"{ {k: v for k, v in p['by_class'].items() if v} }")


def main_mixed():
    """Part 2: heterogeneous launch under the dynamic block scheduler."""
    from repro.core.programs import launch_fft_qrd

    rng = np.random.default_rng(1)
    xs = (rng.standard_normal((6, 256))
          + 1j * rng.standard_normal((6, 256))).astype(np.complex64)
    As = rng.standard_normal((3, 16, 16)).astype(np.float32)

    X, Q, R, res = launch_fft_qrd(xs, As)   # 4 SMs, schedule="dynamic"
    print(f"\nmixed launch: {res.n_blocks} blocks "
          f"({dict(zip(res.program_names, np.bincount(res.grid_map)))}) "
          f"on 4 SMs, schedule={res.schedule}")
    print("FFT ok:", np.allclose(X, np.fft.fft(xs, axis=1), atol=1e-3),
          " QRD ok:",
          np.allclose(np.einsum("bij,bjk->bik", Q, R), As, atol=1e-4))
    p = res.profile()
    print(f"dynamic cycles: {p['total_cycles']}  static-wave baseline: "
          f"{p['static_cycles']}  "
          f"(speedup {p['static_cycles'] / p['total_cycles']:.2f}x)")
    for name, d in p["per_program"].items():
        occ = " ".join(f"{o:.0%}" for o in d["sm_occupancy"])
        print(f"  {name:6s} blocks={d['blocks']} busy={d['busy_cycles']} "
              f"gmem_wait={d['gmem_wait']} per-SM occupancy: {occ}")
    for i, d in enumerate(p["per_sm"]):
        print(f"  SM{i}: busy={d['busy']} wait={d['wait']} "
              f"idle={d['idle']} blocks={d['blocks']}")


if __name__ == "__main__":
    main()
    main_mixed()
