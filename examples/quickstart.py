"""Quickstart: write eGPU assembly, launch it on the multi-SM device, read
the aggregate profile.

    PYTHONPATH=src python examples/quickstart.py

A CUDA-style launch: the grid's thread blocks are scheduled onto the
device's SMs in waves (blocks beyond ``n_sms`` queue for the next round).
Each block owns a private shared memory; all blocks share one global-memory
segment through GLD/GST, and BID gives a block its grid index.
"""
import numpy as np

from repro.core import (
    DeviceConfig,
    SMConfig,
    assemble,
    check_hazards,
    launch,
)
from repro.core.assembler import auto_nop

N_BLOCKS = 4      # grid size: 4 thread blocks ...
N_SMS = 2         # ... on a 2-SM device => 2 scheduling waves
BLOCK = 32        # threads per block
N = N_BLOCKS * BLOCK

# z = 2x + y over global memory, one element per thread; each block also
# folds its chunk with the wavefront SUM unit + thread snooping and commits
# the partial with the paper's single-cycle {w1,d1} store.
ASM = f"""
    BID R7                    // block index
    TDX R1                    // thread index within the block
    LOD R8, #{BLOCK}
    MUL.INT32 R9, R7, R8
    ADD.INT32 R1, R9, R1      // gid = bid*block + tid
    GLD R2, (R1)+0            // x[gid]
    GLD R3, (R1)+{N}          // y[gid]
    LOD.FP32 R4, #2           // alpha = 2.0
    MUL.FP32 R5, R2, R4
    ADD.FP32 R6, R5, R3
    GST R6, (R1)+{2 * N}      // z[gid] back to global
    SUM.FP32 R10, R6, R0      // per-wavefront sums -> lane 0
    ADD.FP32 R11, R10@0, R10@1 {{w1,d1}}  // snoop: fold the 2 wavefronts
    GST R11, (R7)+{3 * N} {{w1,d1}}       // single-cycle partial store
    STOP
"""


def main():
    text = auto_nop(ASM, n_threads=BLOCK)  # pad the 9-cycle RAW windows
    prog = assemble(text)
    print(f"program: {len(prog)} words; hazards:",
          check_hazards(prog, BLOCK) or "none")

    rng = np.random.default_rng(0)
    x = rng.standard_normal(N).astype(np.float32)
    y = rng.standard_normal(N).astype(np.float32)

    dcfg = DeviceConfig(n_sms=N_SMS, global_mem_depth=4 * N,
                        sm=SMConfig(max_steps=1000))
    res = launch(dcfg, prog, grid=(N_BLOCKS,), block=BLOCK,
                 buffers={"x": x, "y": y,
                          "z": np.zeros(N, np.float32),
                          "partials": np.zeros(N_BLOCKS, np.float32)})

    z = np.asarray(res.buffer("z"))
    partials = np.asarray(res.buffer("partials"))
    print(f"grid {res.grid} x block {res.block} on {N_SMS} SMs "
          f"-> {res.n_waves} waves {list(res.wave_cycles)}")
    print("z == 2x+y:", np.allclose(z, 2 * x + y))
    print("block partials ok:",
          np.allclose(partials, z.reshape(N_BLOCKS, BLOCK).sum(axis=1),
                      rtol=1e-5))
    p = res.profile()
    print(f"aggregate cycles: {p['total_cycles']}  by class: "
          f"{ {k: v for k, v in p['by_class'].items() if v} }")


if __name__ == "__main__":
    main()
