"""Quickstart: write eGPU assembly, run it on the ISS, read the profile.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import SMConfig, assemble, check_hazards, profile, run, shmem_f32

# axpy with a wavefront reduction at the end: z = 2x + y; s = sum(z)
ASM = """
    TDX R1                   // thread id
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    LOD R2, (R1)+0           // x[tid]
    LOD R3, (R1)+64          // y[tid]
    LOD.FP32 R4, #2          // alpha = 2.0
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    MUL.FP32 R5, R2, R4
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    ADD.FP32 R6, R5, R3
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    STO R6, (R1)+128         // z back to shared
    SUM.FP32 R7, R6, R0      // per-wavefront sums -> lane 0
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    ADD.FP32 R8, R7@0, R7@1 {w1,d1}   // thread snooping: fold 2 wavefronts
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    NOP
    STO R8, (R0)+192 {w1,d1}          // single-cycle store (flexible ISA)
    STOP
"""


def main():
    cfg = SMConfig(n_threads=32, dim_x=32, shmem_depth=256, max_steps=1000)
    prog = assemble(ASM)
    print(f"program: {len(prog)} words; hazards:",
          check_hazards(prog, cfg.n_threads) or "none")

    rng = np.random.default_rng(0)
    mem = np.zeros(256, np.float32)
    mem[0:32] = x = rng.standard_normal(32).astype(np.float32)
    mem[64:96] = y = rng.standard_normal(32).astype(np.float32)

    state = run(cfg, prog, mem)
    out = np.asarray(shmem_f32(state))
    z = out[128:160]
    print("z == 2x+y:", np.allclose(z, 2 * x + y))
    print("sum(z):", out[192], "expected:", z.sum())
    p = profile(state)
    print(f"cycles: {p['total_cycles']}  by class: "
          f"{ {k: v for k, v in p['by_class'].items() if v} }")


if __name__ == "__main__":
    main()
