"""Batched linear solver via MGS QRD — the paper's motivating workload
("the linear solvers commonly used in wireless systems", §I).

Solves Ax = b for a batch of 16x16 systems three ways:
  1. the eGPU ISS running the paper's assembly (semantic reference),
  2. the Pallas TPU kernel (kernels/mgs_qrd) + triangular back-substitution,
  3. numpy (oracle),
and reports agreement + the eGPU cycle cost per solve.

    PYTHONPATH=src python examples/qrd_solver.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import profile
from repro.core.programs.qrd import run_qrd
from repro.kernels import ops


def back_substitute(r, y):
    """Solve R x = y for upper-triangular R. r: (B,n,n), y: (B,n)."""
    B, n, _ = r.shape
    x = np.zeros((B, n), np.float64)
    r = np.asarray(r, np.float64)
    y = np.asarray(y, np.float64)
    for i in range(n - 1, -1, -1):
        x[:, i] = (y[:, i] - np.einsum("bj,bj->b", r[:, i, i + 1:],
                                       x[:, i + 1:])) / r[:, i, i]
    return x


def main():
    rng = np.random.default_rng(0)
    B, n = 32, 16
    A = rng.standard_normal((B, n, n)).astype(np.float32)
    A += 4 * np.eye(n, dtype=np.float32)   # well-conditioned
    b = rng.standard_normal((B, n)).astype(np.float32)

    # --- Pallas kernel path (batched, TPU-targeted) -------------------------
    q, r = ops.qrd(jnp.asarray(A))
    y = np.einsum("bij,bi->bj", np.asarray(q), b)    # Q^T b
    x_kernel = back_substitute(np.asarray(r), y)

    # --- eGPU ISS path (the paper's machine, one matrix) --------------------
    q0, r0, st = run_qrd(A[0])
    y0 = q0.T @ b[0]
    x_iss = back_substitute(r0[None], y0[None])[0]

    # --- oracle --------------------------------------------------------------
    x_np = np.stack([np.linalg.solve(A[i], b[i]) for i in range(B)])

    print("kernel max |x - x_np|:", np.abs(x_kernel - x_np).max())
    print("eGPU ISS max |x - x_np| (matrix 0):",
          np.abs(x_iss - x_np[0]).max())
    p = profile(st)
    cyc = p["total_cycles"]
    from repro.core import resources
    us = cyc / (resources.fmax_mhz(1))  # cycles / MHz = microseconds
    print(f"eGPU QRD: {cyc} cycles = {us:.1f} us at 771 MHz "
          f"(hard GPUs hit single-digit % efficiency at this size — paper "
          f"[24,25])")


if __name__ == "__main__":
    main()
