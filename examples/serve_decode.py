"""Serve a small LM with batched requests through the flexible-mask engine.

    PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import Engine, Request


def main():
    cfg = get_arch("granite-3-2b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, max_slots=4, capacity=128)
    rng = np.random.default_rng(0)
    for rid in range(8):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               int(rng.integers(4, 20))),
                           max_new_tokens=int(rng.integers(4, 12))))
        eng.step()   # arrivals interleave with decoding
    outs = eng.run_until_done()
    print(f"served {len(outs)} requests in {eng.steps_run} decode steps")
    print("active-width history (the flexible-ISA analogue):",
          eng.active_history)
    for rid in sorted(outs)[:3]:
        print(f"  req {rid}: {outs[rid]}")


if __name__ == "__main__":
    main()
