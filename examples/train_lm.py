"""End-to-end training driver: a ~100M-param GQA LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py            # full run
    PYTHONPATH=src python examples/train_lm.py --fast     # CI-sized run

Demonstrates the full substrate stack: deterministic pipeline -> jitted
train step (AdamW, clipping, schedule) -> atomic async checkpoints ->
crash-free resume (rerun the same command: it continues from the latest
checkpoint). Loss on the synthetic Markov pipeline falls well below the
uniform baseline ln(V).
"""
import argparse
import dataclasses
import shutil

import numpy as np

from repro.configs import RunConfig, get_arch
from repro.data import PipelineSpec
from repro.models import build_model
from repro.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fresh", action="store_true")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    # ~100M params: granite family scaled down (12L x 768 x d_ff 2048)
    cfg = dataclasses.replace(
        get_arch("granite-3-2b"),
        name="granite-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=3072, head_dim=64, vocab_size=1024,
        vocab_pad=256)
    if args.fast:
        cfg = get_arch("granite-3-2b", smoke=True)
    model = build_model(cfg)

    steps = args.steps or (30 if args.fast else 300)
    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    rc = RunConfig(learning_rate=args.lr, warmup_steps=20,
                   ckpt_dir=args.ckpt_dir, ckpt_every=50, async_ckpt=True,
                   seed=0)
    spec = PipelineSpec(vocab=cfg.vocab_size,
                        seq_len=args.seq or (64 if args.fast else 256),
                        global_batch=args.batch or (4 if args.fast else 8),
                        seed=0)
    res = train_loop(model, cfg, rc, spec, steps,
                     log_path=args.ckpt_dir + ".jsonl")
    uniform = np.log(cfg.vocab_size)
    print(f"arch={cfg.name} steps={len(res.losses)} "
          f"resumed_from={res.resumed_from}")
    print(f"loss: first={res.losses[0]:.3f} last={res.losses[-1]:.3f} "
          f"uniform-baseline={uniform:.3f}")
    assert res.losses[-1] < res.losses[0], "training did not improve"
    if res.straggler_steps:
        print("straggler steps flagged:", res.straggler_steps)


if __name__ == "__main__":
    main()
